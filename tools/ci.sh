#!/usr/bin/env bash
# Time-bounded tier-1 verification: the full suite minus the
# jit-compiling model smokes (marked `slow`), so a CI lap finishes in
# well under a minute instead of ~3 minutes of XLA compile time.
#
#   tools/ci.sh              # fast subset (default: -m "not slow")
#   CI_MARKER="" tools/ci.sh # everything
#   tools/ci.sh -k executor  # extra pytest args pass through
#   tools/ci.sh smoke        # example + benchmark bit-rot tier: runs
#                            # examples/quickstart.py, the serving smoke
#                            # lap (examples/serve_sim.py: short Poisson
#                            # run, asserts nonzero goodput + stats), and
#                            # `python -m benchmarks.run --json fidelity`
#                            # (writes BENCH_desim.json)
#   tools/ci.sh golden       # gem5-style golden-stats regression tier:
#                            # diffs live stats dumps of the canonical
#                            # board x trace runs against the committed
#                            # tests/golden/*.txt (regen with
#                            # `pytest tests/test_golden_stats.py
#                            #  --regen-golden`, then review + commit)
#   tools/ci.sh perf         # perf-smoke tier: asserts AtomicTiming is
#                            # >= 3x faster wall-clock than Detailed-
#                            # Timing on the pod_torus reference trace
#                            # (and tick-exact there) — fails loudly if
#                            # the fast path regresses
#   tools/ci.sh parallel     # parallel-smoke tier: asserts the multi-
#                            # process ParallelEngine (workers=4) is
#                            # >= 2x faster wall-clock than the serial
#                            # TraceExecutor on the 32-pod reference
#                            # workload AND bit-exact (full ExecResult
#                            # + stats-tree equality) across two laps
#                            # of one warm worker pool — then the
#                            # fleet gate: workers=8 on the 64-pod
#                            # v5e_fleet_big board >= 4x serial, bit-
#                            # exact, with barriers bounded by the DCN
#                            # collective count (lookahead elision)
#   tools/ci.sh fleet        # autoscaled-serving tier: the flash-crowd
#                            # lap (benchmarks/fleet_sweep.py
#                            # --assert-fleet) — asserts the autoscaler
#                            # scales up, post-crowd SLO compliance
#                            # recovers (and provably does not on the
#                            # fixed-size fleet), and the lap is bit-
#                            # identical across two runs — plus the
#                            # examples/fleet_sim.py demo with its
#                            # DES-vs-controller replay identity check
#   tools/ci.sh simpoint     # sampling-accuracy tier: the bursty
#                            # reference workload (benchmarks/
#                            # simpoint_sweep.py --assert-simpoint) —
#                            # asserts the SimPoint-weighted
#                            # reconstruction AND the checkpoint-library
#                            # fanout land within 5% of the full-detail
#                            # total while the equal-budget fixed-stride
#                            # plan misses by more
#   tools/ci.sh trace        # observability tier: fully-instrumented
#                            # smoke lap (m5out stats.txt/config.json +
#                            # Perfetto trace, serial and workers=4),
#                            # validates the trace-event JSON schema,
#                            # asserts bit-identity with the bare lap
#                            # and < 5% flags-disabled DPRINTF overhead
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [ "${1-}" = "golden" ]; then
  shift
  python -m pytest -q tests/test_golden_stats.py "$@"
  echo "golden tier OK"
  exit 0
fi
if [ "${1-}" = "perf" ]; then
  shift
  python -m benchmarks.engine_microbench --assert-speedup 3
  echo "perf tier OK"
  exit 0
fi
if [ "${1-}" = "parallel" ]; then
  shift
  python -m benchmarks.distgem5_scaling --assert-parallel 2
  python -m benchmarks.distgem5_scaling --assert-parallel-big 4
  echo "parallel tier OK"
  exit 0
fi
if [ "${1-}" = "simpoint" ]; then
  shift
  python -m benchmarks.simpoint_sweep --assert-simpoint
  echo "simpoint tier OK"
  exit 0
fi
if [ "${1-}" = "trace" ]; then
  shift
  python -m benchmarks.observability --assert-overhead 5
  echo "trace tier OK"
  exit 0
fi
if [ "${1-}" = "fleet" ]; then
  shift
  python -m benchmarks.fleet_sweep --assert-fleet
  python examples/fleet_sim.py
  echo "fleet tier OK"
  exit 0
fi
if [ "${1-}" = "smoke" ]; then
  shift
  python examples/quickstart.py
  python examples/serve_sim.py
  python -m benchmarks.run --json fidelity
  echo "smoke tier OK"
  exit 0
fi
MARKER=${CI_MARKER-"not slow"}
if [ -n "$MARKER" ]; then
  exec python -m pytest -q -m "$MARKER" "$@"
fi
exec python -m pytest -q "$@"
