"""§Perf hillclimbing driver for the three selected cells.

For each cell: baseline (shipped config) + the enumerated candidate
changes; every variant re-lowers, re-compiles, re-analyzes; results go
to results/perf/<cell>.json for EXPERIMENTS.md §Perf.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses
import json

import jax.numpy as jnp

from repro.launch.dryrun import TRAIN_ACCUM, dryrun_cell
from repro.train.step import default_options_for
from repro.configs import get_config

os.makedirs("results/perf", exist_ok=True)


def opts_for(arch, shape_kind, **kw):
    base = default_options_for(get_config(arch))
    kw.setdefault("accum_steps",
                  TRAIN_ACCUM.get(arch, 1) if shape_kind == "train" else 1)
    kw.setdefault("moment_dtype",
                  "bfloat16" if arch in ("mixtral-8x22b", "jamba-v0.1-52b")
                  else "float32")
    return dataclasses.replace(base, **kw)


def run(cell_name, variants):
    out = []
    for name, kwargs in variants:
        res = dryrun_cell(**kwargs)
        r = res["roofline"]
        row = {
            "variant": name,
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "bound_s": r["bound_s"],
            "mem_gb": res["memory"]["per_device_total"] / 1e9,
            "fits": res["fits_hbm"],
            "collectives": res["collectives"],
        }
        out.append(row)
        print(f"{cell_name}/{name:34s} comp={r['compute_s']:8.3f} "
              f"mem={r['memory_s']:8.3f} coll={r['collective_s']:8.3f} "
              f"dom={r['dominant']:10s} hbm={row['mem_gb']:5.1f}GB "
              f"fits={row['fits']}", flush=True)
    with open(f"results/perf/{cell_name}.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


# ---------------------------------------------------------------------------
# Cell 1: olmoe-1b-7b train_4k — most collective-bound
# ---------------------------------------------------------------------------
A = "olmoe-1b-7b"
run("olmoe_train4k", [
    ("baseline(accum4)", dict(arch=A, shape_name="train_4k")),
    ("accum2", dict(arch=A, shape_name="train_4k",
                    opts=opts_for(A, "train", accum_steps=2))),
    ("accum1", dict(arch=A, shape_name="train_4k",
                    opts=opts_for(A, "train", accum_steps=1))),
    ("accum2+chunk4096", dict(arch=A, shape_name="train_4k",
                              opts=opts_for(A, "train", accum_steps=2,
                                            chunk=4096))),
])

# ---------------------------------------------------------------------------
# Cell 2: deepseek-67b train_4k — flagship dense training (memory-dominated)
# ---------------------------------------------------------------------------
B = "deepseek-67b"
run("deepseek_train4k", [
    ("baseline(accum8,chunk2048)", dict(arch=B, shape_name="train_4k")),
    ("accum4", dict(arch=B, shape_name="train_4k",
                    opts=opts_for(B, "train", accum_steps=4))),
    ("chunk4096", dict(arch=B, shape_name="train_4k",
                       opts=opts_for(B, "train", chunk=4096))),
    ("accum4+chunk4096", dict(arch=B, shape_name="train_4k",
                              opts=opts_for(B, "train", accum_steps=4,
                                            chunk=4096))),
])

# ---------------------------------------------------------------------------
# Cell 3: stablelm-1.6b decode_32k — worst roofline-fraction family
# ---------------------------------------------------------------------------
C = "stablelm-1.6b"
run("stablelm_decode32k", [
    ("baseline(f32 params)", dict(arch=C, shape_name="decode_32k")),
    ("bf16 serving params", dict(arch=C, shape_name="decode_32k",
                                 serve_param_dtype=jnp.bfloat16)),
    ("bf16+batch_over_all", dict(
        arch=C, shape_name="decode_32k", serve_param_dtype=jnp.bfloat16,
        rules_override={"batch": ("data",), "kv_seq": ("model",)})),
])
print("hillclimb done")
