"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun/summary.json (+ the v0 baseline for before/after)."""

import json
import sys

PEAK = 197e12


def frac(r):
    t_model = r["model_flops_global"] / r["mesh_desc"]["devices"] / PEAK
    return t_model / r["roofline"]["bound_s"] if r["roofline"]["bound_s"] \
        else 0.0


def dryrun_table(rows, mesh):
    out = ["| arch | shape | status | compile_s | mem GB/dev | fits 16GB | "
           "collective schedule (count x kind) |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | skipped "
                       f"| — | — | — | long_500k needs sub-quadratic path |")
            continue
        colls = " ".join(f"{int(v['count'])}x{k}"
                         for k, v in sorted(r["collectives"].items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.1f} "
            f"| {r['memory']['per_device_total']/1e9:.2f} "
            f"| {'yes' if r['fits_hbm'] else 'NO'} | {colls} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | compute_s | memory_s | mem_s (TPU-alias) "
           "| collective_s | dominant | MODEL/HLO | roofline frac "
           "| what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    hints = {
        "memory": "flash-attn/wkv kernels delete f32 intermediate "
                  "traffic; fewer activation round-trips",
        "collective": "per-token MoE all-reduces (routing rendezvous); "
                      "localize dispatch",
        "compute": "already compute-limited; raise MXU utilization",
    }
    for r in rows:
        if r.get("mesh") != "single":
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                       f"| — | — | n/a (documented skip) |")
            continue
        rl = r["roofline"]
        mem_ex = rl.get("memory_s_ex_copies", rl["memory_s"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4f} "
            f"| {rl['memory_s']:.4f} | {mem_ex:.4f} "
            f"| {rl['collective_s']:.4f} "
            f"| **{rl['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {frac(r):.3f} | {hints[rl['dominant']]} |")
    return "\n".join(out)


def before_after(v0_rows, v3_rows):
    v0 = {(r["arch"], r["shape"], r["mesh"]): r for r in v0_rows
          if r["status"] == "ok"}
    out = ["| cell | v0 mem GB | v3 mem GB | v0 bound_s | v3 bound_s |",
           "|---|---|---|---|---|"]
    for r in v3_rows:
        if r["status"] != "ok" or r["mesh"] != "single":
            continue
        key = (r["arch"], r["shape"], r["mesh"])
        if key not in v0:
            continue
        a = v0[key]
        out.append(
            f"| {r['arch']} {r['shape']} "
            f"| {a['memory']['per_device_total']/1e9:.1f} "
            f"| {r['memory']['per_device_total']/1e9:.1f} "
            f"| {a['roofline']['bound_s']:.2f} "
            f"| {r['roofline']['bound_s']:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = json.load(open("results/dryrun/summary.json"))
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### single-pod mesh (16x16 = 256 chips)\n")
        print(dryrun_table(rows, "single"))
        print("\n### multi-pod mesh (2x16x16 = 512 chips)\n")
        print(dryrun_table(rows, "multi"))
    if which in ("all", "roofline"):
        print("\n## Roofline (single-pod)\n")
        print(roofline_table(rows))
    if which in ("all", "before"):
        v0 = json.load(open("results/dryrun_v0_baseline/summary.json"))
        print("\n## v0 -> v3\n")
        print(before_after(v0, rows))
