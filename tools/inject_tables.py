"""Inject generated tables into EXPERIMENTS.md placeholders."""

import json

from gen_experiments_tables import (before_after, dryrun_table, frac,
                                    roofline_table)

rows = json.load(open("results/dryrun/summary.json"))
v0 = json.load(open("results/dryrun_v0_baseline/summary.json"))

dr = ("### Single-pod mesh (16×16 = 256 chips)\n\n"
      + dryrun_table(rows, "single")
      + "\n\n### Multi-pod mesh (2×16×16 = 512 chips)\n\n"
      + dryrun_table(rows, "multi"))
rl = roofline_table(rows)
ba = ("Per-cell before/after of the §Perf global iterations "
      "(v0 = paper-faithful naive baseline, v4 = shipped):\n\n"
      + before_after(v0, rows))

text = open("EXPERIMENTS.md").read()
text = text.replace("<!-- DRYRUN_TABLES -->", dr)
text = text.replace("<!-- ROOFLINE_TABLE -->", rl + "\n\n" + ba)
open("EXPERIMENTS.md", "w").write(text)
print("injected:",
      dr.count("\n"), "dryrun lines,", rl.count("\n"), "roofline lines")
