"""Engine microbenchmark: wall time + engine events per (timing model,
board, trace) — the first real perf trajectory for the event engine.

The tentpole claim of the pluggable-timing refactor is that
``AtomicTiming`` (contention-free analytical costing, batch-resolved
completions) beats ``DetailedTiming`` (per-op engine events, link-level
arbitration over the full torus footprint) by >=5x wall clock and
>=10x engine events on the reference traces, while staying tick-exact
on contention-free chains.  This module measures exactly that, one row
per (case, model) plus a speedup row per case, so regressions of the
fast path show up in ``BENCH_desim.json`` across PRs.

CLI (the ``tools/ci.sh perf`` tier)::

    python -m benchmarks.engine_microbench                    # rows only
    python -m benchmarks.engine_microbench --assert-speedup 3
        # exit 1 LOUDLY unless atomic is >= 3x faster than detailed
        # on the pod_torus reference trace
"""

from __future__ import annotations

import sys
import time

from benchmarks.common import emit
from repro.core.desim.trace import analytic_trace
from repro.sim import repeat_trace, v5e_multipod, v5e_pod

COLLS = [{"kind": "all-reduce", "bytes": 1e8, "participants": 256}]
DCN_TAIL = [{"kind": "all-reduce", "bytes": 1e9, "participants": 512,
             "scope": "dcn"}]
STEPS = 40           # repetitions of the 6-layer golden-style step

# the reference traces: the golden pod_torus chain on one pod, and the
# multipod DCN/quantum variant (the `v5e_multipod`-class acceptance
# case for the >=5x wall / >=10x events criteria)
CASES = {
    "pod_torus": (lambda: v5e_pod(),
                  lambda: repeat_trace(
                      analytic_trace("golden", 6, 1e12, 1e9, COLLS),
                      STEPS)),
    "v5e_multipod": (lambda: v5e_multipod(2),
                     lambda: repeat_trace(
                         analytic_trace("golden", 6, 1e12, 1e9, COLLS,
                                        tail_collectives=DCN_TAIL),
                         STEPS)),
}


def _bench(board, trace, timing: str, repeats: int = 3):
    best = None
    events = makespan = 0
    for _ in range(repeats):
        ex = board.executor(timing=timing)
        t0 = time.perf_counter()
        res = ex.execute(trace)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
        events, makespan = res.events, res.makespan_s
    return best, events, makespan


def measure(case: str):
    """(wall_s, events, makespan_s) per model for one case."""
    board_fn, trace_fn = CASES[case]
    out = {}
    for timing in ("detailed", "atomic"):
        out[timing] = _bench(board_fn(), trace_fn(), timing)
    return out


def run() -> None:
    for case in CASES:
        res = measure(case)
        n_ops = len(CASES[case][1]().ops)
        for timing in ("detailed", "atomic"):
            wall, events, makespan = res[timing]
            emit(f"engine/{case}/{timing}", wall * 1e6,
                 f"events={events} ops={n_ops} "
                 f"makespan={makespan:.4f}s "
                 f"events_per_s={events / max(wall, 1e-12):.0f}")
        wd, ed, _ = res["detailed"]
        wa, ea, _ = res["atomic"]
        emit(f"engine/{case}/atomic_speedup", wa * 1e6,
             f"wall={wd / max(wa, 1e-12):.1f}x "
             f"events={ed / max(ea, 1):.0f}x "
             f"(detailed {wd * 1e3:.1f}ms -> atomic {wa * 1e3:.1f}ms)")


def assert_speedup(threshold: float, case: str = "pod_torus") -> None:
    """CI perf-smoke: fail loudly if the atomic fast path regressed."""
    res = measure(case)
    wd, ed, md = res["detailed"]
    wa, ea, ma = res["atomic"]
    speedup = wd / max(wa, 1e-12)
    print(f"perf-smoke [{case}]: detailed {wd * 1e3:.1f}ms "
          f"({ed} events) vs atomic {wa * 1e3:.1f}ms ({ea} events) "
          f"-> {speedup:.1f}x wall (threshold {threshold:.1f}x)")
    if md != ma:
        print(f"perf-smoke FAILED: atomic makespan {ma} != detailed {md} "
              "on the contention-free reference trace (atomic must stay "
              "tick-exact there)", file=sys.stderr)
        raise SystemExit(1)
    if speedup < threshold:
        print(f"perf-smoke FAILED: AtomicTiming is only {speedup:.1f}x "
              f"faster than DetailedTiming on {case} (need >= "
              f"{threshold:.1f}x) — the fast path regressed",
              file=sys.stderr)
        raise SystemExit(1)
    print("perf-smoke OK")


if __name__ == "__main__":
    args = sys.argv[1:]
    if "--assert-speedup" in args:
        i = args.index("--assert-speedup")
        assert_speedup(float(args[i + 1]))
    else:
        run()
