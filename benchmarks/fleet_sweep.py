"""Fleet DSE sweep: fleet size x router policy x traffic model ->
goodput / p99-TTFT / scale-event frontier (the autoscaled layer above
serving_sweep).

The gem5 full-system promise at datacenter scale: every cell runs the
FleetSim co-simulation — continuous-batching replicas behind the pure
``FleetPolicy`` router+autoscaler — over one *seeded* traffic stream,
so rows are reproducible and comparable across policies and fleet
shapes.  Three axes:

* **router** — round_robin / least_loaded / p2c / prefix_affinity on
  the flash-crowd stream with the autoscaler live;
* **fleet size** — max_replicas 2 (a fixed fleet: the floor equals the
  ceiling) / 4 / 6 under least_loaded;
* **traffic** — the flash crowd vs. a diurnal curve (lognormal lengths
  and two tenant classes in both).

The **recovery row** is the headline claim: after the crowd passes,
the autoscaled fleet is back in SLO compliance while the fixed-size
fleet — identical except ``max_replicas == min_replicas`` — provably
is not (still churning through backlog).  The row *asserts* this, like
serving_sweep's fidelity spot-check asserts exactness.

``--fidelity {atomic,detailed}`` picks the timing model (default:
atomic — exact for fleets, whose injected ops are per-pod compute); one
cell re-runs detailed as a spot-check.  ``--assert-fleet`` is the
``tools/ci.sh fleet`` tier: a short flash-crowd lap run twice,
asserting the autoscaler scales up, SLO recovers, and the lap —
decision log and summary — is bit-identical across runs.

Emits one row per cell:
  fleet_sweep/<axis>/<cell> , wall_us , goodput/p99-ttft/scale events
"""

from __future__ import annotations

import math
import sys
import time

from benchmarks.common import emit, fidelity_from_argv, fmt_ms
from repro.core.desim.simnodes import to_ticks
from repro.serve.fleet_policy import FleetPolicy
from repro.sim import (FleetSim, ServingCost, Simulator, diurnal_requests,
                       flash_crowd_requests, v5e_fleet)

SEED = 7
NUM_REQUESTS = 420
BASE_RPS = 15.0
CROWD_RPS = 90.0
CROWD_START_S = 2.0
CROWD_LEN_S = 3.0
POST_CROWD_S = 8.0       # compliance window: requests submitted after
SLOTS = 8
MIN_REPLICAS = 2
MAX_REPLICAS = 6
COLD_START_S = 1.0
CONTROL_PERIOD_S = 0.5
SLO_TTFT_S = 0.6
SLO_LATENCY_S = 4.0
TENANT_SLO = {"batch": 4.0}      # batch tenants get 4x relaxed SLOs

# a 70B-class model on 4x4 replica slices (16 chips each)
MODEL = dict(num_params=70e9, layers=80, d_model=8192)
REPLICA_NX = REPLICA_NY = 4


def _flash(num: int = NUM_REQUESTS):
    return flash_crowd_requests(
        num, seed=SEED, base_rps=BASE_RPS, crowd_rps=CROWD_RPS,
        crowd_start_s=CROWD_START_S, crowd_len_s=CROWD_LEN_S,
        prefix_groups=8)


def _diurnal(num: int = NUM_REQUESTS):
    return diurnal_requests(num, seed=SEED, base_rps=BASE_RPS,
                            peak_rps=CROWD_RPS, period_s=10.0,
                            prefix_groups=8)


def _lap(requests, *, router: str = "least_loaded",
         min_replicas: int = MIN_REPLICAS,
         max_replicas: int = MAX_REPLICAS, timing: str = "atomic"):
    board = v5e_fleet(max_replicas=max_replicas, nx=REPLICA_NX,
                      ny=REPLICA_NY)
    cost = ServingCost.from_params(
        chips=REPLICA_NX * REPLICA_NY, **MODEL)
    policy = FleetPolicy(router, min_replicas=min_replicas,
                         max_replicas=max_replicas,
                         slots_per_replica=SLOTS,
                         cold_start_ticks=to_ticks(COLD_START_S),
                         control_period_ticks=to_ticks(CONTROL_PERIOD_S),
                         seed=SEED)
    fleet = FleetSim(cost=cost, requests=requests, policy=policy,
                     seq_capacity=1024, slo_ttft_s=SLO_TTFT_S,
                     slo_latency_s=SLO_LATENCY_S, tenant_slo=TENANT_SLO)
    sim = Simulator(board, fleet, timing=timing)
    t0 = time.perf_counter()
    sim.run_to_completion()
    return (time.perf_counter() - t0) * 1e6, fleet


def _derived(s) -> str:
    return (f"goodput={s['goodput_rps']:.1f}rps "
            f"thru={s['throughput_rps']:.1f}rps "
            f"viol={int(s['slo_violations'])} "
            f"p99_ttft={fmt_ms(s['p99_ttft_s'])} "
            f"ups={int(s['scale_ups'])} downs={int(s['scale_downs'])} "
            f"peak={int(s['replicas_peak'])}")


def recovery_lap(timing: str = "atomic"):
    """The headline pair: autoscaled vs fixed fleet on the same
    seeded flash crowd.  Returns (auto FleetSim, fixed FleetSim,
    auto wall us, fixed wall us)."""
    wall_a, auto = _lap(_flash(), router="p2c")
    wall_f, fixed = _lap(_flash(), router="p2c",
                         max_replicas=MIN_REPLICAS)
    return auto, fixed, wall_a, wall_f


def check_recovery(auto: FleetSim, fixed: FleetSim) -> None:
    """Assert the autoscaler claim: it scales up under the crowd and
    restores post-crowd SLO compliance that the fixed fleet provably
    cannot."""
    ok_auto = auto.slo_ok_frac(POST_CROWD_S)
    ok_fixed = fixed.slo_ok_frac(POST_CROWD_S)
    if not (auto.summary()["scale_ups"] >= 1):
        raise RuntimeError("fleet recovery: autoscaler never scaled up")
    if not (ok_auto >= 0.9):
        raise RuntimeError(
            f"fleet recovery: autoscaled post-crowd compliance {ok_auto} "
            "< 0.9 — the autoscaler no longer restores the SLO")
    if math.isnan(ok_fixed) or ok_fixed > 0.2:
        raise RuntimeError(
            f"fleet recovery: fixed-fleet post-crowd compliance "
            f"{ok_fixed} > 0.2 — the scenario no longer saturates the "
            "floor fleet (the comparison is vacuous)")


def run(fidelity: str = "atomic") -> None:
    if fidelity not in ("atomic", "detailed"):
        raise ValueError(f"--fidelity {fidelity!r}: atomic or detailed")
    # axis 1: router policy (flash crowd, autoscaler live)
    for router in ("round_robin", "least_loaded", "p2c",
                   "prefix_affinity"):
        wall_us, fleet = _lap(_flash(), router=router, timing=fidelity)
        emit(f"fleet_sweep/router/{router}", wall_us,
             _derived(fleet.summary()))
    # axis 2: fleet ceiling (max_replicas == min is the fixed fleet)
    for max_replicas in (MIN_REPLICAS, 4, MAX_REPLICAS):
        wall_us, fleet = _lap(_flash(), max_replicas=max_replicas,
                              timing=fidelity)
        emit(f"fleet_sweep/fleet/max{max_replicas}", wall_us,
             _derived(fleet.summary()))
    # axis 3: traffic model
    wall_us, fleet = _lap(_diurnal(), timing=fidelity)
    emit("fleet_sweep/traffic/diurnal", wall_us,
         _derived(fleet.summary()))
    wall_us, fleet = _lap(_diurnal(), router="prefix_affinity",
                          timing=fidelity)
    emit("fleet_sweep/traffic/diurnal_affinity", wall_us,
         _derived(fleet.summary()))
    # the recovery claim (asserted)
    auto, fixed, wall_a, wall_f = recovery_lap(fidelity)
    check_recovery(auto, fixed)
    emit("fleet_sweep/recovery/flash_crowd", wall_a + wall_f,
         f"post_crowd_ok auto={auto.slo_ok_frac(POST_CROWD_S):.2f} "
         f"fixed={fixed.slo_ok_frac(POST_CROWD_S):.2f} "
         f"ups={int(auto.summary()['scale_ups'])} "
         f"cold_start={COLD_START_S:.1f}s")
    if fidelity == "atomic":
        # detailed spot-check: fleet timing must be fidelity-exact
        wall_a2, fa = _lap(_flash(num=120), timing="atomic")
        wall_d, fd = _lap(_flash(num=120), timing="detailed")
        s_a, s_d = fa.summary(), fd.summary()
        ok = s_a == s_d and fa.policy.decisions == fd.policy.decisions
        emit("fleet_sweep/detailed_check", wall_d,
             f"{'exact-match' if ok else 'MISMATCH'} "
             f"atomic_wall={wall_a2:.0f}us "
             f"speedup={wall_d / max(wall_a2, 1e-9):.1f}x")
        if not ok:
            raise RuntimeError(
                "fleet sweep: atomic and detailed laps diverged on the "
                f"spot-check cell: {s_a} vs {s_d}")


def assert_fleet() -> None:
    """The ``tools/ci.sh fleet`` smoke tier: one short flash-crowd lap,
    run twice — the autoscaler must scale up, SLO compliance must
    recover after the crowd (and provably not on the fixed fleet), and
    the lap must be bit-identical across runs (seed-deterministic
    decision log and summary)."""
    auto1, fixed, _, _ = recovery_lap()
    check_recovery(auto1, fixed)
    print(f"fleet: scale_ups={int(auto1.summary()['scale_ups'])} "
          f"post_crowd_ok={auto1.slo_ok_frac(POST_CROWD_S):.2f} "
          f"(fixed fleet: {fixed.slo_ok_frac(POST_CROWD_S):.2f}) ... PASS")
    wall2, auto2 = _lap(_flash(), router="p2c")
    if auto2.policy.decisions != auto1.policy.decisions:
        raise RuntimeError(
            "fleet lap is not deterministic: decision logs differ "
            "between two identical runs")
    if auto2.summary() != auto1.summary() or auto2.feed != auto1.feed:
        raise RuntimeError(
            "fleet lap is not deterministic: summary/feed differ "
            "between two identical runs")
    print(f"fleet: lap bit-identical across two runs "
          f"({len(auto1.policy.decisions)} decisions, "
          f"{len(auto1.feed)} feed rows) ... PASS")


if __name__ == "__main__":
    if "--assert-fleet" in sys.argv:
        assert_fleet()
    else:
        run(fidelity_from_argv(sys.argv))
