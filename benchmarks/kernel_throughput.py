"""Substrate claim: Pallas kernels vs jnp oracle.  Reports interpret-mode
µs/call (correctness-path timing) and the MODELED TPU v5e time from the
kernel's HBM-byte/FLOP footprint vs the XLA path's footprint — the
quantity the dry-run roofline actually scores."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_us
from repro.core.desim.machine import ChipModel

# the same parameterized chip model desim replays traces on, at raw
# datasheet peaks (efficiency derates off: kernels are scored against
# the hardware ceiling, not the achievable fraction)
_CHIP = ChipModel("v5e", mxu_efficiency=1.0, hbm_efficiency=1.0)
HBM = _CHIP.hbm_bw


def _modeled(flops, nbytes):
    return _CHIP.compute_time_s(flops, nbytes)


def run() -> None:
    key = jax.random.PRNGKey(0)

    # flash attention: b=1, s=1024, h=4, d=128
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    b, s, h, d = 1, 1024, 4, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    t_k = time_us(lambda: jax.block_until_ready(
        flash_attention(q, k, v, interpret=True)), iters=2)
    t_r = time_us(lambda: jax.block_until_ready(
        jax.jit(attention_ref)(q, k, v)), iters=2)
    fl = 4 * b * h * s * s * d / 2            # causal
    bytes_kernel = 4 * b * s * h * d * 4      # q,k,v,o once
    bytes_xla = bytes_kernel + 6 * b * h * s * s * 4 / 2  # score passes
    emit("kernel/flash_attention_interp", t_k,
         f"ref_jnp={t_r:.0f}us modeled_tpu={_modeled(fl, bytes_kernel)*1e6:.1f}us"
         f" xla_path={_modeled(fl, bytes_xla)*1e6:.1f}us")

    # wkv6: b=1, s=512, h=4, n=64
    from repro.kernels.rwkv6_wkv.ops import wkv6
    from repro.kernels.rwkv6_wkv.ref import wkv6_ref
    b, s, h, n = 1, 512, 4, 64
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, n), jnp.float32)
    kk = jax.random.normal(ks[1], (b, s, h, n), jnp.float32)
    vv = jax.random.normal(ks[2], (b, s, h, n), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, n)) - 1)
    u = jax.random.normal(ks[4], (h, n), jnp.float32) * 0.5
    t_k = time_us(lambda: jax.block_until_ready(
        wkv6(r, kk, vv, w, u, interpret=True)), iters=2)
    t_r = time_us(lambda: jax.block_until_ready(
        jax.jit(lambda *a: wkv6_ref(*a)[0])(r, kk, vv, w, u)), iters=2)
    L = 64
    fl = b * h * (s / L) * (2 * L * n * n * 2 + 2 * L * L * n * 2)
    nbytes = 5 * b * s * h * n * 4
    emit("kernel/rwkv6_wkv_interp", t_k,
         f"ref_seq_scan={t_r:.0f}us modeled_tpu={_modeled(fl, nbytes)*1e6:.1f}us")

    # moe expert mlp: g=1,e=4,c=256,d=256,f=512
    from repro.kernels.moe_mlp.ops import expert_mlp
    from repro.kernels.moe_mlp.ref import expert_mlp_ref
    g, e, c, dd, f = 1, 4, 256, 256, 512
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (g, e, c, dd), jnp.float32)
    wi = jax.random.normal(ks[1], (e, dd, f)) / jnp.sqrt(dd * 1.0)
    wg = jax.random.normal(ks[2], (e, dd, f)) / jnp.sqrt(dd * 1.0)
    wo = jax.random.normal(ks[3], (e, f, dd)) / jnp.sqrt(f * 1.0)
    t_k = time_us(lambda: jax.block_until_ready(
        expert_mlp(x, wi, wg, wo, interpret=True)), iters=2)
    t_r = time_us(lambda: jax.block_until_ready(
        jax.jit(expert_mlp_ref)(x, wi, wg, wo)), iters=2)
    fl = g * e * c * (3 * 2 * dd * f)
    b_kernel = (g * e * c * dd * 2 + 3 * e * dd * f) * 4
    b_xla = b_kernel + 3 * g * e * c * f * 4   # h/u round-trips
    emit("kernel/moe_mlp_interp", t_k,
         f"ref={t_r:.0f}us modeled_tpu={_modeled(fl, b_kernel)*1e6:.1f}us"
         f" xla_path={_modeled(fl, b_xla)*1e6:.1f}us")

    # quantize: 1M elements
    from repro.kernels.quantize.ops import quantize
    from repro.kernels.quantize.ref import quantize_ref
    xq = jax.random.normal(key, (1 << 20,), jnp.float32)
    t_k = time_us(lambda: jax.block_until_ready(
        quantize(xq, interpret=True)[0]), iters=2)
    blocks = xq.reshape(-1, 256)
    t_r = time_us(lambda: jax.block_until_ready(
        jax.jit(quantize_ref)(blocks)[0]), iters=2)
    nbytes = xq.size * 5
    emit("kernel/quantize_interp", t_k,
         f"ref={t_r:.0f}us modeled_tpu={nbytes / HBM * 1e6:.1f}us")
