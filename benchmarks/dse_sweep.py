"""Paper claim §1: 'design-space exploration' — THE canonical gem5 use
case.  The DES sweeps system parameters (collective algorithm, overlap,
straggler mitigation, pod count, link contention on/off) over a
workload trace derived from a real dry-run artifact (if present) and
reports the best configuration; thousands of variants evaluate in
milliseconds each, which is the whole point of simulation-driven
design.  The contention dimension is new with the event-driven
executor: it quantifies how much of a makespan is link queueing."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, time_us
from repro.core.desim.collectives import ALGORITHMS
from repro.core.desim.trace import analytic_trace
from repro.sim import v5e_multipod, v5e_pod


def _workload():
    """Layer costs from a real dry-run artifact when available."""
    cands = sorted(glob.glob(
        "results/dryrun/stablelm-1.6b__train_4k__single.json"))
    if cands:
        d = json.load(open(cands[0]))
        r = d["roofline"]
        L = 24
        return {
            "layers": L,
            "flops": r["hlo_flops_per_device"] / L,
            "bytes": r["hlo_bytes_per_device"] / L,
            "coll": r["collective_bytes_per_device"] / L,
            "src": "dryrun artifact",
        }
    return {"layers": 24, "flops": 2e14, "bytes": 2e11, "coll": 5e8,
            "src": "analytic"}


def run() -> None:
    w = _workload()
    configs = []
    for alg in ALGORITHMS:
        for overlap in (False, True):
            for slow in (None, [1.0, 1.3]):
                for pods in (1, 2):
                    configs.append((alg, overlap, slow, pods))

    def evaluate(alg, overlap, slow, pods, contention=True):
        board = (v5e_pod(algorithm=alg) if pods == 1
                 else v5e_multipod(pods, algorithm=alg))
        colls = [{"kind": "all-reduce", "bytes": w["coll"] * 256,
                  "participants": 256}]
        tr = analytic_trace("w", w["layers"], w["flops"], w["bytes"],
                            colls, overlap=overlap)
        sl = (slow * pods)[:pods] if slow else None
        return board.executor(straggler_slowdowns=sl,
                              contention=contention
                              ).execute(tr).makespan_s

    t = time_us(lambda: [evaluate(*c) for c in configs], iters=1)
    # key on makespan only: tick-exact ties are common and configs
    # (lists/None) are not comparable
    results = sorted(((evaluate(*c), c) for c in configs),
                     key=lambda kv: kv[0])
    best_t, best_c = results[0]
    worst_t, worst_c = results[-1]
    emit("dse/sweep", t / len(configs),
         f"{len(configs)}_configs src={w['src']}")
    emit("dse/best", best_t * 1e6,
         f"alg={best_c[0]} overlap={best_c[1]} pods={best_c[3]}")
    emit("dse/worst", worst_t * 1e6,
         f"alg={worst_c[0]} overlap={worst_c[1]} "
         f"span={worst_t / best_t:.2f}x")
    # contention ablation on the best config: how much of the makespan
    # is link/fabric queueing?
    free_t = evaluate(*best_c, contention=False)
    emit("dse/best_no_contention", free_t * 1e6,
         f"queueing_share={1.0 - free_t / best_t:.3f}")
