"""Paper claim §1: 'design-space exploration' — THE canonical gem5 use
case.  The DES sweeps system parameters (collective algorithm, overlap,
straggler mitigation, pod count) over a workload trace derived from a
real dry-run artifact (if present) and reports the best configuration;
thousands of variants evaluate in milliseconds each, which is the whole
point of simulation-driven design.

``--fidelity {atomic,detailed}`` picks the timing model of the outer
sweep (default: atomic — the gem5 fast-forward trick applied to DSE).
The winning config is always re-scored under DetailedTiming (the
spot-check row ``dse/best_detailed_check``), and a contention ablation
on it quantifies how much of the makespan is link queueing."""

from __future__ import annotations

import glob
import json
import os
import sys

from benchmarks.common import emit, fidelity_from_argv, time_us
from repro.core.desim.collectives import ALGORITHMS
from repro.core.desim.trace import analytic_trace
from repro.sim import v5e_multipod, v5e_pod


def _workload():
    """Layer costs from a real dry-run artifact when available."""
    cands = sorted(glob.glob(
        "results/dryrun/stablelm-1.6b__train_4k__single.json"))
    if cands:
        d = json.load(open(cands[0]))
        r = d["roofline"]
        L = 24
        return {
            "layers": L,
            "flops": r["hlo_flops_per_device"] / L,
            "bytes": r["hlo_bytes_per_device"] / L,
            "coll": r["collective_bytes_per_device"] / L,
            "src": "dryrun artifact",
        }
    return {"layers": 24, "flops": 2e14, "bytes": 2e11, "coll": 5e8,
            "src": "analytic"}


def run(fidelity: str = "atomic") -> None:
    if fidelity not in ("atomic", "detailed"):
        raise ValueError(f"--fidelity {fidelity!r}: atomic or detailed")
    w = _workload()
    configs = []
    for alg in ALGORITHMS:
        for overlap in (False, True):
            for slow in (None, [1.0, 1.3]):
                for pods in (1, 2):
                    configs.append((alg, overlap, slow, pods))

    def evaluate(alg, overlap, slow, pods, timing=fidelity):
        board = (v5e_pod(algorithm=alg) if pods == 1
                 else v5e_multipod(pods, algorithm=alg))
        colls = [{"kind": "all-reduce", "bytes": w["coll"] * 256,
                  "participants": 256}]
        tr = analytic_trace("w", w["layers"], w["flops"], w["bytes"],
                            colls, overlap=overlap)
        sl = (slow * pods)[:pods] if slow else None
        return board.executor(straggler_slowdowns=sl,
                              timing=timing).execute(tr).makespan_s

    t = time_us(lambda: [evaluate(*c) for c in configs], iters=1)
    # key on makespan only: tick-exact ties are common and configs
    # (lists/None) are not comparable
    results = sorted(((evaluate(*c), c) for c in configs),
                     key=lambda kv: kv[0])
    best_t, best_c = results[0]
    worst_t, worst_c = results[-1]
    emit("dse/sweep", t / len(configs),
         f"{len(configs)}_configs src={w['src']} fidelity={fidelity}")
    emit("dse/best", best_t * 1e6,
         f"alg={best_c[0]} overlap={best_c[1]} pods={best_c[3]}")
    emit("dse/worst", worst_t * 1e6,
         f"alg={worst_c[0]} overlap={worst_c[1]} "
         f"span={worst_t / best_t:.2f}x")
    # detailed spot-check of the winner (the sweep ran atomic by
    # default): full-contention makespan + how much of it is queueing
    det_t = (best_t if fidelity == "detailed"
             else evaluate(*best_c, timing="detailed"))
    emit("dse/best_detailed_check", det_t * 1e6,
         f"atomic/detailed={best_t / det_t:.3f}" if fidelity == "atomic"
         else "sweep already detailed")
    free_t = (best_t if fidelity == "atomic"
              else evaluate(*best_c, timing="atomic"))
    emit("dse/best_no_contention", free_t * 1e6,
         f"queueing_share={1.0 - free_t / det_t:.3f}")


if __name__ == "__main__":
    run(fidelity_from_argv(sys.argv))
