"""Benchmark driver: one benchmark per gem5-20 paper claim.

Prints ``name,us_per_call,derived`` CSV rows (see each module's
docstring for the claim it reproduces).

  python -m benchmarks.run                  # all
  python -m benchmarks.run fidelity         # substring filter
  python -m benchmarks.run --json           # also write BENCH_desim.json
  python -m benchmarks.run --json fidelity  # filtered + JSON

``--json`` writes ``BENCH_desim.json`` (per-benchmark ``us_per_call``
plus the derived-metric string) so the perf trajectory across PRs is
machine-readable.
"""

from __future__ import annotations

import json
import sys
import time
import traceback

from benchmarks import (checkpoint_fork, collective_protocols, dse_sweep,
                        distgem5_scaling, elastic_trace, engine_microbench,
                        fidelity_spectrum, fleet_sweep, ft_sweep,
                        kernel_throughput, observability, roofline,
                        sampled_sim, serving_sweep, simpoint_sweep)
from benchmarks.common import rows_as_dict

BENCHES = [
    ("fidelity_spectrum", fidelity_spectrum.run),
    ("engine_microbench", engine_microbench.run),
    ("elastic_trace", elastic_trace.run),
    ("collective_protocols", collective_protocols.run),
    ("distgem5_scaling", distgem5_scaling.run),
    ("checkpoint_fork", checkpoint_fork.run),
    ("sampled_sim", sampled_sim.run),
    ("simpoint_sweep", simpoint_sweep.run),
    ("serving_sweep", serving_sweep.run),
    ("fleet_sweep", fleet_sweep.run),
    ("ft_sweep", ft_sweep.run),
    ("kernel_throughput", kernel_throughput.run),
    ("dse_sweep", dse_sweep.run),
    ("roofline", roofline.run),
    ("observability", observability.run),
]

JSON_PATH = "BENCH_desim.json"


def write_json(path: str, rows: dict, pat: str, failed: list) -> int:
    """Write the perf-trajectory file.  A *filtered* run merges its
    rows into the existing file (update matching rows, keep the rest)
    instead of clobbering the committed trajectory down to the subset —
    the ``tools/ci.sh smoke`` tier runs ``--json fidelity`` and must
    not erase the other ~100 rows.  An unfiltered run replaces the file
    wholesale (the full-regeneration semantics, so renamed/retired
    benchmarks don't linger).  Returns the row count written."""
    merged = dict(rows)
    if pat:
        try:
            with open(path) as f:
                existing = json.load(f).get("benchmarks", {})
        except (OSError, ValueError):
            existing = {}
        merged = {**existing, **rows}
    doc = {
        "generated_unix": time.time(),
        "filter": pat,
        "failed": failed,
        "benchmarks": merged,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return len(merged)


def main() -> None:
    args = [a for a in sys.argv[1:]]
    json_mode = "--json" in args
    if json_mode:
        args.remove("--json")
    pat = args[0] if args else ""
    print("name,us_per_call,derived")
    failed = []
    for name, fn in BENCHES:
        if pat and pat not in name:
            continue
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if json_mode:
        n = write_json(JSON_PATH, rows_as_dict(), pat, failed)
        print(f"wrote {JSON_PATH} ({n} rows)", file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
