"""Benchmark driver: one benchmark per gem5-20 paper claim.

Prints ``name,us_per_call,derived`` CSV rows (see each module's
docstring for the claim it reproduces).

  python -m benchmarks.run            # all
  python -m benchmarks.run fidelity   # substring filter
"""

from __future__ import annotations

import sys
import traceback

from benchmarks import (checkpoint_fork, collective_protocols, dse_sweep,
                        distgem5_scaling, elastic_trace, fidelity_spectrum,
                        kernel_throughput, roofline)

BENCHES = [
    ("fidelity_spectrum", fidelity_spectrum.run),
    ("elastic_trace", elastic_trace.run),
    ("collective_protocols", collective_protocols.run),
    ("distgem5_scaling", distgem5_scaling.run),
    ("checkpoint_fork", checkpoint_fork.run),
    ("kernel_throughput", kernel_throughput.run),
    ("dse_sweep", dse_sweep.run),
    ("roofline", roofline.run),
]


def main() -> None:
    pat = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    failed = []
    for name, fn in BENCHES:
        if pat and pat not in name:
            continue
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
