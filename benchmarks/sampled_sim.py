"""Paper claim §1.3/§2.7: sampled simulation trades detail for speed
without losing the answer.  A 200-step steady-state training run is
simulated (a) fully detailed, (b) SMARTS-sampled (fixed-stride
detailed windows + fast-forward), and (c) SimPoint-sampled (phase
fingerprint → k-means → representative windows, weighted
reconstruction); derived columns record the wall-clock speedup, the
fraction of ops that ran at detailed fidelity, and the prediction
error — the acceptance contract is <=20% detailed ops within 5% of
the full-detail makespan.  On a *steady-state* run both schemes agree
(one phase, so SimPoint degenerates to a handful of windows); the
bursty workload where they diverge is ``benchmarks/simpoint_sweep.py``."""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.desim.trace import analytic_trace
from repro.sim import (SamplePlan, repeat_trace, sampled_run,
                       simpoint_plan, v5e_pod)

STEPS = 200


def run() -> None:
    colls = [{"kind": "all-reduce", "bytes": 2e8, "participants": 256}]
    step = analytic_trace("train_step", 8, 1e12, 1e9, colls)

    board = v5e_pod()
    t0 = time.perf_counter()
    full = board.executor().execute(repeat_trace(step, STEPS))
    t_full = time.perf_counter() - t0
    emit("sampled/full_detail", t_full * 1e6,
         f"makespan={full.makespan_s:.4f}s events={full.events}")

    plan = SamplePlan(warmup=2, interval=20, window=2)
    t0 = time.perf_counter()
    sr = sampled_run(v5e_pod(), step, STEPS, plan)
    t_sampled = time.perf_counter() - t0
    err = abs(sr.predicted_total_s - full.makespan_s) / full.makespan_s
    emit("sampled/sampled", t_sampled * 1e6,
         f"predicted={sr.predicted_total_s:.4f}s err={100 * err:.2f}% "
         f"detailed_ops={100 * sr.detailed_op_fraction:.1f}% "
         f"speedup={t_full / max(t_sampled, 1e-9):.1f}x "
         f"events={sr.events}/{full.events}")

    # SimPoint on the same steady-state run: the fingerprint finds ONE
    # phase (modulo float jitter), so the plan collapses to a few
    # representative windows and the weighted reconstruction matches
    # the stride prediction — the degenerate-case sanity row
    trace = repeat_trace(step, STEPS)
    t0 = time.perf_counter()
    spplan = simpoint_plan(trace, window=2, seed=0)
    sp = sampled_run(v5e_pod(), trace, STEPS, spplan)
    t_sp = time.perf_counter() - t0
    err_sp = (abs(sp.weighted_total_s - full.makespan_s)
              / full.makespan_s)
    emit("sampled/simpoint", t_sp * 1e6,
         f"weighted={sp.weighted_total_s:.4f}s err={100 * err_sp:.2f}% "
         f"regions={len(spplan.representatives)} "
         f"detailed_steps={sp.detailed_steps}/{STEPS} "
         f"speedup={t_full / max(t_sp, 1e-9):.1f}x")
