"""Paper claim §1.3/§2.7: sampled simulation trades detail for speed
without losing the answer.  A 200-step steady-state training run is
simulated (a) fully detailed and (b) SMARTS-sampled (detailed windows +
fast-forward, repro.sim.sampling); derived columns record the
wall-clock speedup, the fraction of ops that ran at detailed fidelity,
and the prediction error — the acceptance contract is <=20% detailed
ops within 5% of the full-detail makespan."""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.desim.trace import analytic_trace
from repro.sim import SamplePlan, repeat_trace, sampled_run, v5e_pod

STEPS = 200


def run() -> None:
    colls = [{"kind": "all-reduce", "bytes": 2e8, "participants": 256}]
    step = analytic_trace("train_step", 8, 1e12, 1e9, colls)

    board = v5e_pod()
    t0 = time.perf_counter()
    full = board.executor().execute(repeat_trace(step, STEPS))
    t_full = time.perf_counter() - t0
    emit("sampled/full_detail", t_full * 1e6,
         f"makespan={full.makespan_s:.4f}s events={full.events}")

    plan = SamplePlan(warmup=2, interval=20, window=2)
    t0 = time.perf_counter()
    sr = sampled_run(v5e_pod(), step, STEPS, plan)
    t_sampled = time.perf_counter() - t0
    err = abs(sr.predicted_total_s - full.makespan_s) / full.makespan_s
    emit("sampled/sampled", t_sampled * 1e6,
         f"predicted={sr.predicted_total_s:.4f}s err={100 * err:.2f}% "
         f"detailed_ops={100 * sr.detailed_op_fraction:.1f}% "
         f"speedup={t_full / max(t_sampled, 1e-9):.1f}x "
         f"events={sr.events}/{full.events}")
