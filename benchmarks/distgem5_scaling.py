"""Paper claim §2.17 (dist-gem5): parallel multi-node simulation with
quantum-based synchronization.  Measures (a) the in-process QuantumSync
engine's barrier overhead vs quantum length, (b) DES-predicted step
time vs pod count for a fixed per-pod workload (weak scaling: the
hierarchical DCN all-reduce is the scaling cost)."""

from __future__ import annotations

from benchmarks.common import emit, time_us
from repro.core.desim.executor import TraceExecutor
from repro.core.desim.machine import ClusterModel
from repro.core.desim.trace import analytic_trace
from repro.core.events import EventQueue, QuantumSync


def run() -> None:
    # (a) engine: 4 queues, 10k events each, quantum sweep
    for quantum in (100, 1_000, 10_000):
        def sim():
            queues = [EventQueue(f"pod{i}") for i in range(4)]
            for q in queues:
                for t in range(0, 100_000, 50):
                    q.schedule(lambda: None, t)
            QuantumSync(queues, quantum).run(100_000)

        t = time_us(sim, iters=2)
        def barriers(quantum=quantum):
            return 100_000 // quantum
        emit(f"distgem5/engine_q{quantum}", t,
             f"barriers={barriers()} events=8000")

    # (b) weak scaling: per-pod layer work fixed; DCN AR grows with pods
    layer_colls = [{"kind": "all-reduce", "bytes": 5e8, "participants": 256}]
    for pods in (1, 2, 4, 8):
        m = ClusterModel("c", num_pods=pods)
        m.instantiate()
        tail = ([] if pods == 1 else
                [{"kind": "all-reduce", "bytes": 2e9,
                  "participants": 256 * pods, "scope": "dcn"}])
        tr = analytic_trace("step", 32, 5e13, 5e10, layer_colls,
                            tail_collectives=tail, overlap=False)
        res = TraceExecutor(m).execute(tr)
        emit(f"distgem5/step_{pods}pods", res.makespan_s * 1e6,
             f"exposed_coll_s={res.exposed_collective_s:.3f}")
