"""Paper claim §2.17 (dist-gem5): parallel multi-node simulation with
quantum-based synchronization.  Measures (a) the in-process QuantumSync
engine's barrier overhead vs quantum length (dense lockstep ``run`` vs
the work-skipping ``run_until_drained`` the trace executor uses),
(b) DES-predicted step time vs pod count for a fixed per-pod workload
(weak scaling: the hierarchical DCN all-reduce is the scaling cost),
(c) the multiprocess ``ParallelEngine``'s wall-clock scaling on a
32-pod board across a quantum x workers grid, and (d) the same engine
on the 64-pod ``v5e_fleet_big`` board — each parallel row breaks the
wall time into coordination phases (spawn / barrier-wait / collect /
compute) and records the batched-protocol counters (barriers, pipe
messages, quanta elided by lookahead), so a scaling regression is
attributable to a phase, not just visible in the total.  Every row
asserts tick-exactness (the dist-gem5 bar: parallelism must change
wall clock only, never the simulated numbers).

The (d) grid also documents why speedup is not monotonic in workers on
a homogeneous SPMD board: clone folding collapses each worker's pods
to one representative per clone class, so w2 already simulates only a
few distinct pods and extra workers buy little compute while adding
per-barrier pipe traffic — hence w2 can beat w4.

    python -m benchmarks.distgem5_scaling --assert-parallel 2
        CI parallel tier (tools/ci.sh parallel): fail loudly unless
        workers=4 is >= 2x faster than serial AND bit-exact, across
        two laps of one warm engine (worker-pool reuse path).
    python -m benchmarks.distgem5_scaling --assert-parallel-big 4
        CI parallel tier: workers=8 on the 64-pod v5e_fleet_big board
        must be >= 4x faster than serial, bit-exact, with barriers
        bounded by the DCN collective count (lookahead elision).
"""

from __future__ import annotations

import sys
import time

from benchmarks.common import emit, time_us
from repro.core.desim.trace import analytic_trace
from repro.core.events import EventQueue, QuantumSync
from repro.sim import v5e_fleet_big, v5e_multipod, v5e_pod

# the multiprocess-scaling workload: one homogeneous 32-pod board, a
# step with per-layer ICI all-reduces and a DCN tail collective (so the
# sync path — quantum barriers + coordinator rendezvous — is exercised,
# not just the embarrassing free-run path).  The wall-clock win on a
# homogeneous board comes from SPMD clone folding (each worker
# simulates one representative pod per clone class), so the speedup
# survives even a single-CPU CI container.
PARALLEL_PODS = 32
FLEET_PODS = 64


def _parallel_board(quantum_ns: int = 100_000):
    return v5e_multipod(PARALLEL_PODS, quantum_ns=quantum_ns, nx=8, ny=8)


def _parallel_trace(pods: int = PARALLEL_PODS):
    return analytic_trace(
        "step", 96, 2e13, 2e10,
        [{"kind": "all-reduce", "bytes": 2e8, "participants": 64}],
        tail_collectives=[{"kind": "all-reduce", "bytes": 1e9,
                           "participants": 64 * pods,
                           "scope": "dcn"}])


def _fleet_board(quantum_ns: int = 100_000):
    return v5e_fleet_big(FLEET_PODS, quantum_ns=quantum_ns)


def _fleet_trace():
    # per-layer ICI collectives plus several DCN tail collectives: the
    # lookahead grant path has multiple rendezvous to elide between.
    # Deep enough (512 layers x 64 pods serially) that worker spawn
    # cost is small against the simulated work.
    return analytic_trace(
        "step", 512, 4e12, 4e9,
        [{"kind": "all-reduce", "bytes": 5e7, "participants": 16}],
        tail_collectives=[{"kind": "all-reduce", "bytes": 2e8 * (i + 1),
                           "participants": 16 * FLEET_PODS,
                           "scope": "dcn"} for i in range(4)])


def _phase_detail(wall: float, eng) -> str:
    """Coordination-phase breakdown + protocol counters for one row."""
    pw = eng.phase_wall
    coord = pw["spawn"] + pw["barrier_wait"] + pw["collect"]
    c = eng.sync_counters()
    return (f"spawn_ms={pw['spawn'] * 1e3:.0f} "
            f"barrier_ms={pw['barrier_wait'] * 1e3:.0f} "
            f"collect_ms={pw['collect'] * 1e3:.0f} "
            f"compute_ms={max(wall - coord, 0.0) * 1e3:.0f} "
            f"barriers={c['barriers']} elided={c['quanta_elided']} "
            f"msgs={c['pipe_msgs_sent'] + c['pipe_msgs_recv']}")


def _measure_parallel(workers: int, quantum_ns: int, board_fn=_parallel_board,
                      trace_fn=_parallel_trace, warm: bool = False):
    """(wall seconds, ExecResult, engine-or-None).  Parallel runs hand
    back the closed engine so callers can read ``phase_wall`` and
    ``sync_counters()`` (both survive ``close()``).  ``warm=True``
    measures a *second* lap on the same engine — the warm worker-pool
    steady state — so grid rows report protocol cost, not process
    start-up (which, under a spawn context with jax loaded, is ~0.5s
    of child imports per worker and would swamp every other phase)."""
    board = board_fn(quantum_ns)
    t0 = time.perf_counter()
    if workers <= 1:
        res = board.executor(record_stats=True).execute(trace_fn())
        return time.perf_counter() - t0, res, None
    eng = board.executor(workers=workers, record_stats=True)
    try:
        res = eng.execute(trace_fn())
        wall = time.perf_counter() - t0
        if warm:
            t0 = time.perf_counter()
            res = eng.execute(trace_fn())
            wall = time.perf_counter() - t0
    finally:
        eng.close()
    return wall, res, eng


def run() -> None:
    # (a) engine: 4 queues, 10k events each, quantum sweep
    for quantum in (100, 1_000, 10_000):
        def sim(drained: bool, quantum=quantum):
            queues = [EventQueue(f"pod{i}") for i in range(4)]
            for q in queues:
                for t in range(0, 100_000, 50):
                    q.schedule(lambda: None, t)
            sync = QuantumSync(queues, quantum)
            if drained:
                sync.run_until_drained()
            else:
                sync.run(100_000)
            return sync.barriers

        t_dense = time_us(lambda: sim(False), iters=2)
        t_drain = time_us(lambda: sim(True), iters=2)
        emit(f"distgem5/engine_q{quantum}", t_dense,
             f"barriers={100_000 // quantum} events=8000 "
             f"drained={t_drain:.0f}us")

    # (b) weak scaling: per-pod layer work fixed; DCN AR grows with pods
    layer_colls = [{"kind": "all-reduce", "bytes": 5e8, "participants": 256}]
    for pods in (1, 2, 4, 8):
        board = v5e_pod() if pods == 1 else v5e_multipod(pods)
        tail = ([] if pods == 1 else
                [{"kind": "all-reduce", "bytes": 2e9,
                  "participants": 256 * pods, "scope": "dcn"}])
        tr = analytic_trace("step", 32, 5e13, 5e10, layer_colls,
                            tail_collectives=tail, overlap=False)
        res = board.executor(record_stats=True).execute(tr)
        dcn_colls = int(res.stats["sim.dcn.collectives"])
        emit(f"distgem5/step_{pods}pods", res.makespan_s * 1e6,
             f"exposed_coll_s={res.exposed_collective_s:.3f} "
             f"events={res.events} dcn_colls={dcn_colls}")

    # (c) multiprocess scaling: quantum x workers grid, speedup vs the
    # serial engine on the same board/trace, exactness asserted per row
    for quantum_ns in (10_000, 100_000, 1_000_000):
        w_serial, ref, _ = _measure_parallel(1, quantum_ns)
        emit(f"distgem5/par_q{quantum_ns}_w1", w_serial * 1e6,
             f"pods={PARALLEL_PODS} makespan={ref.makespan_s:.4f}s "
             f"events={ref.events}")
        for workers in (2, 4, 8):
            wall, res, eng = _measure_parallel(workers, quantum_ns,
                                               warm=True)
            exact = res == ref
            emit(f"distgem5/par_q{quantum_ns}_w{workers}", wall * 1e6,
                 f"speedup={w_serial / max(wall, 1e-9):.2f}x "
                 f"exact={exact} {_phase_detail(wall, eng)}")
            if not exact:
                raise AssertionError(
                    f"parallel run (workers={workers}, "
                    f"quantum={quantum_ns}) diverged from serial")

    # (d) fleet-scale grid: 64 pods, workers 1..8.  The phase breakdown
    # is the point: on this homogeneous board clone folding means w2
    # already holds few distinct pods per worker, so compute_ms stops
    # falling past w2 while barrier_ms grows with the worker count —
    # which is why w2 > w4 is expected, not a bug.
    w_serial, ref, _ = _measure_parallel(1, 100_000, _fleet_board,
                                         lambda: _fleet_trace())
    emit(f"distgem5/fleet{FLEET_PODS}_w1", w_serial * 1e6,
         f"pods={FLEET_PODS} makespan={ref.makespan_s:.4f}s "
         f"events={ref.events}")
    for workers in (2, 4, 8):
        wall, res, eng = _measure_parallel(workers, 100_000, _fleet_board,
                                           lambda: _fleet_trace(), warm=True)
        exact = res == ref
        emit(f"distgem5/fleet{FLEET_PODS}_w{workers}", wall * 1e6,
             f"speedup={w_serial / max(wall, 1e-9):.2f}x "
             f"exact={exact} {_phase_detail(wall, eng)}")
        if not exact:
            raise AssertionError(
                f"fleet parallel run (workers={workers}) diverged")


def assert_parallel(threshold: float, workers: int = 4,
                    quantum_ns: int = 100_000) -> None:
    """CI parallel tier: fail loudly unless the multiprocess engine is
    both >= ``threshold``x faster than serial on the 32-pod reference
    workload AND tick-exact (full ExecResult equality, stats tree
    included).  Runs TWO laps on one engine so the warm worker-pool
    reuse path (``begin`` after ``result`` without ``close``) is
    exercised, then closes it (teardown path)."""
    w_serial, ref, _ = _measure_parallel(1, quantum_ns)
    board = _parallel_board(quantum_ns)
    eng = board.executor(workers=workers, record_stats=True)
    try:
        t0 = time.perf_counter()
        res = eng.execute(_parallel_trace())
        w_par = time.perf_counter() - t0
        res2 = eng.execute(_parallel_trace())   # warm-pool lap
    finally:
        eng.close()
    speedup = w_serial / max(w_par, 1e-9)
    print(f"parallel-smoke [{PARALLEL_PODS} pods, quantum={quantum_ns}ns]: "
          f"serial {w_serial * 1e3:.0f}ms vs workers={workers} "
          f"{w_par * 1e3:.0f}ms -> {speedup:.1f}x wall "
          f"(threshold {threshold:.1f}x)")
    if res != ref or res2 != ref:
        print("parallel-smoke FAILED: multiprocess run diverged from the "
              "serial engine (must be bit-identical — makespan "
              f"{res.makespan_s}/{res2.makespan_s} vs {ref.makespan_s})",
              file=sys.stderr)
        raise SystemExit(1)
    if speedup < threshold:
        print(f"parallel-smoke FAILED: workers={workers} is only "
              f"{speedup:.1f}x faster than serial (need >= "
              f"{threshold:.1f}x) — pod sharding or SPMD clone folding "
              "regressed", file=sys.stderr)
        raise SystemExit(1)
    print("parallel-smoke OK")


def assert_parallel_big(threshold: float, workers: int = 8,
                        quantum_ns: int = 100_000) -> None:
    """CI parallel tier, fleet scale: workers=8 on the 64-pod
    ``v5e_fleet_big`` board must be >= ``threshold``x faster than
    serial, bit-exact, AND the batched protocol must actually elide
    barriers — the coordinator may take at most ``2 * dcn_collectives
    + 4`` barriers (vs ~makespan/quantum without lookahead)."""
    w_serial, ref, _ = _measure_parallel(1, quantum_ns, _fleet_board,
                                         lambda: _fleet_trace())
    w_par, res, eng = _measure_parallel(workers, quantum_ns, _fleet_board,
                                        lambda: _fleet_trace())
    speedup = w_serial / max(w_par, 1e-9)
    c = eng.sync_counters()
    dcn_colls = int(ref.stats["sim.dcn.collectives"])
    budget = 2 * dcn_colls + 4
    print(f"parallel-fleet [{FLEET_PODS} pods, quantum={quantum_ns}ns]: "
          f"serial {w_serial * 1e3:.0f}ms vs workers={workers} "
          f"{w_par * 1e3:.0f}ms -> {speedup:.1f}x wall "
          f"(threshold {threshold:.1f}x); barriers={c['barriers']} "
          f"(budget {budget}, elided {c['quanta_elided']}) "
          f"msgs={c['pipe_msgs_sent']}+{c['pipe_msgs_recv']}")
    if res != ref:
        print("parallel-fleet FAILED: multiprocess run diverged from the "
              "serial engine (must be bit-identical — makespan "
              f"{res.makespan_s} vs {ref.makespan_s})", file=sys.stderr)
        raise SystemExit(1)
    if c["barriers"] > budget:
        print(f"parallel-fleet FAILED: {c['barriers']} barriers for "
              f"{dcn_colls} DCN collectives (budget {budget}) — "
              "lookahead elision regressed", file=sys.stderr)
        raise SystemExit(1)
    if speedup < threshold:
        print(f"parallel-fleet FAILED: workers={workers} is only "
              f"{speedup:.1f}x faster than serial (need >= "
              f"{threshold:.1f}x) — coordinator batching regressed",
              file=sys.stderr)
        raise SystemExit(1)
    print("parallel-fleet OK")


if __name__ == "__main__":
    args = sys.argv[1:]
    if "--assert-parallel" in args:
        i = args.index("--assert-parallel")
        assert_parallel(float(args[i + 1]))
    elif "--assert-parallel-big" in args:
        i = args.index("--assert-parallel-big")
        assert_parallel_big(float(args[i + 1]))
    else:
        run()
