"""Paper claim §2.17 (dist-gem5): parallel multi-node simulation with
quantum-based synchronization.  Measures (a) the in-process QuantumSync
engine's barrier overhead vs quantum length (dense lockstep ``run`` vs
the work-skipping ``run_until_drained`` the trace executor uses),
(b) DES-predicted step time vs pod count for a fixed per-pod workload
(weak scaling: the hierarchical DCN all-reduce is the scaling cost),
with the engine's own event/stat counters as the derived columns."""

from __future__ import annotations

from benchmarks.common import emit, time_us
from repro.core.desim.trace import analytic_trace
from repro.core.events import EventQueue, QuantumSync
from repro.sim import v5e_multipod, v5e_pod


def run() -> None:
    # (a) engine: 4 queues, 10k events each, quantum sweep
    for quantum in (100, 1_000, 10_000):
        def sim(drained: bool, quantum=quantum):
            queues = [EventQueue(f"pod{i}") for i in range(4)]
            for q in queues:
                for t in range(0, 100_000, 50):
                    q.schedule(lambda: None, t)
            sync = QuantumSync(queues, quantum)
            if drained:
                sync.run_until_drained()
            else:
                sync.run(100_000)
            return sync.barriers

        t_dense = time_us(lambda: sim(False), iters=2)
        t_drain = time_us(lambda: sim(True), iters=2)
        emit(f"distgem5/engine_q{quantum}", t_dense,
             f"barriers={100_000 // quantum} events=8000 "
             f"drained={t_drain:.0f}us")

    # (b) weak scaling: per-pod layer work fixed; DCN AR grows with pods
    layer_colls = [{"kind": "all-reduce", "bytes": 5e8, "participants": 256}]
    for pods in (1, 2, 4, 8):
        board = v5e_pod() if pods == 1 else v5e_multipod(pods)
        tail = ([] if pods == 1 else
                [{"kind": "all-reduce", "bytes": 2e9,
                  "participants": 256 * pods, "scope": "dcn"}])
        tr = analytic_trace("step", 32, 5e13, 5e10, layer_colls,
                            tail_collectives=tail, overlap=False)
        res = board.executor(record_stats=True).execute(tr)
        dcn_colls = int(res.stats["sim.dcn.collectives"])
        emit(f"distgem5/step_{pods}pods", res.makespan_s * 1e6,
             f"exposed_coll_s={res.exposed_collective_s:.3f} "
             f"events={res.events} dcn_colls={dcn_colls}")
