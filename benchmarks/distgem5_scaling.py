"""Paper claim §2.17 (dist-gem5): parallel multi-node simulation with
quantum-based synchronization.  Measures (a) the in-process QuantumSync
engine's barrier overhead vs quantum length (dense lockstep ``run`` vs
the work-skipping ``run_until_drained`` the trace executor uses),
(b) DES-predicted step time vs pod count for a fixed per-pod workload
(weak scaling: the hierarchical DCN all-reduce is the scaling cost),
(c) the multiprocess ``ParallelEngine``'s wall-clock scaling on a
16-pod board across a quantum x workers grid — each row records the
speedup over the serial TraceExecutor and asserts tick-exactness (the
dist-gem5 bar: parallelism must change wall clock only, never the
simulated numbers).

    python -m benchmarks.distgem5_scaling --assert-parallel 2
        CI parallel tier (tools/ci.sh parallel): fail loudly unless
        workers=4 is >= 2x faster than serial AND bit-exact.
"""

from __future__ import annotations

import sys
import time

from benchmarks.common import emit, time_us
from repro.core.desim.trace import analytic_trace
from repro.core.events import EventQueue, QuantumSync
from repro.sim import run_parallel, v5e_multipod, v5e_pod

# the multiprocess-scaling workload: one homogeneous 32-pod board, a
# step with per-layer ICI all-reduces and a DCN tail collective (so the
# sync path — quantum barriers + coordinator rendezvous — is exercised,
# not just the embarrassing free-run path).  The wall-clock win on a
# homogeneous board comes from SPMD clone folding (each worker
# simulates one representative pod per clone class), so the speedup
# survives even a single-CPU CI container.
PARALLEL_PODS = 32


def _parallel_board(quantum_ns: int = 100_000):
    return v5e_multipod(PARALLEL_PODS, quantum_ns=quantum_ns, nx=8, ny=8)


def _parallel_trace():
    return analytic_trace(
        "step", 96, 2e13, 2e10,
        [{"kind": "all-reduce", "bytes": 2e8, "participants": 64}],
        tail_collectives=[{"kind": "all-reduce", "bytes": 1e9,
                           "participants": 64 * PARALLEL_PODS,
                           "scope": "dcn"}])


def _measure_parallel(workers: int, quantum_ns: int):
    board = _parallel_board(quantum_ns)
    t0 = time.perf_counter()
    if workers <= 1:
        res = board.executor(record_stats=True).execute(_parallel_trace())
    else:
        res = run_parallel(board, _parallel_trace(), workers=workers,
                           record_stats=True)
    return time.perf_counter() - t0, res


def run() -> None:
    # (a) engine: 4 queues, 10k events each, quantum sweep
    for quantum in (100, 1_000, 10_000):
        def sim(drained: bool, quantum=quantum):
            queues = [EventQueue(f"pod{i}") for i in range(4)]
            for q in queues:
                for t in range(0, 100_000, 50):
                    q.schedule(lambda: None, t)
            sync = QuantumSync(queues, quantum)
            if drained:
                sync.run_until_drained()
            else:
                sync.run(100_000)
            return sync.barriers

        t_dense = time_us(lambda: sim(False), iters=2)
        t_drain = time_us(lambda: sim(True), iters=2)
        emit(f"distgem5/engine_q{quantum}", t_dense,
             f"barriers={100_000 // quantum} events=8000 "
             f"drained={t_drain:.0f}us")

    # (b) weak scaling: per-pod layer work fixed; DCN AR grows with pods
    layer_colls = [{"kind": "all-reduce", "bytes": 5e8, "participants": 256}]
    for pods in (1, 2, 4, 8):
        board = v5e_pod() if pods == 1 else v5e_multipod(pods)
        tail = ([] if pods == 1 else
                [{"kind": "all-reduce", "bytes": 2e9,
                  "participants": 256 * pods, "scope": "dcn"}])
        tr = analytic_trace("step", 32, 5e13, 5e10, layer_colls,
                            tail_collectives=tail, overlap=False)
        res = board.executor(record_stats=True).execute(tr)
        dcn_colls = int(res.stats["sim.dcn.collectives"])
        emit(f"distgem5/step_{pods}pods", res.makespan_s * 1e6,
             f"exposed_coll_s={res.exposed_collective_s:.3f} "
             f"events={res.events} dcn_colls={dcn_colls}")

    # (c) multiprocess scaling: quantum x workers grid, speedup vs the
    # serial engine on the same board/trace, exactness asserted per row
    for quantum_ns in (10_000, 100_000, 1_000_000):
        w_serial, ref = _measure_parallel(1, quantum_ns)
        emit(f"distgem5/par_q{quantum_ns}_w1", w_serial * 1e6,
             f"pods={PARALLEL_PODS} makespan={ref.makespan_s:.4f}s "
             f"events={ref.events}")
        for workers in (2, 4, 8):
            wall, res = _measure_parallel(workers, quantum_ns)
            exact = res == ref
            emit(f"distgem5/par_q{quantum_ns}_w{workers}", wall * 1e6,
                 f"speedup={w_serial / max(wall, 1e-9):.2f}x "
                 f"exact={exact}")
            if not exact:
                raise AssertionError(
                    f"parallel run (workers={workers}, "
                    f"quantum={quantum_ns}) diverged from serial")


def assert_parallel(threshold: float, workers: int = 4,
                    quantum_ns: int = 100_000) -> None:
    """CI parallel tier: fail loudly unless the multiprocess engine is
    both >= ``threshold``x faster than serial on the 16-pod reference
    workload AND tick-exact (full ExecResult equality, stats tree
    included)."""
    w_serial, ref = _measure_parallel(1, quantum_ns)
    w_par, res = _measure_parallel(workers, quantum_ns)
    speedup = w_serial / max(w_par, 1e-9)
    print(f"parallel-smoke [{PARALLEL_PODS} pods, quantum={quantum_ns}ns]: "
          f"serial {w_serial * 1e3:.0f}ms vs workers={workers} "
          f"{w_par * 1e3:.0f}ms -> {speedup:.1f}x wall "
          f"(threshold {threshold:.1f}x)")
    if res != ref:
        print("parallel-smoke FAILED: multiprocess run diverged from the "
              "serial engine (must be bit-identical — makespan "
              f"{res.makespan_s} vs {ref.makespan_s})", file=sys.stderr)
        raise SystemExit(1)
    if speedup < threshold:
        print(f"parallel-smoke FAILED: workers={workers} is only "
              f"{speedup:.1f}x faster than serial (need >= "
              f"{threshold:.1f}x) — pod sharding or SPMD clone folding "
              "regressed", file=sys.stderr)
        raise SystemExit(1)
    print("parallel-smoke OK")


if __name__ == "__main__":
    args = sys.argv[1:]
    if "--assert-parallel" in args:
        i = args.index("--assert-parallel")
        assert_parallel(float(args[i + 1]))
    else:
        run()
