"""Paper claim §2.12/§2.13: pluggable protocols (Ruby/SLICC) and network
fidelity (Garnet).  The pod analogue: swap collective algorithms per
simulation and compare predicted times across payloads/participants."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.desim.collectives import ALGORITHMS, best_algorithm
from repro.core.desim.machine import ClusterModel


def run() -> None:
    m1 = ClusterModel("single", num_pods=1)
    m1.instantiate()
    m2 = ClusterModel("multi", num_pods=2)
    m2.instantiate()

    for nbytes in (1e6, 1e8, 1e10):
        for n, machine in ((256, m1), (512, m2)):
            times = {name: alg.time_s("all-reduce", nbytes, n, machine)
                     for name, alg in ALGORITHMS.items()}
            best = min(times, key=times.get)
            for name, t in sorted(times.items()):
                emit(f"collectives/ar_{nbytes:.0e}B_{n}chips/{name}",
                     t * 1e6, "best" if name == best else "")

    name, t = best_algorithm("all-to-all", 1e9, 256, m1)
    emit("collectives/a2a_1e9B_best", t * 1e6, name)
