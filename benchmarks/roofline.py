"""§Roofline table generator: renders the per-(arch x shape) roofline
terms from the dry-run artifacts (single-pod mesh, per assignment) into
markdown for EXPERIMENTS.md."""

from __future__ import annotations

import json
import os
from typing import List, Optional

from benchmarks.common import emit

HEADER = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| MODEL/HLO flops | roofline frac | fits 16GB | one-line fix |")
SEP = "|---" * 10 + "|"

FIX_HINTS = {
    "memory": "cut activation round-trips (flash-attn kernel / fusion)",
    "collective": "reshard to cut all-gathers; overlap with compute",
    "compute": "at compute roofline: increase MXU utilization/efficiency",
}


def render(summary_path: str = "results/dryrun/summary.json",
           out_path: Optional[str] = "results/roofline.md") -> str:
    rows = json.load(open(summary_path))
    lines: List[str] = [HEADER, SEP]
    for r in rows:
        if r.get("mesh") != "single":
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | — | skipped: sub-quadratic n/a |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED "
                         f"| | | | | | | {r.get('error', '')[:40]} |")
            continue
        rl = r["roofline"]
        # roofline fraction: useful model flops time / bound time
        t_model = (r["model_flops_global"] / r["mesh_desc"]["devices"]
                   / 197e12)
        frac = t_model / rl["bound_s"] if rl["bound_s"] else 0.0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4f} "
            f"| {rl['memory_s']:.4f} | {rl['collective_s']:.4f} "
            f"| **{rl['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {frac:.3f} | {'yes' if r['fits_hbm'] else 'NO'} "
            f"| {FIX_HINTS[rl['dominant']]} |")
    text = "\n".join(lines)
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            f.write(text + "\n")
    return text


def run() -> None:
    if not os.path.exists("results/dryrun/summary.json"):
        emit("roofline/table", 0.0, "no dryrun artifacts; run launch.dryrun")
        return
    text = render()
    n = text.count("\n") - 1
    emit("roofline/table", 0.0, f"{n}_rows -> results/roofline.md")
