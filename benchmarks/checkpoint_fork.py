"""Paper claims §2.7/§2.12.1: checkpoint/restore and simulator fork.
Measures checkpoint save/restore throughput and the fork-and-diverge
pattern: one region-checkpoint library, restored through the
``ckptlib`` fanout onto two *different* machine configurations — the
gem5 checkpoint-once/sweep-everything move, with divergence isolation
confirmed (the forks disagree; the library and a re-restore do not
change)."""

from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_us
from repro.checkpoint import CheckpointManager


def run() -> None:
    key = jax.random.PRNGKey(0)
    state = {"params": {f"w{i}": jax.random.normal(key, (256, 256))
                        for i in range(16)},
             "step": jnp.asarray(0)}
    nbytes = sum(x.nbytes for x in jax.tree.leaves(state))

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        t_save = time_us(lambda: mgr.save(state, 1), iters=3)
        emit("checkpoint/save", t_save,
             f"{nbytes / (t_save / 1e6) / 1e9:.2f} GB/s")
        t_restore = time_us(lambda: mgr.restore(state, step=1), iters=3)
        emit("checkpoint/restore", t_restore,
             f"{nbytes / (t_restore / 1e6) / 1e9:.2f} GB/s")

        # async save: foreground cost only
        mgr2 = CheckpointManager(d, async_save=True)
        t_async = time_us(lambda: (mgr2.save(state, 2), mgr2.wait()),
                          iters=3)
        mgr3 = CheckpointManager(d, async_save=True)

        def fg_only():
            mgr3.wait()
            mgr3.save(state, 3)
        t_fg = time_us(fg_only, iters=3)
        mgr3.wait()
        emit("checkpoint/async_foreground", t_fg,
             f"hides {100 * (1 - t_fg / max(t_async, 1e-9)):.0f}% of save")

    # fork: one checkpoint library, two restores onto different
    # machines (gem5's fork call, done properly through ckptlib: the
    # checkpoint is the fork point, the restored executors are the
    # children, and nothing the children do touches the library)
    from repro.sim import (bursty_trace, reconstruct, restore_fanout,
                           simpoint_plan, take_region_checkpoints,
                           v5e_degraded, v5e_pod)
    trace = bursty_trace(num_steps=40, burst_start=20, burst_len=10,
                         seed=0)
    plan = simpoint_plan(trace, window=2, seed=0)
    with tempfile.TemporaryDirectory() as td:
        root = os.path.join(td, "lib")
        lib = take_region_checkpoints(v5e_pod(), trace, plan, root)
        with open(os.path.join(root, "index.json"), "rb") as f:
            index_before = f.read()
        fork_a = restore_fanout(lib)                       # as captured
        fork_b = restore_fanout(lib, board=v5e_degraded(),  # sick ICI
                                timing="detailed")
        fork_a2 = restore_fanout(lib)                       # re-restore
        with open(os.path.join(root, "index.json"), "rb") as f:
            index_after = f.read()
        ta = reconstruct(fork_a, lib=lib)
        tb = reconstruct(fork_b, lib=lib)
        isolated = (ta != tb                      # forks diverged
                    and fork_a == fork_a2         # ...without cross-talk
                    and index_before == index_after)
        emit("checkpoint/fork_diverge", 0.0,
             f"isolated={isolated} base={ta:.4f}s degraded={tb:.4f}s "
             f"checkpoints={len(lib.entries)}")
