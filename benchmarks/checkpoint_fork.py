"""Paper claims §2.7/§2.12.1: checkpoint/restore and simulator fork.
Measures checkpoint save/restore throughput and the fork-and-diverge
pattern (clone trainer state, run both, confirm divergence isolation)."""

from __future__ import annotations

import copy
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us
from repro.checkpoint import CheckpointManager


def run() -> None:
    key = jax.random.PRNGKey(0)
    state = {"params": {f"w{i}": jax.random.normal(key, (256, 256))
                        for i in range(16)},
             "step": jnp.asarray(0)}
    nbytes = sum(x.size * 4 for x in jax.tree.leaves(state))

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        t_save = time_us(lambda: mgr.save(state, 1), iters=3)
        emit("checkpoint/save", t_save,
             f"{nbytes / (t_save / 1e6) / 1e9:.2f} GB/s")
        t_restore = time_us(lambda: mgr.restore(state, step=1), iters=3)
        emit("checkpoint/restore", t_restore,
             f"{nbytes / (t_restore / 1e6) / 1e9:.2f} GB/s")

        # async save: foreground cost only
        mgr2 = CheckpointManager(d, async_save=True)
        t_async = time_us(lambda: (mgr2.save(state, 2), mgr2.wait()),
                          iters=3)
        mgr3 = CheckpointManager(d, async_save=True)

        def fg_only():
            mgr3.wait()
            mgr3.save(state, 3)
        t_fg = time_us(fg_only, iters=3)
        mgr3.wait()
        emit("checkpoint/async_foreground", t_fg,
             f"hides {100 * (1 - t_fg / max(t_async, 1e-9)):.0f}% of save")

    # fork: clone state, diverge, confirm isolation (gem5 fork call)
    def step_fn(s, x):
        return {"params": jax.tree.map(lambda w: w + x, s["params"]),
                "step": s["step"] + 1}

    fork_a = state
    fork_b = jax.tree.map(lambda x: x, state)   # clone
    fork_a = step_fn(fork_a, 1.0)
    fork_b = step_fn(fork_b, -1.0)
    wa = float(fork_a["params"]["w0"][0, 0])
    wb = float(fork_b["params"]["w0"][0, 0])
    emit("checkpoint/fork_diverge", 0.0,
         f"isolated={abs(wa - wb) > 1.0}")
