"""Observability overhead + fidelity benchmark (the ``trace`` tier).

The observability layer's house rule is gem5's: tracing must *observe*,
never *perturb*.  Two enforceable halves:

* **flags-disabled cost**: with no debug flag enabled every ``DPRINTF``
  is a suppressed call (or skipped outright behind an ``_ACTIVE``
  guard).  The per-call kill-switch cost times the number of suppressed
  calls on the pod_torus reference lap must stay under a few percent of
  the lap's wall time (``--assert-overhead 5`` is the CI gate).
* **bit-identity**: a fully-instrumented lap (every flag enabled, DPRINTF
  to a sink, m5out stats dumps, Perfetto trace recording, workers=4)
  must produce the exact same final tick / event count / stats tree as
  a bare lap.  Asserted here on every run, not just in the test suite.

CLI (the ``tools/ci.sh trace`` tier)::

    python -m benchmarks.observability                      # rows only
    python -m benchmarks.observability --assert-overhead 5
        # exit 1 LOUDLY if the flags-disabled DPRINTF tax exceeds 5%
        # of pod_torus wall time, or if instrumented != bare
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time

from benchmarks.common import emit
from repro.core import trace as dbg
from repro.core.desim.trace import analytic_trace
from repro.sim import (Simulator, repeat_trace, v5e_pod, v5e_straggler,
                       validate_trace_events)

COLLS = [{"kind": "all-reduce", "bytes": 1e8, "participants": 256}]
DCN_TAIL = [{"kind": "all-gather", "bytes": 5e7, "participants": 64,
             "scope": "dcn"}]
STEPS = 40


def _pod_torus():
    return (v5e_pod(),
            repeat_trace(analytic_trace("golden", 6, 1e12, 1e9, COLLS),
                         STEPS))


def _multipod():
    return (v5e_straggler(num_pods=4, nx=4, ny=4),
            repeat_trace(analytic_trace("golden", 4, 1e12, 1e9, COLLS,
                                        tail_collectives=DCN_TAIL), 5))


def _lap(board, trace, repeats: int = 3, **sim_kwargs):
    """Best-of-N wall seconds plus the last lap's ExecResult."""
    best = res = None
    for _ in range(repeats):
        sim = Simulator(board, trace, **sim_kwargs)
        t0 = time.perf_counter()
        sim.run_to_completion()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
        res = sim.result()
    return best, res


def _disabled_call_ns(iters: int = 200_000) -> float:
    """Cost of one suppressed ``dprintf`` (flags off, no guard)."""
    dbg.disable()
    dp = dbg.dprintf
    for _ in range(1000):                       # warmup
        dp("Exec", None, "x %d", 1)
    t0 = time.perf_counter()
    for _ in range(iters):
        dp("Exec", None, "x %d", 1)
    return (time.perf_counter() - t0) / iters * 1e9


def _suppressed_on_lap(board, trace) -> int:
    """DPRINTF call-sites hit on one bare lap (counting mode keeps the
    ``_ACTIVE`` guards open, so guarded hot-path sites are counted too —
    a conservative overestimate of the disabled-mode tax)."""
    with dbg.counting():
        Simulator(board, trace).run_to_completion()
        return dbg.suppressed_calls()


def measure():
    """The tier's numbers: wall on/off, overhead model, identity."""
    board, trace = _pod_torus()
    wall_off, res_off = _lap(board, trace)

    # fully instrumented lap: all flags, sink output, m5out, Perfetto
    d = tempfile.mkdtemp(prefix="g5x-trace-bench-")
    dbg.enable("All")
    sink = open(os.devnull, "w")
    dbg.set_output(sink)
    try:
        wall_on, res_on = _lap(board, trace, repeats=1, outdir=d,
                               trace_events=True)
    finally:
        dbg.disable()
        dbg.set_output(None)
        sink.close()

    with open(os.path.join(d, "telemetry.json")) as f:
        telemetry = json.load(f)           # the machine-readable banner

    calls = _suppressed_on_lap(board, trace)
    call_ns = _disabled_call_ns()
    overhead_pct = calls * call_ns / (wall_off * 1e9) * 100.0
    identical = (res_on.makespan_s == res_off.makespan_s
                 and res_on.events == res_off.events)
    return {"wall_off": wall_off, "wall_on": wall_on,
            "calls": calls, "call_ns": call_ns,
            "overhead_pct": overhead_pct, "identical": identical,
            "outdir": d, "result": res_off, "telemetry": telemetry}


def _check_parallel_trace() -> int:
    """workers=4 traced lap: bit-identical to serial, and the merged
    Perfetto file must validate with worker/pod/DCN/barrier tracks."""
    board, trace = _multipod()
    _, res_serial = _lap(board, trace, repeats=1)
    d = tempfile.mkdtemp(prefix="g5x-trace-par-")
    sim = Simulator(board, trace, workers=4, outdir=d, trace_events=True)
    sim.run_to_completion()
    res = sim.result()
    if (res.makespan_s, res.events) != (res_serial.makespan_s,
                                        res_serial.events):
        raise SystemExit("trace tier FAILED: workers=4 traced lap "
                         f"diverged ({res.makespan_s} != "
                         f"{res_serial.makespan_s})")
    with open(os.path.join(d, "trace.json")) as f:
        doc = json.load(f)
    problems = validate_trace_events(doc)
    if problems:
        raise SystemExit("trace tier FAILED: invalid trace-event JSON: "
                         + "; ".join(problems[:5]))
    return len(doc["traceEvents"])


def run() -> None:
    m = measure()
    emit("obs/pod_torus/flags_off", m["wall_off"] * 1e6,
         f"events={m['result'].events} "
         f"makespan={m['result'].makespan_s:.4f}s")
    emit("obs/pod_torus/fully_traced", m["wall_on"] * 1e6,
         f"identical={m['identical']} m5out+perfetto+dprintf(All)")
    emit("obs/dprintf_disabled", m["call_ns"] / 1e3,
         f"ns_per_call={m['call_ns']:.1f}")
    emit("obs/pod_torus/disabled_overhead", m["overhead_pct"],
         f"suppressed_calls={m['calls']} "
         f"pct_of_wall={m['overhead_pct']:.3f}%")
    tel = m["telemetry"]
    emit("obs/pod_torus/host_telemetry", tel["host_seconds"] * 1e6,
         f"final_tick={tel['final_tick']} "
         f"sim_seconds={tel['sim_seconds']:.4f} "
         f"sim_rate={tel['sim_rate']:.2f}x events={tel['events']} "
         f"events_per_host_sec={tel['events_per_host_sec']:.0f}")
    n_events = _check_parallel_trace()
    emit("obs/multipod_w4/trace_events", float(n_events),
         "merged workers=4 Perfetto file validates")


def assert_overhead(threshold_pct: float) -> None:
    """CI trace-smoke: flags-disabled tax under threshold, and the
    instrumented lap bit-identical to the bare one."""
    m = measure()
    print(f"trace-smoke [pod_torus]: bare {m['wall_off'] * 1e3:.1f}ms, "
          f"{m['calls']} suppressed dprintf calls x "
          f"{m['call_ns']:.0f}ns = {m['overhead_pct']:.3f}% of wall "
          f"(threshold {threshold_pct:.1f}%)")
    if not m["identical"]:
        print("trace-smoke FAILED: fully-instrumented lap is not "
              "bit-identical to the bare lap — tracing perturbed the "
              "simulation", file=sys.stderr)
        raise SystemExit(1)
    if m["overhead_pct"] >= threshold_pct:
        print(f"trace-smoke FAILED: flags-disabled DPRINTF overhead "
              f"{m['overhead_pct']:.2f}% >= {threshold_pct:.1f}% of "
              "pod_torus wall time — the kill-switch fast path "
              "regressed", file=sys.stderr)
        raise SystemExit(1)
    n = _check_parallel_trace()
    print(f"trace-smoke: workers=4 merged trace OK ({n} events); "
          f"m5out at {m['outdir']}")
    print("trace-smoke OK")


if __name__ == "__main__":
    args = sys.argv[1:]
    if "--assert-overhead" in args:
        i = args.index("--assert-overhead")
        assert_overhead(float(args[i + 1]))
    else:
        run()
