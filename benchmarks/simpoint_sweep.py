"""Paper claim §1.3/§2.7: SimPoint-style sampling over a checkpoint
library catches the phase a fixed-stride plan misses.

The reference workload is ``bursty_trace``: a seeded 100-step run whose
flash-crowd-like burst phase (steps 55-74) issues large *parallel*
collectives that contend for shared ICI links — the one trace shape
where detailed and atomic timing genuinely diverge, so a sampling
scheme that never runs a burst window in detail is provably wrong.

Four rows tell the story:

* ``simpoint/full_detail``   — ground truth (and the wall-clock cost
  sampling is buying back).
* ``simpoint/simpoint``      — fingerprint → k-means → SimPointPlan →
  one in-engine sampled run; the weighted reconstruction
  ``num_steps * Σ w_i * step_time_i`` vs ground truth.
* ``simpoint/fixed_stride``  — the default SMARTS ``SamplePlan`` at an
  equal-or-LARGER detailed-step budget; its in-engine prediction times
  most burst steps at atomic fidelity and lands far off.
* ``simpoint/ckpt_fanout``   — the full library lap: one atomic
  capture pass (`take_region_checkpoints`), parallel ``workers=2``
  restore fanout re-timing each region detailed, weighted reconstruct.

    python -m benchmarks.simpoint_sweep --assert-simpoint
        CI simpoint tier (tools/ci.sh simpoint): fail loudly unless
        the SimPoint reconstruction AND the checkpoint-fanout lap land
        within 5% of full detail while fixed-stride misses by more.
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

from benchmarks.common import emit
from repro.sim import (SamplePlan, bursty_trace, reconstruct,
                       restore_fanout, sampled_run, simpoint_plan,
                       take_region_checkpoints, v5e_pod)

STEPS = 100
SEED = 0
WINDOW = 2


def _workload():
    return bursty_trace(num_steps=STEPS, seed=SEED)


def _lap():
    """One full comparison lap; returns the error percentages."""
    trace = _workload()
    board = v5e_pod()

    t0 = time.perf_counter()
    full = board.executor(timing="detailed").execute(trace)
    t_full = time.perf_counter() - t0
    emit("simpoint/full_detail", t_full * 1e6,
         f"makespan={full.makespan_s:.4f}s events={full.events}")

    t0 = time.perf_counter()
    plan = simpoint_plan(trace, window=WINDOW, seed=SEED)
    sp = sampled_run(v5e_pod(), trace, STEPS, plan)
    t_sp = time.perf_counter() - t0
    err_sp = (abs(sp.weighted_total_s - full.makespan_s)
              / full.makespan_s * 100)
    emit("simpoint/simpoint", t_sp * 1e6,
         f"weighted={sp.weighted_total_s:.4f}s err={err_sp:.2f}% "
         f"regions={len(plan.representatives)} "
         f"detailed_steps={sp.detailed_steps}/{STEPS} "
         f"speedup={t_full / max(t_sp, 1e-9):.1f}x")

    stride = SamplePlan()            # warmup=2, interval=12, window=2
    t0 = time.perf_counter()
    st = sampled_run(v5e_pod(), trace, STEPS, stride)
    t_st = time.perf_counter() - t0
    err_st = (abs(st.predicted_total_s - full.makespan_s)
              / full.makespan_s * 100)
    emit("simpoint/fixed_stride", t_st * 1e6,
         f"predicted={st.predicted_total_s:.4f}s err={err_st:.2f}% "
         f"detailed_steps={st.detailed_steps}/{STEPS}")

    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        lib = take_region_checkpoints(board, trace, plan,
                                      os.path.join(td, "lib"))
        regions = restore_fanout(lib, workers=2)
        total = reconstruct(regions, lib=lib)
        t_ck = time.perf_counter() - t0
    err_ck = abs(total - full.makespan_s) / full.makespan_s * 100
    emit("simpoint/ckpt_fanout", t_ck * 1e6,
         f"reconstructed={total:.4f}s err={err_ck:.2f}% "
         f"checkpoints={len(lib.entries)} workers=2")

    budget_note = (sp.detailed_steps, st.detailed_steps)
    return err_sp, err_st, err_ck, budget_note


def run() -> None:
    _lap()


def assert_simpoint(threshold_pct: float = 5.0) -> None:
    """CI simpoint tier: the fingerprint+cluster+checkpoint+fanout lap
    on the bursty reference workload must land within ``threshold_pct``
    of full detail — and the equal-budget fixed-stride plan must miss
    by more (otherwise the phase-detection machinery adds nothing)."""
    err_sp, err_st, err_ck, (b_sp, b_st) = _lap()
    print(f"simpoint-smoke [{STEPS} steps, window={WINDOW}]: "
          f"simpoint {err_sp:.2f}% / fanout {err_ck:.2f}% vs "
          f"fixed-stride {err_st:.2f}% (budget {b_sp} vs {b_st} "
          f"detailed steps, threshold {threshold_pct:.1f}%)")
    if err_sp > threshold_pct:
        print(f"simpoint-smoke FAILED: SimPoint reconstruction off by "
              f"{err_sp:.2f}% (> {threshold_pct:.1f}%) — fingerprint "
              "clustering or window timing regressed", file=sys.stderr)
        raise SystemExit(1)
    if err_ck > threshold_pct:
        print(f"simpoint-smoke FAILED: checkpoint-fanout lap off by "
              f"{err_ck:.2f}% (> {threshold_pct:.1f}%) — region "
              "capture or restore re-timing regressed", file=sys.stderr)
        raise SystemExit(1)
    if err_st <= max(err_sp, err_ck):
        print(f"simpoint-smoke FAILED: fixed-stride ({err_st:.2f}%) "
              "did not miss the burst phase by more than SimPoint — "
              "the reference workload is no longer bursty enough to "
              "discriminate", file=sys.stderr)
        raise SystemExit(1)
    if b_st < b_sp:
        print(f"simpoint-smoke FAILED: the comparison is unfair — "
              f"fixed-stride ran {b_st} detailed steps vs SimPoint's "
              f"{b_sp} (must be >=)", file=sys.stderr)
        raise SystemExit(1)
    print("simpoint-smoke OK")


if __name__ == "__main__":
    if "--assert-simpoint" in sys.argv:
        assert_simpoint()
    else:
        run()
