"""Paper claim §1.3.1②: interchangeable fidelity models trade simulation
speed for detail.  One StepProgram under native / dryrun / desim."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_us
from repro.core.fidelity import (DesimBackend, DryRunBackend, NativeBackend,
                                 StepProgram)


def run() -> None:
    def step(w, x):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return x.sum()

    D = 256
    specs = (jax.ShapeDtypeStruct((D, D), jnp.float32),
             jax.ShapeDtypeStruct((64, D), jnp.float32))
    prog = StepProgram("fidelity_toy", step, specs)
    w = 0.01 * jnp.ones((D, D))
    x = jnp.ones((64, D))

    native = NativeBackend()
    native.run(prog, w, x)  # compile
    t_native = time_us(lambda: native.run(prog, w, x, iters=1), iters=3)
    emit("fidelity/native", t_native, "executes (gem5 KVM-mode analogue)")

    dr = DryRunBackend()
    rep = dr.run(prog)
    emit("fidelity/dryrun", rep.wall_s * 1e6,
         f"flops={rep.flops:.0f} (atomic-mode analogue)")

    ds = DesimBackend()
    t_desim = time_us(lambda: ds.run(prog, dryrun_report=rep), iters=3)
    rep2 = ds.run(prog, dryrun_report=rep)
    emit("fidelity/desim", t_desim,
         f"predicted_step_s={rep2.predicted_step_s:.3e} (detailed-mode)")
