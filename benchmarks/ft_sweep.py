"""Fault-tolerance DSE sweep: checkpoint interval x MTBF -> goodput
frontier, validated against the Young/Daly optimum-interval formula.

The gem5 use case applied to training reliability engineering: for
each MTBF setting, sweep the FT policy's checkpoint interval with
``TrainSim`` on the ``v5e_unreliable`` board and read off the goodput
frontier — the interval that best balances checkpoint overhead
(too-frequent saves) against rollback loss (too-rare saves).  The
classic first-order answer is Young's ``tau = sqrt(2 * delta * M)``
(Daly's refinement subtracts ``delta``); the sweep recovers it from
the discrete-event simulation within 25% at every MTBF, which is the
acceptance bar for the whole failure/recovery timing model.

Methodology: common random numbers — every interval is evaluated on
the *same* seeded failure schedules (the schedule does not depend on
the interval), so goodput differences across intervals are signal,
not sampling noise; per-(MTBF, interval) goodput is the mean over
``SEEDS`` schedules, and the optimum is the argmax refined by a
log-space parabolic fit through its neighbours.

``--fidelity {atomic,detailed}`` picks the timing model (default:
atomic — exact for TrainSim, whose injected ops are a single compute
chain, and far fewer engine events; this is what makes the big
interval x MTBF x seed grid cheap).  One cell is re-run detailed as a
spot-check row asserting goodput is fidelity-invariant.

Emits one row per cell plus a summary row per MTBF:
  ft_sweep/mtbf<M>/i<interval> , wall_us , goodput=...
  ft_sweep/mtbf<M>             , wall_us , tau_sim=.. young=.. ratio=..
"""

from __future__ import annotations

import math
import sys
import time

from benchmarks.common import emit, fidelity_from_argv
from repro.configs import get_config
from repro.sim import Simulator, TrainSim, TrainStepCost, v5e_unreliable
from repro.train.ft_policy import FTPolicy, daly_interval, young_interval

CFG = get_config("deepseek-67b")
PODS = 2
SEEDS = tuple(range(8))
MTBFS = (150.0, 400.0, 1000.0)      # mean attempts between pod failures
DELTA_STEPS = 2.0                   # checkpoint cost, in step times
GRID = tuple(1.25 ** k for k in range(-3, 4))   # around Young's tau
TOLERANCE = 0.25


def _cost(board) -> TrainStepCost:
    """A 7B-class training step on the board's chips; checkpoint and
    restore bytes are sized so the save costs ``DELTA_STEPS`` steps of
    HBM-roofline time (checkpoints go to slow persistent storage, not
    HBM — the byte count models the slower path)."""
    chip = board.machine.pod.chip
    chips = board.machine.num_chips
    base = TrainStepCost.from_params(7e9, tokens_per_batch=500_000,
                                     chips=chips)
    step_s = chip.compute_time_s(base.step_flops, base.step_bytes)
    ckpt_bytes = DELTA_STEPS * step_s * chip.hbm_bw * chip.hbm_efficiency
    return TrainStepCost(base.step_flops, base.step_bytes,
                         ckpt_bytes=ckpt_bytes,
                         restore_bytes=1.5 * ckpt_bytes)


def _run(mtbf: float, interval: int, seed: int, num_steps: int,
         fidelity: str = "atomic") -> float:
    board = v5e_unreliable(PODS, seed=seed,
                           horizon=int(1.5 * num_steps) + 100,
                           mtbf=mtbf, repair=(0, 0), nx=16, ny=16,
                           timing=fidelity)
    pol = FTPolicy(CFG, num_steps=num_steps, ckpt_interval=interval,
                   pods=PODS,
                   chips_per_pod=board.machine.pod.num_chips,
                   dead_after_misses=1)
    ts = TrainSim(cost=_cost(board), policy=pol,
                  schedule=board.failure_schedule)
    Simulator(board, ts).run_to_completion()
    return ts.summary()["goodput"]


def _refine(log_taus, goodputs, best: int) -> float:
    """Parabolic refinement of the argmax in log-interval space (the
    3-point vertex formula for unevenly spaced abscissae)."""
    if best in (0, len(goodputs) - 1):
        return math.exp(log_taus[best])
    x0, x1, x2 = log_taus[best - 1:best + 2]
    y0, y1, y2 = goodputs[best - 1:best + 2]
    num = (x1 - x0) ** 2 * (y1 - y2) - (x1 - x2) ** 2 * (y1 - y0)
    den = (x1 - x0) * (y1 - y2) - (x1 - x2) * (y1 - y0)
    if den == 0:
        return math.exp(x1)
    x_star = x1 - 0.5 * num / den
    lo, hi = min(x0, x2), max(x0, x2)
    return math.exp(min(max(x_star, lo), hi))   # clamp to the bracket


def run(fidelity: str = "atomic") -> None:
    if fidelity not in ("atomic", "detailed"):
        raise ValueError(f"--fidelity {fidelity!r}: atomic or detailed")
    if fidelity == "atomic":
        # detailed spot-check: the FT timing model must be
        # fidelity-invariant (TrainSim injects a pure compute chain)
        mtbf0, iv0, steps0 = MTBFS[0], 8, 1500
        t0 = time.perf_counter()
        g_d = _run(mtbf0, iv0, SEEDS[0], steps0, fidelity="detailed")
        g_a = _run(mtbf0, iv0, SEEDS[0], steps0, fidelity="atomic")
        emit(f"ft_sweep/detailed_check/mtbf{int(mtbf0)}/i{iv0}",
             (time.perf_counter() - t0) * 1e6,
             f"{'exact-match' if g_d == g_a else 'MISMATCH'} "
             f"goodput={g_a:.4f}")
        if g_d != g_a:
            raise RuntimeError(
                f"ft sweep: atomic goodput {g_a} != detailed {g_d} on "
                "the spot-check cell")
    for mtbf in MTBFS:
        num_steps = max(6000, int(10 * mtbf))
        tau_y = young_interval(DELTA_STEPS, mtbf)   # in step units
        intervals = sorted({max(2, int(round(tau_y * g))) for g in GRID})
        goodputs = []
        t_mtbf0 = time.perf_counter()
        for iv in intervals:
            t0 = time.perf_counter()
            g = sum(_run(mtbf, iv, s, num_steps, fidelity) for s in SEEDS) \
                / len(SEEDS)
            goodputs.append(g)
            emit(f"ft_sweep/mtbf{int(mtbf)}/i{iv}",
                 (time.perf_counter() - t0) * 1e6 / len(SEEDS),
                 f"goodput={g:.4f}")
        best = max(range(len(goodputs)), key=goodputs.__getitem__)
        tau_sim = _refine([math.log(iv) for iv in intervals], goodputs,
                          best)
        tau_d = daly_interval(DELTA_STEPS, mtbf)
        ratio = tau_sim / tau_y
        ok = abs(ratio - 1.0) <= TOLERANCE \
            or abs(tau_sim / tau_d - 1.0) <= TOLERANCE
        emit(f"ft_sweep/mtbf{int(mtbf)}",
             (time.perf_counter() - t_mtbf0) * 1e6,
             f"tau_sim={tau_sim:.1f} young={tau_y:.1f} "
             f"daly={tau_d:.1f} ratio={ratio:.2f} "
             f"{'ok' if ok else 'OUTSIDE 25%'}")


if __name__ == "__main__":
    run(fidelity_from_argv(sys.argv))
