"""Paper claim §2.8: elastic traces replay under DIFFERENT memory-system
parameters without re-running the expensive pipeline, at high accuracy.

g5x analogue: capture the HLO trace of a compiled step ONCE, then
replay under swept machine parameters (HBM bandwidth x2, ICI x2, ...)
in microseconds — versus re-lowering + recompiling each variant.  The
replay must track the closed-form roofline bound across the sweep
(accuracy metric; the paper reports 83-93%)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_us
from repro.core.desim.executor import TraceExecutor
from repro.core.desim.machine import ClusterModel
from repro.core.desim.trace import HloTrace
from repro.core.fidelity import DryRunBackend, StepProgram


def run() -> None:
    # a layered matmul step: memory- and compute-mixed
    L, B, D = 8, 128, 512

    def step(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    specs = (jax.ShapeDtypeStruct((L, D, D), jnp.float32),
             jax.ShapeDtypeStruct((B, D), jnp.float32))
    prog = StepProgram("elastic", step, specs)

    t0 = time.perf_counter()
    rep = DryRunBackend().run(prog)
    t_capture_us = (time.perf_counter() - t0) * 1e6
    trace = HloTrace.from_hlo_text(rep.detail["hlo"], name="elastic",
                                   total_flops=rep.flops or 0.0,
                                   total_bytes=rep.bytes_accessed or 0.0)
    emit("elastic/capture_once", t_capture_us,
         f"trace_ops={len(trace.ops)}")

    # replay across machine variants WITHOUT recompiling.  Per-device
    # semantics: a 1-chip machine with efficiency derates off so the
    # closed-form roofline bound is directly comparable.
    variants = []
    for hbm_mult in (0.5, 1.0, 2.0, 4.0):
        m = ClusterModel("m")
        m.pod._params["nx"] = 1
        m.pod._params["ny"] = 1
        m.pod.chip._params["hbm_bw"] = 819e9 * hbm_mult
        m.pod.chip._params["mxu_efficiency"] = 1.0
        m.pod.chip._params["hbm_efficiency"] = 1.0
        m.instantiate()
        variants.append((hbm_mult, m))

    def replay_all():
        return [TraceExecutor(m).execute(trace).makespan_s
                for _, m in variants]

    t_replay_us = time_us(replay_all, iters=3)
    times = replay_all()
    emit("elastic/replay_4_variants", t_replay_us,
         f"speedup_vs_recapture={4 * t_capture_us / t_replay_us:.0f}x")

    # accuracy: replay must track the analytic roofline bound per variant
    errs = []
    for (mult, m), t in zip(variants, times):
        rl = m.roofline_terms((rep.flops or 0.0), (rep.bytes_accessed or 0.0),
                              rep.collective_bytes or 0.0)
        bound = rl["bound_s"]
        if bound > 0:
            errs.append(abs(t - bound) / max(t, bound))
    acc = 100 * (1 - sum(errs) / len(errs))
    emit("elastic/accuracy_vs_roofline", 0.0,
         f"{acc:.1f}% (paper: 83-93% vs detailed model)")
