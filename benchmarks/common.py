"""Shared benchmark helpers: timing + CSV/JSON emission."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def rows_as_dict() -> Dict[str, Dict[str, object]]:
    """Machine-readable view of everything emitted so far (for
    ``benchmarks.run --json``)."""
    return {name: {"us_per_call": us, "derived": derived}
            for name, us, derived in ROWS}


def fmt_ms(x: float) -> str:
    """Render a seconds value as milliseconds — ``n/a`` for NaN (an
    empty percentile sketch: zero finished requests), never a fake
    0.00ms."""
    return "n/a" if x != x else f"{x * 1e3:.2f}ms"


def fidelity_from_argv(argv: List[str]) -> str:
    """Parse the sweeps' shared ``--fidelity {atomic,detailed}`` flag
    (default: atomic — the fast outer-sweep model)."""
    if "--fidelity" in argv:
        i = argv.index("--fidelity")
        if i + 1 >= len(argv):
            raise SystemExit("--fidelity needs a value: atomic | detailed")
        return argv[i + 1]
    return "atomic"


def time_us(fn: Callable, iters: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6
