"""Serving DSE sweep: slots x arrival rate x board -> goodput/SLO
frontier (the dynamic-workload counterpart of dse_sweep).

The gem5 use case applied to serving capacity planning: for each board
(a healthy serving slice and a degraded one) sweep KV-slot counts and
open-loop Poisson arrival rates, and read off the goodput/SLO frontier
— the highest load each configuration sustains before TTFT/latency
SLOs start failing.  Every cell replays the *same seeded request
stream* per rate, so rows are reproducible and comparable across
boards.

Emits one row per cell:
  serving_sweep/<board>/s<slots>/r<rate> , wall_us , goodput/p99-ttft/...
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.sim import (ServeSim, ServingCost, Simulator, poisson_requests,
                       v5e_degraded, v5e_serving)

SEED = 20
NUM_REQUESTS = 80
SLOTS = (4, 16)
RATES_RPS = (50.0, 200.0, 800.0)
SLO_TTFT_S = 0.05
SLO_LATENCY_S = 2.0

# a 70B-class model sharded over whatever the board offers
MODEL = dict(num_params=70e9, layers=80, d_model=8192)


def _boards():
    # >= 2 boards: a healthy 8x8 serving slice and a degraded full pod
    # (half HBM / half ICI) — the capacity-planning comparison
    return [("v5e_serving", lambda: v5e_serving(8, 8)),
            ("v5e_degraded", lambda: v5e_degraded(0.5, 0.5))]


def run() -> None:
    for bname, mk in _boards():
        for slots in SLOTS:
            for rate in RATES_RPS:
                board = mk()
                cost = ServingCost.from_params(
                    chips=board.machine.num_chips, **MODEL)
                reqs = poisson_requests(
                    NUM_REQUESTS, rate, seed=SEED,
                    prompt_len=(64, 512), decode_len=(16, 64))
                srv = ServeSim(cost=cost, requests=reqs, slots=slots,
                               seq_capacity=1024, slo_ttft_s=SLO_TTFT_S,
                               slo_latency_s=SLO_LATENCY_S)
                sim = Simulator(board, srv)
                t0 = time.perf_counter()
                sim.run_to_completion()
                wall_us = (time.perf_counter() - t0) * 1e6
                s = srv.summary()
                emit(f"serving_sweep/{bname}/s{slots}/r{int(rate)}",
                     wall_us,
                     f"goodput={s['goodput_rps']:.1f}rps "
                     f"thru={s['throughput_rps']:.1f}rps "
                     f"viol={int(s['slo_violations'])} "
                     f"p99_ttft={s['p99_ttft_s'] * 1e3:.2f}ms "
                     f"p99_lat={s['p99_latency_s'] * 1e3:.1f}ms "
                     f"batch={s['mean_batch']:.1f}")


if __name__ == "__main__":
    run()
