"""Serving DSE sweep: slots x arrival rate x board -> goodput/SLO
frontier (the dynamic-workload counterpart of dse_sweep).

The gem5 use case applied to serving capacity planning: for each board
(a healthy serving slice and a degraded one) sweep KV-slot counts and
open-loop Poisson arrival rates, and read off the goodput/SLO frontier
— the highest load each configuration sustains before TTFT/latency
SLOs start failing.  Every cell replays the *same seeded request
stream* per rate, so rows are reproducible and comparable across
boards.

``--fidelity {atomic,detailed}`` picks the timing model (default:
atomic — exact for serving, whose injected ops are per-pod compute, and
far fewer engine events).  One cell is re-run detailed as a spot-check
row asserting the goodput frontier is fidelity-invariant.

Emits one row per cell:
  serving_sweep/<board>/s<slots>/r<rate> , wall_us , goodput/p99-ttft/...
"""

from __future__ import annotations

import sys
import time

from benchmarks.common import emit, fidelity_from_argv, fmt_ms
from repro.sim import (ServeSim, ServingCost, Simulator, poisson_requests,
                       v5e_degraded, v5e_serving)

SEED = 20
NUM_REQUESTS = 80
SLOTS = (4, 16)
RATES_RPS = (50.0, 200.0, 800.0)
SLO_TTFT_S = 0.05
SLO_LATENCY_S = 2.0

# a 70B-class model sharded over whatever the board offers
MODEL = dict(num_params=70e9, layers=80, d_model=8192)


def _boards():
    # >= 2 boards: a healthy 8x8 serving slice and a degraded full pod
    # (half HBM / half ICI) — the capacity-planning comparison
    return [("v5e_serving", lambda: v5e_serving(8, 8)),
            ("v5e_degraded", lambda: v5e_degraded(0.5, 0.5))]


def _cell(mk, slots: int, rate: float, timing: str):
    board = mk()
    cost = ServingCost.from_params(chips=board.machine.num_chips, **MODEL)
    reqs = poisson_requests(NUM_REQUESTS, rate, seed=SEED,
                            prompt_len=(64, 512), decode_len=(16, 64))
    srv = ServeSim(cost=cost, requests=reqs, slots=slots,
                   seq_capacity=1024, slo_ttft_s=SLO_TTFT_S,
                   slo_latency_s=SLO_LATENCY_S)
    sim = Simulator(board, srv, timing=timing)
    t0 = time.perf_counter()
    sim.run_to_completion()
    return (time.perf_counter() - t0) * 1e6, srv.summary()


def run(fidelity: str = "atomic") -> None:
    if fidelity not in ("atomic", "detailed"):
        raise ValueError(f"--fidelity {fidelity!r}: atomic or detailed")
    first = None
    for bname, mk in _boards():
        for slots in SLOTS:
            for rate in RATES_RPS:
                wall_us, s = _cell(mk, slots, rate, fidelity)
                if first is None:
                    first = (mk, slots, rate)
                emit(f"serving_sweep/{bname}/s{slots}/r{int(rate)}",
                     wall_us,
                     f"goodput={s['goodput_rps']:.1f}rps "
                     f"thru={s['throughput_rps']:.1f}rps "
                     f"viol={int(s['slo_violations'])} "
                     f"p99_ttft={fmt_ms(s['p99_ttft_s'])} "
                     f"p99_lat={fmt_ms(s['p99_latency_s'])} "
                     f"batch={s['mean_batch']:.1f}")
    if fidelity == "atomic" and first is not None:
        # detailed spot-check: serving timing must be fidelity-exact
        # (re-run the atomic cell warm so the speedup column compares
        # like with like — the sweep's first cell paid the cold start)
        mk, slots, rate = first
        wall_a, s_a = _cell(mk, slots, rate, "atomic")
        wall_d, s_d = _cell(mk, slots, rate, "detailed")
        ok = s_d == s_a
        emit(f"serving_sweep/detailed_check/s{slots}/r{int(rate)}",
             wall_d,
             f"{'exact-match' if ok else 'MISMATCH'} "
             f"atomic_wall={wall_a:.0f}us "
             f"speedup={wall_d / max(wall_a, 1e-9):.1f}x")
        if not ok:
            raise RuntimeError(
                "serving sweep: atomic and detailed summaries diverged "
                f"on the spot-check cell: {s_a} vs {s_d}")


if __name__ == "__main__":
    run(fidelity_from_argv(sys.argv))
