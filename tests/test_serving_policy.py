"""The headline serving test: the real ``repro.serve.server`` slot
scheduler and the DES ``ServeSim`` make *identical* scheduling
decisions (admission order, slot assignment, finish order) on the same
request stream — because both drive the same pure
``repro.serve.policy.SlotScheduler``.  Plus unit coverage of the
policy's state machine itself."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import BatchServer, Request
from repro.serve.policy import Decision, SlotScheduler
from repro.sim import (ServeRequest, ServeSim, ServingCost, Simulator,
                       v5e_serving)


# ---------------------------------------------------------------------------
# policy unit tests
# ---------------------------------------------------------------------------

def _drive(sched: SlotScheduler, max_iters: int = 200) -> None:
    """Run the engine contract loop to completion (no eos)."""
    for _ in range(max_iters):
        if sched.idle():
            return
        sched.fill()
        sched.note_step()
        for slot in sched.active_slots():
            sched.complete_token(slot)
    raise AssertionError("policy did not converge")


def test_fifo_admission_lowest_slot_first():
    s = SlotScheduler(num_slots=2, seq_capacity=32)
    for rid in range(4):
        s.submit(rid, prompt_len=4, max_new_tokens=3)
    assert s.fill() == [(0, 0), (1, 1)]     # FIFO into ascending slots
    # nothing free: fill is a no-op
    assert s.fill() == []
    # finish slot 1 -> next fill admits rid 2 there
    s.note_step()
    s.complete_token(1)                     # not finished (needs 3 tokens)
    s.note_step()
    fin = s.complete_token(1)
    assert fin is not None and fin.reason == "max_tokens"
    assert s.fill() == [(1, 2)]


def test_finish_reasons_and_token_accounting():
    s = SlotScheduler(num_slots=1, seq_capacity=8)
    # capacity: prompt 5 in cap 8 -> context hits cap-1 after 2 decodes
    s.submit(0, prompt_len=5, max_new_tokens=100)
    s.fill()
    s.note_step()
    assert s.complete_token(0) is None
    s.note_step()
    d = s.complete_token(0)
    assert d.reason == "capacity"
    assert s.requests[0].tokens_out == 3    # prefill token + 2 decodes
    # eos beats capacity when flagged earlier
    s.submit(1, prompt_len=2, max_new_tokens=100)
    s.fill()
    s.note_step()
    d = s.complete_token(0, is_eos=True)
    assert d.reason == "eos"
    # max_tokens wins over a simultaneous eos (the server's check order)
    s.submit(2, prompt_len=2, max_new_tokens=2)
    s.fill()
    s.note_step()
    d = s.complete_token(0, is_eos=True)
    assert d.reason == "max_tokens"


def test_policy_validation():
    s = SlotScheduler(num_slots=2, seq_capacity=8)
    s.submit(0, prompt_len=3, max_new_tokens=4)
    with pytest.raises(ValueError, match="duplicate"):
        s.submit(0, prompt_len=3, max_new_tokens=4)
    with pytest.raises(ValueError, match="fit"):
        s.submit(1, prompt_len=8, max_new_tokens=4)
    with pytest.raises(ValueError, match="not active"):
        s.complete_token(0)


def test_policy_state_dict_round_trip():
    s = SlotScheduler(num_slots=2, seq_capacity=32)
    for rid in range(5):
        s.submit(rid, prompt_len=3 + rid, max_new_tokens=4)
    s.fill()
    s.note_step()
    s.complete_token(0)
    import json
    state = json.loads(json.dumps(s.state_dict()))   # through JSON
    s2 = SlotScheduler(num_slots=2, seq_capacity=32)
    s2.load_state_dict(state)
    assert s2.decisions == s.decisions
    assert list(s2.queue) == list(s.queue)
    assert s2.active == s.active
    _drive(s)
    _drive(s2)
    assert s2.decisions == s.decisions


# ---------------------------------------------------------------------------
# the real server vs the DES (the acceptance criterion)
# ---------------------------------------------------------------------------

class ToyModel:
    """Minimal ``Model`` duck-type: deterministic next-token logits and
    a tiny cache, so ``BatchServer``'s jitted steps compile in
    milliseconds.  Scheduling never depends on token *values* (no eos
    in the stream), so any model exercises the same decisions."""

    def prefill(self, params, batch, sharder=None, chunk=2048,
                seq_capacity=0):
        toks = batch["tokens"]
        cache = jnp.zeros((toks.shape[0], seq_capacity, 4), jnp.bfloat16)
        logits = jax.nn.one_hot((toks[:, -1:] + 1) % 32, 32) * 10.0
        return logits, cache

    def decode(self, params, batch, cache, cur_len, sharder=None):
        logits = jax.nn.one_hot((batch["tokens"] + 1) % 32, 32) * 10.0
        return logits, cache

    def init_cache(self, batch, seq_len, dtype=jnp.bfloat16):
        return jnp.zeros((batch, seq_len, 4), dtype)


def _request_stream(seed: int, n: int, cap: int):
    rng = np.random.RandomState(seed)
    prompts = [np.arange(1, 1 + rng.randint(2, min(cap - 2, 9)),
                         dtype=np.int32) for _ in range(n)]
    max_new = [int(rng.randint(2, 10)) for _ in range(n)]
    return prompts, max_new


@pytest.mark.parametrize("seed,slots,cap", [(11, 3, 16), (5, 2, 8),
                                            (99, 4, 32)])
def test_des_matches_real_server_decisions(seed, slots, cap):
    prompts, max_new = _request_stream(seed, 14, cap)

    # the real continuous-batching server (jax inference loop)
    srv = BatchServer(model=ToyModel(), params={}, slots=slots,
                      seq_capacity=cap)
    srv.instantiate()
    done = srv.serve([Request(rid=i, prompt=p, max_new_tokens=m)
                      for i, (p, m) in enumerate(zip(prompts, max_new))])
    assert len(done) == len(prompts)
    real = srv.scheduler.decisions

    # the DES serving simulation of the same stream (all arrive at t=0,
    # like the server's pre-queued batch)
    reqs = [ServeRequest(rid=i, prompt_len=len(p), decode_len=m)
            for i, (p, m) in enumerate(zip(prompts, max_new))]
    ssim = ServeSim(cost=ServingCost.from_params(1e9, layers=4, d_model=128,
                                                 chips=16),
                    requests=reqs, slots=slots, seq_capacity=cap)
    Simulator(v5e_serving(4, 4), ssim).run_to_completion()
    des = ssim.schedulers[0].decisions

    assert real == des                      # the whole point of the PR
    admits = [d for d in real if d.kind == "admit"]
    finishes = [d for d in real if d.kind == "finish"]
    assert len(admits) == len(finishes) == len(prompts)


def test_des_decisions_invariant_to_hardware_speed():
    """Scheduling decisions are policy, not timing: a 10x slower board
    produces the same decision log (only the timestamps move)."""
    prompts, max_new = _request_stream(42, 10, 16)
    reqs = [ServeRequest(rid=i, prompt_len=len(p), decode_len=m)
            for i, (p, m) in enumerate(zip(prompts, max_new))]
    logs = []
    for hbm in (819e9, 81.9e9):
        ssim = ServeSim(cost=ServingCost.from_params(1e9, layers=4,
                                                     d_model=128, chips=16),
                        requests=reqs, slots=3, seq_capacity=16)
        Simulator(v5e_serving(4, 4, chip={"hbm_bw": hbm}),
                  ssim).run_to_completion()
        logs.append(ssim.schedulers[0].decisions)
    assert logs[0] == logs[1]


def test_server_output_tokens_match_policy_counts():
    """The refactored server's generated-token counts agree with the
    policy's accounting (prefill token + one per decode step)."""
    prompts, max_new = _request_stream(7, 6, 16)
    srv = BatchServer(model=ToyModel(), params={}, slots=2, seq_capacity=16)
    srv.instantiate()
    done = srv.serve([Request(rid=i, prompt=p, max_new_tokens=m)
                      for i, (p, m) in enumerate(zip(prompts, max_new))])
    for req in done:
        assert len(req.output) == srv.scheduler.requests[req.rid].tokens_out
