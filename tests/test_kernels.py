"""Pallas kernel validation (interpret=True) against pure-jnp oracles,
sweeping shapes and dtypes per the assignment."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_mlp.ops import expert_mlp
from repro.kernels.moe_mlp.ref import expert_mlp_ref
from repro.kernels.quantize.ops import quantize
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref
from repro.kernels.rwkv6_wkv.ops import wkv6
from repro.kernels.rwkv6_wkv.ref import wkv6_ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("b,s,h,d,win,bq,bk", [
    (2, 256, 4, 64, 0, 128, 128),
    (1, 512, 2, 128, 0, 128, 128),
    (2, 256, 4, 64, 128, 64, 64),
    (1, 128, 8, 32, 0, 64, 32),
    (3, 192, 2, 64, 0, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, s, h, d, win, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, h, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, h, d)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, window=win,
                          block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True, window=win)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("b,s,h,n,chunk", [
    (2, 128, 2, 64, 64),
    (1, 256, 4, 32, 32),
    (2, 64, 1, 16, 16),
    (1, 96, 2, 32, 32),
])
def test_wkv6(b, s, h, n, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (b, s, h, n), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, n), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, n), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, n)) - 1.0)
    u = 0.5 * jax.random.normal(ks[4], (h, n), jnp.float32)
    y = wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    yr, _ = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)


def test_wkv6_strong_decay_numerics():
    """Strong decay (w -> 0) must not overflow: the pairwise-difference
    formulation keeps every exponent <= 0."""
    b, s, h, n = 1, 128, 1, 32
    ks = jax.random.split(KEY, 4)
    r = jax.random.normal(ks[0], (b, s, h, n), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, n), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, n), jnp.float32)
    w = jnp.full((b, s, h, n), 1e-3, jnp.float32)       # aggressive decay
    u = jnp.zeros((h, n), jnp.float32)
    y = wkv6(r, k, v, w, u, chunk=64, interpret=True)
    yr, _ = wkv6_ref(r, k, v, w, u)
    assert np.isfinite(np.asarray(y)).all()
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("g,e,c,d,f,bc,bf", [
    (2, 4, 128, 64, 256, 64, 128),
    (1, 2, 64, 128, 512, 64, 256),
    (2, 2, 128, 32, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_mlp(g, e, c, d, f, bc, bf, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (g, e, c, d)).astype(dtype)
    wi = (jax.random.normal(ks[1], (e, d, f)) / np.sqrt(d)).astype(dtype)
    wg = (jax.random.normal(ks[2], (e, d, f)) / np.sqrt(d)).astype(dtype)
    wo = (jax.random.normal(ks[3], (e, f, d)) / np.sqrt(f)).astype(dtype)
    out = expert_mlp(x, wi, wg, wo, block_c=bc, block_f=bf, interpret=True)
    ref = expert_mlp_ref(x.astype(jnp.float32), wi.astype(jnp.float32),
                         wg.astype(jnp.float32), wo.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("n", [256, 1000, 4096, 65536])
def test_quantize(n):
    x = jax.random.normal(KEY, (n,), jnp.float32) * 3.0
    q, s, pad = quantize(x, block=256, interpret=True)
    blocks = jnp.pad(x, (0, pad)).reshape(-1, 256)
    qr, sr = quantize_ref(blocks)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    # quantization error bounded by scale/2 per element
    deq = dequantize_ref(q, s)
    err = np.abs(np.asarray(deq) - np.asarray(blocks))
    assert (err <= np.asarray(s)[:, None] / 2 + 1e-7).all()
