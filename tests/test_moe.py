"""MoE dispatch invariants: exactness vs dense reference when nothing
drops, gate normalization, capacity-drop behavior."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import get_config, smoke
from repro.models import moe
from repro.models.common import unzip

KEY = jax.random.PRNGKey(11)


def dense_moe_ref(params, x, cfg):
    """Compute EVERY expert for every token, weight by top-k gates —
    exact reference (no capacity)."""
    logits = jnp.einsum("gtd,de->gte", x, params["router"]
                        ).astype(jnp.float32)
    gates, eidx = moe.route_topk(logits, cfg.top_k)
    h = jnp.einsum("gtd,edf->gtef", x, params["wi"])
    u = jnp.einsum("gtd,edf->gtef", x, params["wg"])
    h = jax.nn.silu(h) * u
    out_all = jnp.einsum("gtef,efd->gted", h, params["wo"])
    onehot = jax.nn.one_hot(eidx, cfg.n_experts, dtype=x.dtype)  # (g,t,k,e)
    w = jnp.einsum("gtke,gtk->gte", onehot, gates.astype(x.dtype))
    return jnp.einsum("gte,gted->gtd", w, out_all)


def make(cfg_name="olmoe-1b-7b", cf=8.0):
    cfg = replace(smoke(get_config(cfg_name)), capacity_factor=cf)
    p_marked = moe.init_moe(KEY, cfg)
    params, _ = unzip(p_marked)
    return cfg, params


def test_exact_when_capacity_large():
    cfg, params = make(cf=8.0)      # capacity >> needed: dropless
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y, aux = moe.apply_moe(params, x, cfg)
    y_ref = dense_moe_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_gates_renormalized():
    logits = jax.random.normal(KEY, (3, 7, 8), jnp.float32)
    gates, idx = moe.route_topk(logits, 2)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)),
                               np.ones((3, 7)), rtol=1e-5)
    assert int(idx.max()) < 8


def test_capacity_drop_is_partial_output():
    """With tiny capacity some tokens drop: output is a gated SUBSET of
    the dense reference (never garbage)."""
    cfg, params = make(cf=0.25)
    x = jax.random.normal(KEY, (1, 32, cfg.d_model), jnp.float32)
    y, _ = moe.apply_moe(params, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    # dropped-token rows are exactly zero or partial; norm never exceeds
    # the dropless reference by more than numerics
    y_ref = dense_moe_ref(params, x, cfg)
    n = np.linalg.norm(np.asarray(y), axis=-1)
    nr = np.linalg.norm(np.asarray(y_ref), axis=-1) + 1e-4
    assert (n <= nr * 1.05).all()


def test_aux_loss_balanced_routing_lower():
    """Uniform router logits minimize the load-balance loss (= 1)."""
    E = 8
    probs_uniform = jnp.full((4, 64, E), 1.0 / E)
    idx = jnp.tile(jnp.arange(E)[None, None, :2], (4, 64, 1))
    # uniform f and P -> loss == E * sum(1/E * 1/E) * ... == 1
    idx_balanced = jnp.stack(
        [jnp.arange(64) % E, (jnp.arange(64) + 1) % E], -1)[None].repeat(
            4, axis=0)
    l_bal = moe.load_balance_loss(probs_uniform, idx_balanced, E)
    probs_skewed = jnp.zeros((4, 64, E)).at[..., 0].set(1.0)
    idx_skewed = jnp.zeros((4, 64, 2), jnp.int32)
    l_skew = moe.load_balance_loss(probs_skewed, idx_skewed, E)
    assert float(l_bal) < float(l_skew)


def test_decode_single_token():
    cfg, params = make(cf=2.0)
    x = jax.random.normal(KEY, (4, 1, cfg.d_model), jnp.float32)
    y, _ = moe.apply_moe(params, x, cfg)
    y_ref = dense_moe_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
