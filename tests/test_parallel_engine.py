"""ParallelEngine exactness: multiprocess pod sharding must be
*bit-identical* to the serial TraceExecutor (dist-gem5's correctness
bar, paper §2.17 — quantum-based synchronization must not change
simulated behaviour, only wall clock).

Enforced here:

* full :class:`ExecResult` equality (makespan, per-chip busy, timeline,
  stats tree, event counts) on homogeneous and straggler boards,
* free-run mode (no cross-pod DCN traffic) equality,
* ``mp_context="spawn"`` equality (the fork-unsafe path),
* drained snapshots JSON-identical to serial — including one taken
  **mid-rendezvous** (a DCN collective with some pods arrived, some
  not),
* worker-count-agnostic checkpoints: a snapshot taken under N workers
  restores under M workers (N→1, 1→N, N→N) with identical results,
* the ``Simulator`` front-end's ``workers=`` knob: same exit events
  (work markers), same result,
* serial fallbacks: configurations the parallel plan can't shard run
  through the exact-by-construction serial facade.

A restored run legitimately differs from a never-paused run in ONE
field: ``ExecResult.events`` counts one extra re-issue event per
deferred frontier op (see ``TraceExecutor.restore``).  Restore tests
therefore compare restored-vs-restored in full, and restored-vs-
uninterrupted on every field except ``events``.
"""

import dataclasses
import json

import pytest

from repro.core.desim.executor import TraceExecutor
from repro.core.desim.parallel import ParallelEngine
from repro.core.desim.trace import analytic_trace
from repro.sim import (ExitEventType, Simulator, checkpoint_executor,
                       parallel_supported, restore_executor, run_parallel,
                       v5e_multipod, v5e_straggler)

# a drain at this tick lands INSIDE the tail DCN all-reduce's rendezvous
# on the straggler board below: pods 0-2 have arrived, the 2x-slow pod 3
# has not (asserted in the checkpoint test, so a cost-model change that
# moves the window fails loudly instead of silently degrading the test)
MID_RENDEZVOUS_TICK = 125_000_000


def _trace(dcn=True):
    tail = ([{"kind": "all-reduce", "bytes": 5e8, "scope": "dcn"}]
            if dcn else [])
    return analytic_trace(
        "t", layers=6, layer_flops=2e12, layer_bytes=1e10,
        layer_collectives=[{"kind": "all-reduce", "bytes": 2e8}],
        tail_collectives=tail)


def _board():
    return v5e_multipod(num_pods=4, nx=4, ny=4)


def _straggler_board():
    return v5e_straggler(num_pods=4, slowdown=2.0, nx=4, ny=4)


def _cfg(board):
    return dict(algorithm=board.algorithm,
                straggler_slowdowns=board.straggler_slowdowns,
                record_stats=True, timing="detailed")


def _assert_equal_sans_events(got, ref):
    for f in dataclasses.fields(ref):
        if f.name == "events":
            continue
        assert getattr(got, f.name) == getattr(ref, f.name), f.name


@pytest.fixture(scope="module")
def serial_ref():
    return _board().executor(record_stats=True).execute(_trace())


@pytest.fixture(scope="module")
def serial_straggler_ref():
    return _straggler_board().executor(record_stats=True).execute(_trace())


# ---------------------------------------------------------------------------
# bit-identity, complete runs
# ---------------------------------------------------------------------------

def test_parallel_identical_homogeneous(serial_ref):
    got = run_parallel(_board(), _trace(), workers=2, record_stats=True)
    assert got == serial_ref            # full ExecResult, stats included


def test_parallel_identical_straggler(serial_straggler_ref):
    # heterogeneous pods, uneven shard (4 pods across 3 workers)
    got = run_parallel(_straggler_board(), _trace(), workers=3,
                       record_stats=True)
    assert got == serial_straggler_ref


def test_parallel_free_run_identical():
    # no DCN ops -> workers free-run to completion with no barriers
    board = _board()
    ref = board.executor(record_stats=True).execute(_trace(dcn=False))
    eng = ParallelEngine(board.machine, workers=4, **_cfg(board))
    assert eng._parallel_plan(_trace(dcn=False), None) == "free"
    try:
        assert eng.execute(_trace(dcn=False)) == ref
    finally:
        eng.close()


def test_spawn_context_identical(serial_ref):
    got = run_parallel(_board(), _trace(), workers=2, mp_context="spawn",
                       record_stats=True)
    assert got == serial_ref


# ---------------------------------------------------------------------------
# checkpoints: mid-rendezvous + worker-count changes
# ---------------------------------------------------------------------------

def _paused_snapshot(engine_or_ex):
    engine_or_ex.advance(max_tick=MID_RENDEZVOUS_TICK)
    engine_or_ex.drain()
    return engine_or_ex.snapshot()


def test_mid_rendezvous_snapshot_identical():
    board = _straggler_board()
    es = TraceExecutor(board.machine, **_cfg(board))
    es.begin(_trace())
    ssnap = _paused_snapshot(es)
    # the scenario guard: the pause really is mid-rendezvous
    assert ssnap["rendezvous"], "drain tick no longer lands mid-rendezvous"
    arrived = {p for p, _ in ssnap["rendezvous"][0]["arrivals"]}
    assert 0 < len(arrived) < board.machine.num_pods

    ep = ParallelEngine(board.machine, workers=3, **_cfg(board))
    ep.begin(_trace())
    psnap = _paused_snapshot(ep)
    ep.close()
    assert (json.dumps(psnap, sort_keys=True)
            == json.dumps(ssnap, sort_keys=True))


def test_worker_count_change_restore(serial_straggler_ref):
    board = _straggler_board()
    cfg = _cfg(board)
    ep = ParallelEngine(board.machine, workers=4, **cfg)
    ep.begin(_trace())
    snap = _paused_snapshot(ep)
    ep.close()
    assert snap["rendezvous"]

    # 4 -> 1: the parallel snapshot restores into a plain serial executor
    r1 = TraceExecutor(board.machine, **cfg).restore(_trace(), snap)
    r1.advance()
    res1 = r1.result()
    # 4 -> 3: and into a differently-sharded parallel engine
    e3 = ParallelEngine(board.machine, workers=3, **cfg).restore(
        _trace(), snap)
    e3.advance()
    res3 = e3.result()
    e3.close()

    assert res1 == res3                 # restored runs: full equality
    _assert_equal_sans_events(res1, serial_straggler_ref)


def test_serial_snapshot_restores_under_workers(serial_straggler_ref):
    board = _straggler_board()
    cfg = _cfg(board)
    es = TraceExecutor(board.machine, **cfg)
    es.begin(_trace())
    snap = _paused_snapshot(es)

    e4 = ParallelEngine(board.machine, workers=4, **cfg).restore(
        _trace(), snap)
    e4.advance()
    res4 = e4.result()
    e4.close()
    r1 = TraceExecutor(board.machine, **cfg).restore(_trace(), snap)
    r1.advance()

    assert res4 == r1.result()
    _assert_equal_sans_events(res4, serial_straggler_ref)


def test_checkpoint_document_roundtrip_across_worker_counts():
    """The full serialize-layer path: checkpoint a drained parallel
    engine via ``checkpoint_executor`` and restore via
    ``restore_executor(..., workers=N)``."""
    board = _board()
    eng = board.executor(workers=2, record_stats=True)
    eng.begin(_trace())
    eng.advance(max_tick=60_000_000)
    eng.drain()
    ckpt = checkpoint_executor(eng)
    eng.close()

    r1 = restore_executor(ckpt, machine=board.machine)
    r1.advance()
    r4 = restore_executor(ckpt, machine=board.machine, workers=4)
    r4.advance()
    assert r4.result() == r1.result()
    r4.close()


# ---------------------------------------------------------------------------
# Simulator front-end
# ---------------------------------------------------------------------------

def _run_simulator(workers):
    from repro.core.desim.trace import TraceOp
    tr = _trace()
    n = len(tr.ops)
    tr.ops.append(TraceOp(kind="compute", flops=1e9, bytes=1e6,
                          deps=(n - 1,), name="work_end_roi"))
    old = tr.ops[1]
    tr.ops[1] = TraceOp(kind=old.kind, flops=old.flops, bytes=old.bytes,
                        deps=old.deps, name="work_begin_roi")
    sim = Simulator(_board(), tr, record_stats=True, workers=workers)
    events = [(e.kind, e.tick, e.cause) for e in sim.run()]
    return events, sim.result(), sim.tick


def test_simulator_workers_knob_same_exit_events():
    ev1, res1, tick1 = _run_simulator(workers=1)
    ev4, res4, tick4 = _run_simulator(workers=4)
    assert ev1 == ev4                   # incl. WORK_BEGIN/WORK_END ticks
    assert res1 == res4
    assert tick1 == tick4
    kinds = [k for k, _, _ in ev4]
    assert ExitEventType.WORK_BEGIN in kinds
    assert ExitEventType.WORK_END in kinds


# ---------------------------------------------------------------------------
# serial fallbacks + helpers
# ---------------------------------------------------------------------------

def test_atomic_timing_with_dcn_falls_back_to_serial(serial_ref):
    board = _board()
    eng = ParallelEngine(board.machine, workers=2, algorithm=board.algorithm,
                         record_stats=True, timing="atomic")
    assert eng._parallel_plan(_trace(), None) is None
    ref = board.executor(record_stats=True, timing="atomic").execute(_trace())
    try:
        assert eng.execute(_trace()) == ref
    finally:
        eng.close()


def test_parallel_supported_helper():
    board = _board()
    assert parallel_supported(board, _trace(), timing="detailed")
    assert not parallel_supported(board, _trace(), timing="atomic")
    # atomic CAN shard when there is no cross-pod traffic to order
    assert parallel_supported(board, _trace(dcn=False), timing="atomic")


def test_single_pod_board_falls_back_to_serial():
    from repro.sim import v5e_pod
    board = v5e_pod()
    ref = board.executor(record_stats=True).execute(_trace(dcn=False))
    got = run_parallel(board, _trace(dcn=False), workers=4,
                       record_stats=True)
    assert got == ref


def test_close_is_idempotent():
    eng = ParallelEngine(_board().machine, workers=2,
                         algorithm="torus2d", timing="detailed")
    eng.begin(_trace())
    eng.advance()
    eng.result()
    eng.close()
    eng.close()


# ---------------------------------------------------------------------------
# mp-context selection (the JAX/os.fork RuntimeWarning fix)
# ---------------------------------------------------------------------------

def test_default_mp_context_is_spawn_under_jax():
    """With jax loaded (it always is in this suite — the kernels import
    it), forking is unsafe (XLA's threads deadlock in the child) and
    CPython warns on every os.fork().  The engine must therefore pick
    spawn on its own."""
    import sys
    import jax  # noqa: F401  (force it into sys.modules)
    from repro.core.desim.parallel import default_mp_context
    assert "jax" in sys.modules
    assert default_mp_context() == "spawn"


def test_run_parallel_emits_no_fork_runtimewarning(serial_ref):
    """Regression: ParallelEngine used to default to fork whenever the
    platform offered it, tripping CPython's multi-threaded-fork
    RuntimeWarning once per worker under JAX.  Escalate that warning to
    an error around a real parallel lap."""
    import warnings
    import jax  # noqa: F401
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        got = run_parallel(_board(), _trace(), workers=2,
                           record_stats=True)
    assert got == serial_ref
