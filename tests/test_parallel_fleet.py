"""Fleet-scale ParallelEngine: hierarchical collective sharding, the
batched barrier/lookahead protocol, warm worker-pool reuse, and
checkpoint format v2.

The exactness bar is unchanged from ``test_parallel_engine.py`` (full
ExecResult / snapshot equality with the serial engine); what is new
here is *what* must be exact:

* the ``hierarchical`` collective algorithm now runs sharded — a shard
  machine prices DCN phases off ``global_num_pods`` (the cost context
  ``ParallelEngine`` plants), so a worker holding 1 of N pods costs a
  cross-pod all-reduce identically to the full machine,
* the batched protocol's coordinator-local counters
  (``ParallelEngine.sync_counters()``): pipe traffic is O(workers) per
  barrier — not O(pods), not O(arrivals) — and lookahead elides the
  empty quanta between DCN rendezvous,
* worker processes stay warm across laps of one engine and die on
  ``close()``,
* checkpoints are stamped version 2 + ``parallel_protocol`` and v1
  documents still restore.
"""

import dataclasses
import json

import pytest

from repro.core.desim.collectives import HierarchicalAlgorithm
from repro.core.desim.executor import TraceExecutor
from repro.core.desim.machine import ClusterModel
from repro.core.desim.parallel import PARALLEL_PROTOCOL, ParallelEngine
from repro.core.desim.trace import analytic_trace
from repro.sim import (CheckpointError, checkpoint_executor,
                       restore_executor, run_parallel, v5e_multipod,
                       v5e_straggler)
from repro.sim.serialize import (CHECKPOINT_VERSION,
                                 SUPPORTED_CHECKPOINT_VERSIONS)

# a drain here lands INSIDE the tail DCN all-reduce's rendezvous on the
# hierarchical straggler config below: pods 0-2 arrived, the 2x-slow
# pod 3 has not (guard-asserted, so a cost-model change that moves the
# window fails loudly instead of silently degrading the test)
HIER_MID_RENDEZVOUS_TICK = 150_000_000


def _trace(dcn_tails=1):
    tails = [{"kind": "all-reduce", "bytes": 5e8 * (i + 1), "scope": "dcn"}
             for i in range(dcn_tails)]
    return analytic_trace(
        "t", layers=6, layer_flops=2e12, layer_bytes=1e10,
        layer_collectives=[{"kind": "all-reduce", "bytes": 2e8}],
        tail_collectives=tails)


def _hier_cfg(board):
    return dict(algorithm="hierarchical",
                straggler_slowdowns=board.straggler_slowdowns,
                record_stats=True, timing="detailed")


def _assert_equal_sans_events(got, ref):
    for f in dataclasses.fields(ref):
        if f.name == "events":
            continue
        assert getattr(got, f.name) == getattr(ref, f.name), f.name


# ---------------------------------------------------------------------------
# hierarchical collectives shard exactly
# ---------------------------------------------------------------------------

def test_hierarchical_shard_machine_costs_globally():
    """A 1-pod shard with ``global_num_pods=4`` prices a cross-pod
    collective identically to the real 4-pod machine (the unit fact
    the sharded run's bit-identity rests on)."""
    full = ClusterModel("full", num_pods=4)
    full.instantiate()
    shard = ClusterModel("shard", num_pods=1, global_num_pods=4)
    shard.instantiate()
    assert shard.total_pods == 4
    alg = HierarchicalAlgorithm()
    chips = full.num_chips
    for kind in ("all-reduce", "all-gather", "reduce-scatter"):
        pf = alg.phases(kind, 1e9, chips, full)
        ps = alg.phases(kind, 1e9, chips, shard)
        assert [(p.name, p.time_s, p.bytes_on_wire) for p in pf] \
            == [(p.name, p.time_s, p.bytes_on_wire) for p in ps]


def test_hierarchical_parallel_identical():
    board = v5e_multipod(num_pods=4, nx=4, ny=4)
    board.algorithm = "hierarchical"
    ref = board.executor(record_stats=True).execute(_trace())
    got = run_parallel(board, _trace(), workers=2, record_stats=True)
    assert got == ref                   # full ExecResult, stats included


def test_hierarchical_straggler_parallel_identical():
    board = v5e_straggler(num_pods=4, slowdown=2.0, nx=4, ny=4)
    cfg = _hier_cfg(board)
    ref = TraceExecutor(board.machine, **cfg).execute(_trace())
    eng = ParallelEngine(board.machine, workers=3, **cfg)
    try:
        assert eng.execute(_trace()) == ref
    finally:
        eng.close()


def test_hierarchical_mid_rendezvous_checkpoint_w4_to_w1():
    """The ISSUE's hardest case: a checkpoint taken at workers=4 in the
    middle of a hierarchical DCN rendezvous restores at workers=1."""
    board = v5e_straggler(num_pods=4, slowdown=2.0, nx=4, ny=4)
    cfg = _hier_cfg(board)
    ref = TraceExecutor(board.machine, **cfg).execute(_trace())

    # serial paused snapshot for the JSON-identity bar
    es = TraceExecutor(board.machine, **cfg)
    es.begin(_trace())
    es.advance(max_tick=HIER_MID_RENDEZVOUS_TICK)
    es.drain()
    ssnap = es.snapshot()
    assert ssnap["rendezvous"], \
        "drain tick no longer lands mid-rendezvous"
    arrived = {p for p, _ in ssnap["rendezvous"][0]["arrivals"]}
    assert 0 < len(arrived) < board.machine.num_pods

    eng = ParallelEngine(board.machine, workers=4, **cfg)
    eng.begin(_trace())
    eng.advance(max_tick=HIER_MID_RENDEZVOUS_TICK)
    eng.drain()
    ckpt = checkpoint_executor(eng)
    psnap = eng.snapshot()
    eng.close()
    assert (json.dumps(psnap, sort_keys=True)
            == json.dumps(ssnap, sort_keys=True))

    # workers=1: restores into a plain serial executor
    r1 = restore_executor(ckpt, machine=board.machine)
    assert isinstance(r1, TraceExecutor)
    r1.advance()
    # and back under workers=2 for the restored-vs-restored bar
    r2 = restore_executor(ckpt, machine=board.machine, workers=2)
    r2.advance()
    res2 = r2.result()
    r2.close()
    assert r1.result() == res2
    _assert_equal_sans_events(r1.result(), ref)


# ---------------------------------------------------------------------------
# batched protocol: counters
# ---------------------------------------------------------------------------

def test_sync_counters_message_and_barrier_bounds():
    """Pipe traffic is O(workers) per barrier and lookahead elides the
    quanta between rendezvous: with quantum 100us and a ~200ms
    makespan, ~2000 lockstep barriers collapse to a handful."""
    board = v5e_multipod(num_pods=8, nx=4, ny=4, quantum_ns=100_000)
    workers = 4
    eng = board.executor(workers=workers, record_stats=True)
    try:
        res = eng.execute(_trace(dcn_tails=3))
    finally:
        eng.close()
    c = eng.sync_counters()
    dcn_colls = int(res.stats["sim.dcn.collectives"])
    assert dcn_colls >= 3
    # barrier elision: bounded by the rendezvous count, not the quantum
    # count (the serial quantum walk here is makespan/quantum ~ 2000)
    assert 0 < c["barriers"] <= 2 * dcn_colls + 4
    assert c["quanta_elided"] > 10 * c["barriers"]
    assert c["lookahead_grants"] + c["alignment_barriers"] \
        == c["barriers"]
    # one command per worker per round trip, one reply each — and only
    # init + barriers + drain + collect round trips ever happen
    assert c["pipe_msgs_sent"] == c["pipe_msgs_recv"]
    assert c["pipe_msgs_sent"] <= (c["barriers"] + 3) * workers
    # arrival rows ride the barrier replies batched per clone class:
    # O(collectives x workers), strictly fewer than per-pod rows
    assert 0 < c["arrival_rows"] <= dcn_colls * workers
    assert c["arrival_rows"] < dcn_colls * board.machine.num_pods
    assert c["completion_rows"] == dcn_colls
    # the benchmark probe that rides along with the counters
    assert eng.phase_wall["spawn"] > 0
    assert eng.phase_wall["barrier_wait"] > 0


def test_counters_reset_per_lap():
    board = v5e_multipod(num_pods=4, nx=4, ny=4)
    eng = board.executor(workers=2, record_stats=True)
    try:
        eng.execute(_trace())
        first = eng.sync_counters()
        eng.execute(_trace())
        second = eng.sync_counters()
    finally:
        eng.close()
    assert first["barriers"] > 0
    # a fresh lap starts its counters from zero (not cumulative), and
    # the same trace takes the same schedule
    assert second["barriers"] == first["barriers"]
    assert second["arrival_rows"] == first["arrival_rows"]


# ---------------------------------------------------------------------------
# warm worker pool
# ---------------------------------------------------------------------------

def test_warm_pool_reuses_processes_across_laps():
    board = v5e_multipod(num_pods=4, nx=4, ny=4)
    ref = board.executor(record_stats=True).execute(_trace())
    eng = board.executor(workers=2, record_stats=True)
    try:
        res1 = eng.execute(_trace())
        procs1 = list(eng._procs)
        pids1 = [p.pid for p in procs1]
        res2 = eng.execute(_trace())
        pids2 = [p.pid for p in eng._procs]
    finally:
        eng.close()
    assert res1 == ref and res2 == ref
    assert pids1 == pids2               # same processes, not respawned
    # teardown: close() really ends them
    for p in procs1:
        p.join(timeout=10)
        assert not p.is_alive()


def test_worker_count_change_respawns_pool():
    board = v5e_straggler(num_pods=4, slowdown=2.0, nx=4, ny=4)
    cfg = _hier_cfg(board)
    ref = TraceExecutor(board.machine, **cfg).execute(_trace())
    eng = ParallelEngine(board.machine, workers=4, **cfg)
    try:
        assert eng.execute(_trace()) == ref
        eng.workers = 2                 # next lap shards differently
        assert eng.execute(_trace()) == ref
        assert len(eng._procs) == 2
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# checkpoint format v2
# ---------------------------------------------------------------------------

def test_checkpoint_v2_header_and_v1_compat():
    board = v5e_multipod(num_pods=4, nx=4, ny=4)
    eng = board.executor(workers=2, record_stats=True)
    eng.begin(_trace())
    eng.advance(max_tick=HIER_MID_RENDEZVOUS_TICK)
    eng.drain()
    ckpt = checkpoint_executor(eng)
    eng.close()

    assert CHECKPOINT_VERSION == 2
    assert ckpt["version"] == 2
    assert ckpt["parallel_protocol"] == PARALLEL_PROTOCOL

    ref = restore_executor(ckpt, machine=board.machine)
    ref.advance()

    # a v1 document (no parallel_protocol key) still restores
    v1 = dict(ckpt)
    v1["version"] = 1
    del v1["parallel_protocol"]
    assert 1 in SUPPORTED_CHECKPOINT_VERSIONS
    r1 = restore_executor(v1, machine=board.machine)
    r1.advance()
    assert r1.result() == ref.result()

    with pytest.raises(CheckpointError, match="version"):
        restore_executor(dict(ckpt, version=999))
