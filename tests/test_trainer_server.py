"""End-to-end system behaviour: training (loss decreases, failure
recovery) and continuous-batching serving (matches single-request
decoding)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.configs.base import ShapeConfig
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.serve import BatchServer, Request

# end-to-end trainer/server loops jit-compile real (reduced) models;
# tools/ci.sh skips them for the fast tier-1 loop
pytestmark = pytest.mark.slow
from repro.train import TrainOptions, build_train_step, init_train_state
from repro.train.trainer import SimulatedFailure, Trainer


@pytest.fixture(scope="module")
def trained():
    cfg = smoke(get_config("stablelm-1.6b"))
    shape = ShapeConfig("smoke", 32, 4, "train")
    model = build_model(cfg)
    opts = TrainOptions(peak_lr=1e-2, warmup=5, total_steps=60, chunk=16)
    state = init_train_state(model, jax.random.PRNGKey(0), opts)
    step = build_train_step(model, opts)
    pipe = SyntheticPipeline(cfg, shape, seed=3)
    return cfg, model, opts, state, step, pipe


def test_training_decreases_loss_and_recovers(trained, tmp_path):
    cfg, model, opts, state, step, pipe = trained
    # the trainer's jitted step donates its state: hand it a copy so the
    # module-scoped fixture's buffers stay alive for the serving test
    state = jax.tree.map(jnp.copy, state)
    tr = Trainer(model=model, train_step=step, pipeline=pipe, state=state,
                 ckpt_dir=os.path.join(str(tmp_path), "ckpt"),
                 ckpt_interval=10,
                 heartbeat_path=os.path.join(str(tmp_path), "hb.json"))
    tr.instantiate()
    res = tr.run(25, fail_at={13: SimulatedFailure("node died")})
    losses = [h["loss"] for h in res["history"]]
    assert losses[-1] < losses[0]
    assert res["final_step"] == 25
    assert tr.s_failures.value() == 1
    assert tr.heartbeat.alive(max_age=300)
    # stats exported through the SimObject tree
    assert tr.stats.flat()["trainer.steps"] >= 25


def test_server_matches_sequential_decode(trained):
    cfg, model, opts, state, step, pipe = trained
    params = state["params"]
    srv = BatchServer(model=model, params=params, slots=2, seq_capacity=32)
    srv.instantiate()
    prompts = [np.asarray([1, 2, 3, 4]), np.asarray([9, 8, 7]),
               np.asarray([5, 5])]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    done = srv.serve(reqs)
    assert len(done) == 3

    # sequential greedy reference for each request
    for req in done:
        logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, seq_capacity=32))(
                params, {"tokens": jnp.asarray(req.prompt[None])})
        toks = [int(jnp.argmax(logits[0, -1].astype(jnp.float32)))]
        cur = len(req.prompt)
        for _ in range(4):
            logits, cache = jax.jit(
                lambda p, t, c, cl: model.decode(p, {"tokens": t}, c, cl))(
                    params, jnp.asarray([[toks[-1]]]), cache,
                    jnp.asarray(cur, jnp.int32))
            toks.append(int(jnp.argmax(logits[0, -1].astype(jnp.float32))))
            cur += 1
        assert req.output == toks, (req.rid, req.output, toks)


def test_pipeline_determinism(trained):
    cfg, model, opts, state, step, pipe = trained
    b1 = pipe.batch(12)
    b2 = pipe.batch(12)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    b3 = pipe.batch(13)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
