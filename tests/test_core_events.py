"""Deterministic event engine + dist-gem5 quantum sync (paper §1.3.1,
§2.17)."""

import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core.events import EventQueue, QuantumSync, SimExit


def test_priority_then_insertion_order():
    q = EventQueue()
    order = []
    q.schedule(lambda: order.append("b"), 10)
    q.schedule(lambda: order.append("a"), 10, priority=-1)
    q.schedule(lambda: order.append("c"), 10)
    q.run()
    assert order == ["a", "b", "c"]


def test_cannot_schedule_in_past():
    q = EventQueue()
    q.schedule(lambda: None, 5)
    q.run()
    with pytest.raises(ValueError):
        q.schedule(lambda: None, 1)


def test_squash():
    q = EventQueue()
    fired = []
    ev = q.schedule(lambda: fired.append(1), 5)
    ev.squash()
    q.run()
    assert fired == [] and not ev.scheduled()


def test_sim_exit():
    q = EventQueue()

    def boom():
        raise SimExit("checkpoint")
    q.schedule(boom, 3)
    q.schedule(lambda: None, 10)
    assert q.run() == "checkpoint"
    assert q.now == 3


@given(st.lists(st.tuples(st.integers(0, 1000), st.integers(-5, 5)),
                min_size=1, max_size=50))
@settings(max_examples=30, deadline=None)
def test_determinism_property(events):
    """Two queues fed identical schedules fire in identical order."""
    def run_once():
        q = EventQueue()
        log = []
        for i, (t, p) in enumerate(events):
            q.schedule(lambda i=i: log.append(i), t, priority=p)
        q.run()
        return log
    assert run_once() == run_once()


def test_quantum_sync_barriers_and_delivery():
    qa, qb = EventQueue("a"), EventQueue("b")
    sync = QuantumSync([qa, qb], quantum=100)
    got = []
    # message sent at t=10 with latency 50 -> delivered at boundary 100
    sync.send(10, qb, lambda: got.append(qb.now), latency=50)
    sync.run(max_tick=500)
    assert sync.barriers == 5
    assert got and got[0] >= 100 and got[0] % 100 == 0


@given(st.integers(1, 10), st.integers(1, 400))
@settings(max_examples=25, deadline=None)
def test_quantum_sync_never_delivers_early(quantum_mult, latency):
    """Cross-queue messages arrive at a quantum boundary >= send+latency."""
    quantum = 50 * quantum_mult
    qa, qb = EventQueue(), EventQueue()
    sync = QuantumSync([qa, qb], quantum=quantum)
    got = []
    sync.send(25, qb, lambda: got.append(qb.now), latency=latency)
    sync.run(max_tick=quantum * 20 + latency + 100)
    assert got
    t = got[0]
    assert t >= 25 + min(latency, quantum)
    assert t % quantum == 0
