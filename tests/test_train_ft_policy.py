"""The headline fault-tolerance test: the real ``Trainer`` FT stack
(jitted steps, CheckpointManager writes/restores on disk) and the DES
``TrainSim`` make *identical* recovery decisions (checkpoint cadence,
pod-death declarations, elastic reshards, restore targets) on the same
seeded failure schedule — because both drive the same pure
``repro.train.ft_policy.FTPolicy``.  Plus unit coverage of the policy
state machine and the TrainSim mid-recovery checkpoint identity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.desim.simnodes import TICKS_PER_S
from repro.sim import (ExitEventType, Simulator, TrainSim, TrainStepCost,
                       v5e_unreliable)
from repro.train.ft_policy import (FailureEvent, FailureSchedule, FTPolicy,
                                   checkpoint_due, daly_interval,
                                   young_interval)
from repro.train.trainer import Trainer

CFG = get_config("deepseek-67b")
PODS, CHIPS_PER_POD = 4, 16


def _policy(num_steps=60, ckpt_interval=10, **kw):
    return FTPolicy(CFG, num_steps=num_steps, ckpt_interval=ckpt_interval,
                    pods=PODS, chips_per_pod=CHIPS_PER_POD, **kw)


def _schedule(seed, horizon=200):
    return FailureSchedule.generate(
        seed=seed, horizon=horizon, pods=PODS, mtbf=40.0,
        straggler_mtbs=60.0, preemption_mtbs=150.0, repair=(10, 40))


# ---------------------------------------------------------------------------
# policy unit tests
# ---------------------------------------------------------------------------

def _drive(policy, schedule):
    policy.start()
    plans = []
    while not policy.done():
        plans.append(policy.execute_step(
            schedule.events_at(policy.attempt)))
    return plans


def test_cadence_and_final_checkpoint():
    pol = _policy(num_steps=25, ckpt_interval=10)
    plans = _drive(pol, FailureSchedule((), pods=PODS))
    saves = [p.post_save for p in plans if p.post_save is not None]
    assert saves == [10, 20, 25]            # cadence + final state
    assert all(p.kind == "step" for p in plans)
    assert checkpoint_due(10, 10) and not checkpoint_due(5, 10)
    assert not checkpoint_due(0, 10)        # the initial save is start()


def test_failure_stall_declare_restore():
    sched = FailureSchedule(
        (FailureEvent(5, "pod_failed", pod=2, repair=10),), pods=PODS)
    pol = _policy(num_steps=20, ckpt_interval=4, dead_after_misses=3)
    plans = _drive(pol, sched)
    kinds = [p.kind for p in plans]
    # attempt 5 + 6 stall (misses 1, 2); attempt 7 declares and recovers
    assert kinds[5] == kinds[6] == "stall"
    assert plans[7].kind == "recover" and plans[7].restore_to == 4
    assert plans[7].lost_steps == 1         # step 4 done, step 5 lost
    dead = [d for d in pol.decisions if d.kind == "pod_dead"]
    assert [d.pod for d in dead] == [2]
    # the mesh shrank to the 3 surviving pods, then grew back on repair
    reshards = [d for d in pol.decisions if d.kind == "reshard"]
    assert len(reshards) == 2
    assert reshards[0].chips < reshards[1].chips
    assert pol.step == 20 and pol.done()


def test_preemption_saves_before_losing_the_pod():
    sched = FailureSchedule(
        (FailureEvent(3, "preemption", pod=1, repair=8),), pods=PODS)
    pol = _policy(num_steps=12, ckpt_interval=100)
    plans = _drive(pol, sched)
    assert plans[3].pre_save == 3           # notice -> proactive save
    assert all(p.kind != "recover" for p in plans)   # no work lost
    kinds = [d.kind for d in pol.decisions if d.attempt == 3]
    assert kinds == ["preempt", "checkpoint", "pod_dead", "reshard"]


def test_straggler_slows_but_does_not_roll_back():
    sched = FailureSchedule(
        (FailureEvent(2, "straggler", pod=0, slowdown=3.0, duration=4),),
        pods=PODS)
    pol = _policy(num_steps=10, ckpt_interval=100)
    plans = _drive(pol, sched)
    assert [p.slowdown for p in plans[2:6]] == [3.0] * 4
    assert plans[6].slowdown == 1.0
    assert all(p.kind == "step" for p in plans)


def test_straggler_does_not_outlive_its_pod():
    """Regression: a straggler slowdown is a property of the slow
    hardware — when that pod dies and is replaced, the replacement
    must not inherit the slowdown."""
    sched = FailureSchedule(
        (FailureEvent(2, "straggler", pod=0, slowdown=3.0, duration=8),
         FailureEvent(3, "pod_failed", pod=0, repair=0)), pods=PODS)
    pol = _policy(num_steps=12, ckpt_interval=100, dead_after_misses=1)
    plans = _drive(pol, sched)
    assert plans[2].slowdown == 3.0          # straggling while alive
    assert plans[3].kind == "recover"        # replaced immediately
    assert all(p.slowdown == 1.0 for p in plans[4:])


def test_policy_state_dict_round_trip():
    import json
    sched = _schedule(9)
    pol = _policy()
    pol.start()
    for _ in range(25):
        pol.execute_step(sched.events_at(pol.attempt))
    state = json.loads(json.dumps(pol.state_dict()))
    pol2 = _policy()
    pol2.start()
    pol2.load_state_dict(state)
    while not pol.done():
        pol.execute_step(sched.events_at(pol.attempt))
        pol2.execute_step(sched.events_at(pol2.attempt))
    assert pol2.decisions == pol.decisions


def test_schedule_seeded_and_indexed():
    a, b = _schedule(1), _schedule(1)
    assert a.events == b.events
    assert a.events != _schedule(2).events
    by_hand = [ev for ev in a.events if ev.attempt == a.events[0].attempt]
    assert list(a.events_at(a.events[0].attempt)) == by_hand


def test_young_daly_formulas():
    assert young_interval(10.0, 2000.0) == pytest.approx(200.0)
    assert daly_interval(10.0, 2000.0) == pytest.approx(190.0)


# ---------------------------------------------------------------------------
# the real trainer vs the DES (the acceptance criterion)
# ---------------------------------------------------------------------------

class _TinyPipeline:
    """Duck-typed pipeline: deterministic per-step batches, no config."""

    def batch(self, step):
        return {"x": np.full((4,), float(step % 7), np.float32)}


def _tiny_train_step(state, batch):
    params = state["params"] * 0.9 + 0.01 * jnp.sum(batch["x"])
    return ({"params": params, "step": state["step"] + 1},
            {"loss": jnp.sum(params ** 2)})


def _tiny_state():
    return {"params": jnp.ones((4,), jnp.float32),
            "step": jnp.asarray(0, jnp.int32)}


def _train_cost():
    return TrainStepCost.from_params(
        1e9, tokens_per_batch=100_000, chips=PODS * CHIPS_PER_POD)


@pytest.mark.parametrize("seed", [7, 21, 1234])
def test_trainer_and_trainsim_decide_identically(seed, tmp_path):
    sched = _schedule(seed)

    # the real FT stack: jitted steps + on-disk checkpoint/restore
    tr = Trainer(model=None, train_step=_tiny_train_step,
                 pipeline=_TinyPipeline(), state=_tiny_state(),
                 ckpt_dir=str(tmp_path / f"ckpt{seed}"))
    tr.instantiate()
    real_pol = _policy()
    res = tr.run_ft(sched, real_pol)
    assert res["final_step"] == 60          # it really recovered

    # the DES co-simulation of the same schedule
    board = v5e_unreliable(PODS, seed=0, mtbf=0.0, nx=4, ny=4)
    sim_pol = _policy()
    ts = TrainSim(cost=_train_cost(), policy=sim_pol, schedule=sched)
    Simulator(board, ts).run_to_completion()

    assert real_pol.decisions == sim_pol.decisions   # the whole point
    assert res["decisions"] == sim_pol.decisions


def test_trainer_restores_through_real_checkpoints(tmp_path):
    """The decisions drive *real* restores: after a rollback the state
    really rewinds (history shows re-run steps) and ends at num_steps."""
    sched = FailureSchedule(
        (FailureEvent(15, "pod_failed", pod=1, repair=0),), pods=PODS)
    tr = Trainer(model=None, train_step=_tiny_train_step,
                 pipeline=_TinyPipeline(), state=_tiny_state(),
                 ckpt_dir=str(tmp_path))
    tr.instantiate()
    pol = _policy(num_steps=30, ckpt_interval=10)
    res = tr.run_ft(sched, pol)
    steps_run = [h["step"] for h in res["history"]]
    assert steps_run.count(14) == 2         # step 14 ran, was lost, re-ran
    assert res["final_step"] == 30
    assert tr.s_failures.value() == 1 and tr.s_stalls.value() >= 1
    # the run ends with a checkpoint of the final state on disk
    assert tr.ckpt.latest_step() == 30


def test_trainsim_exit_events_and_goodput():
    board = v5e_unreliable(PODS, seed=11, horizon=200, mtbf=50.0,
                           repair=(10, 30), nx=4, ny=4)
    pol = _policy()
    ts = TrainSim(cost=_train_cost(), policy=pol,
                  schedule=board.failure_schedule)
    sim = Simulator(board, ts)
    kinds = [ev.kind for ev in sim.run()]
    assert ExitEventType.POD_FAILED in kinds
    assert ExitEventType.RESHARD in kinds
    assert kinds[-1] is ExitEventType.DONE
    s = ts.summary()
    assert 0.0 < s["goodput"] < 1.0         # faults cost, but it finished
    assert s["restores"] == ts.s_failures.value() >= 1


def test_pod_failed_exit_fires_at_the_failure_not_at_the_end():
    """Exit events are reactive hooks: a POD_FAILED must yield while
    the run is still in flight (so the driver can checkpoint, stop, or
    rescope), not be batched up until DONE."""
    board = v5e_unreliable(PODS, seed=11, horizon=200, mtbf=50.0,
                           repair=(10, 30), nx=4, ny=4)
    pol = _policy()
    ts = TrainSim(cost=_train_cost(), policy=pol,
                  schedule=board.failure_schedule)
    sim = Simulator(board, ts)
    for ev in sim.run():
        if ev.kind is ExitEventType.POD_FAILED:
            break
    assert not pol.done()                   # the run is still in flight
    first_dead = next(d for d in pol.decisions if d.kind == "pod_dead")
    assert pol.attempt <= first_dead.attempt + 2    # and near the fault
    # mid-run goodput is a real fraction, not scaled to the full plan
    assert 0.0 < ts.goodput() <= 1.0 + 1e-9


def test_trainsim_rejects_checkpoint_from_different_schedule():
    """Same event COUNT but a different seed must still be refused —
    the digest, not just the length, guards the restore."""
    def mk(seed):
        board = v5e_unreliable(PODS, seed=seed, horizon=200, mtbf=50.0,
                               repair=(10, 30), nx=4, ny=4)
        pol = _policy()
        return board, TrainSim(cost=_train_cost(), policy=pol,
                               schedule=board.failure_schedule)

    board, ts = mk(11)
    sim = Simulator(board, ts)
    ckpt = sim.save_checkpoint()
    # find another seed with the same number of events
    n = len(board.failure_schedule.events)
    other = None
    for seed in range(100, 200):
        b2, t2 = mk(seed)
        if len(b2.failure_schedule.events) == n \
                and b2.failure_schedule.events \
                != board.failure_schedule.events:
            other = t2
            break
    assert other is not None
    with pytest.raises(Exception, match="different failure schedule"):
        Simulator.from_checkpoint(ckpt, workload=other)


@pytest.mark.parametrize("frac", [0.35, 0.6, 0.85])
def test_trainsim_checkpoint_restores_bit_identically(frac, tmp_path):
    """A TrainSim checkpoint — including one taken mid-failure-recovery
    — restores bit-identically: final tick, stats tree, decision log."""
    def build():
        board = v5e_unreliable(PODS, seed=5, horizon=300, mtbf=35.0,
                               straggler_mtbs=80.0, repair=(10, 40),
                               nx=4, ny=4)
        pol = _policy(num_steps=80, ckpt_interval=10)
        ts = TrainSim(cost=_train_cost(), policy=pol,
                      schedule=board.failure_schedule)
        return board, ts

    board, ref = build()
    res_ref = Simulator(board, ref).run_to_completion()
    assert ref.s_failures.value() >= 2      # the schedule really bites

    board2, ts2 = build()
    sim2 = Simulator(board2, ts2, checkpoint_dir=str(tmp_path))
    tick = int(res_ref.makespan_s * TICKS_PER_S * frac)
    sim2.schedule_checkpoint(tick)
    path = None
    for ev in sim2.run():
        if ev.kind is ExitEventType.CHECKPOINT:
            path = ev.payload["path"]
            break
    assert path is not None

    board3, fresh = build()
    sim3 = Simulator.from_checkpoint(path, workload=fresh)
    res3 = sim3.run_to_completion()
    assert res3.makespan_s == res_ref.makespan_s      # identical final tick
    assert res3.stats == res_ref.stats                # identical stats tree
    assert fresh.policy.decisions == ref.policy.decisions
    assert fresh.stats.state_dict() == ref.stats.state_dict()


def test_trainsim_checkpoint_rejects_wrong_workload(tmp_path):
    from repro.sim import CheckpointError, ServeRequest, ServeSim, ServingCost
    board = v5e_unreliable(2, seed=1, mtbf=0.0, nx=4, ny=4)
    pol = FTPolicy(CFG, num_steps=5, ckpt_interval=5, pods=2,
                   chips_per_pod=16)
    ts = TrainSim(cost=_train_cost(), policy=pol,
                  schedule=board.failure_schedule)
    sim = Simulator(board, ts)
    ckpt = sim.save_checkpoint()
    other = ServeSim(cost=ServingCost.from_params(1e9, layers=4,
                                                  d_model=128),
                     requests=[ServeRequest(0, 8, 4)])
    with pytest.raises(CheckpointError, match="TrainSim"):
        Simulator.from_checkpoint(ckpt, workload=other)
