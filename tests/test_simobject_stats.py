"""SimObject param system + hierarchical stats (paper §1.3, §2.21.1)."""

import pytest

from repro.core.ports import Port, PortError, PortSet
from repro.core.simobject import Param, ParamError, SimObject
from repro.core.stats import StatGroup, TimeSeries


class Cache(SimObject):
    size_kb = Param(int, 32, "size", check=lambda v: v > 0)
    policy = Param(str, "lru", choices=("lru", "fifo"))


class Core(SimObject):
    width = Param(int, 4)


def test_param_defaults_and_coercion():
    c = Cache(size_kb="64")
    assert c.size_kb == 64 and c.policy == "lru"


def test_param_validation():
    with pytest.raises(ParamError):
        Cache(size_kb=-1)
    with pytest.raises(ParamError):
        Cache(policy="rand")
    with pytest.raises(ParamError):
        Cache(bogus=1)


def test_param_coercion_failure_is_param_error():
    with pytest.raises(ParamError, match="size_kb"):
        Cache(size_kb="not-a-number")
    # post-construction assignment goes through the same coercion/check
    c = Cache()
    with pytest.raises(ParamError, match="failed validation"):
        c.size_kb = 0
    with pytest.raises(ParamError, match="not in"):
        c.policy = "mru"
    # and a failed set leaves the old value intact
    assert c.size_kb == 32 and c.policy == "lru"


def test_hierarchy_paths_and_freeze():
    sys_ = SimObject("system")
    sys_.core = Core()
    sys_.core.l1 = Cache(size_kb=64)
    assert sys_.find("core.l1").size_kb == 64
    assert sys_.core.l1.path == "system.core.l1"
    sys_.instantiate()
    with pytest.raises(ParamError):
        sys_.core.width = 8


def test_find_missing_path_reports_where_it_failed():
    sys_ = SimObject("system")
    sys_.core = Core()
    sys_.core.l1 = Cache()
    with pytest.raises(KeyError, match="no child 'l2'"):
        sys_.find("core.l2")
    # the error names the resolved prefix and the full path being found
    with pytest.raises(KeyError, match=r"under 'system\.core'"):
        sys_.find("core.l2.tags")
    with pytest.raises(KeyError, match="children:.*'core'"):
        sys_.find("gpu")


def test_serialize_round_trip_params_stats_children():
    """Satellite of the repro.sim checkpoint work: the SimObject tree
    (params + nested children) and the stats tree (accumulator state)
    both round-trip through plain dicts."""
    sys_ = SimObject("system")
    sys_.core = Core(width=8)
    sys_.core.l1 = Cache(size_kb=128, policy="fifo")
    ipc = sys_.core.stats.scalar("ipc")
    lat = sys_.core.l1.stats.distribution("lat")
    ipc.set(1.75)
    for v in (1.0, 2.0, 5.0):
        lat.sample(v)
    sys_.instantiate()

    blob = sys_.serialize()
    assert blob["children"]["core"]["params"]["width"] == 8
    assert blob["children"]["core"]["children"]["l1"]["class"] == "Cache"

    # rebuild an equivalent (unfrozen) tree and apply
    sys2 = SimObject("system")
    sys2.core = Core()
    sys2.core.l1 = Cache()
    st2 = sys2.core.stats.scalar("ipc")
    lat2 = sys2.core.l1.stats.distribution("lat")
    sys2.load_serialized(blob)
    sys2.instantiate()
    assert sys2.core.width == 8
    assert sys2.core.l1.size_kb == 128 and sys2.core.l1.policy == "fifo"

    sys2.stats.load_state_dict(sys_.stats.state_dict())
    assert st2.value() == 1.75
    assert lat2.value() == lat.value()          # count/mean/stddev/min/max
    # continuing to stream into the restored distribution matches
    lat.sample(9.0)
    lat2.sample(9.0)
    assert lat2.value() == lat.value()

    # unknown params/children are rejected in strict mode, skipped else
    with pytest.raises(ParamError):
        sys2.core.load_serialized({"params": {"bogus": 1}})
    sys2.core.load_serialized({"params": {"bogus": 1}}, strict=False)


def test_stats_tree_and_subtree_dump():
    sys_ = SimObject("system")
    sys_.core = Core()
    s = sys_.core.stats.scalar("ipc", "instr per cycle")
    s.set(1.5)
    sys_.instantiate()
    flat = sys_.stats.flat()
    assert flat["system.core.ipc"] == 1.5
    # subtree dump (gem5: "dump statistics for a subset of the graph")
    assert sys_.core.stats.flat() == {"core.ipc": 1.5}


def test_distribution_and_formula():
    g = StatGroup("g")
    d = g.distribution("lat")
    for v in (1.0, 2.0, 3.0):
        d.sample(v)
    assert d.mean == pytest.approx(2.0)
    n = g.scalar("n")
    n.set(4)
    f = g.formula("half", lambda: n.value() / 2)
    assert f.value() == 2.0
    g.reset()
    assert d.count == 0


def test_timeseries():
    g = StatGroup("g")
    s = g.scalar("x")
    ts = TimeSeries(g)
    for t in range(3):
        s.set(t * 10)
        ts.sample(float(t))
    assert ts.column("g.x") == [0, 10, 20]


def test_ports_protocol_and_roles():
    a, b = object(), object()
    pa = PortSet(a).requestor("mem", "pkt")
    pb = PortSet(b).responder("cpu_side", "pkt", handler=lambda p: p + 1)
    pa.connect(pb)
    assert pa.send(41) == 42
    with pytest.raises(PortError):
        Port(a, "x", "pkt", "requestor").connect(
            Port(b, "y", "other", "responder"))
    with pytest.raises(PortError):
        Port(a, "x", "pkt", "requestor").connect(
            Port(b, "y", "pkt", "requestor"))
