"""Observability layer (PR 7): gem5 DebugFlags/DPRINTF, m5out-style
output dirs, Perfetto trace export, host telemetry — and, above all,
the house rule that tracing *observes, never perturbs*: every test that
turns instrumentation on asserts bit-identity with the bare run
(results, stats trees, scheduler/policy decision logs, serial and
workers=4)."""

import io
import json
import os

import pytest

from repro.core import trace as dbg
from repro.core.desim.trace import analytic_trace
from repro.sim import (ExitEventType, ServingCost, ServeSim, Simulator,
                       TrainSim, TrainStepCost, poisson_requests,
                       repeat_trace, v5e_serving, v5e_straggler,
                       v5e_unreliable, validate_trace_events)
from repro.sim.instrument import OutDir, format_host_banner
from repro.configs import get_config
from repro.train.ft_policy import FailureSchedule, FTPolicy

COLLS = [{"kind": "all-reduce", "bytes": 1e8, "participants": 64}]
DCN_TAIL = [{"kind": "all-gather", "bytes": 5e7, "participants": 128,
             "scope": "dcn"}]


@pytest.fixture(autouse=True)
def _clean_debug_state():
    """Debug flags are process-global: leave no test's flags behind."""
    yield
    dbg.disable()
    dbg.set_output(None)


def _board():
    return v5e_straggler(num_pods=2, nx=4, ny=4)


def _trace(steps=4):
    return repeat_trace(
        analytic_trace("obs", 3, 1e12, 1e9, COLLS,
                       tail_collectives=DCN_TAIL), steps)


def _fingerprint(sim):
    res = sim.result()
    return (res.makespan_s, res.events, sim._ex.sim_root.stats.flat())


# ---------------------------------------------------------------------------
# debug flags + DPRINTF
# ---------------------------------------------------------------------------

def test_flag_catalog_and_hierarchy():
    cat = dbg.flags()
    assert {"Exec", "Chip", "Wire", "Wire.Contention", "Dcn", "Quantum",
            "Ckpt", "Sim", "Parallel"} <= set(cat)
    dbg.enable("Wire")                      # parent implies dotted child
    assert dbg.enabled("Wire") and dbg.enabled("Wire.Contention")
    dbg.disable()
    dbg.enable("Wire.Contention")           # child does NOT imply parent
    assert dbg.enabled("Wire.Contention") and not dbg.enabled("Wire")
    dbg.disable()
    dbg.enable("All")
    assert dbg.enabled("Exec") and dbg.enabled("Dcn")


def test_unknown_flag_raises_with_catalog():
    with pytest.raises(ValueError, match="Exec"):
        dbg.enable("NoSuchFlag")


def test_env_selection():
    got = dbg.init_from_env({"G5X_DEBUG_FLAGS": "Exec, Dcn"})
    assert set(got) == {"Exec", "Dcn"}
    assert dbg.enabled("Exec") and not dbg.enabled("Wire")
    dbg.disable()
    assert dbg.init_from_env({}) == []      # no env var: nothing enabled


def test_dprintf_format_and_sink():
    buf = io.StringIO()
    dbg.set_output(buf)
    dbg.enable("Exec")

    class Obj:
        name = "pod0.chip"
    dbg.dprintf("Exec", Obj(), "issue op=%d kind=%s", 3, "compute",
                tick=1234)
    assert buf.getvalue() == "      1234: pod0.chip: issue op=3 kind=compute\n"
    buf.truncate(0), buf.seek(0)
    dbg.dprintf("Exec", None, "bare", tick=0)
    assert buf.getvalue() == "         0: -: bare\n"


def test_dprintf_disabled_never_formats():
    class Exploding:
        def __repr__(self):
            raise AssertionError("formatted while disabled")
        __str__ = __repr__

    buf = io.StringIO()
    dbg.set_output(buf)
    dbg.dprintf("Exec", None, "boom %s", Exploding(), tick=1)  # no flags on
    dbg.enable("Dcn")                                          # wrong flag
    dbg.dprintf("Exec", None, "boom %s", Exploding(), tick=1)
    assert buf.getvalue() == ""


def test_counting_mode_counts_suppressed_calls():
    with dbg.counting():
        dbg.dprintf("Exec", None, "a")
        dbg.dprintf("Dcn", None, "b")
        assert dbg.suppressed_calls() == 2
    assert not dbg._ACTIVE                  # counting mode fully unwinds


def test_flag_context_restores_previous_set():
    dbg.enable("Sim")
    with dbg.flag_context("Exec,Dcn"):
        assert dbg.enabled("Exec") and dbg.enabled("Sim")
    assert dbg.enabled("Sim") and not dbg.enabled("Exec")


# ---------------------------------------------------------------------------
# the house rule: tracing observes, never perturbs
# ---------------------------------------------------------------------------

def test_full_instrumentation_is_bit_identical_serial(tmp_path):
    bare = Simulator(_board(), _trace())
    bare.run_to_completion()

    dbg.enable("All")
    dbg.set_output(io.StringIO())
    sim = Simulator(_board(), _trace(), outdir=str(tmp_path),
                    trace_events=True)
    sim.schedule_stat_dump(5_000_000)       # periodic dumps every 5ms
    sim.run_to_completion()

    assert _fingerprint(sim) == _fingerprint(bare)
    assert sim.outdir.dumps > 1             # periodic + final really fired


def test_full_instrumentation_is_bit_identical_workers4(tmp_path):
    board = v5e_straggler(num_pods=4, nx=4, ny=4)
    bare = Simulator(board, _trace())
    bare.run_to_completion()

    dbg.enable("All")
    dbg.set_output(io.StringIO())
    sim = Simulator(board, _trace(), workers=4, outdir=str(tmp_path),
                    trace_events=True)
    sim.run_to_completion()
    assert _fingerprint(sim) == _fingerprint(bare)


def test_servesim_decisions_unperturbed(tmp_path):
    reqs = poisson_requests(30, 300.0, seed=4, decode_len=(4, 16))
    cost = ServingCost.from_params(7e9, layers=32, d_model=4096, chips=64)

    def lap(**kw):
        srv = ServeSim(cost=cost, requests=reqs, slots=4,
                       seq_capacity=1024)
        sim = Simulator(v5e_serving(8, 8), srv, **kw)
        sim.run_to_completion()
        return srv, sim

    s0, sim0 = lap()
    dbg.enable("All")
    dbg.set_output(io.StringIO())
    s1, sim1 = lap(outdir=str(tmp_path), trace_events=True)
    assert s1.schedulers[0].decisions == s0.schedulers[0].decisions
    assert s1.summary() == s0.summary()
    assert sim1.result().makespan_s == sim0.result().makespan_s


def test_trainsim_decisions_unperturbed(tmp_path):
    pods, chips = 4, 16
    sched = FailureSchedule.generate(seed=7, horizon=100, pods=pods,
                                     mtbf=40.0, straggler_mtbs=60.0,
                                     preemption_mtbs=150.0,
                                     repair=(10, 40))
    cost = TrainStepCost.from_params(1e9, tokens_per_batch=100_000,
                                     chips=pods * chips)

    def lap(**kw):
        pol = FTPolicy(get_config("deepseek-67b"), num_steps=30,
                       ckpt_interval=10, pods=pods, chips_per_pod=chips)
        ts = TrainSim(cost=cost, policy=pol, schedule=sched)
        sim = Simulator(v5e_unreliable(pods, seed=0, mtbf=0.0,
                                       nx=4, ny=4), ts, **kw)
        sim.run_to_completion()
        return pol, sim

    p0, sim0 = lap()
    dbg.enable("All")
    dbg.set_output(io.StringIO())
    p1, sim1 = lap(outdir=str(tmp_path), trace_events=True)
    assert p1.decisions == p0.decisions
    assert sim1.result().makespan_s == sim0.result().makespan_s


def test_no_stdout_with_flags_disabled(capsys):
    sim = Simulator(_board(), _trace())
    sim.run_to_completion()
    assert capsys.readouterr().out == ""    # nothing ad hoc on stdout


# ---------------------------------------------------------------------------
# m5out-style output dir
# ---------------------------------------------------------------------------

def test_outdir_layout_and_stats_sections(tmp_path):
    d = str(tmp_path / "m5out")
    sim = Simulator(_board(), _trace(), outdir=d, trace_events=True)
    sim.dump_stats(reason="warm")           # manual dump mid-stream
    sim.run_to_completion()                 # final dump + telemetry + trace

    assert sorted(os.listdir(d)) == ["config.json", "stats.txt",
                                     "telemetry.json", "trace.json"]
    text = open(os.path.join(d, "stats.txt")).read()
    assert text.count("Begin Simulation Statistics") == 2
    assert text.count("End Simulation Statistics") == 2
    assert "// final" in text
    assert "simTicks" in text and "simSeconds" in text

    cfg = json.load(open(os.path.join(d, "config.json")))
    assert cfg["board"]["name"].startswith("v5e")
    assert cfg["machine"]["class"] == "ClusterModel"
    assert cfg["machine"]["params"]["num_pods"] == 2
    assert cfg["workload"]["kind"] == "trace"
    assert "timing" in cfg["executor"]

    tel = json.load(open(os.path.join(d, "telemetry.json")))
    assert tel["final_tick"] == round(
        sim.result().makespan_s * 1_000_000_000)
    assert tel["events"] == sim.result().events
    assert tel["host_seconds"] > 0 and tel["sim_rate"] > 0
    assert "simSeconds" in format_host_banner(tel)
    assert "simRate" in format_host_banner(tel)


def test_periodic_stat_dump_exit_events_and_reset(tmp_path):
    d = str(tmp_path / "m5out")
    sim = Simulator(_board(), _trace(steps=6), outdir=d)
    sim.schedule_stat_dump(10_000_000, reset=True)
    kinds = [ev.kind for ev in sim.run()]
    assert ExitEventType.STAT_DUMP in kinds
    assert kinds[-1] == ExitEventType.DONE
    n_dumps = kinds.count(ExitEventType.STAT_DUMP)
    text = open(os.path.join(d, "stats.txt")).read()
    assert text.count("Begin Simulation Statistics") == n_dumps + 1
    # reset=True: later sections cover intervals, so per-pod op counts
    # in the final section are below the full-run total
    assert sim.outdir.dumps == n_dumps + 1


def test_reset_stats_zeroes_tree():
    sim = Simulator(_board(), _trace())
    sim.run_to_completion()
    flat = sim._ex.sim_root.stats.flat()
    assert any(v for v in flat.values() if isinstance(v, (int, float)) and v)
    sim.reset_stats()
    flat2 = sim._ex.sim_root.stats.flat()
    assert all(not v for v in flat2.values()
               if isinstance(v, (int, float)))


# ---------------------------------------------------------------------------
# exit banner + host telemetry
# ---------------------------------------------------------------------------

def test_exit_banner_behind_verbosity_knob(capsys):
    sim = Simulator(_board(), _trace())
    sim.run_to_completion(verbose=True)
    out = capsys.readouterr().out
    assert "Exiting @ tick" in out and "because workload complete" in out
    assert "simSeconds" in out and "simRate" in out   # gem5-style banner

    sim2 = Simulator(_board(), _trace())
    sim2.run_to_completion()                # default: silent
    assert capsys.readouterr().out == ""


def test_host_record_fields():
    sim = Simulator(_board(), _trace())
    sim.run_to_completion()
    rec = sim.host_record()
    assert set(rec) == {"final_tick", "sim_seconds", "host_seconds",
                        "sim_rate", "events", "events_per_host_sec"}
    assert rec["sim_seconds"] == pytest.approx(sim.result().makespan_s)
    assert rec["events"] == sim.result().events


# ---------------------------------------------------------------------------
# Perfetto / Chrome trace-event export
# ---------------------------------------------------------------------------

def test_trace_schema_and_tracks_serial(tmp_path):
    d = str(tmp_path / "m5out")
    sim = Simulator(_board(), _trace(), outdir=d, trace_events=True)
    sim.run_to_completion()
    doc = json.load(open(os.path.join(d, "trace.json")))
    assert validate_trace_events(doc) == []
    evs = doc["traceEvents"]
    tnames = {e["args"]["name"] for e in evs
              if e.get("ph") == "M" and e["name"] == "thread_name"}
    assert {"pod0/compute", "pod0/ici+dcn", "pod1/compute",
            "quantum barriers", "dcn transactions"} <= tnames
    assert any(e.get("ph") == "X" for e in evs)        # op slices
    assert any(e.get("ph") == "i" for e in evs)        # barrier instants
    assert any(e.get("ph") == "s" for e in evs)        # dcn flows


def test_trace_merges_worker_lanes(tmp_path):
    d = str(tmp_path / "m5out")
    board = v5e_straggler(num_pods=4, nx=4, ny=4)
    sim = Simulator(board, _trace(), workers=4, outdir=d,
                    trace_events=True)
    sim.run_to_completion()
    doc = json.load(open(os.path.join(d, "trace.json")))
    assert validate_trace_events(doc) == []
    pnames = {e["args"]["name"] for e in doc["traceEvents"]
              if e.get("ph") == "M" and e["name"] == "process_name"}
    assert {"worker0 (pods 0..0)", "worker3 (pods 3..3)",
            "coordinator (dcn + quantum)"} <= pnames
    # every pod shows up as a lane in some worker process
    tnames = {e["args"]["name"] for e in doc["traceEvents"]
              if e.get("ph") == "M" and e["name"] == "thread_name"}
    assert {f"pod{p}/compute" for p in range(4)} <= tnames
    # dcn rendezvous arrive on the coordinator's transaction track
    coord_x = [e for e in doc["traceEvents"]
               if e.get("ph") == "X" and e["pid"] == 2]
    assert coord_x and all(e["tid"] == 0 for e in coord_x)


def test_validate_trace_events_catches_malformed():
    bad = {"traceEvents": [{"ph": "X", "name": "op"}]}   # no ts/dur/pid/tid
    assert validate_trace_events(bad)
    good = {"traceEvents": [{"ph": "X", "name": "op", "ts": 0.0,
                             "dur": 1.0, "pid": 1, "tid": 1}]}
    assert validate_trace_events(good) == []


def test_write_trace_requires_recorder(tmp_path):
    sim = Simulator(_board(), _trace())
    sim.run_to_completion()
    with pytest.raises(RuntimeError, match="trace_events"):
        sim.write_trace(str(tmp_path / "t.json"))


def test_outdir_constant_names():
    assert (OutDir.STATS, OutDir.CONFIG, OutDir.TELEMETRY, OutDir.TRACE) \
        == ("stats.txt", "config.json", "telemetry.json", "trace.json")
