"""Sharding-rule unit tests on a mock production mesh (no multi-device
runtime needed: rules only read axis names/sizes)."""

import numpy as np
import pytest

from repro.configs import REGISTRY, SHAPES, get_config, get_shape
from repro.dist.sharding import Rules, make_rules


class FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


SINGLE = FakeMesh((16, 16), ("data", "model"))
MULTI = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_heads_fallback_for_indivisible_archs():
    shape = get_shape("train_4k")
    for arch, expect in [("stablelm-1.6b", ("model",)),
                         ("deepseek-67b", ("model",)),
                         ("minicpm-2b", None),       # 36 heads % 16 != 0
                         ("qwen2-vl-7b", None),      # 28 heads
                         ("whisper-small", None)]:   # 12 heads
        r = make_rules(get_config(arch), shape, SINGLE)
        assert r.mapping["heads"] == expect, arch
        # context parallelism replaces head TP
        if expect is None:
            assert r.mapping["q_seq"] == ("model",)


def test_kv_heads_fallback():
    shape = get_shape("train_4k")
    r = make_rules(get_config("deepseek-67b"), shape, SINGLE)
    assert r.mapping["kv_heads"] is None        # kv=8 % 16 != 0
    r = make_rules(get_config("stablelm-1.6b"), shape, SINGLE)
    assert r.mapping["kv_heads"] == ("model",)  # kv=32


def test_batch_hierarchical_dp():
    r = make_rules(get_config("stablelm-1.6b"), get_shape("train_4k"),
                   MULTI)
    assert r.mapping["batch"] == ("pod", "data")
    # long_500k batch=1: unshardable
    r = make_rules(get_config("rwkv6-7b"), get_shape("long_500k"), MULTI)
    assert r.mapping["batch"] is None


def test_kv_seq_rule_sliding_window():
    # mixtral decode cache capacity = window 4096 -> divisible by 16
    r = make_rules(get_config("mixtral-8x22b"), get_shape("decode_32k"),
                   SINGLE)
    assert r.mapping["kv_seq"] == ("model",)


def test_spec_no_duplicate_mesh_axes():
    r = Rules({"a": ("model",), "b": ("model",), "c": ("pod", "data")})
    spec = r.spec(("a", "b", "c"))
    # second use of "model" dropped (PartitionSpec axes must be unique)
    assert spec[0] == "model" and spec[1] is None


@pytest.mark.parametrize("arch", sorted(REGISTRY))
@pytest.mark.parametrize("shape", list(SHAPES))
def test_rules_build_for_every_cell(arch, shape):
    for mesh in (SINGLE, MULTI):
        r = make_rules(get_config(arch), get_shape(shape), mesh)
        # every logical axis resolves to a valid spec
        p = r.spec(("batch", "seq", "embed", "mlp", "heads", "kv_heads",
                    "vocab", "q_seq", "kv_seq"))
        assert p is not None
