"""Dynamic serving workload on the event engine: arrivals as events,
slot contention, SLO exit events, closed-loop clients, multi-replica
round-robin — the scenario family the tentpole opens."""

import pytest

from repro.sim import (ExitEventType, ServeRequest, ServeSim, ServingCost,
                       Simulator, poisson_requests, trace_requests,
                       uniform_requests, v5e_pod, v5e_serving)

COST = ServingCost.from_params(7e9, layers=32, d_model=4096, chips=64)


def _serve(requests, board=None, **params):
    srv = ServeSim(cost=COST, requests=requests, **params)
    sim = Simulator(board or v5e_serving(8, 8), srv)
    events = list(sim.run())
    return srv, sim, events


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def test_poisson_requests_are_seed_reproducible():
    a = poisson_requests(40, 100.0, seed=9)
    b = poisson_requests(40, 100.0, seed=9)
    c = poisson_requests(40, 100.0, seed=10)
    assert a == b
    assert a != c
    assert all(x.arrival_tick <= y.arrival_tick for x, y in zip(a, a[1:]))


def test_trace_requests_sorted_and_indexed():
    reqs = trace_requests([(0.2, 64, 8), (0.1, 32, 4), (0.3, 16, 2)])
    assert [r.rid for r in reqs] == [0, 1, 2]
    assert [r.prompt_len for r in reqs] == [32, 64, 16]   # sorted by time
    assert reqs[0].arrival_tick == 100_000_000


def test_serving_run_is_deterministic():
    reqs = poisson_requests(30, 300.0, seed=4, decode_len=(4, 16))
    s1, sim1, _ = _serve(reqs, slots=4, seq_capacity=1024)
    s2, sim2, _ = _serve(reqs, slots=4, seq_capacity=1024)
    assert sim1.result().makespan_s == sim2.result().makespan_s
    assert s1.summary() == s2.summary()
    assert s1.schedulers[0].decisions == s2.schedulers[0].decisions


# ---------------------------------------------------------------------------
# the serving model itself
# ---------------------------------------------------------------------------

def test_all_requests_complete_with_metrics():
    reqs = poisson_requests(25, 200.0, seed=1, decode_len=(4, 12))
    srv, sim, events = _serve(reqs, slots=4, seq_capacity=1024)
    assert [e.kind for e in events] == [ExitEventType.DONE]
    summ = srv.summary()
    assert summ["requests"] == 25
    assert summ["throughput_rps"] > 0
    assert summ["tokens_out"] > 0
    assert 0 < summ["p50_ttft_s"] <= summ["p99_ttft_s"]
    assert summ["p50_latency_s"] <= summ["p99_latency_s"]
    # every ttft/latency was sampled exactly once per request
    assert srv.p_latency.count == 25
    assert srv.p_ttft.count == 25
    # engine stats flow through the normal stats tree too
    flat = srv.stats.flat()
    assert flat["serve.requests_done"] == 25


def test_kv_slot_contention_queues_requests():
    """With 1 slot the same stream waits far longer for admission than
    with 8 slots (KV slots are the contended resource)."""
    reqs = poisson_requests(20, 2000.0, seed=2, prompt_len=(128, 256),
                            decode_len=(16, 32))
    few, _, _ = _serve(reqs, slots=1, seq_capacity=1024)
    many, _, _ = _serve(reqs, slots=8, seq_capacity=1024)
    assert few.p_queue_wait.quantile(0.9) > many.p_queue_wait.quantile(0.9)
    assert few.summary()["throughput_rps"] < many.summary()["throughput_rps"]
    # decode batching actually happened in the 8-slot run
    assert many.d_batch.mean > 1.0


def test_slo_violation_exit_events():
    reqs = poisson_requests(10, 5000.0, seed=3, prompt_len=(256, 512),
                            decode_len=(16, 32))
    srv, sim, events = _serve(reqs, slots=1, seq_capacity=1024,
                              slo_ttft_s=1e-6, exit_on_slo=True)
    kinds = [e.kind for e in events]
    assert kinds[-1] == ExitEventType.DONE
    viol = [e for e in events if e.kind is ExitEventType.SLO_VIOLATION]
    assert len(viol) == srv.s_slo_viol.value() > 0
    assert {"rid", "ttft_s", "latency_s"} <= set(viol[0].payload)
    assert srv.summary()["goodput_rps"] < srv.summary()["throughput_rps"]


def test_closed_loop_keeps_concurrency_bounded():
    reqs = uniform_requests(24, seed=5, prompt_len=(64, 128),
                            decode_len=(8, 16))
    srv, sim, _ = _serve(reqs, slots=8, seq_capacity=1024,
                         closed_loop_clients=3, think_time_s=0.001)
    assert srv.summary()["requests"] == 24
    # never more than the client population in flight
    assert srv.d_batch.value()["max"] <= 3


def test_multi_replica_round_robin():
    reqs = poisson_requests(20, 500.0, seed=6, decode_len=(4, 8))
    srv, sim, _ = _serve(reqs, board=v5e_serving(4, 4, replicas=2),
                         slots=4, seq_capacity=1024)
    assert srv.summary()["requests"] == 20
    scheds = srv.schedulers
    assert len(scheds) == 2
    # rid i goes to replica i % 2
    for p, sched in enumerate(scheds):
        rids = {d.rid for d in sched.decisions}
        assert rids == {r.rid for r in reqs if r.rid % 2 == p}
    # compute totals count BOTH replicas' injected ops (each op runs
    # once on its owning pod, so compute_s == sum of chip busy time)
    stats = sim.result().stats
    assert sim.result().compute_s == pytest.approx(
        stats["sim.chip0.busy_seconds"] + stats["sim.chip1.busy_seconds"],
        rel=1e-9)


def test_serving_on_training_board_and_degraded_hardware():
    """Serving runs on any existing board; slower hardware serves the
    same stream with a longer makespan."""
    reqs = poisson_requests(15, 1000.0, seed=8, decode_len=(4, 8))
    _, fast, _ = _serve(reqs, board=v5e_pod(), slots=4, seq_capacity=1024)
    _, slow, _ = _serve(reqs, board=v5e_pod(chip={"hbm_bw": 819e9 / 8}),
                        slots=4, seq_capacity=1024)
    assert slow.result().makespan_s > fast.result().makespan_s


def test_max_tick_exit_interleaves_with_serving():
    reqs = poisson_requests(20, 500.0, seed=12, decode_len=(8, 16))
    srv = ServeSim(cost=COST, requests=reqs, slots=4, seq_capacity=1024)
    sim = Simulator(v5e_serving(8, 8), srv)
    sim.schedule_max_tick(1_000_000)         # 1 ms, mid-stream
    kinds = [e.kind for e in sim.run()]
    assert kinds[0] == ExitEventType.MAX_TICK
    assert kinds[-1] == ExitEventType.DONE
    assert srv.summary()["requests"] == 20


def test_request_validation():
    with pytest.raises(ValueError, match="rid"):
        ServeSim(cost=COST, requests=[ServeRequest(rid=3, prompt_len=8,
                                                   decode_len=4)])
    with pytest.raises(ValueError, match="at least one"):
        ServeSim(cost=COST, requests=[])
    # oversized prompts fail at construction, not mid-simulation
    with pytest.raises(ValueError, match="fit"):
        ServeSim(cost=COST, seq_capacity=512,
                 requests=[ServeRequest(rid=0, prompt_len=600,
                                        decode_len=4)])
    with pytest.raises(ValueError, match=">= 1"):
        ServeSim(cost=COST, requests=[ServeRequest(rid=0, prompt_len=8,
                                                   decode_len=0)])


# ---------------------------------------------------------------------------
# span semantics: throughput over first-submit -> last-finish
# ---------------------------------------------------------------------------

def test_offset_trace_reports_real_throughput():
    """A trace whose arrivals start at t=1000 s (production logs with
    an epoch offset) must report the throughput of its busy span —
    span_s used to be measured from tick 0, diluting throughput ~1000x
    for this stream."""
    late = trace_requests([(1000.0 + 0.01 * i, 64, 8) for i in range(10)])
    srv, _, _ = _serve(late, slots=4, seq_capacity=1024)
    s = srv.summary()
    assert s["span_s"] < 10.0
    assert s["throughput_rps"] > 1.0
    # the same stream shifted to t=0 spans (essentially) the same window
    base = trace_requests([(0.01 * i, 64, 8) for i in range(10)])
    srv0, _, _ = _serve(base, slots=4, seq_capacity=1024)
    assert s["span_s"] == pytest.approx(srv0.summary()["span_s"], rel=1e-3)


def test_summary_before_any_finish_is_nan_not_zero():
    """Mid-run summaries with an empty percentile sketch report NaN,
    never a fake-perfect 0.0 (and zero rates, not a division blowup)."""
    reqs = poisson_requests(5, 10.0, seed=1, decode_len=(4, 8))
    srv = ServeSim(cost=COST, requests=reqs, slots=4, seq_capacity=1024)
    sim = Simulator(v5e_serving(8, 8), srv)
    sim.schedule_max_tick(1000)              # 1 us: nothing finished yet
    ev = next(iter(sim.run()))
    assert ev.kind == ExitEventType.MAX_TICK
    s = srv.summary()
    assert s["requests"] == 0 and s["span_s"] == 0.0
    assert s["throughput_rps"] == 0.0 and s["goodput_rps"] == 0.0
    for key in ("p50_ttft_s", "p99_ttft_s", "p50_latency_s",
                "p99_latency_s", "mean_tpot_s", "mean_batch"):
        assert s[key] != s[key], f"{key} should be NaN, got {s[key]}"


# ---------------------------------------------------------------------------
# inject_op contract (the executor layer the workloads build on)
# ---------------------------------------------------------------------------

def test_inject_op_honors_ready_floor_behind_pending_dep():
    """An injected op with an in-flight dep must not issue before its
    requested ``ready`` tick, even when the dep finishes earlier."""
    from repro.core.desim.trace import HloTrace, TraceOp
    board = v5e_pod()
    ex = board.executor()
    ex.begin(HloTrace("dyn", ops=[TraceOp("compute", flops=1e12,
                                          bytes=1e9)]))
    floor = 10_000_000_000           # 10 s, far beyond the dep's end
    idx = ex.inject_op(TraceOp("compute", flops=1e9, bytes=1e6,
                               deps=(0,), name="late"), ready=floor)
    seen = {}
    ex.injection_hook = (lambda op, i, pod, start, end:
                         seen.setdefault(i, start))
    ex.advance()
    assert seen[idx] >= floor


def test_inject_op_from_completion_hook_respects_pending_deps():
    """An injection_hook that reacts to op A's completion by injecting
    C with deps on A *and* a still-in-flight B must not see C issued
    until B completes (the dependents list is snapshotted before hooks
    run, so the freshly-injected C is not double-decremented)."""
    from repro.core.desim.trace import HloTrace, TraceOp
    board = v5e_pod()
    ex = board.executor()
    ex.begin(HloTrace("dyn", ops=[]))
    spans = {}
    a = ex.inject_op(TraceOp("compute", flops=1e9, bytes=1e6, name="A"),
                     ready=0)
    b = ex.inject_op(TraceOp("compute", flops=1e13, bytes=1e10, name="B"),
                     ready=0)

    def hook(op, idx, pod, start, end):
        spans[op.name] = (start, end)
        if op.name == "A":
            c = ex.inject_op(TraceOp("compute", flops=1e9, bytes=1e6,
                                     deps=(a, b), name="C"), ready=end)
            assert c == 2
    ex.injection_hook = hook
    assert ex.advance()
    assert spans["C"][0] >= spans["B"][1]    # C waited for B


def test_inject_op_rejects_dcn_routed_collectives():
    from repro.core.desim.trace import HloTrace, TraceOp
    from repro.sim import v5e_multipod
    board = v5e_multipod(2)
    ex = board.executor()
    ex.begin(HloTrace("dyn", ops=[TraceOp("compute", flops=1e9,
                                          bytes=1e6)]))
    with pytest.raises(ValueError, match="dcn"):
        ex.inject_op(TraceOp("all-reduce", coll_bytes=1e6, scope="dcn",
                             participants=board.machine.num_chips),
                     ready=0, pod=0)


def test_sim_stack_import_stays_jax_free():
    """The DES must stay importable (and fast) without jax: the shared
    policy import must not drag repro.serve.server's jax dependency
    into repro.sim (serve/__init__ loads jax modules lazily)."""
    import subprocess
    import sys
    code = ("import repro.sim, repro.serve, sys; "
            "assert 'jax' not in sys.modules, 'jax leaked into the DES'")
    subprocess.run([sys.executable, "-c", code], check=True)


def test_serving_cost_model_shapes():
    c = ServingCost.from_params(7e9, layers=32, d_model=4096, chips=64)
    f1, b1 = c.prefill_cost(128)
    f2, b2 = c.prefill_cost(256)
    assert f2 == pytest.approx(2 * f1)       # prefill flops scale with prompt
    assert b2 > b1
    df1, db1 = c.decode_cost(1, 128)
    df8, db8 = c.decode_cost(8, 1024)
    assert df8 == pytest.approx(8 * df1)     # decode flops scale with batch
    assert db8 > db1                         # more KV context to stream
    # decode is weight-read dominated at small batch (memory bound)
    assert db1 * 64 == pytest.approx(c.weight_bytes
                                     + c.kv_bytes_per_token * 129)
    assert c.kv_slot_bytes(2048) == pytest.approx(
        c.kv_bytes_per_token * 2048)
