"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see the real single CPU device; only launch/dryrun.py
forces 512 placeholder devices (and only in its own process)."""

import jax
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--regen-golden", action="store_true", default=False,
        help="rewrite the golden stats dumps under tests/golden/ from "
             "the current simulator output instead of diffing against "
             "them (commit the result after reviewing the diff)")


@pytest.fixture
def regen_golden(request):
    return request.config.getoption("--regen-golden")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
