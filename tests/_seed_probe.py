"""Subprocess probe for tests/test_seed_determinism.py.

Runs a short ServeSim and a short TrainSim from one seed and prints a
JSON digest of everything that must be seed-deterministic: arrival
streams, decision logs, percentile accumulator state, final ticks.
Executed in a FRESH interpreter per invocation so Python hash
randomization differs between runs — any iteration order leaking from
an unordered container shows up as a digest mismatch.

    python tests/_seed_probe.py <seed>
"""

import json
import sys


def serve_digest(seed: int):
    from repro.sim import (ServeSim, ServingCost, Simulator,
                           poisson_requests, v5e_serving)
    reqs = poisson_requests(30, 200.0, seed=seed)
    srv = ServeSim(cost=ServingCost.from_params(1e9, layers=4,
                                                d_model=128, chips=16),
                   requests=reqs, slots=3, seq_capacity=1024)
    Simulator(v5e_serving(4, 4, replicas=2), srv).run_to_completion()
    return {
        "arrivals": [r.arrival_tick for r in reqs],
        "decisions": [[d.kind, d.rid, d.slot, d.step, d.reason]
                      for s in srv.schedulers for d in s.decisions],
        "ttft_state": srv.p_ttft.state_dict(),
        "latency_state": srv.p_latency.state_dict(),
    }


def train_digest(seed: int):
    from repro.configs import get_config
    from repro.sim import (Simulator, TrainSim, TrainStepCost,
                           v5e_unreliable)
    from repro.train.ft_policy import FTPolicy
    board = v5e_unreliable(4, seed=seed, horizon=150, mtbf=30.0,
                           straggler_mtbs=60.0, repair=(10, 30),
                           nx=4, ny=4)
    pol = FTPolicy(get_config("deepseek-67b"), num_steps=50,
                   ckpt_interval=10, pods=4, chips_per_pod=16)
    ts = TrainSim(
        cost=TrainStepCost.from_params(1e9, tokens_per_batch=100_000,
                                       chips=64),
        policy=pol, schedule=board.failure_schedule)
    Simulator(board, ts).run_to_completion()
    return {
        "events": [[e.attempt, e.kind, e.pod, e.slowdown, e.duration,
                    e.repair] for e in board.failure_schedule.events],
        "decisions": [d.to_row() for d in pol.decisions],
        "final_tick": ts.summary()["makespan_s"],
        "step_state": ts.p_step.state_dict(),
    }


if __name__ == "__main__":
    seed = int(sys.argv[1])
    json.dump({"serve": serve_digest(seed), "train": train_digest(seed)},
              sys.stdout, sort_keys=True)
