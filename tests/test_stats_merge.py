"""Stat/StatGroup merge semantics (the parallel-engine reduction).

The multiprocess engine (``repro.core.desim.parallel``) reassembles one
gem5-style stats tree from per-worker slices via ``StatGroup.merge`` /
``merge_state_dict``; these unit tests pin the algebra that makes the
reassembly exact:

* serial equivalence — splitting a sample stream across two stats and
  merging equals accumulating the whole stream into one stat,
* commutativity — a merge order must not change the combined value,
* adopt-verbatim — merging into a zero/empty stat is *bit*-exact, which
  is the property the engine actually leans on (each worker owns its
  counters exclusively, the facade's copies stay zero until collect).
"""

import math
import random

import pytest

from repro.core.stats import (Distribution, Percentiles, Scalar, StatGroup,
                              Vector)


def _dist(name, samples):
    d = Distribution(name)
    for v in samples:
        d.sample(v)
    return d


def _pct(name, samples, rel_err=0.01):
    p = Percentiles(name, rel_err=rel_err)
    for v in samples:
        p.sample(v)
    return p


# ---------------------------------------------------------------------------
# Scalar / Vector
# ---------------------------------------------------------------------------

def test_scalar_merge_adds():
    a, b = Scalar("s"), Scalar("s")
    a.inc(3.0)
    b.inc(4.5)
    a.merge(b)
    assert a.value() == 7.5
    assert b.value() == 4.5            # source untouched


def test_scalar_merge_into_zero_is_bit_exact():
    src = Scalar("s")
    src.inc(0.1 + 0.2)                 # a value with fp texture
    dst = Scalar("s")
    dst.merge(src)
    assert dst.state_dict() == src.state_dict()


def test_vector_merge_elementwise():
    a, b = Vector("v", 3), Vector("v", 3)
    a.inc(0, 1.0)
    a.inc(2, 5.0)
    b.inc(1, 2.0)
    b.inc(2, 0.5)
    a.merge(b)
    assert a.value() == [1.0, 2.0, 5.5]


def test_vector_merge_size_mismatch_raises():
    a, b = Vector("v", 3), Vector("v", 4)
    with pytest.raises(ValueError, match="size mismatch"):
        a.merge(b)


def test_merge_rejects_kind_mismatch():
    with pytest.raises(TypeError, match="cannot merge"):
        Scalar("x").merge(Vector("x", 2))


# ---------------------------------------------------------------------------
# Distribution (Chan et al. parallel Welford)
# ---------------------------------------------------------------------------

def test_distribution_serial_equivalence():
    rng = random.Random(7)
    xs = [rng.uniform(-5, 50) for _ in range(500)]
    whole = _dist("d", xs)
    a, b = _dist("d", xs[:173]), _dist("d", xs[173:])
    a.merge(b)
    assert a.count == whole.count
    assert a.value()["min"] == whole.value()["min"]
    assert a.value()["max"] == whole.value()["max"]
    assert a.mean == pytest.approx(whole.mean, rel=1e-12)
    assert a.stddev == pytest.approx(whole.stddev, rel=1e-9)


def test_distribution_commutative():
    rng = random.Random(11)
    xs = [rng.gauss(10, 3) for _ in range(200)]
    ab = _dist("d", xs[:60])
    ab.merge(_dist("d", xs[60:]))
    ba = _dist("d", xs[60:])
    ba.merge(_dist("d", xs[:60]))
    assert ab.count == ba.count
    assert ab.mean == pytest.approx(ba.mean, rel=1e-12)
    assert ab.stddev == pytest.approx(ba.stddev, rel=1e-9)


def test_distribution_merge_empty_sides():
    xs = [1.0, 2.0, 4.0]
    d = _dist("d", xs)
    d.merge(Distribution("d"))          # empty rhs: no-op
    assert d.state_dict() == _dist("d", xs).state_dict()
    e = Distribution("d")
    e.merge(_dist("d", xs))             # empty lhs: adopt verbatim
    assert e.state_dict() == _dist("d", xs).state_dict()


# ---------------------------------------------------------------------------
# Percentiles (DDSketch bin-wise merge)
# ---------------------------------------------------------------------------

def test_percentiles_serial_equivalence():
    rng = random.Random(3)
    xs = [rng.expovariate(1 / 50.0) for _ in range(800)] + [0.0, 0.0]
    whole = _pct("p", xs)
    a, b = _pct("p", xs[:300]), _pct("p", xs[300:])
    a.merge(b)
    sa, sw = a.state_dict(), whole.state_dict()
    assert sa["bins"] == sw["bins"]      # integer bin counts: exact
    assert sa["count"] == sw["count"]
    assert sa["min"] == sw["min"] and sa["max"] == sw["max"]
    assert sa["sum"] == pytest.approx(sw["sum"], rel=1e-12)
    for q in (0.5, 0.9, 0.99):
        assert a.quantile(q) == whole.quantile(q)


def test_percentiles_commutative_bitwise_on_bins():
    xs = [float(i) for i in range(1, 101)]
    ab = _pct("p", xs[:37])
    ab.merge(_pct("p", xs[37:]))
    ba = _pct("p", xs[37:])
    ba.merge(_pct("p", xs[:37]))
    assert ab.state_dict()["bins"] == ba.state_dict()["bins"]
    assert ab.quantile(0.99) == ba.quantile(0.99)


def test_percentiles_rel_err_mismatch_raises():
    with pytest.raises(ValueError):
        Percentiles("p", rel_err=0.01).merge(Percentiles("p", rel_err=0.05))


def test_percentiles_merge_into_empty_is_bit_exact():
    src = _pct("p", [0.3, 7.7, 123.4])
    dst = Percentiles("p")
    dst.merge(src)
    assert dst.state_dict() == src.state_dict()


# ---------------------------------------------------------------------------
# StatGroup tree merge
# ---------------------------------------------------------------------------

def _tree():
    g = StatGroup("sim")
    g.scalar("ticks")
    sub = StatGroup("chip0")
    sub.scalar("flops")
    sub.distribution("op_ns")
    g.add_child(sub)
    return g


def test_group_merge_recurses():
    a, b = _tree(), _tree()
    a["ticks"].inc(10)
    a["chip0.flops"].inc(100)
    a["chip0.op_ns"].sample(5.0)
    b["ticks"].inc(32)
    b["chip0.flops"].inc(11)
    b["chip0.op_ns"].sample(9.0)
    a.merge(b)
    assert a["ticks"].value() == 42
    assert a["chip0.flops"].value() == 111
    assert a["chip0.op_ns"].count == 2


def test_group_merge_into_zero_tree_is_bit_exact():
    src = _tree()
    src["ticks"].inc(0.1 + 0.2)
    src["chip0.op_ns"].sample(math.pi)
    dst = _tree()
    dst.merge(src)
    assert dst.state_dict() == src.state_dict()


def test_group_merge_strict_rejects_shape_mismatch():
    a, b = _tree(), _tree()
    extra = StatGroup("chip1")
    extra.scalar("flops")
    b.add_child(extra)
    with pytest.raises(KeyError):
        a.merge(b, strict=True)
    a.merge(b)                          # lenient: unknown subtree skipped
    with pytest.raises(KeyError):
        a["chip1.flops"]


def test_merge_state_dict_partial_subtree():
    """The engine's collect path: merge one worker's ``chip{g}`` slice
    (as a state dict) into the facade tree without touching siblings."""
    a = _tree()
    a["chip0.flops"].inc(5)
    donor = _tree()
    donor["chip0.flops"].inc(37)
    donor["chip0.op_ns"].sample(2.5)
    sd = donor.state_dict()["children"]["chip0"]
    a.merge_state_dict({"children": {"chip0": sd}})
    assert a["chip0.flops"].value() == 42
    assert a["chip0.op_ns"].count == 1
    assert a["ticks"].value() == 0      # untouched sibling
