"""Property-based tests on layer invariants (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, smoke
from repro.models import layers as ll
from repro.models.common import IDENTITY_SHARDER

CFG = smoke(get_config("stablelm-1.6b"))
KEY = jax.random.PRNGKey(3)


@given(st.integers(1, 3), st.sampled_from([16, 32, 64]),
       st.sampled_from([4, 8]))
@settings(max_examples=10, deadline=None)
def test_rope_preserves_norm(b, s, h):
    """Rotary embedding is a rotation: per-pair L2 norms are invariant."""
    from dataclasses import replace
    cfg = replace(CFG, rope_pct=1.0, n_heads=h, d_head=16)
    x = jax.random.normal(KEY, (b, s, h, 16), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    y = ll.apply_rope(cfg, x, pos)
    nx = jnp.linalg.norm(x, axis=-1)
    ny = jnp.linalg.norm(y, axis=-1)
    np.testing.assert_allclose(np.asarray(nx), np.asarray(ny), rtol=1e-5)


def test_rope_relative_property():
    """Score q_i . k_j after RoPE depends only on i - j."""
    from dataclasses import replace
    cfg = replace(CFG, rope_pct=1.0, n_heads=1, d_head=16)
    q = jnp.ones((1, 8, 1, 16))
    k = jnp.ones((1, 8, 1, 16)) * 0.5
    pos = jnp.arange(8)[None, :]
    qr = ll.apply_rope(cfg, q, pos)
    kr = ll.apply_rope(cfg, k, pos)
    s = jnp.einsum("bqhd,bkhd->bqk", qr, kr)[0]
    # all (i, j) with equal i-j have equal scores
    for delta in (1, 3):
        vals = [float(s[i, i - delta]) for i in range(delta, 8)]
        assert max(vals) - min(vals) < 1e-4


@given(st.sampled_from([32, 64, 128]), st.sampled_from([16, 32, 64]),
       st.sampled_from([0, 24]))
@settings(max_examples=12, deadline=None)
def test_blockwise_equals_naive(s, chunk, window):
    """Online-softmax blockwise attention == naive attention."""
    b, h, d = 2, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    naive = ll.naive_causal_attention(q, k, v, pos, pos, window=window)
    block = ll.blockwise_attention(q, k, v, pos, pos, window=window,
                                   chunk=chunk)
    np.testing.assert_allclose(np.asarray(block), np.asarray(naive),
                               atol=2e-5, rtol=2e-5)


@given(st.integers(5, 40), st.sampled_from([8, 16]))
@settings(max_examples=15, deadline=None)
def test_kv_cache_ring_buffer_consistency(s, cap):
    """kv_to_cache slot layout matches decode's ring-buffer writes:
    token t lives at slot t % capacity, keeping the last cap tokens."""
    kvh, hd = 2, 4
    k = jnp.arange(s, dtype=jnp.float32)[None, :, None, None]
    k = jnp.broadcast_to(k, (1, s, kvh, hd))
    cache = ll.kv_to_cache(k, k, cap, IDENTITY_SHARDER)
    ck = np.asarray(cache["k"])           # (1, kvh, cap, hd)
    for t in range(max(0, s - cap), s):
        assert ck[0, 0, t % cap, 0] == t


def test_decode_per_slot_matches_scalar():
    """Vector cur_len with equal entries == scalar cur_len decode."""
    cfg = CFG
    key = KEY
    p = ll.init_attention(key, cfg)
    from repro.models.common import unzip
    params, _ = unzip(p)
    b, S = 2, 16
    cache = {"k": jax.random.normal(key, (b, cfg.n_kv_heads, S,
                                          cfg.head_dim), jnp.float32),
             "v": jax.random.normal(key, (b, cfg.n_kv_heads, S,
                                          cfg.head_dim), jnp.float32)}
    x = jax.random.normal(key, (b, 1, cfg.d_model), jnp.float32)
    y1, c1 = ll.attention_decode(params, x, cfg, cache,
                                 jnp.asarray(5), IDENTITY_SHARDER)
    y2, c2 = ll.attention_decode(params, x, cfg, cache,
                                 jnp.asarray([5, 5]), IDENTITY_SHARDER)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(c1["k"]), np.asarray(c2["k"]),
                               atol=1e-6)


def test_cross_entropy_vocab_padding_invariant():
    """Padded vocab positions must not change the loss."""
    from repro.configs import get_config
    cfg = get_config("minicpm-2b")     # vocab 122753 -> padded 122880
    b, s, v = 2, 8, cfg.vocab_size
    vp = ll.padded_vocab(cfg)
    assert vp > v
    logits_real = jax.random.normal(KEY, (b, s, v), jnp.float32)
    labels = jax.random.randint(KEY, (b, s), 0, v)
    # same logits with huge garbage in the padded tail
    pad = jnp.full((b, s, vp - v), 37.0)
    logits_padded = jnp.concatenate([logits_real, pad], axis=-1)
    l_pad = ll.cross_entropy(logits_padded, labels, cfg)

    class VCfg:
        vocab_size = v
    l_real = ll.cross_entropy(logits_real, labels, VCfg)
    np.testing.assert_allclose(float(l_pad), float(l_real), rtol=1e-5)
