"""Seed determinism across PROCESS boundaries: the same seed must
reproduce identical ServeSim/TrainSim decision logs, arrival streams,
and percentile accumulator state in two fresh interpreters (each with
its own hash randomization — this is what catches set/dict iteration
order leaking into simulation behaviour), and different seeds must
actually differ."""

import json
import os
import subprocess
import sys

import pytest

_PROBE = os.path.join(os.path.dirname(__file__), "_seed_probe.py")
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _probe(seed: int, hash_seed: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    # force DIFFERENT hash seeds so unordered-container leaks diverge
    env["PYTHONHASHSEED"] = hash_seed
    out = subprocess.run([sys.executable, _PROBE, str(seed)],
                         capture_output=True, text=True, env=env,
                         cwd=_ROOT, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout)


@pytest.fixture(scope="module")
def digests():
    return {
        ("a", 7): _probe(7, hash_seed="1"),
        ("b", 7): _probe(7, hash_seed="99"),
        ("a", 8): _probe(8, hash_seed="5"),
    }


def test_same_seed_identical_across_processes(digests):
    a, b = digests[("a", 7)], digests[("b", 7)]
    assert a["serve"]["decisions"] == b["serve"]["decisions"]
    assert a["serve"]["ttft_state"] == b["serve"]["ttft_state"]
    assert a["serve"]["latency_state"] == b["serve"]["latency_state"]
    assert a["train"]["decisions"] == b["train"]["decisions"]
    assert a["train"]["step_state"] == b["train"]["step_state"]
    assert a["train"]["final_tick"] == b["train"]["final_tick"]
    assert a == b                      # and everything else too


def test_different_seeds_differ(digests):
    a, c = digests[("a", 7)], digests[("a", 8)]
    assert a["serve"]["arrivals"] != c["serve"]["arrivals"]
    assert a["train"]["events"] != c["train"]["events"]
