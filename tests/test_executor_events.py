"""Event-driven TraceExecutor: equivalence with the analytic float-clock
model on contention-free traces, link/fabric contention, engine event
accounting, and the gem5-style stats tree.  (No hypothesis dependency:
this file is the always-on tier-1 coverage of the desim engine.)"""

import pytest

from repro.core.desim.collectives import get_algorithm
from repro.core.desim.executor import TICKS_PER_S, TraceExecutor
from repro.core.desim.machine import ClusterModel
from repro.core.desim.trace import HloTrace, TraceOp, analytic_trace
from repro.core.events import EventQueue


def cluster(pods=1):
    c = ClusterModel("c", num_pods=pods)
    c.instantiate()
    return c


# ---------------------------------------------------------------------------
# equivalence: event-driven == float-clock on a linear no-contention trace
# ---------------------------------------------------------------------------

def float_clock_makespan(m, trace, algorithm="torus2d"):
    """The seed executor's float-second resource-clock model, kept here
    as the analytic oracle for linear (chain-dependency) traces."""
    alg = get_algorithm(algorithm)
    compute_free = wire_free = 0.0
    op_done = [0.0] * len(trace.ops)
    for idx, op in enumerate(trace.ops):
        dep_ready = max((op_done[d] for d in op.deps), default=0.0)
        if op.kind == "compute":
            dur = m.pod.chip.compute_time_s(op.flops, op.bytes)
            start = max(dep_ready, compute_free)
            compute_free = start + dur
            op_done[idx] = compute_free
        else:
            dur = alg.time_s(op.kind, op.coll_bytes,
                             op.participants or m.pod.num_chips, m)
            start = max(dep_ready, wire_free)
            wire_free = start + dur
            op_done[idx] = wire_free
    return max(op_done) if op_done else 0.0


def test_equivalence_linear_trace():
    m = cluster()
    colls = [{"kind": "all-reduce", "bytes": 1e8, "participants": 256}]
    tr = analytic_trace("lin", 8, 1e12, 1e9, colls, overlap=False)
    got = TraceExecutor(m).execute(tr).makespan_s
    want = float_clock_makespan(m, tr)
    # 1 tick = 1 ns: rounding error is bounded by 0.5 ns per op
    assert got == pytest.approx(want, abs=len(tr.ops) * 1e-9)
    assert got == pytest.approx(want, rel=1e-6)


def test_equivalence_memory_bound_trace():
    m = cluster()
    tr = analytic_trace("mem", 6, 1e9, 1e12, [])
    got = TraceExecutor(m).execute(tr).makespan_s
    assert got == pytest.approx(float_clock_makespan(m, tr), rel=1e-6)


def test_overlap_flag_is_stat_only_and_hides_exposure():
    m = cluster()
    colls = [{"kind": "all-reduce", "bytes": 1e8, "participants": 256}]
    sync = TraceExecutor(m).execute(
        analytic_trace("s", 8, 1e12, 1e9, colls, overlap=False))
    ovl = TraceExecutor(m).execute(
        analytic_trace("o", 8, 1e12, 1e9, colls, overlap=True))
    assert ovl.makespan_s <= sync.makespan_s
    assert ovl.summary()["overlap_efficiency"] >= \
        sync.summary()["overlap_efficiency"]
    assert sync.exposed_collective_s > 0
    assert ovl.exposed_collective_s == 0


def test_straggler_scales_makespan():
    m = cluster(pods=2)
    tr = analytic_trace("t", 4, 1e12, 1e9, [])
    base = TraceExecutor(m).execute(tr).makespan_s
    slowed = TraceExecutor(m, straggler_slowdowns=[1.0, 3.0]).execute(tr)
    assert slowed.makespan_s == pytest.approx(base * 3.0, rel=1e-6)


# ---------------------------------------------------------------------------
# engine accounting (acceptance: events == engine events_fired)
# ---------------------------------------------------------------------------

def test_events_equal_engine_events_fired():
    m = cluster()
    colls = [{"kind": "all-gather", "bytes": 1e7, "participants": 16}]
    tr = analytic_trace("e", 5, 1e11, 1e8, colls)
    res = TraceExecutor(m).execute(tr)
    # one completion event per op on the single pod queue
    assert res.events == len(tr.ops)

    m2 = cluster(pods=3)
    res2 = TraceExecutor(m2).execute(tr)
    assert res2.events == 3 * len(tr.ops)


def test_dcn_completion_on_quantum_boundary():
    m = cluster(pods=2)
    tr = analytic_trace("x", 1, 1e10, 1e8, [],
                        tail_collectives=[{"kind": "all-reduce",
                                           "bytes": 1e9,
                                           "participants": 512,
                                           "scope": "dcn"}])
    res = TraceExecutor(m).execute(tr)
    q = m.quantum_ns / TICKS_PER_S
    assert (res.makespan_s / q) == pytest.approx(
        round(res.makespan_s / q), abs=1e-6)
    # the barrier costs at least one quantum beyond the pure wire time
    assert res.makespan_s > float_clock_makespan(
        m, analytic_trace("x", 1, 1e10, 1e8, []))


# ---------------------------------------------------------------------------
# contention (acceptance: shared links serialize, disjoint don't)
# ---------------------------------------------------------------------------

def _two_collective_trace(region_a, region_b):
    t = HloTrace("contend")
    t.ops.append(TraceOp(kind="compute", flops=1e12, bytes=1e9, name="c0"))
    for i, region in enumerate((region_a, region_b)):
        t.ops.append(TraceOp(kind="all-gather", coll_bytes=1e8,
                             participants=4, deps=(0,), region=region,
                             name=f"ag{i}"))
    return t


def test_torus_shared_link_serializes():
    m = cluster()
    shared = TraceExecutor(m).execute(
        _two_collective_trace((0, 0, 4, 1), (0, 0, 4, 1)))
    disjoint = TraceExecutor(m).execute(
        _two_collective_trace((0, 0, 4, 1), (0, 2, 4, 1)))
    # same ring -> serialized; disjoint rows -> fully parallel
    assert shared.makespan_s > disjoint.makespan_s
    coll = get_algorithm("torus2d").time_s("all-gather", 1e8, 4, m)
    comp = m.pod.chip.compute_time_s(1e12, 1e9)
    assert shared.makespan_s == pytest.approx(comp + 2 * coll, rel=1e-6)
    assert disjoint.makespan_s == pytest.approx(comp + coll, rel=1e-6)


def test_default_region_is_whole_pod_conservative():
    """Collectives without placement all contend (seed-equivalent)."""
    m = cluster()
    res = TraceExecutor(m).execute(_two_collective_trace(None, None))
    coll = get_algorithm("torus2d").time_s("all-gather", 1e8, 4, m)
    comp = m.pod.chip.compute_time_s(1e12, 1e9)
    assert res.makespan_s == pytest.approx(comp + 2 * coll, rel=1e-6)


def _dcn_pair_trace():
    t = HloTrace("dcn2")
    t.ops.append(TraceOp(kind="compute", flops=1e12, bytes=1e9))
    for i in range(2):
        t.ops.append(TraceOp(kind="all-reduce", coll_bytes=1e9,
                             participants=512, scope="dcn", deps=(0,),
                             name=f"ar{i}"))
    return t


def test_shared_dcn_link_contention_lengthens_makespan():
    """Acceptance scenario: two pods, two concurrent cross-pod
    collectives on the shared DCN fabric — the contention-aware run is
    strictly longer than the contention-free run."""
    m = cluster(pods=2)
    contended = TraceExecutor(m).execute(_dcn_pair_trace())
    free = TraceExecutor(m, contention=False).execute(_dcn_pair_trace())
    assert contended.makespan_s > free.makespan_s


# ---------------------------------------------------------------------------
# stats tree (record_stats=True)
# ---------------------------------------------------------------------------

def test_record_stats_dumps_simobject_tree():
    m = cluster(pods=2)
    colls = [{"kind": "all-reduce", "bytes": 1e8, "participants": 256}]
    tr = analytic_trace("s", 4, 1e12, 1e9, colls)
    ex = TraceExecutor(m, record_stats=True)
    res = ex.execute(tr)
    assert res.stats is not None
    for p in range(2):
        assert res.stats[f"sim.chip{p}.ops_executed"] == 4
        assert res.stats[f"sim.wire{p}.collectives"] == 4
        assert res.stats[f"sim.wire{p}.bytes_on_wire"] == pytest.approx(4e8)
    assert res.stats["sim.dcn.collectives"] == 0
    # gem5-style text dump renders the same tree
    text = ex.sim_root.stats.dump_text()
    assert "sim.chip0.ops_executed" in text
    # default: no stats overhead
    assert TraceExecutor(m).execute(tr).stats is None


def test_stats_busy_matches_result_totals():
    m = cluster()
    tr = analytic_trace("b", 3, 1e12, 1e9,
                        [{"kind": "all-gather", "bytes": 1e8,
                          "participants": 256}])
    res = TraceExecutor(m, record_stats=True).execute(tr)
    assert res.stats["sim.chip0.busy_seconds"] == \
        pytest.approx(res.compute_s, rel=1e-9)
    assert res.stats["sim.wire0.busy_seconds"] == \
        pytest.approx(res.collective_s, rel=1e-9)


# ---------------------------------------------------------------------------
# engine regression: squashed events must not leak heap entries
# ---------------------------------------------------------------------------

def test_squashed_events_do_not_leak():
    q = EventQueue()
    events = [q.schedule(lambda: None, t) for t in range(1000)]
    for ev in events:
        ev.squash()
    assert q.empty()           # lazily reclaims cancelled heads...
    assert q.pending() == 0    # ...so nothing is left in the heap
    # and still correct when live events are interleaved
    fired = []
    keep = q.schedule(lambda: fired.append(1), 2000)
    dead = q.schedule(lambda: fired.append(2), 1500)
    dead.squash()
    assert not q.empty() and keep.scheduled()
    q.run()
    assert fired == [1] and q.pending() == 0


def test_quantum_zero_disables_rounding():
    """quantum_ns=0 (seed behavior: no quantum error model) must not
    crash and completes dcn ops at their exact tick."""
    m = ClusterModel("c", num_pods=2, quantum_ns=0)
    m.instantiate()
    tr = analytic_trace("x", 1, 1e10, 1e8, [],
                        tail_collectives=[{"kind": "all-reduce",
                                           "bytes": 1e9,
                                           "participants": 512,
                                           "scope": "dcn"}])
    res = TraceExecutor(m).execute(tr)
    mq = cluster(pods=2)
    rounded = TraceExecutor(mq).execute(tr)
    # exact completion is never later than the quantum-rounded one
    assert 0 < res.makespan_s <= rounded.makespan_s


def test_permute_does_not_pollute_footprint_cache():
    """collective-permute appends its route links to a COPY of the
    cached region footprint; repeated permutes must not grow it."""
    m = cluster()
    t = HloTrace("perm")
    t.ops.append(TraceOp(kind="compute", flops=1e9, bytes=1e6))
    prev = 0
    for i in range(3):
        t.ops.append(TraceOp(kind="collective-permute", coll_bytes=1e6,
                             participants=4, deps=(prev,),
                             region=(0, 0, 2, 2), name=f"cp{i}"))
        prev = len(t.ops) - 1
    ex = TraceExecutor(m)
    ex.execute(t)
    wire = ex._wires[0]
    assert len(wire._footprints[(0, 0, 2, 2)]) == 2 * 2 * 4


def test_quantum_zero_delivery_to_drained_queue():
    """quantum_ns=0 with a pod whose queue drains far past the dcn
    completion tick: delivery must clamp to now, not crash."""
    m = ClusterModel("c", num_pods=2, quantum_ns=0)
    m.instantiate()
    t = HloTrace("late")
    t.ops.append(TraceOp(kind="compute", flops=1e10, bytes=1e7))
    t.ops.append(TraceOp(kind="all-reduce", coll_bytes=1e6,
                         participants=512, scope="dcn", deps=(0,)))
    # long compute independent of the dcn op: pod0 drains way past it
    t.ops.append(TraceOp(kind="compute", flops=1e13, bytes=1e9,
                         deps=(0,)))
    res = TraceExecutor(m).execute(t)
    assert res.makespan_s > 0


def test_busy_high_water_mark_with_contention_off():
    """per_chip_busy_s must not rewind when a short transfer completes
    after a long one under contention=False."""
    m = cluster()
    t = HloTrace("hw")
    t.ops.append(TraceOp(kind="compute", flops=1e10, bytes=1e7))
    t.ops.append(TraceOp(kind="all-reduce", coll_bytes=1e9,
                         participants=256, deps=(0,)))   # long
    t.ops.append(TraceOp(kind="all-gather", coll_bytes=1e3,
                         participants=4, deps=(0,)))     # tiny
    res = TraceExecutor(m, contention=False).execute(t)
    assert res.per_chip_busy_s[0] == pytest.approx(res.makespan_s,
                                                   rel=1e-9)


def test_run_until_drained_clamps_to_max_tick():
    from repro.core.events import QuantumSync
    q = EventQueue()
    fired = []
    q.schedule(lambda: fired.append(q.now), 950)
    sync = QuantumSync([q], quantum=100)
    end = sync.run_until_drained(max_tick=980)
    # same clamped semantics as run(): tick-950 event fires by 980
    assert fired == [950] and end == 980


def test_trace_deadlock_detection():
    m = cluster()
    t = HloTrace("cycle")
    t.ops.append(TraceOp(kind="compute", flops=1e9, bytes=1e6, deps=(1,)))
    t.ops.append(TraceOp(kind="compute", flops=1e9, bytes=1e6, deps=(0,)))
    with pytest.raises(RuntimeError, match="deadlock"):
        TraceExecutor(m).execute(t)
