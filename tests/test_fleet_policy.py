"""Pure fleet control plane: deterministic routers, watermark
autoscaling with first-class cold start, and the checkpointable
decision log — no event engine, no jax (the FleetSim tentpole's policy
half)."""

import pytest

from repro.serve.fleet_policy import (DOWN, LIVE, ROUTERS, WARMING,
                                      FleetDecision, FleetPolicy)


def mk(router="least_loaded", **kw):
    cfg = dict(min_replicas=2, max_replicas=4, slots_per_replica=2,
               cold_start_ticks=50, control_period_ticks=100, seed=3)
    cfg.update(kw)
    return FleetPolicy(router, **cfg)


def started(router="least_loaded", **kw):
    p = mk(router, **kw)
    p.start()
    return p


# ---------------------------------------------------------------------------
# construction + lifecycle
# ---------------------------------------------------------------------------

def test_validation():
    with pytest.raises(ValueError, match="router"):
        mk("hash_ring")
    with pytest.raises(ValueError, match="min_replicas"):
        mk(min_replicas=5, max_replicas=4)
    with pytest.raises(ValueError, match="min_replicas"):
        mk(min_replicas=0)
    with pytest.raises(ValueError, match="slots"):
        mk(slots_per_replica=0)
    with pytest.raises(ValueError, match="control_period"):
        mk(control_period_ticks=0)
    with pytest.raises(RuntimeError, match="start"):
        mk().route(1, 0)


def test_start_brings_up_floor_fleet():
    p = mk()
    p.start()
    p.start()                       # idempotent
    assert p.live_replicas() == [0, 1]
    assert p.serving_replicas() == [0, 1]
    assert p.state(2) == DOWN
    assert [d.to_row() for d in p.decisions] == [
        ["replica_up", 0, -1, 0, "initial"],
        ["replica_up", 0, -1, 1, "initial"]]


def test_decision_row_round_trip():
    d = FleetDecision("scale_up", 17, rid=3, replica=2, note="queue 9/8")
    assert FleetDecision.from_row(d.to_row()) == d


# ---------------------------------------------------------------------------
# routers
# ---------------------------------------------------------------------------

def test_round_robin_cycles_serving_set():
    p = started("round_robin", min_replicas=3, max_replicas=3)
    assert [p.route(1, rid) for rid in range(5)] == [0, 1, 2, 0, 1]


def test_least_loaded_prefers_fewest_outstanding_then_lowest_id():
    p = started()
    assert p.route(1, 0) == 0
    assert p.route(1, 1) == 1
    assert p.route(1, 2) == 0       # tie on load -> lowest id
    p.finish(2, 1)
    assert p.route(3, 3) == 1       # replica 1 is now the lightest


def test_p2c_is_seed_deterministic_and_stays_on_serving_set():
    a = started("p2c", min_replicas=3, max_replicas=3)
    b = started("p2c", min_replicas=3, max_replicas=3)
    routes = [a.route(1, rid) for rid in range(20)]
    assert routes == [b.route(1, rid) for rid in range(20)]
    assert set(routes) <= {0, 1, 2}
    assert a.decisions == b.decisions


def test_prefix_affinity_sticks_until_overloaded():
    # overload threshold = affinity_overload * slots = 2.0 * 2 = 4
    p = started("prefix_affinity", min_replicas=3, max_replicas=3)
    home = p.route(1, 0, prefix=7)
    assert home == 0                # first of the group homes least-loaded
    for rid in (1, 2, 3):
        assert p.route(1, rid, prefix=7) == home
    # home now holds 4 outstanding: the next group member spills and
    # the group re-homes to the spill target
    spill = p.route(1, 4, prefix=7)
    assert spill != home
    assert p.route(1, 5, prefix=7) == spill
    # requests without a prefix group fall back to least-loaded
    assert p.route(1, 6) == 2


# ---------------------------------------------------------------------------
# autoscaler
# ---------------------------------------------------------------------------

def test_scale_up_on_queue_pressure_with_cold_start():
    p = started()
    for rid in range(5):            # 5 outstanding > cap 2*2
        p.route(10, rid)
    assert p.decisions[-1].kind == "route"
    p.observe(100)                  # first control boundary
    ups = [d for d in p.decisions if d.kind == "scale_up"]
    assert [d.replica for d in ups] == [2]      # ceil(5/2)=3 replicas
    assert ups[0].tick == 100
    assert ups[0].note.startswith("queue 5/4")
    assert p.state(2) == WARMING
    assert p.serving_replicas() == [0, 1, 2]    # routable while warming
    assert p.live_replicas() == [0, 1]          # ...but not executing
    assert p.next_wake() == 150                 # the promotion, not 200
    p.observe(149)
    assert p.state(2) == WARMING
    p.observe(150)
    assert p.state(2) == LIVE
    last = p.decisions[-1]
    assert (last.kind, last.tick, last.replica) == ("replica_up", 150, 2)


def test_scale_up_on_slo_pressure():
    p = started()
    p.route(10, 0)
    p.finish(20, 0, ok=False)       # 1/1 window violations > 10%
    p.observe(100)
    ups = [d for d in p.decisions if d.kind == "scale_up"]
    assert len(ups) == 1 and ups[0].note == "slo 1/1"


def test_scale_down_retires_idle_newest_after_quiet_windows():
    p = started(down_windows=3)
    for rid in range(5):
        p.route(10, rid)
    p.observe(100)                  # scale up to 3
    for rid in range(5):
        p.finish(160 + rid, rid)
    p.observe(400)                  # quiet boundaries at 200/300/400
    downs = [d for d in p.decisions if d.kind == "scale_down"]
    assert [(d.tick, d.replica) for d in downs] == [(400, 2)]
    assert p.state(2) == DOWN
    # never below the floor: arbitrarily many more quiet windows
    p.observe(2000)
    assert len([d for d in p.decisions if d.kind == "scale_down"]) == 1
    assert p.live_replicas() == [0, 1]


def test_scale_down_skips_busy_replicas():
    p = started(down_windows=1, min_replicas=1, max_replicas=2)
    p.route(10, 0)
    p.route(10, 1)
    p.route(10, 2)                  # 3 > cap 2 -> scale up at 100
    p.observe(100)
    assert p.state(1) == WARMING
    p.finish(160, 0)
    p.finish(160, 1)
    # rid 2 still outstanding on replica 0; replica 1 (promoted, idle)
    # is the only retirement candidate even though 0 is older
    p.observe(300)
    downs = [d for d in p.decisions if d.kind == "scale_down"]
    assert [d.replica for d in downs] == [1]
    p.finish(310, 2)


def test_promotion_processed_before_boundary_at_equal_tick():
    # cold_start == control_period: the ready tick lands exactly on the
    # next boundary, and the boundary must see the replica live
    p = started(cold_start_ticks=100)
    for rid in range(5):
        p.route(10, rid)
    p.observe(100)                  # scale_up(2), ready at 200
    p.observe(200)
    kinds = [d.kind for d in p.decisions if d.tick == 200]
    assert kinds[0] == "replica_up"
    assert p.state(2) == LIVE


def test_catch_up_processes_all_missed_boundaries_in_order():
    p = started()
    for rid in range(5):
        p.route(10, rid)
    # one late event catches up boundary 100 (scale up) AND the
    # promotion at 150 before routing
    r = p.route(500, 99)
    ticks = [d.tick for d in p.decisions]
    assert ticks == sorted(ticks)
    assert p.state(2) == LIVE
    assert r in (0, 1, 2)


def test_next_wake_is_boundary_when_nothing_warming():
    p = started()
    assert p.next_wake() == 100
    p.observe(100)
    assert p.next_wake() == 200


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_state_dict_round_trip_continues_identically():
    a = started("prefix_affinity")
    b = started("prefix_affinity")

    def drive(p, t0, rids):
        for i, rid in enumerate(rids):
            p.route(t0 + 10 * i, rid, prefix=rid % 3)
        p.observe(t0 + 100)

    drive(a, 10, range(5))
    drive(b, 10, range(5))
    fresh = mk("prefix_affinity")
    fresh.load_state_dict(a.state_dict())
    drive(fresh, 200, range(5, 10))
    drive(b, 200, range(5, 10))
    assert fresh.decisions == b.decisions
    assert fresh.state_dict() == b.state_dict()


def test_load_rejects_mismatched_configuration():
    d = started().state_dict()
    with pytest.raises(ValueError, match="slots_per_replica"):
        mk(slots_per_replica=4).load_state_dict(d)
    with pytest.raises(ValueError, match="router"):
        mk("p2c").load_state_dict(d)


def test_all_routers_are_replayable_from_state():
    """Routing after a restore matches routing without one for every
    router (no hidden RNG or unserialized state)."""
    for router in ROUTERS:
        a = started(router)
        for rid in range(8):
            a.route(10 + rid, rid, prefix=rid % 2)
        b = mk(router)
        b.load_state_dict(a.state_dict())
        assert [a.route(200 + i, 100 + i, prefix=i % 2)
                for i in range(6)] == \
               [b.route(200 + i, 100 + i, prefix=i % 2)
                for i in range(6)]
