"""Property test (MGSim-style deterministic-replay validation): chopping
a run into ARBITRARY ``advance(max_tick)`` pauses and checkpoint-restore
round trips must be invisible — the final tick and the full stats tree
are bit-identical to an uninterrupted run, for any cut placement
hypothesis can dream up."""

import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core.desim.simnodes import TICKS_PER_S
from repro.core.desim.trace import analytic_trace
from repro.sim import ExitEventType, Simulator, v5e_multipod, v5e_pod

COLLS = [{"kind": "all-reduce", "bytes": 5e7, "participants": 256}]
DCN_TAIL = [{"kind": "all-reduce", "bytes": 2e8, "participants": 512,
             "scope": "dcn"}]


def _trace(pods):
    return analytic_trace("chop", 5, 5e11, 5e8, COLLS,
                          tail_collectives=DCN_TAIL if pods > 1 else ())


def _board(pods):
    return v5e_pod() if pods == 1 else v5e_multipod(pods)


def _reference(pods):
    sim = Simulator(_board(pods), _trace(pods), record_stats=True)
    res = sim.run_to_completion()
    return res.makespan_s, res.stats


# cuts: up to 6 fractions of the makespan, each either a plain pause
# (advance to tick, yield MAX_TICK) or a full drain-serialize-restore
# checkpoint; duplicates and unsorted draws are part of the property
@given(cuts=st.lists(
    st.tuples(st.floats(0.01, 0.99), st.booleans()),
    min_size=1, max_size=6),
    pods=st.sampled_from([1, 2]))
@settings(max_examples=12, deadline=None)
def test_chopped_run_is_bit_identical(cuts, pods):
    ref_makespan, ref_stats = _reference(pods)
    horizon = ref_makespan * TICKS_PER_S
    sim = Simulator(_board(pods), _trace(pods), record_stats=True)
    for frac, is_ckpt in cuts:
        tick = int(horizon * frac)
        if is_ckpt:
            sim.schedule_checkpoint(tick)   # drain+serialize+restore
        else:
            sim.schedule_max_tick(tick)     # plain pause
    n_exits = 0
    for ev in sim.run():
        n_exits += 1
        if ev.kind is ExitEventType.DONE:
            break
    res = sim.result()
    assert res.makespan_s == ref_makespan
    assert res.stats == ref_stats
    # every cut really fired (fracs are all < 1, so every scheduled
    # exit lands before the end of the run): cuts + DONE
    assert n_exits == len(cuts) + 1


@given(fracs=st.lists(st.floats(0.05, 0.95), min_size=1, max_size=4))
@settings(max_examples=10, deadline=None)
def test_chained_checkpoint_files_round_trip(fracs, tmp_path_factory):
    """Serializing at every cut *through a JSON file* and resuming from
    the last file still lands on the reference result."""
    from repro.sim import Simulator as S
    ref_makespan, ref_stats = _reference(2)
    tmp = tmp_path_factory.mktemp("chain")
    sim = S(_board(2), _trace(2), record_stats=True,
            checkpoint_dir=str(tmp))
    for f in sorted(fracs):
        sim.schedule_checkpoint(int(ref_makespan * TICKS_PER_S * f))
    for ev in sim.run():
        if ev.kind is ExitEventType.CHECKPOINT:
            continue
    paths = sim.checkpoint_paths
    assert len(paths) >= 1
    resumed = S.from_checkpoint(paths[-1])
    res = resumed.run_to_completion()
    assert res.makespan_s == ref_makespan
    assert res.stats == ref_stats
