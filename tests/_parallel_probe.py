"""Subprocess probe for tests/test_parallel_determinism.py.

Runs the same simulations under a requested worker count and prints a
JSON digest of everything that must be identical between serial and
multiprocess execution: final ticks, the full stats-tree accumulator
state, drained snapshots, and dynamic-workload decision logs.  Executed
in a FRESH interpreter per invocation so Python hash randomization
differs between runs — any iteration order leaking from an unordered
container (in the engine, the coordinator, or the pipe protocol) shows
up as a digest mismatch.

    python tests/_parallel_probe.py <workers>
"""

import json
import sys


def static_digest(workers: int):
    """Static trace replay: straggler board, mid-run drained snapshot,
    then run to completion — the paths the parallel engine reorders."""
    from repro.core.desim.trace import analytic_trace
    from repro.sim import run_parallel, v5e_straggler

    def trace():
        return analytic_trace(
            "t", layers=6, layer_flops=2e12, layer_bytes=1e10,
            layer_collectives=[{"kind": "all-reduce", "bytes": 2e8}],
            tail_collectives=[{"kind": "all-reduce", "bytes": 5e8,
                               "scope": "dcn"}])

    board = v5e_straggler(num_pods=4, slowdown=2.0, nx=4, ny=4)
    res = run_parallel(board, trace(), workers=workers, record_stats=True)

    eng = board.executor(workers=workers, record_stats=True)
    eng.begin(trace())
    eng.advance(max_tick=125_000_000)   # mid-rendezvous (see engine tests)
    eng.drain()
    snap = eng.snapshot()
    close = getattr(eng, "close", None)
    if close:
        close()
    return {
        "makespan_s": res.makespan_s,
        "per_chip_busy_s": res.per_chip_busy_s,
        "stats": res.stats,
        "snapshot": json.dumps(snap, sort_keys=True),
    }


def serve_digest(workers: int):
    """ServeSim decision log.  Dynamic workloads are co-simulated
    in-process (Simulator coerces workers -> 1); the digest pins that
    the coercion path stays decision-for-decision identical."""
    from repro.sim import (ServeSim, ServingCost, Simulator,
                           poisson_requests, v5e_serving)
    reqs = poisson_requests(20, 200.0, seed=7)
    srv = ServeSim(cost=ServingCost.from_params(1e9, layers=4,
                                                d_model=128, chips=16),
                   requests=reqs, slots=3, seq_capacity=1024)
    Simulator(v5e_serving(4, 4, replicas=2), srv,
              workers=workers).run_to_completion()
    return {
        "arrivals": [r.arrival_tick for r in reqs],
        "decisions": [[d.kind, d.rid, d.slot, d.step, d.reason]
                      for s in srv.schedulers for d in s.decisions],
        "ttft_state": srv.p_ttft.state_dict(),
    }


def train_digest(workers: int):
    """TrainSim fault-injection decision log under the workers knob."""
    from repro.configs import get_config
    from repro.sim import (Simulator, TrainSim, TrainStepCost,
                           v5e_unreliable)
    from repro.train.ft_policy import FTPolicy
    board = v5e_unreliable(4, seed=11, horizon=100, mtbf=30.0,
                           straggler_mtbs=60.0, repair=(10, 30),
                           nx=4, ny=4)
    pol = FTPolicy(get_config("deepseek-67b"), num_steps=30,
                   ckpt_interval=10, pods=4, chips_per_pod=16)
    ts = TrainSim(
        cost=TrainStepCost.from_params(1e9, tokens_per_batch=100_000,
                                       chips=64),
        policy=pol, schedule=board.failure_schedule)
    Simulator(board, ts, workers=workers).run_to_completion()
    return {
        "decisions": [d.to_row() for d in pol.decisions],
        "final_tick": ts.summary()["makespan_s"],
        "step_state": ts.p_step.state_dict(),
    }


if __name__ == "__main__":
    workers = int(sys.argv[1])
    json.dump({"static": static_digest(workers),
               "serve": serve_digest(workers),
               "train": train_digest(workers)},
              sys.stdout, sort_keys=True)
