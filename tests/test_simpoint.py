"""SimPoint-style automatic sampling + the versioned checkpoint
library (repro.sim.fingerprint, repro.sim.ckptlib).

Acceptance (ISSUE 9): on the seeded bursty reference workload the
SimPoint-weighted reconstruction lands within 5% of the full-detail
total while the equal-budget fixed-stride SamplePlan misses by more;
region checkpoints restore bit-identically through the library —
including onto a different timing model and a re-parameterized board;
the fingerprint → cluster → plan pipeline is deterministic across
fresh interpreters.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.sim import (FEATURE_NAMES, CheckpointError, ExitEventType,
                       SampledSimulation, SamplePlan, SimPointPlan,
                       bursty_trace, chain_steps, cluster_fingerprint,
                       fingerprint_trace, reconstruct, restore_executor,
                       restore_fanout, sampled_run, simpoint_plan,
                       take_region_checkpoints, v5e_degraded, v5e_pod)
from repro.sim.fingerprint import kmeans, op_mix_vector
from repro.sim.ckptlib import (INDEX_FORMAT, INDEX_VERSION,
                               CheckpointLibrary, board_digest,
                               trace_digest)

STEPS = 60
BURST = (30, 12)          # start, length — inside the 60-step run


def _trace(seed=0):
    return bursty_trace(num_steps=STEPS, burst_start=BURST[0],
                        burst_len=BURST[1], seed=seed)


@pytest.fixture(scope="module")
def trace():
    return _trace()


@pytest.fixture(scope="module")
def plan(trace):
    # max_k=4 keeps the detailed budget below the fixed-stride plan's
    # (BIC otherwise gives every jittered burst window its own cluster)
    return simpoint_plan(trace, window=2, max_k=4, seed=0)


@pytest.fixture(scope="module")
def full_detail(trace):
    return v5e_pod().executor(timing="detailed").execute(trace)


@pytest.fixture(scope="module")
def library(tmp_path_factory, trace, plan):
    root = str(tmp_path_factory.mktemp("ckptlib") / "lib")
    return take_region_checkpoints(v5e_pod(), trace, plan, root)


# ---------------------------------------------------------------------------
# fingerprint
# ---------------------------------------------------------------------------

def test_fingerprint_windows_and_feature_dims(trace):
    fp = fingerprint_trace(trace, window=2)
    assert fp.num_windows == STEPS // 2
    assert all(len(v) == len(FEATURE_NAMES) for v in fp.vectors)
    # the op-mix signal: burst windows carry ~100x the ICI payload
    ici = FEATURE_NAMES.index("ici_coll_bytes")
    calm = fp.vectors[0][ici]
    burst = fp.vectors[BURST[0] // 2 + 1][ici]
    assert burst > 10 * calm
    # ... and identical op counts (uniform step structure)
    n_ar = FEATURE_NAMES.index("n_all-reduce")
    assert fp.vectors[0][n_ar] == fp.vectors[BURST[0] // 2 + 1][n_ar]


def test_fingerprint_partial_last_window(trace):
    fp = fingerprint_trace(trace, window=7)
    assert fp.num_windows == (STEPS + 6) // 7    # 9 windows
    assert fp.window_steps(0) == 7
    assert fp.window_steps(fp.num_windows - 1) == STEPS % 7  # 4, partial


def test_fingerprint_rejects_bad_slicing(trace):
    with pytest.raises(ValueError, match="divisible"):
        fingerprint_trace(trace, num_steps=7)
    with pytest.raises(ValueError, match="num_steps"):
        fingerprint_trace(trace, num_steps=0)
    bare = _trace()
    bare.meta.pop("steps")
    with pytest.raises(ValueError, match="meta"):
        fingerprint_trace(bare)
    with pytest.raises(ValueError, match="window"):
        fingerprint_trace(trace, window=0)


def test_op_mix_vector_scope_split(trace):
    ops = trace.ops[:5]          # one step: compute + 4 ici all-reduces
    v = op_mix_vector(ops)
    assert v[FEATURE_NAMES.index("n_compute")] == 1
    assert v[FEATURE_NAMES.index("n_all-reduce")] == 4
    assert v[FEATURE_NAMES.index("dcn_coll_bytes")] == 0
    assert v[FEATURE_NAMES.index("ici_coll_bytes")] > 0


# ---------------------------------------------------------------------------
# clustering + plan construction
# ---------------------------------------------------------------------------

def test_kmeans_is_seed_deterministic(trace):
    fp = fingerprint_trace(trace, window=2)
    a = kmeans(fp.vectors, 3, seed=11)
    b = kmeans(fp.vectors, 3, seed=11)
    assert a == b
    with pytest.raises(ValueError, match="1 <= k"):
        kmeans(fp.vectors, 0, seed=0)
    with pytest.raises(ValueError, match="1 <= k"):
        kmeans(fp.vectors, len(fp.vectors) + 1, seed=0)


def test_cluster_separates_burst_from_calm(trace):
    fp = fingerprint_trace(trace, window=2)
    labels, k = cluster_fingerprint(fp, seed=0)
    assert k >= 2
    calm_label = labels[0]
    burst_label = labels[BURST[0] // 2 + 1]
    assert calm_label != burst_label


def test_simpoint_plan_structure(trace, plan):
    assert plan.window == 2
    assert plan.representatives == sorted(set(plan.representatives))
    assert sum(plan.weights) == pytest.approx(1.0)
    assert len(plan.labels) == STEPS // 2
    # at least one representative inside the burst, one outside
    lo, hi = BURST[0] // 2, (BURST[0] + BURST[1]) // 2
    assert any(lo <= r < hi for r in plan.representatives)
    assert any(r < lo or r >= hi for r in plan.representatives)
    # SimPoint's point: few regions, small detailed budget
    assert plan.detailed_fraction(STEPS) <= 0.40


def test_simpoint_plan_validation():
    with pytest.raises(ValueError, match="align"):
        SimPointPlan(window=2, representatives=[1, 2], weights=[1.0])
    with pytest.raises(ValueError, match="sorted"):
        SimPointPlan(window=2, representatives=[2, 1],
                     weights=[0.5, 0.5])
    with pytest.raises(ValueError, match="sum to 1"):
        SimPointPlan(window=2, representatives=[1, 2],
                     weights=[0.5, 0.2])
    with pytest.raises(ValueError, match="window"):
        SimPointPlan(window=0)
    plan = SimPointPlan(window=2, representatives=[0, 2],
                        weights=[0.5, 0.5])
    with pytest.raises(ValueError, match="window times"):
        plan.weighted_total_s(10, [0.1])


def test_simpoint_segments_cover_exactly(plan):
    for n in (STEPS, STEPS - 1, 7, 1):
        segs = plan.segments(n)
        assert sum(c for _, c in segs) == n
        assert all(c > 0 for _, c in segs)
    # one segment per window, detailed exactly at the representatives
    segs = plan.segments(STEPS)
    det = [i for i, (kind, _) in enumerate(segs) if kind == "detailed"]
    assert det == plan.representatives


# ---------------------------------------------------------------------------
# the acceptance criterion: SimPoint catches the burst, stride misses
# ---------------------------------------------------------------------------

def test_simpoint_beats_fixed_stride_on_bursty_workload(trace, plan,
                                                        full_detail):
    sp = sampled_run(v5e_pod(), trace, STEPS, plan)
    assert sp.weighted_total_s is not None
    err_sp = (abs(sp.weighted_total_s - full_detail.makespan_s)
              / full_detail.makespan_s)
    assert err_sp <= 0.05

    stride = SamplePlan()           # default fixed-stride plan
    st = sampled_run(v5e_pod(), trace, STEPS, stride)
    assert st.weighted_total_s is None      # no weights, no reconstruction
    err_st = (abs(st.predicted_total_s - full_detail.makespan_s)
              / full_detail.makespan_s)
    # equal-or-larger budget, yet the stride plan misses the phase
    assert st.detailed_steps >= sp.detailed_steps
    assert err_st > err_sp
    assert err_st > 0.05


def test_chained_trace_is_used_verbatim(trace):
    sim = SampledSimulation(v5e_pod(), trace, STEPS, SamplePlan())
    events = list(sim.run())
    assert events[-1].kind is ExitEventType.DONE
    # uniform-step contract enforced
    bad = bursty_trace(num_steps=STEPS, burst_start=BURST[0],
                       burst_len=BURST[1], seed=0)
    bad.ops.pop()
    with pytest.raises(ValueError, match="divisible"):
        SampledSimulation(v5e_pod(), bad, STEPS)


def test_chain_steps_rejects_uneven_steps(trace):
    from repro.core.desim.trace import HloTrace, TraceOp
    a = HloTrace("a", ops=[TraceOp(kind="compute", flops=1.0)])
    b = HloTrace("b", ops=[TraceOp(kind="compute", flops=1.0),
                           TraceOp(kind="compute", flops=1.0, deps=(0,))])
    with pytest.raises(ValueError, match="same op count"):
        chain_steps([a, b])
    chained = chain_steps([a, a, a])
    assert chained.meta["steps"] == 3
    assert chained.ops[1].deps == (0,)      # step 1 root depends on sink


# ---------------------------------------------------------------------------
# checkpoint library
# ---------------------------------------------------------------------------

def test_library_index_format(library, trace, plan):
    index = os.path.join(library.root, "index.json")
    with open(index) as f:
        doc = json.load(f)
    assert doc["format"] == INDEX_FORMAT
    assert doc["version"] == INDEX_VERSION
    assert doc["board_digest"] == board_digest(v5e_pod())
    assert doc["trace_digest"] == trace_digest(trace)
    assert doc["timing"] == "atomic"
    assert doc["num_steps"] == STEPS
    assert len(doc["entries"]) == len(plan.representatives)
    for e, widx, w in zip(sorted(doc["entries"],
                                 key=lambda e: e["window"]),
                          plan.representatives, plan.weights):
        assert e["id"] == f"region-{widx:04d}"
        assert e["step"] == widx * plan.window
        assert e["weight"] == pytest.approx(w)
        assert os.path.exists(os.path.join(library.root, e["file"]))

    # reload from disk round-trips meta + entries
    lib2 = CheckpointLibrary(library.root)
    assert lib2.meta == library.meta
    assert sorted(e["id"] for e in lib2.entries) == \
        sorted(e["id"] for e in library.entries)


def test_library_rejects_foreign_index(tmp_path):
    root = tmp_path / "notalib"
    root.mkdir()
    (root / "index.json").write_text(json.dumps({"format": "nope"}))
    with pytest.raises(CheckpointError, match="format"):
        CheckpointLibrary(str(root))
    (root / "index.json").write_text(json.dumps(
        {"format": INDEX_FORMAT, "version": 99}))
    with pytest.raises(CheckpointError, match="version"):
        CheckpointLibrary(str(root))


def test_region_checkpoints_restore_bit_identically(library):
    """The same region restored twice yields bit-identical executors:
    equal snapshots at restore, equal results after running out."""
    eid = library.entries[0]["id"]
    a = restore_executor(library.load(eid))
    b = restore_executor(library.load(eid))
    a.advance()
    b.advance()
    assert a.result() == b.result()
    assert a.result().final_tick > 0


def test_restore_onto_different_timing_model(library, full_detail):
    """Checkpoints captured under ATOMIC restore under DETAILED — the
    gem5 switch_cpus move — and the re-timed fanout is deterministic
    and accurate."""
    rows_a = restore_fanout(library, timing="detailed")
    rows_b = restore_fanout(library, timing="detailed")
    assert rows_a == rows_b                      # bit-identical re-timing
    total = reconstruct(rows_a, lib=library)
    err = abs(total - full_detail.makespan_s) / full_detail.makespan_s
    assert err <= 0.05
    # atomic re-timing is cheaper or equal per region (contention-free)
    rows_at = restore_fanout(library, timing="atomic")
    assert all(at.step_s <= dt.step_s + 1e-12
               for at, dt in zip(rows_at, rows_a))


def test_fanout_parallel_matches_serial(library):
    serial = restore_fanout(library, workers=1)
    par = restore_fanout(library, workers=2)
    assert serial == par
    with pytest.raises(ValueError, match="workers"):
        restore_fanout(library, workers=0)


def test_fanout_onto_reparameterized_board(library):
    """checkpoint-once / sweep-everything: the library restores onto a
    derated board and the burst regions get slower."""
    base = restore_fanout(library)
    sick = restore_fanout(library, board=v5e_degraded())
    assert [r.id for r in base] == [r.id for r in sick]
    assert all(s.step_s > b.step_s for b, s in zip(base, sick))
    assert reconstruct(sick, lib=library) > reconstruct(base, lib=library)


def test_reconstruct_matches_in_engine_weighted_total(library, trace,
                                                      plan):
    """The fanout measurement and the in-engine sampled run are two
    routes to the same number."""
    sp = sampled_run(v5e_pod(), trace, STEPS, plan)
    total = reconstruct(restore_fanout(library), lib=library)
    assert total == pytest.approx(sp.weighted_total_s, rel=1e-6)


# ---------------------------------------------------------------------------
# determinism across fresh interpreters (_seed_probe.py-style)
# ---------------------------------------------------------------------------

_PROBE = os.path.join(os.path.dirname(__file__), "_simpoint_probe.py")
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _probe(seed: int, hash_seed: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["PYTHONHASHSEED"] = hash_seed
    out = subprocess.run([sys.executable, _PROBE, str(seed)],
                         capture_output=True, text=True, env=env,
                         cwd=_ROOT, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout)


def test_same_seed_same_plan_across_fresh_interpreters():
    a = _probe(3, hash_seed="1")
    b = _probe(3, hash_seed="17")        # different hash randomization
    assert a == b
    c = _probe(4, hash_seed="1")
    assert c["vectors"] != a["vectors"]  # the seed actually matters
