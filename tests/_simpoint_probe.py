"""Subprocess probe for tests/test_simpoint.py's determinism test.

Builds the bursty reference trace, fingerprints it, clusters it, and
prints a JSON digest of everything a SimPoint plan must pin down from
a seed alone: feature vectors, cluster labels, representatives,
weights.  Executed in a FRESH interpreter per invocation with
different PYTHONHASHSEEDs — any dict-iteration-order leak in the
feature ordering or the clustering shows up as a digest mismatch.

    python tests/_simpoint_probe.py <seed>
"""

import json
import sys


def plan_digest(seed: int):
    from repro.sim import bursty_trace, fingerprint_trace, simpoint_plan
    trace = bursty_trace(num_steps=60, burst_start=30, burst_len=12,
                         seed=seed)
    fp = fingerprint_trace(trace, window=2)
    plan = simpoint_plan(trace, window=2, seed=seed)
    return {
        "vectors": fp.vectors,
        "labels": plan.labels,
        "representatives": plan.representatives,
        "weights": plan.weights,
    }


if __name__ == "__main__":
    json.dump(plan_digest(int(sys.argv[1])), sys.stdout, sort_keys=True)
