"""Acceptance: SimPoint/SMARTS-style sampled simulation
(repro.sim.sampling).  On a >=100-step steady-state trace, sampled mode
executes <= 20% of ops at detailed fidelity yet predicts the total time
of the full detailed run within 5%."""

import pytest

from repro.core.desim.trace import analytic_trace
from repro.sim import (ExitEventType, SamplePlan, SampledSimulation,
                       atomic_step_time_s, repeat_trace, sampled_run,
                       v5e_multipod, v5e_pod)

COLLS = [{"kind": "all-reduce", "bytes": 2e8, "participants": 256}]


def _step(layers=4):
    return analytic_trace("step", layers, 1e12, 1e9, COLLS)


def test_sampled_acceptance_contract():
    """The headline criterion: >=100 steps, <=20% detailed ops, <=5%
    error vs the full contention-aware detailed run."""
    step = _step()
    num_steps = 120
    board = v5e_pod()
    full = board.executor().execute(repeat_trace(step, num_steps))

    res = sampled_run(v5e_pod(), step, num_steps,
                      SamplePlan(warmup=2, interval=12, window=2))
    assert res.detailed_op_fraction <= 0.20
    err = abs(res.predicted_total_s - full.makespan_s) / full.makespan_s
    assert err <= 0.05
    # and it genuinely fired far fewer engine events
    assert res.events <= 0.25 * full.events


def test_sampled_multipod_with_dcn():
    tail = [{"kind": "all-reduce", "bytes": 1e9, "participants": 512,
             "scope": "dcn"}]
    step = analytic_trace("step", 4, 1e12, 1e9, COLLS,
                          tail_collectives=tail)
    num_steps = 100
    full = v5e_multipod(2).executor().execute(repeat_trace(step, num_steps))
    res = sampled_run(v5e_multipod(2), step, num_steps,
                      SamplePlan(warmup=2, interval=20, window=2))
    assert res.detailed_op_fraction <= 0.20
    err = abs(res.predicted_total_s - full.makespan_s) / full.makespan_s
    assert err <= 0.05


def test_plan_segments_cover_the_run_exactly():
    plan = SamplePlan(warmup=3, interval=10, window=2)
    for n in (1, 3, 17, 100, 123):
        segs = plan.segments(n)
        assert sum(c for _, c in segs) == n
        assert all(c > 0 for _, c in segs)
    assert plan.detailed_fraction(100) <= 0.25
    with pytest.raises(ValueError):
        SamplePlan(interval=2, window=4)


def test_sample_begin_exit_events_stream():
    step = _step(layers=2)
    sim = SampledSimulation(v5e_pod(), step, 50,
                            SamplePlan(warmup=1, interval=10, window=1))
    events = list(sim.run())
    kinds = [e.kind for e in events]
    n_windows = sum(1 for k, _ in sim.result().segments if k == "detailed")
    assert kinds.count(ExitEventType.SAMPLE_BEGIN) == n_windows
    assert kinds[-1] is ExitEventType.DONE
    # sample windows report their step position
    assert events[0].payload["step"] == 0


def test_atomic_ff_mode_uses_roofline_estimate():
    step = _step()
    atomic = atomic_step_time_s(v5e_pod(), step)
    assert atomic > 0
    res = sampled_run(v5e_pod(), step, 40,
                      SamplePlan(warmup=0, interval=20, window=2),
                      ff_mode="atomic")
    assert res.atomic_step_s == atomic
    # prediction is still in the right ballpark (atomic ignores
    # contention, so allow a loose band)
    full = v5e_pod().executor().execute(repeat_trace(step, 40))
    assert res.predicted_total_s == pytest.approx(full.makespan_s, rel=0.3)


def test_fast_forward_accumulates_real_stats():
    """The in-engine rewrite's headline: fast-forwarded steps execute
    for real at atomic fidelity, so the stats tree covers EVERY op of
    EVERY step — no extrapolated dead zones."""
    step = _step(layers=4)
    num_steps = 60
    res = sampled_run(v5e_pod(), step, num_steps,
                      SamplePlan(warmup=1, interval=12, window=1))
    assert res.detailed_op_fraction < 0.25       # mostly fast-forwarded
    assert res.stats is not None
    assert res.stats["sim.chip0.ops_executed"] == 4 * num_steps
    assert res.stats["sim.wire0.collectives"] == 4 * num_steps
    # chain-structured steps: atomic FF is tick-exact, so the sampled
    # run's final tick EQUALS the full-detail run's
    full = v5e_pod().executor().execute(repeat_trace(step, num_steps))
    assert res.predicted_total_s == full.makespan_s


def test_sampling_rejects_bad_ff_mode():
    with pytest.raises(ValueError, match="ff_mode"):
        SampledSimulation(v5e_pod(), _step(), 10, ff_mode="psychic")
    # the analytical extrapolation mode was removed with the in-engine
    # rewrite; the error says where to look
    with pytest.raises(ValueError, match="in-engine"):
        SampledSimulation(v5e_pod(), _step(), 10, ff_mode="extrapolate")


# ---------------------------------------------------------------------------
# SamplePlan.segments edge cases
# ---------------------------------------------------------------------------

def test_segments_warmup_covers_whole_run():
    # warmup >= num_steps: one detailed segment, nothing else
    plan = SamplePlan(warmup=10, interval=12, window=2)
    assert plan.segments(10) == [("detailed", 10)]
    assert plan.segments(3) == [("detailed", 3)]
    assert plan.detailed_fraction(3) == 1.0


def test_segments_interval_equals_window_is_all_detailed():
    # interval == window leaves no room to fast-forward
    plan = SamplePlan(warmup=0, interval=3, window=3)
    segs = plan.segments(9)
    assert all(kind == "detailed" for kind, _ in segs)
    assert sum(n for _, n in segs) == 9
    assert plan.detailed_fraction(9) == 1.0


def test_segments_zero_and_one_step():
    plan = SamplePlan(warmup=2, interval=12, window=2)
    # num_steps=0: NO segments at all — in particular no zero-length
    # ("detailed", 0) warmup stub (regression: the old code emitted one)
    assert plan.segments(0) == []
    assert plan.segments(1) == [("detailed", 1)]
    no_warm = SamplePlan(warmup=0, interval=12, window=2)
    assert no_warm.segments(0) == []
    assert no_warm.segments(1) == [("detailed", 1)]


def test_segments_never_zero_length():
    for warmup in (0, 1, 5):
        for interval, window in ((2, 1), (2, 2), (12, 2), (7, 7)):
            plan = SamplePlan(warmup=warmup, interval=interval,
                              window=window)
            for n in (0, 1, 2, 7, 24, 100):
                segs = plan.segments(n)
                assert sum(c for _, c in segs) == n
                assert all(c > 0 for _, c in segs), (plan, n, segs)
