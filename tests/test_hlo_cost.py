"""Loop-aware HLO cost analysis: trip-count multiplication, dot flops,
collective bytes.  Uses a synthetic HLO module (single-device pytest
must not force multi-device XLA flags) plus a real single-device
compile for the scan-vs-unroll invariant."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.desim.hlo_cost import (HloCostModel, analyze_hlo,
                                       parse_module, shape_elems_bytes)

SYNTH = """\
HloModule synth, num_partitions=4

%body (p: (s32[], f32[128,256], f32[8,256,256])) -> (s32[], f32[128,256], f32[8,256,256]) {
  %p = (s32[], f32[128,256]{1,0}, f32[8,256,256]{2,1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,256,256]{2,1,0} get-tuple-element(%p), index=2
  %c1 = s32[] constant(1)
  %zero = s32[] constant(0)
  %inext = s32[] add(%i, %c1)
  %ws = f32[1,256,256]{2,1,0} dynamic-slice(%w, %i, %zero, %zero), dynamic_slice_sizes={1,256,256}
  %wsq = f32[256,256]{1,0} bitcast(%ws)
  %ag = f32[128,256]{1,0} all-gather(%x), channel_id=1, replica_groups=[1,4]<=[4], dimensions={1}
  %dot = f32[128,256]{1,0} dot(%ag, %wsq), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[128,256]{1,0}, f32[8,256,256]{2,1,0}) tuple(%inext, %dot, %w)
}

%cond (p: (s32[], f32[128,256], f32[8,256,256])) -> pred[] {
  %p = (s32[], f32[128,256]{1,0}, f32[8,256,256]{2,1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(8)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,256], w: f32[8,256,256]) -> f32[128,256] {
  %x = f32[128,256]{1,0} parameter(0)
  %w = f32[8,256,256]{2,1,0} parameter(1)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[128,256]{1,0}, f32[8,256,256]{2,1,0}) tuple(%c0, %x, %w)
  %loop = (s32[], f32[128,256]{1,0}, f32[8,256,256]{2,1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_shape_parse():
    e, b = shape_elems_bytes("bf16[4,8]{1,0}")
    assert e == 32 and b == 64
    e, b = shape_elems_bytes("(f32[2,2], s32[])")
    assert e == 5 and b == 20


def test_synthetic_while_multiplies_costs():
    comps, entry = parse_module(SYNTH)
    assert entry == "main" and set(comps) == {"body", "cond", "main"}
    cost = analyze_hlo(SYNTH)
    # dot: 2 * 128*256 * 256 per trip, 8 trips
    dot_flops = 2 * 128 * 256 * 256 * 8
    assert cost.flops == pytest.approx(dot_flops, rel=0.01)
    # all-gather operand: 128*256*4 bytes per trip, 8 trips
    assert cost.collective_bytes == pytest.approx(128 * 256 * 4 * 8)
    assert cost.collectives["all-gather"]["count"] == 8
    m = HloCostModel(SYNTH)
    m.analyze()
    assert m.while_trips == [("loop", 8)]


def test_dynamic_slice_charged_at_slice_size():
    cost = analyze_hlo(SYNTH)
    # bytes should NOT include 8 full reads of the (8,256,256) stacked
    # weights: slice-aware accounting charges the (256,256) slice.
    full_w = 8 * 256 * 256 * 4
    assert cost.bytes < 8 * full_w          # would be >= if over-charged


def test_real_compile_scan_equals_unroll():
    L, B, D = 6, 64, 32

    def f_scan(x, w):
        def body(x, wi):
            return x @ wi, None
        return jax.lax.scan(body, x, w)[0].sum()

    def f_unroll(x, w):
        for i in range(L):
            x = x @ w[i]
        return x.sum()

    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    costs = {}
    for name, fn in [("scan", f_scan), ("unroll", f_unroll)]:
        c = jax.jit(fn).lower(x, w).compile()
        costs[name] = analyze_hlo(c.as_text())
    assert costs["scan"].flops == pytest.approx(costs["unroll"].flops,
                                                rel=0.05)
    analytic = L * 2 * B * D * D
    assert costs["unroll"].flops == pytest.approx(analytic, rel=0.15)


# ---------------------------------------------------------------------------
# the shared dtype table (repro.core.desim.dtypes)
# ---------------------------------------------------------------------------

def test_both_hlo_parsers_agree_on_tricky_shapes():
    """trace.shape_bytes and hlo_cost.shape_elems_bytes are two views
    of one shared lexer; they must agree byte-for-byte on the awkward
    cases: half-byte int4, one-byte f8 variants, f32[] scalars, tuple
    return types, and zero-width token/opaque types."""
    from repro.core.desim import dtypes
    from repro.core.desim.trace import shape_bytes as trace_bytes

    cases = {
        "f8e4m3fn[128,64]{1,0}": 128 * 64 * 1,
        "f8e5m2[16]": 16,
        "s4[256,2]{1,0}": 256 * 2 * 0.5,          # packed int4: half bytes
        "u4[3]": 1.5,                              # fractional total
        "f32[]": 4,                                # scalar: empty dims
        "(f32[2,3]{1,0}, s4[8], bf16[])": 2 * 3 * 4 + 4 + 2,
        "(s32[], f32[128,256]{1,0}, f32[8,256,256]{2,1,0})":
            4 + 128 * 256 * 4 + 8 * 256 * 256 * 4,
        "token[]": 0,
        "opaque[]": 0,
        "mystery99[64]": 0,                        # unknown dtype: skipped
        "pred[7]": 7,
    }
    for type_str, expect in cases.items():
        tb = trace_bytes(type_str)
        he, hb = shape_elems_bytes(type_str)
        assert tb == pytest.approx(expect), type_str
        assert hb == pytest.approx(expect), type_str
        assert tb == hb, type_str
        assert dtypes.shape_bytes(type_str) == tb


def test_dtype_table_is_single_sourced():
    """Neither parser carries a private copy of the width table."""
    import repro.core.desim.hlo_cost as hc
    import repro.core.desim.trace as tr
    from repro.core.desim import dtypes
    assert not hasattr(tr, "_DTYPE_BYTES")
    assert not hasattr(hc, "_DTYPE_BYTES")
    assert tr.shape_bytes is dtypes.shape_bytes
    assert hc.shape_elems_bytes is dtypes.shape_elems_bytes
    # s4/u4 stay half-byte, f8s one byte (the values tests rely on)
    assert dtypes.DTYPE_BYTES["s4"] == 0.5
    assert dtypes.DTYPE_BYTES["f8e4m3fn"] == 1
