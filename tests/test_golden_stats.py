"""Golden-stats regression tier (gem5's nightly golden-output tests).

gem5's regression suite diffs each run's ``stats.txt`` against a
committed golden copy: any timing change — intended or not — shows up
as a stats diff that a human must bless.  This reproduces that tier
for three canonical board x trace runs: the full gem5-style stats dump
(plus the final tick and event count, the two values every timing bug
perturbs first) is rendered to text and diffed line-by-line against
``tests/golden/<name>.txt``.

Updating a golden (after an *intended* timing change)::

    python -m pytest tests/test_golden_stats.py --regen-golden
    git diff tests/golden/        # review every changed line!

Run this tier alone with ``tools/ci.sh golden``.
"""

import difflib
import os

import pytest

from repro.configs import get_config
from repro.core.desim.simnodes import TICKS_PER_S
from repro.core.desim.trace import analytic_trace
from repro.sim import (ServeSim, ServingCost, Simulator, TrainSim,
                       TrainStepCost, poisson_requests, v5e_multipod,
                       v5e_pod, v5e_serving, v5e_straggler, v5e_unreliable)
from repro.train.ft_policy import FTPolicy

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

COLLS = [{"kind": "all-reduce", "bytes": 1e8, "participants": 256}]
DCN_TAIL = [{"kind": "all-reduce", "bytes": 1e9, "participants": 512,
             "scope": "dcn"}]


def _mixed_trace(tail=False):
    """A deterministic, code-defined trace: compute + torus collectives
    per layer, optionally a cross-pod DCN tail (exercises QuantumSync)."""
    return analytic_trace("golden", 6, 1e12, 1e9, COLLS,
                          tail_collectives=DCN_TAIL if tail else ())


def _serve_workload(board):
    """A short, fully-seeded serving run (dynamic-workload golden)."""
    cost = ServingCost.from_params(7e9, layers=32, d_model=4096,
                                   chips=board.machine.num_chips)
    reqs = poisson_requests(12, 40.0, seed=7, prompt_len=(32, 128),
                            decode_len=(8, 24))
    return ServeSim(cost=cost, requests=reqs, slots=4, seq_capacity=256,
                    slo_ttft_s=0.01, slo_latency_s=1.0)


def _train_workload(board):
    """A short fault-injected training run (dynamic-workload golden)."""
    pol = FTPolicy(get_config("deepseek-67b"), num_steps=20,
                   ckpt_interval=5, pods=2,
                   chips_per_pod=board.machine.pod.num_chips,
                   dead_after_misses=1)
    cost = TrainStepCost.from_params(1e9, tokens_per_batch=100_000,
                                     chips=board.machine.num_chips)
    return TrainSim(cost=cost, policy=pol,
                    schedule=board.failure_schedule)


# name -> (board builder, workload builder); canonical runs covering
# the single-pod torus, the multipod DCN/quantum path, straggler
# injection, and the two dynamic workloads (serving + FT training)
CASES = {
    "pod_torus": (lambda: v5e_pod(), lambda b: _mixed_trace()),
    "multipod_dcn": (lambda: v5e_multipod(2), lambda b: _mixed_trace(True)),
    "straggler": (lambda: v5e_straggler(2, 2.0),
                  lambda b: _mixed_trace(True)),
    "serve_sim": (lambda: v5e_serving(4, 4), _serve_workload),
    "train_sim": (lambda: v5e_unreliable(2, seed=5, horizon=120,
                                         mtbf=30.0, repair=(5, 15),
                                         nx=8, ny=8),
                  _train_workload),
}


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.12g}"          # stable text for accumulated floats
    if isinstance(v, list):
        return "[" + ", ".join(_fmt(x) for x in v) + "]"
    if isinstance(v, dict):         # distribution stats render as dicts
        return "{" + ", ".join(f"{k!r}: {_fmt(x)}"
                               for k, x in v.items()) + "}"
    return str(v)


def _render(name: str) -> str:
    board_fn, workload_fn = CASES[name]
    board = board_fn()
    sim = Simulator(board, workload_fn(board), record_stats=True)
    res = sim.run_to_completion()
    stats = dict(res.stats)
    if sim.workload is not None:
        # dynamic workloads carry their own stats tree (TTFT
        # percentiles, goodput, ...) — golden-diff it too
        stats.update(sim.workload.stats.flat())
    lines = [f"case: {name}",
             f"board: {board.name}",
             f"final_tick: {int(round(res.makespan_s * TICKS_PER_S))}",
             f"events: {res.events}",
             "---------- Begin Simulation Statistics ----------"]
    for k, v in sorted(stats.items()):
        lines.append(f"{k:<48} {_fmt(v)}")
    lines.append("---------- End Simulation Statistics ----------")
    return "\n".join(lines) + "\n"


@pytest.mark.parametrize("name", sorted(CASES))
def test_golden_stats(name, regen_golden):
    got = _render(name)
    path = os.path.join(GOLDEN_DIR, f"{name}.txt")
    if regen_golden:
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            f.write(got)
        pytest.skip(f"regenerated {path}")
    if not os.path.exists(path):
        pytest.fail(f"missing golden file {path}; run "
                    f"`python -m pytest {__file__} --regen-golden` "
                    "and commit the result")
    with open(path) as f:
        want = f.read()
    if got != want:
        diff = "\n".join(difflib.unified_diff(
            want.splitlines(), got.splitlines(),
            fromfile=f"golden/{name}.txt (committed)",
            tofile=f"{name} (this run)", lineterm=""))
        pytest.fail(
            f"stats for {name!r} diverged from the committed golden "
            f"dump.\nIf this timing change is INTENDED, regenerate with "
            f"--regen-golden and commit; otherwise it is a regression.\n"
            f"{diff}")


def test_render_is_deterministic():
    """The rendering itself is stable within one process — a flaky
    golden tier would train everyone to ignore it."""
    name = sorted(CASES)[0]
    assert _render(name) == _render(name)
