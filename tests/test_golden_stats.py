"""Golden-stats regression tier (gem5's nightly golden-output tests).

gem5's regression suite diffs each run's ``stats.txt`` against a
committed golden copy: any timing change — intended or not — shows up
as a stats diff that a human must bless.  This reproduces that tier
for three canonical board x trace runs: the full gem5-style stats dump
(plus the final tick and event count, the two values every timing bug
perturbs first) is rendered to text and diffed line-by-line against
``tests/golden/<name>.txt``.

Updating a golden (after an *intended* timing change)::

    python -m pytest tests/test_golden_stats.py --regen-golden
    git diff tests/golden/        # review every changed line!

Run this tier alone with ``tools/ci.sh golden``.
"""

import difflib
import os

import pytest

from repro.core.desim.simnodes import TICKS_PER_S
from repro.core.desim.trace import analytic_trace
from repro.sim import Simulator, v5e_multipod, v5e_pod, v5e_straggler

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

COLLS = [{"kind": "all-reduce", "bytes": 1e8, "participants": 256}]
DCN_TAIL = [{"kind": "all-reduce", "bytes": 1e9, "participants": 512,
             "scope": "dcn"}]


def _mixed_trace(tail=False):
    """A deterministic, code-defined trace: compute + torus collectives
    per layer, optionally a cross-pod DCN tail (exercises QuantumSync)."""
    return analytic_trace("golden", 6, 1e12, 1e9, COLLS,
                          tail_collectives=DCN_TAIL if tail else ())


# name -> (board builder, trace builder); three canonical runs covering
# the single-pod torus, the multipod DCN/quantum path, and straggler
# injection
CASES = {
    "pod_torus": (lambda: v5e_pod(), lambda: _mixed_trace()),
    "multipod_dcn": (lambda: v5e_multipod(2), lambda: _mixed_trace(True)),
    "straggler": (lambda: v5e_straggler(2, 2.0),
                  lambda: _mixed_trace(True)),
}


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.12g}"          # stable text for accumulated floats
    if isinstance(v, list):
        return "[" + ", ".join(_fmt(x) for x in v) + "]"
    if isinstance(v, dict):         # distribution stats render as dicts
        return "{" + ", ".join(f"{k!r}: {_fmt(x)}"
                               for k, x in v.items()) + "}"
    return str(v)


def _render(name: str) -> str:
    board_fn, trace_fn = CASES[name]
    board = board_fn()
    sim = Simulator(board, trace_fn(), record_stats=True)
    res = sim.run_to_completion()
    lines = [f"case: {name}",
             f"board: {board.name}",
             f"final_tick: {int(round(res.makespan_s * TICKS_PER_S))}",
             f"events: {res.events}",
             "---------- Begin Simulation Statistics ----------"]
    for k, v in sorted(res.stats.items()):
        lines.append(f"{k:<48} {_fmt(v)}")
    lines.append("---------- End Simulation Statistics ----------")
    return "\n".join(lines) + "\n"


@pytest.mark.parametrize("name", sorted(CASES))
def test_golden_stats(name, regen_golden):
    got = _render(name)
    path = os.path.join(GOLDEN_DIR, f"{name}.txt")
    if regen_golden:
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            f.write(got)
        pytest.skip(f"regenerated {path}")
    if not os.path.exists(path):
        pytest.fail(f"missing golden file {path}; run "
                    f"`python -m pytest {__file__} --regen-golden` "
                    "and commit the result")
    with open(path) as f:
        want = f.read()
    if got != want:
        diff = "\n".join(difflib.unified_diff(
            want.splitlines(), got.splitlines(),
            fromfile=f"golden/{name}.txt (committed)",
            tofile=f"{name} (this run)", lineterm=""))
        pytest.fail(
            f"stats for {name!r} diverged from the committed golden "
            f"dump.\nIf this timing change is INTENDED, regenerate with "
            f"--regen-golden and commit; otherwise it is a regression.\n"
            f"{diff}")


def test_render_is_deterministic():
    """The rendering itself is stable within one process — a flaky
    golden tier would train everyone to ignore it."""
    name = sorted(CASES)[0]
    assert _render(name) == _render(name)
