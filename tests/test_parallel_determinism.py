"""Multiprocess determinism across PROCESS boundaries: a workers=4 run
must produce bit-identical results, stats trees, snapshots, and
dynamic-workload decision logs to a workers=1 run — in fresh
interpreters with DIFFERENT hash randomization, so set/dict iteration
order leaking into the coordinator, pipe protocol, or shard folding
shows up as a digest mismatch (the same bar ``test_seed_determinism``
sets for seeded workloads)."""

import json
import os
import subprocess
import sys

import pytest

_PROBE = os.path.join(os.path.dirname(__file__), "_parallel_probe.py")
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _probe(workers: int, hash_seed: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["PYTHONHASHSEED"] = hash_seed
    out = subprocess.run([sys.executable, _PROBE, str(workers)],
                         capture_output=True, text=True, env=env,
                         cwd=_ROOT, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout)


@pytest.fixture(scope="module")
def digests():
    return {1: _probe(1, hash_seed="1"),
            4: _probe(4, hash_seed="99")}


def test_static_replay_identical_across_worker_counts(digests):
    a, b = digests[1]["static"], digests[4]["static"]
    assert a["makespan_s"] == b["makespan_s"]
    assert a["per_chip_busy_s"] == b["per_chip_busy_s"]
    assert a["stats"] == b["stats"]
    assert a["snapshot"] == b["snapshot"]   # incl. mid-rendezvous state


def test_serve_decisions_identical_under_workers_knob(digests):
    a, b = digests[1]["serve"], digests[4]["serve"]
    assert a["decisions"] == b["decisions"]
    assert a["ttft_state"] == b["ttft_state"]
    assert a == b


def test_train_decisions_identical_under_workers_knob(digests):
    a, b = digests[1]["train"], digests[4]["train"]
    assert a["decisions"] == b["decisions"]
    assert a["final_tick"] == b["final_tick"]
    assert a == b
