"""The streaming percentile Stat: relative-error accuracy bounds vs
numpy on known distributions, and checkpoint-grade state round-trips."""

import json
import math
import random

import numpy as np
import pytest

from repro.core.stats import Percentiles, StatGroup

QS = [0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99]


def _check_accuracy(samples, rel_err):
    p = Percentiles("x", rel_err=rel_err)
    for v in samples:
        p.sample(v)
    arr = np.asarray(samples)
    for q in QS:
        # compare against the exact order statistic the sketch targets
        exact = float(np.quantile(arr, q, method="lower"))
        got = p.quantile(q)
        assert got == pytest.approx(exact, rel=2 * rel_err), (q, got, exact)


@pytest.mark.parametrize("rel_err", [0.01, 0.05])
def test_quantile_accuracy_lognormal(rel_err):
    rng = random.Random(0)
    _check_accuracy([rng.lognormvariate(0.0, 1.5) for _ in range(20_000)],
                    rel_err)


def test_quantile_accuracy_uniform_and_exponential():
    rng = random.Random(1)
    _check_accuracy([rng.uniform(1e-3, 10.0) for _ in range(20_000)], 0.01)
    _check_accuracy([rng.expovariate(3.0) for _ in range(20_000)], 0.01)


def test_heavy_tail_relative_error_holds_at_p99():
    """The point of log bins: a distribution whose p99 is ~1000x the
    median still reports p99 within relative (not absolute) error."""
    rng = random.Random(2)
    samples = [rng.lognormvariate(0.0, 3.0) for _ in range(50_000)]
    p = Percentiles("lat", rel_err=0.01)
    for v in samples:
        p.sample(v)
    exact = float(np.quantile(np.asarray(samples), 0.99, method="lower"))
    assert abs(p.quantile(0.99) - exact) / exact <= 0.02


def test_small_and_degenerate_inputs():
    p = Percentiles("x")
    assert p.quantile(0.5) == 0.0           # empty sketch
    assert p.value()["count"] == 0
    p.sample(0.0)                           # zero bin
    p.sample(-1.0)                          # clamped to zero bin
    assert p.quantile(0.5) == 0.0
    assert p.value()["min"] == 0.0          # clamp covers min/mean too
    assert p.mean == 0.0
    p2 = Percentiles("y")
    p2.sample(42.0)
    assert p2.quantile(0.0) == pytest.approx(42.0, rel=0.02)
    assert p2.quantile(1.0) == pytest.approx(42.0, rel=0.02)
    assert p2.mean == 42.0
    with pytest.raises(ValueError):
        p2.quantile(1.5)
    with pytest.raises(ValueError):
        Percentiles("z", rel_err=1.0)


def test_value_dict_shape():
    p = Percentiles("lat", unit="s")
    for i in range(1, 101):
        p.sample(i / 100.0)
    v = p.value()
    assert set(v) == {"count", "mean", "min", "max",
                      "p50", "p90", "p95", "p99"}
    assert v["count"] == 100
    assert v["min"] == 0.01 and v["max"] == 1.0
    assert v["p50"] <= v["p90"] <= v["p95"] <= v["p99"]


def test_state_dict_round_trip_continues_streaming():
    """Restore + continue == never paused (the checkpoint contract all
    Stats obey), including through a JSON round trip."""
    rng = random.Random(3)
    first = [rng.lognormvariate(0, 1) for _ in range(5000)]
    rest = [rng.lognormvariate(0, 1) for _ in range(5000)]

    ref = Percentiles("x")
    for v in first + rest:
        ref.sample(v)

    a = Percentiles("x")
    for v in first:
        a.sample(v)
    b = Percentiles("x")
    b.load_state_dict(json.loads(json.dumps(a.state_dict())))
    for v in rest:
        b.sample(v)
    assert b.state_dict() == ref.state_dict()
    assert b.value() == ref.value()


def test_empty_sketch_state_is_strict_json():
    """An unsampled sketch must serialize without Infinity literals
    (RFC 8259 checkpoints) and restore to a working empty sketch."""
    p = Percentiles("x")
    s = json.loads(json.dumps(p.state_dict(), allow_nan=False))
    q = Percentiles("x")
    q.load_state_dict(s)
    q.sample(2.0)
    assert q.value()["min"] == 2.0 and q.value()["max"] == 2.0
    # Distribution obeys the same contract
    from repro.core.stats import Distribution
    d = Distribution("y")
    s2 = json.loads(json.dumps(d.state_dict(), allow_nan=False))
    d2 = Distribution("y")
    d2.load_state_dict(s2)
    d2.sample(3.0)
    assert d2.value()["min"] == 3.0


def test_state_dict_rejects_mismatched_resolution():
    a = Percentiles("x", rel_err=0.01)
    a.sample(1.0)
    b = Percentiles("x", rel_err=0.05)
    with pytest.raises(ValueError, match="rel_err"):
        b.load_state_dict(a.state_dict())


def test_percentiles_in_stat_group_tree():
    g = StatGroup("root")
    p = g.percentiles("ttft", "time to first token", "s")
    p.sample(0.25)
    assert g.flat()["root.ttft"]["count"] == 1
    # group-level state dict carries the sketch
    g2 = StatGroup("root")
    g2.percentiles("ttft", "time to first token", "s")
    g2.load_state_dict(g.state_dict())
    assert g2["ttft"].value() == p.value()


def test_bin_midpoint_is_within_gamma_bound():
    """Every representable value is within rel_err of its bin midpoint
    (the DDSketch guarantee the quantile query rests on)."""
    p = Percentiles("x", rel_err=0.02)
    for v in [1e-6, 0.37, 1.0, 99.5, 1e9]:
        q = Percentiles("q", rel_err=0.02)
        q.sample(v)
        # edge values sit at exactly rel_err from the midpoint; allow
        # a hair of float slack on top of the guarantee
        assert q.quantile(0.5) == pytest.approx(v, rel=0.0201)
        assert math.isfinite(q.quantile(0.5))
