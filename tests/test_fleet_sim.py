"""FleetSim acceptance: the DES fleet and the real FleetController,
driven by the one pure FleetPolicy, scale and route *identically*
(decision-log equality on seeded traffic) — plus autoscaler recovery,
cold-start cost, scale exit events, determinism, and checkpointing."""

import pytest

from repro.core.desim.simnodes import to_ticks
from repro.serve.fleet import SCALE_KINDS, FleetController
from repro.serve.fleet_policy import FleetPolicy
from repro.sim import (ExitEventType, FleetRequest, FleetSim, ServingCost,
                       Simulator, diurnal_requests, flash_crowd_requests,
                       v5e_fleet)

COST = ServingCost.from_params(7e9, layers=32, d_model=4096, chips=4)


def _policy(router="p2c", **kw):
    cfg = dict(min_replicas=1, max_replicas=3, slots_per_replica=4,
               cold_start_ticks=to_ticks(0.25),
               control_period_ticks=to_ticks(0.25), seed=5)
    cfg.update(kw)
    return FleetPolicy(router, **cfg)


def _flash(num=60, seed=3):
    return flash_crowd_requests(num, seed=seed, base_rps=20.0,
                                crowd_rps=120.0, crowd_start_s=0.5,
                                crowd_len_s=1.0, prefix_groups=4)


def _run(reqs, policy, *, timing="detailed", **params):
    params.setdefault("seq_capacity", 1024)
    fleet = FleetSim(cost=COST, requests=reqs, policy=policy, **params)
    sim = Simulator(v5e_fleet(max_replicas=policy.max_replicas,
                              nx=2, ny=2), fleet, timing=timing)
    events = list(sim.run())
    return fleet, sim, events


# ---------------------------------------------------------------------------
# the headline: DES fleet == real controller, decision for decision
# ---------------------------------------------------------------------------

def _assert_identity(reqs, policy_fn, **params):
    fleet, _, _ = _run(reqs, policy_fn(), **params)
    fired = []
    ctl = FleetController(policy_fn(), on_scale=fired.append)
    ctl.replay(fleet.feed, reqs)
    assert ctl.policy.decisions == fleet.policy.decisions
    # the provisioner callback saw exactly the scale actions in the log
    assert fired == [d for d in fleet.policy.decisions
                     if d.kind in SCALE_KINDS]
    return fleet


def test_flash_crowd_identity_des_vs_controller():
    fleet = _assert_identity(_flash(), lambda: _policy("p2c"),
                             slo_ttft_s=0.3, slo_latency_s=2.0)
    kinds = {d.kind for d in fleet.policy.decisions}
    # the scenario exercises the whole control plane, not a quiet lap
    assert {"route", "finish", "scale_up", "replica_up"} <= kinds
    assert fleet.summary()["requests"] == 60


def test_diurnal_identity_with_affinity_and_tenants():
    reqs = diurnal_requests(50, seed=11, base_rps=15.0, peak_rps=120.0,
                            period_s=2.0, prefix_groups=4)
    fleet = _assert_identity(reqs, lambda: _policy("prefix_affinity"),
                             slo_ttft_s=0.3, slo_latency_s=2.0,
                             tenant_slo={"batch": 4.0})
    assert {r.tenant for r in reqs} == {"interactive", "batch"}
    summ = fleet.summary()
    assert "p99_ttft_interactive_s" in summ
    assert "p99_ttft_batch_s" in summ


def test_controller_crosschecks_routing_divergence():
    ctl = FleetController(_policy())
    r = ctl.on_request(10, 0)
    with pytest.raises(RuntimeError, match="diverged"):
        ctl.on_finish(20, 0, replica=r + 1)
    with pytest.raises(RuntimeError, match="never routed"):
        ctl.on_finish(20, 99, replica=0)


# ---------------------------------------------------------------------------
# autoscaling behavior on the engine
# ---------------------------------------------------------------------------

def test_autoscaler_restores_slo_where_fixed_fleet_cannot():
    """The PR's acceptance scenario (same constants as the committed
    fleet_sweep rows): after the flash crowd passes, the autoscaled
    fleet is back to full SLO compliance; the fixed-size fleet, still
    digesting its backlog, never recovers."""
    from benchmarks.fleet_sweep import (POST_CROWD_S, check_recovery,
                                        recovery_lap)
    auto, fixed, _, _ = recovery_lap()
    check_recovery(auto, fixed)       # scale-up happened, SLO recovered
    assert auto.slo_ok_frac(POST_CROWD_S) >= 0.9
    assert fixed.slo_ok_frac(POST_CROWD_S) <= 0.2
    assert fixed.summary()["scale_ups"] == 0
    assert auto.summary()["replicas_peak"] > fixed.summary()["replicas_peak"]


def test_scale_events_surface_as_exit_events():
    fleet, _, events = _run(_flash(), _policy(), slo_ttft_s=0.3,
                            slo_latency_s=2.0)
    kinds = [e.kind for e in events]
    assert kinds[-1] == ExitEventType.DONE
    ups = [e for e in events if e.kind is ExitEventType.SCALE_UP]
    assert len(ups) == fleet.summary()["scale_ups"] > 0
    assert {"replica", "note", "ready_tick"} <= set(ups[0].payload)
    # the promotion honored the advertised ready tick
    promos = {d.replica: d.tick for d in fleet.policy.decisions
              if d.kind == "replica_up" and d.note != "initial"}
    assert promos[ups[0].payload["replica"]] == ups[0].payload["ready_tick"]


def test_cold_start_is_a_first_class_latency_cost():
    """The same stream served with a 1 s cold start pays visibly more
    tail TTFT than with instant replicas (work queues on the warming
    replica until its promotion)."""
    warm, _, _ = _run(_flash(), _policy(cold_start_ticks=0),
                      slo_ttft_s=0.3, slo_latency_s=2.0)
    cold, _, _ = _run(_flash(), _policy(cold_start_ticks=to_ticks(1.0)),
                      slo_ttft_s=0.3, slo_latency_s=2.0)
    w, c = warm.summary(), cold.summary()
    assert c["p50_ttft_s"] > w["p50_ttft_s"]
    assert c["slo_violations"] > w["slo_violations"]
    assert c["span_s"] > w["span_s"]
    assert c["cold_start_s"] == 1.0 and w["cold_start_s"] == 0.0


def test_tenant_priority_orders_same_tick_arrivals():
    t = to_ticks(0.001)
    reqs = [FleetRequest(0, 64, 8, arrival_tick=t, tenant="batch"),
            FleetRequest(1, 64, 8, arrival_tick=t, tenant="interactive")]
    fleet, _, _ = _run(reqs, _policy(max_replicas=1))
    routes = [row for row in fleet.feed if row[0] == "route"]
    assert [r[2] for r in routes] == [1, 0]   # interactive outranks batch


# ---------------------------------------------------------------------------
# determinism + fidelity
# ---------------------------------------------------------------------------

def test_fleet_run_is_deterministic():
    a, sim_a, _ = _run(_flash(), _policy(), slo_ttft_s=0.3)
    b, sim_b, _ = _run(_flash(), _policy(), slo_ttft_s=0.3)
    assert a.summary() == b.summary()
    assert a.feed == b.feed
    assert a.policy.decisions == b.policy.decisions
    assert sim_a.result().makespan_s == sim_b.result().makespan_s


def test_atomic_timing_is_exact_for_fleets():
    det, _, _ = _run(_flash(40), _policy(), slo_ttft_s=0.3,
                     timing="detailed")
    atm, _, _ = _run(_flash(40), _policy(), slo_ttft_s=0.3,
                     timing="atomic")
    assert atm.summary() == det.summary()
    assert atm.policy.decisions == det.policy.decisions


# ---------------------------------------------------------------------------
# traffic models
# ---------------------------------------------------------------------------

def test_traffic_streams_are_seed_reproducible():
    a = _flash(seed=3)
    b = _flash(seed=3)
    c = _flash(seed=4)
    assert a == b != c
    assert all(x.arrival_tick <= y.arrival_tick for x, y in zip(a, a[1:]))
    assert [r.rid for r in a] == list(range(len(a)))
    assert {r.tenant for r in a} <= {"interactive", "batch"}
    assert all(0 <= r.prefix_group < 4 for r in a)
    d = diurnal_requests(30, seed=3, base_rps=10.0, peak_rps=50.0,
                         period_s=5.0)
    assert d == diurnal_requests(30, seed=3, base_rps=10.0,
                                 peak_rps=50.0, period_s=5.0)
    assert all(r.prefix_group == -1 for r in d)   # groups off by default


def test_traffic_validation():
    with pytest.raises(ValueError, match="peak_rps"):
        diurnal_requests(5, seed=0, base_rps=50.0, peak_rps=10.0,
                         period_s=5.0)
    with pytest.raises(ValueError, match="crowd_rps"):
        flash_crowd_requests(5, seed=0, base_rps=50.0, crowd_rps=10.0,
                             crowd_start_s=1.0, crowd_len_s=1.0)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _fingerprint(fleet, sim):
    return {
        "makespan": sim.result().makespan_s,
        "stats": sim.result().stats,
        "summary": fleet.summary(),
        "decisions": fleet.policy.decisions,
        "feed": fleet.feed,
    }


def test_fleet_checkpoint_resumes_identically():
    """CHECKPOINT mid-crowd — pending arrivals, warming replicas,
    in-flight requests — restores into a rebuilt workload and finishes
    bit-identically to an uninterrupted run."""
    mk = lambda: FleetSim(cost=COST, requests=_flash(), policy=_policy(),
                          seq_capacity=1024, slo_ttft_s=0.3,
                          exit_on_scale=False)
    board = lambda: v5e_fleet(max_replicas=3, nx=2, ny=2)
    ref_fleet = mk()
    ref_sim = Simulator(board(), ref_fleet)
    ref_sim.run_to_completion()
    ref = _fingerprint(ref_fleet, ref_sim)

    fleet = mk()
    sim = Simulator(board(), fleet)
    sim.schedule_checkpoint(int(ref["makespan"] * 1e9 * 0.4))
    kinds = [ev.kind for ev in sim.run()]
    assert kinds == [ExitEventType.CHECKPOINT, ExitEventType.DONE]
    ckpt = sim.last_checkpoint
    assert _fingerprint(fleet, sim) == ref

    fresh = mk()
    sim2 = Simulator.from_checkpoint(ckpt, workload=fresh)
    sim2.run_to_completion()
    assert _fingerprint(fresh, sim2) == ref


def test_checkpoint_rejects_mismatched_stream_or_policy():
    fleet = FleetSim(cost=COST, requests=_flash(), policy=_policy(),
                     seq_capacity=1024)
    sim = Simulator(v5e_fleet(max_replicas=3, nx=2, ny=2), fleet)
    ckpt = sim.save_checkpoint()
    other = FleetSim(cost=COST, requests=_flash(seed=9), policy=_policy(),
                     seq_capacity=1024)
    with pytest.raises(ValueError, match="request stream"):
        Simulator.from_checkpoint(ckpt, workload=other)
    repol = FleetSim(cost=COST, requests=_flash(),
                     policy=_policy(slots_per_replica=2), seq_capacity=1024)
    with pytest.raises(ValueError, match="slots_per_replica"):
        Simulator.from_checkpoint(ckpt, workload=repol)


# ---------------------------------------------------------------------------
# construction guard rails
# ---------------------------------------------------------------------------

def test_validation_and_board_sizing():
    with pytest.raises(ValueError, match="at least one"):
        FleetSim(cost=COST, requests=[], policy=_policy())
    with pytest.raises(ValueError, match="rid"):
        FleetSim(cost=COST, policy=_policy(),
                 requests=[FleetRequest(3, 64, 8)])
    with pytest.raises(ValueError, match="fit"):
        FleetSim(cost=COST, policy=_policy(), seq_capacity=64,
                 requests=[FleetRequest(0, 100, 8)])
    with pytest.raises(ValueError, match=">= 1"):
        FleetSim(cost=COST, policy=_policy(),
                 requests=[FleetRequest(0, 64, 0)])
    # a board with fewer pods than the policy's ceiling is refused at
    # bind time (the run's first step)
    fleet = FleetSim(cost=COST, requests=_flash(), policy=_policy(),
                     seq_capacity=1024)
    with pytest.raises(ValueError, match="pods"):
        Simulator(v5e_fleet(max_replicas=2, nx=2, ny=2),
                  fleet).run_to_completion()


def test_v5e_fleet_board_shape():
    board = v5e_fleet(max_replicas=5, nx=2, ny=4)
    assert board.machine.num_pods == 5
    assert board.machine.num_chips == 5 * 8
    assert "v5e_fleet_5x2x4" in board.name
