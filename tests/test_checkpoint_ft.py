"""Checkpoint atomicity/restore/resharding + fault-tolerance machinery."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                        # property tests need the dev extra; the
    from hypothesis import given, settings, strategies as st
except ImportError:         # rest of this module must still run
    given = None

from repro.checkpoint import CheckpointManager
from repro.configs import REGISTRY, get_config
from repro.train.ft import (Heartbeat, StragglerWatchdog, plan_elastic_mesh)


def state_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"m": jnp.ones((8, 4))},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st_ = state_tree()
    mgr.save(st_, 7)
    restored = mgr.restore(st_)
    for a, b in zip(jax.tree.leaves(st_), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(state_tree(0), 5)
    mgr.save(state_tree(1), 10)          # waits for the first internally
    mgr.wait()
    assert mgr.latest_step() == 10


def test_keep_n_pruning(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(state_tree(s), s)
    assert mgr.available_steps() == [3, 4]


def test_atomicity_tmp_never_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(state_tree(), 3)
    # a stale .tmp dir (simulated crash) is not a valid checkpoint
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert mgr.latest_step() == 3


def test_async_save_exception_surfaces_on_wait(tmp_path, monkeypatch):
    """A failed background save must re-raise on wait() (and clear, so
    the manager stays usable) — silently losing a checkpoint would only
    be discovered at restore time, after the data is gone."""
    import repro.checkpoint.manager as mgr_mod

    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(state_tree(0), 1)
    mgr.wait()
    real_save = mgr_mod.np.save
    calls = {"n": 0}

    def boom(*a, **kw):
        calls["n"] += 1
        raise OSError("disk full")

    monkeypatch.setattr(mgr_mod.np, "save", boom)
    mgr.save(state_tree(1), 2)
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    monkeypatch.setattr(mgr_mod.np, "save", real_save)
    assert calls["n"] == 1
    # the failed save never published; the manager still works
    assert mgr.latest_step() == 1
    mgr.save(state_tree(2), 3)
    mgr.wait()
    assert mgr.latest_step() == 3


def test_async_save_exception_also_surfaces_on_next_save(tmp_path,
                                                         monkeypatch):
    import repro.checkpoint.manager as mgr_mod

    mgr = CheckpointManager(str(tmp_path), async_save=True)
    monkeypatch.setattr(mgr_mod.np, "save",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            OSError("enospc")))
    mgr.save(state_tree(0), 1)
    with pytest.raises(OSError, match="enospc"):
        mgr.save(state_tree(1), 2)      # save() waits for the previous


def test_keep_n_pruning_under_back_to_back_async_saves(tmp_path):
    """A rapid sequence of async saves (save() serializes on the
    previous writer thread, so each write+prune fully lands before the
    next begins) must converge to exactly the newest keep_n, with the
    survivors readable."""
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=True)
    for s in range(1, 7):
        mgr.save(state_tree(s), s)
    mgr.wait()
    assert mgr.available_steps() == [5, 6]
    restored = mgr.restore(state_tree(6))
    for a, b in zip(jax.tree.leaves(state_tree(6)),
                    jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_skips_corrupt_newest_checkpoint(tmp_path):
    """A newest checkpoint with no manifest (crash before the atomic
    publish completed its contents) is invisible: latest_step() falls
    back to the previous step and restore works."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st1 = state_tree(1)
    mgr.save(st1, 1)
    mgr.save(state_tree(2), 2)
    os.remove(os.path.join(str(tmp_path), "step_00000002",
                           "manifest.json"))
    assert mgr.latest_step() == 1
    restored = mgr.restore(st1)          # restores step 1, not the husk
    for a, b in zip(jax.tree.leaves(st1), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_of_partially_corrupt_newest_raises_cleanly(tmp_path):
    """A manifest that names a missing/truncated leaf file fails the
    restore of THAT step with a real error (not garbage data), while
    an explicit restore of the previous step still succeeds."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st_ = state_tree()
    mgr.save(st_, 1)
    mgr.save(st_, 2)
    victim = os.path.join(str(tmp_path), "step_00000002",
                          "params__w.npy")
    with open(victim, "wb") as f:
        f.write(b"\x93NUMPY garbage")
    with pytest.raises(Exception):
        mgr.restore(st_, step=2)
    restored = mgr.restore(st_, step=1)
    for a, b in zip(jax.tree.leaves(st_), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_with_dtype_cast(tmp_path):
    """Resharding restore path: restore into bf16 target specs."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st_ = state_tree()
    mgr.save(st_, 1)
    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, st_)
    restored = mgr.restore(target)
    assert restored["params"]["w"].dtype == jnp.bfloat16


def test_watchdog_flags_stragglers():
    wd = StragglerWatchdog(threshold=2.0)
    for i in range(10):
        assert not wd.record(i, 1.0)
    assert wd.record(10, 5.0)
    assert wd.flagged == [(10, 5.0)]


def test_watchdog_median_not_inflated_by_stragglers():
    """Regression: flagged straggler steps used to enter the rolling
    median window, so a burst of slow steps inflated the median until
    equally slow steps stopped being flagged.  Flagged samples must
    stay OUT of the window: detection stays sharp through a long burst,
    and the reported median stays at the healthy baseline."""
    wd = StragglerWatchdog(threshold=2.0, window=8)
    for i in range(8):
        assert not wd.record(i, 1.0)
    for i in range(8, 28):               # a 20-step straggler burst
        assert wd.record(i, 5.0), f"step {i} not flagged: median crept up"
    assert len(wd.flagged) == 20
    assert wd.median() == 1.0            # baseline, not the burst
    # healthy steps afterwards are still clean
    assert not wd.record(28, 1.1)
    # an INTENDED regime change (elastic reshard to fewer chips) resets
    # the window: the slower steps become the new unflagged baseline
    wd.reset_window()
    for i in range(29, 37):
        assert not wd.record(i, 4.0)     # warm-up + new median
    assert wd.median() == 4.0
    assert wd.record(37, 9.0)            # detection works at the new scale


def test_heartbeat(tmp_path):
    hb = Heartbeat(os.path.join(str(tmp_path), "hb.json"))
    assert hb.age() is None and not hb.alive()
    hb.beat(3)
    assert hb.alive(max_age=60)
    assert hb.age() < 5


def test_heartbeat_age_is_monotonic_and_survives_clock_steps(tmp_path):
    import json
    import time

    hb = Heartbeat(os.path.join(str(tmp_path), "hb.json"))
    # a beat recorded with a wall clock an hour in the future (the NTP
    # step case) must still age on the monotonic clock, never negative
    with open(hb.path, "w") as f:
        json.dump({"step": 1, "mono": time.monotonic(),
                   "wall_time": time.time() + 3600}, f)
    assert 0 <= hb.age() < 5 and hb.alive(max_age=60)
    # pre-reboot file: recorded mono exceeds current uptime (monotonic
    # restarted at 0) — must NOT read as fresh; falls back to wall age
    with open(hb.path, "w") as f:
        json.dump({"step": 1, "mono": time.monotonic() + 1e6,
                   "wall_time": time.time() - 7200}, f)
    assert hb.age() == pytest.approx(7200, abs=60)
    assert not hb.alive(max_age=60)
    # legacy wall-clock-only files still work, clamped at zero
    with open(hb.path, "w") as f:
        json.dump({"step": 1, "time": time.time() + 999}, f)
    assert hb.age() == 0.0


def _elastic_planner_props(chips):
    """For every arch and surviving-chip count: plan is valid."""
    for arch in ("deepseek-67b", "minicpm-2b", "whisper-small"):
        cfg = get_config(arch)
        plan = plan_elastic_mesh(cfg, chips)
        data, model = plan.shape
        assert plan.chips == data * model <= chips
        assert cfg.d_ff % model == 0
        assert cfg.d_model % data == 0


if given is not None:
    @given(st.integers(1, 600))
    @settings(max_examples=40, deadline=None)
    def test_elastic_planner_properties(chips):
        _elastic_planner_props(chips)
else:
    @pytest.mark.parametrize("chips", [1, 2, 7, 16, 63, 255, 256, 600])
    def test_elastic_planner_properties(chips):
        # hypothesis not installed: a fixed boundary sweep stands in
        _elastic_planner_props(chips)


def test_elastic_planner_prefers_big_mesh():
    cfg = get_config("deepseek-67b")
    assert plan_elastic_mesh(cfg, 256).chips == 256
    assert plan_elastic_mesh(cfg, 255).chips == 128
    assert plan_elastic_mesh(cfg, 1).chips == 1
