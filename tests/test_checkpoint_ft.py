"""Checkpoint atomicity/restore/resharding + fault-tolerance machinery."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.configs import REGISTRY, get_config
from repro.train.ft import (Heartbeat, StragglerWatchdog, plan_elastic_mesh)


def state_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"m": jnp.ones((8, 4))},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st_ = state_tree()
    mgr.save(st_, 7)
    restored = mgr.restore(st_)
    for a, b in zip(jax.tree.leaves(st_), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(state_tree(0), 5)
    mgr.save(state_tree(1), 10)          # waits for the first internally
    mgr.wait()
    assert mgr.latest_step() == 10


def test_keep_n_pruning(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(state_tree(s), s)
    assert mgr.available_steps() == [3, 4]


def test_atomicity_tmp_never_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(state_tree(), 3)
    # a stale .tmp dir (simulated crash) is not a valid checkpoint
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert mgr.latest_step() == 3


def test_restore_with_dtype_cast(tmp_path):
    """Resharding restore path: restore into bf16 target specs."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st_ = state_tree()
    mgr.save(st_, 1)
    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, st_)
    restored = mgr.restore(target)
    assert restored["params"]["w"].dtype == jnp.bfloat16


def test_watchdog_flags_stragglers():
    wd = StragglerWatchdog(threshold=2.0)
    for i in range(10):
        assert not wd.record(i, 1.0)
    assert wd.record(10, 5.0)
    assert wd.flagged == [(10, 5.0)]


def test_heartbeat(tmp_path):
    hb = Heartbeat(os.path.join(str(tmp_path), "hb.json"))
    assert hb.age() is None and not hb.alive()
    hb.beat(3)
    assert hb.alive(max_age=60)
    assert hb.age() < 5


def test_heartbeat_age_is_monotonic_and_survives_clock_steps(tmp_path):
    import json
    import time

    hb = Heartbeat(os.path.join(str(tmp_path), "hb.json"))
    # a beat recorded with a wall clock an hour in the future (the NTP
    # step case) must still age on the monotonic clock, never negative
    with open(hb.path, "w") as f:
        json.dump({"step": 1, "mono": time.monotonic(),
                   "wall_time": time.time() + 3600}, f)
    assert 0 <= hb.age() < 5 and hb.alive(max_age=60)
    # pre-reboot file: recorded mono exceeds current uptime (monotonic
    # restarted at 0) — must NOT read as fresh; falls back to wall age
    with open(hb.path, "w") as f:
        json.dump({"step": 1, "mono": time.monotonic() + 1e6,
                   "wall_time": time.time() - 7200}, f)
    assert hb.age() == pytest.approx(7200, abs=60)
    assert not hb.alive(max_age=60)
    # legacy wall-clock-only files still work, clamped at zero
    with open(hb.path, "w") as f:
        json.dump({"step": 1, "time": time.time() + 999}, f)
    assert hb.age() == 0.0


@given(st.integers(1, 600))
@settings(max_examples=40, deadline=None)
def test_elastic_planner_properties(chips):
    """For every arch and surviving-chip count: plan is valid."""
    for arch in ("deepseek-67b", "minicpm-2b", "whisper-small"):
        cfg = get_config(arch)
        plan = plan_elastic_mesh(cfg, chips)
        data, model = plan.shape
        assert plan.chips == data * model <= chips
        assert cfg.d_ff % model == 0
        assert cfg.d_model % data == 0


def test_elastic_planner_prefers_big_mesh():
    cfg = get_config("deepseek-67b")
    assert plan_elastic_mesh(cfg, 256).chips == 256
    assert plan_elastic_mesh(cfg, 255).chips == 128
    assert plan_elastic_mesh(cfg, 1).chips == 1
