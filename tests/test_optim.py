"""Optimizer, schedules, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_gradients, cosine_schedule,
                         int8_block_dequantize, int8_block_quantize,
                         wsd_schedule)
from repro.optim.compress import init_error_buffer


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(grads, state, params, lr=0.1,
                                     weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    total = jnp.sqrt(clipped["a"] ** 2 + clipped["b"] ** 2)
    assert float(total[0]) == pytest.approx(1.0, rel=1e-5)


def test_wsd_schedule_phases():
    peak = 1e-3
    lr = lambda s: float(wsd_schedule(s, peak, warmup=10, stable=100,  # noqa
                                      decay=50))
    assert lr(0) == 0.0
    assert lr(5) == pytest.approx(peak / 2)
    assert lr(10) == pytest.approx(peak)
    assert lr(60) == pytest.approx(peak)          # stable phase
    assert lr(115) < peak                          # decaying
    assert lr(160) == pytest.approx(peak * 0.1, rel=1e-3)


def test_cosine_schedule():
    peak = 1.0
    assert float(cosine_schedule(0, peak, 10, 100)) == 0.0
    assert float(cosine_schedule(10, peak, 10, 100)) == pytest.approx(peak)
    assert float(cosine_schedule(100, peak, 10, 100)) == pytest.approx(0.1)


@given(st.integers(1, 2000))
@settings(max_examples=20, deadline=None)
def test_int8_roundtrip_error_bound(n):
    x = np.random.default_rng(n).normal(size=(n,)).astype(np.float32) * 5
    q, s, pad = int8_block_quantize(jnp.asarray(x), block=128)
    deq = int8_block_dequantize(q, s, pad, x.shape)
    scales = np.repeat(np.asarray(s), 128)[:n]
    assert (np.abs(np.asarray(deq) - x) <= scales / 2 + 1e-6).all()


def test_error_feedback_unbiased_accumulation():
    """Sum of compressed grads + final error == sum of true grads."""
    rng = np.random.default_rng(0)
    grads_seq = [jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
                 for _ in range(20)]
    params = {"w": jnp.zeros(512)}
    err = init_error_buffer(params)
    applied = jnp.zeros(512)
    for g in grads_seq:
        deq, err = compress_gradients({"w": g}, err)
        applied = applied + deq["w"]
    true = sum(np.asarray(g) for g in grads_seq)
    residual = np.asarray(err["w"])
    np.testing.assert_allclose(np.asarray(applied) + residual, true,
                               atol=1e-3)
    # and the residual is small relative to the applied sum
    assert np.linalg.norm(residual) < 0.05 * np.linalg.norm(true) + 1.0
