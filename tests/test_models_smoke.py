"""Per-arch reduced-config smoke tests (assignment requirement): one
forward/train step on CPU asserting output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, all_archs, get_config, smoke
from repro.configs.base import ShapeConfig
from repro.models import build_model

# every test here jit-compiles a full (reduced) model — minutes of XLA
# time; tools/ci.sh skips them for the fast tier-1 loop
pytestmark = pytest.mark.slow

B, S = 2, 32


def make_batch(cfg, key, kind="train"):
    s_text = S - (cfg.n_vis if cfg.family == "vlm" else 0)
    batch = {"tokens": jax.random.randint(key, (B, s_text), 0,
                                          cfg.vocab_size)}
    if kind == "train":
        # labels must differ from tokens (tied-embedding archs would
        # otherwise "predict" the input trivially -> zero loss)
        batch["labels"] = jax.random.randint(
            jax.random.fold_in(key, 7), (B, S), 0, cfg.vocab_size)
        batch["mask"] = jnp.ones((B, S), jnp.float32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.n_vis, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "audio":
        batch["enc_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", all_archs())
def test_forward_shapes_no_nans(arch, rng_key):
    cfg = smoke(get_config(arch))
    m = build_model(cfg)
    params = m.init(rng_key)
    batch = make_batch(cfg, rng_key)
    logits, aux = jax.jit(lambda p, b: m.train_logits(p, b))(params, batch)
    from repro.models.layers import padded_vocab
    assert logits.shape == (B, S, padded_vocab(cfg))
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(aux).any())


@pytest.mark.parametrize("arch", all_archs())
def test_one_train_step(arch, rng_key):
    from repro.train import TrainOptions, build_train_step, init_train_state
    cfg = smoke(get_config(arch))
    m = build_model(cfg)
    opts = TrainOptions(peak_lr=1e-3, warmup=2, total_steps=10, chunk=16)
    state = init_train_state(m, rng_key, opts)
    step = jax.jit(build_train_step(m, opts))
    batch = make_batch(cfg, rng_key)
    new_state, metrics = step(state, batch)      # step 0: lr==0 (warmup)
    new_state, metrics = step(new_state, batch)  # step 1: lr>0
    assert float(metrics["loss"]) > 0
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 2
    # params actually changed
    p0 = jax.tree.leaves(state["params"])[0]
    p1 = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(p0), np.asarray(p1))


@pytest.mark.parametrize("arch", all_archs())
def test_prefill_decode_consistency(arch, rng_key):
    """Decode after prefill must match the teacher-forced forward."""
    cfg = smoke(get_config(arch))
    m = build_model(cfg)
    params = m.init(rng_key)
    batch = make_batch(cfg, rng_key, kind="prefill")
    full_logits, _ = jax.jit(lambda p, b: m.train_logits(p, b))(
        params, batch)
    toks = batch["tokens"]
    pre = dict(batch, tokens=toks[:, :-1])
    pl_, cache = jax.jit(lambda p, b: m.prefill(p, b, seq_capacity=S))(
        params, pre)
    dl, _ = jax.jit(lambda p, t, c, cl: m.decode(p, {"tokens": t}, c, cl))(
        params, toks[:, -1:], cache, jnp.asarray(S - 1, jnp.int32))
    f = np.asarray(full_logits, np.float32)
    err_p = np.max(np.abs(np.asarray(pl_, np.float32)[:, 0] - f[:, -2]))
    err_d = np.max(np.abs(np.asarray(dl, np.float32)[:, 0] - f[:, -1]))
    scale = np.max(np.abs(f[:, -2:])) + 1e-9
    # bf16 numerics + MoE capacity drops allow a few percent
    assert err_p / scale < 0.08, err_p / scale
    assert err_d / scale < 0.08, err_d / scale
