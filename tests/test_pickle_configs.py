"""Pickle round-trips for everything the multiprocess engine ships to
workers (``repro.core.desim.parallel`` sends its init payload over a
``multiprocessing.Pipe``, and ``mp_context="spawn"`` pickles the whole
worker bootstrap): trace ops, traces, machines, boards.  A round-tripped
object must not just survive — it must *simulate identically*."""

import pickle

from repro.core.desim.trace import HloTrace, TraceOp, analytic_trace
from repro.sim.boards import v5e_multipod, v5e_pod, v5e_straggler


def _rt(obj):
    return pickle.loads(pickle.dumps(obj))


def _trace():
    return analytic_trace(
        "t", layers=3, layer_flops=1e12, layer_bytes=1e9,
        layer_collectives=[{"kind": "all-reduce", "bytes": 1e7}],
        tail_collectives=[{"kind": "all-reduce", "bytes": 2e7,
                           "scope": "dcn"}])


def test_traceop_roundtrip():
    op = TraceOp(kind="collective", flops=0.0, bytes=5e8, coll_bytes=5e8,
                 deps=(0, 2), name="ar.7", scope="dcn", participants=256)
    assert _rt(op) == op


def test_trace_roundtrip_identical_json():
    tr = _trace()
    rt = _rt(tr)
    assert rt.to_json() == tr.to_json()
    assert [o == p for o, p in zip(rt.ops, tr.ops)] == [True] * len(tr.ops)


def test_machine_roundtrip_serializes_identically():
    m = v5e_multipod(num_pods=4, nx=4, ny=4).machine
    assert _rt(m).serialize() == m.serialize()


def test_board_roundtrip_simulates_identically():
    for board in (v5e_pod(),
                  v5e_multipod(num_pods=2, nx=4, ny=4),
                  v5e_straggler(num_pods=2, slowdown=1.5, nx=4, ny=4)):
        rt = _rt(board)
        assert rt.name == board.name
        assert rt.algorithm == board.algorithm
        assert rt.straggler_slowdowns == board.straggler_slowdowns
        ref = board.executor(record_stats=True).execute(_trace())
        got = rt.executor(record_stats=True).execute(_trace())
        assert got == ref


def test_empty_trace_roundtrip():
    tr = HloTrace("empty")
    assert _rt(tr).to_json() == tr.to_json()
