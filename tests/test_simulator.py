"""repro.sim.Simulator: boards, the exit-event loop, work markers, and
equivalence with the raw TraceExecutor path (the gem5-stdlib front-end
must not change timing, only packaging)."""

import pytest

from repro.core.desim.executor import TraceExecutor
from repro.core.desim.trace import HloTrace, TraceOp, analytic_trace
from repro.sim import (BOARDS, ExitEventType, Simulator,
                       SteadyStateWorkload, get_board, repeat_trace,
                       v5e_degraded, v5e_multipod, v5e_pod, v5e_straggler)

COLLS = [{"kind": "all-reduce", "bytes": 1e8, "participants": 256}]


def _trace(layers=6):
    return analytic_trace("w", layers, 1e12, 1e9, COLLS)


# ---------------------------------------------------------------------------
# boards
# ---------------------------------------------------------------------------

def test_board_catalog_builds_instantiated_machines():
    for name in BOARDS:
        b = get_board(name)
        assert b.machine._frozen, name
    assert v5e_pod().machine.pod.num_chips == 256
    assert v5e_multipod(4).machine.num_pods == 4


def test_board_overrides_apply_before_freeze():
    b = v5e_pod(nx=8, ny=4, chip={"hbm_bw": 1e12}, ici={"bw": 100e9})
    assert b.machine.pod.num_chips == 32
    assert b.machine.pod.chip.hbm_bw == 1e12
    assert b.machine.pod.ici.bw == 100e9


def test_straggler_and_degraded_boards_are_slower():
    tr = _trace()
    base = v5e_pod().executor().execute(tr).makespan_s
    degraded = v5e_degraded(hbm_frac=0.5, ici_frac=0.5)
    assert degraded.executor().execute(tr).makespan_s > base
    strag = v5e_straggler(num_pods=2, slowdown=3.0)
    nominal = v5e_multipod(2).executor().execute(tr).makespan_s
    assert strag.executor().execute(tr).makespan_s > nominal


# ---------------------------------------------------------------------------
# Simulator equivalence + exit events
# ---------------------------------------------------------------------------

def test_simulator_matches_raw_executor():
    tr = _trace()
    board = v5e_pod()
    ref = TraceExecutor(board.machine, record_stats=True).execute(tr)
    sim = Simulator(v5e_pod(), tr)
    res = sim.run_to_completion()
    assert res.makespan_s == ref.makespan_s
    assert res.stats == ref.stats
    assert sim.tick == int(round(ref.makespan_s * 1e9))


def test_exit_event_sequence_max_tick_then_done():
    tr = _trace()
    ref = v5e_pod().executor().execute(tr)
    sim = Simulator(v5e_pod(), tr)
    mid = int(ref.makespan_s * 1e9 // 2)
    sim.schedule_max_tick(mid)
    events = list(sim.run())
    assert [e.kind for e in events] == [ExitEventType.MAX_TICK,
                                        ExitEventType.DONE]
    assert events[0].tick == mid
    assert sim.result().makespan_s == ref.makespan_s


def test_multi_phase_scripting_between_yields():
    """Drivers schedule further exits while iterating — the gem5-stdlib
    'script your simulation in plain Python' loop."""
    tr = _trace(layers=10)
    ref = v5e_pod().executor().execute(tr)
    end = int(ref.makespan_s * 1e9)
    sim = Simulator(v5e_pod(), tr)
    sim.schedule_max_tick(end // 4)
    seen = []
    for ev in sim.run():
        seen.append(ev)
        if ev.kind is ExitEventType.MAX_TICK and len(seen) == 1:
            sim.schedule_max_tick(end // 2)       # phase 2, mid-flight
    kinds = [e.kind for e in seen]
    assert kinds == [ExitEventType.MAX_TICK, ExitEventType.MAX_TICK,
                     ExitEventType.DONE]
    assert seen[0].tick == end // 4 and seen[1].tick == end // 2
    assert sim.result().makespan_s == ref.makespan_s


def test_stale_scheduled_exit_is_dropped():
    tr = _trace(layers=2)
    ref = v5e_pod().executor().execute(tr)
    sim = Simulator(v5e_pod(), tr)
    sim.schedule_max_tick(int(ref.makespan_s * 1e9 * 10))  # beyond the end
    assert [e.kind for e in sim.run()] == [ExitEventType.DONE]


def test_result_before_done_raises():
    sim = Simulator(v5e_pod(), _trace())
    with pytest.raises(RuntimeError, match="not completed"):
        sim.result()


# ---------------------------------------------------------------------------
# work markers (gem5 work items)
# ---------------------------------------------------------------------------

def _marker_trace():
    t = HloTrace("roi")
    t.ops.append(TraceOp(kind="compute", flops=1e12, bytes=1e9,
                         name="warmup"))
    t.ops.append(TraceOp(kind="compute", flops=1e9, bytes=1e6, deps=(0,),
                         name="work_begin_roi"))
    t.ops.append(TraceOp(kind="compute", flops=1e12, bytes=1e9, deps=(1,),
                         name="roi_body"))
    t.ops.append(TraceOp(kind="compute", flops=1e9, bytes=1e6, deps=(2,),
                         name="work_end_roi"))
    t.ops.append(TraceOp(kind="compute", flops=1e12, bytes=1e9, deps=(3,),
                         name="cooldown"))
    return t


def test_work_begin_end_exit_events():
    sim = Simulator(v5e_pod(), _marker_trace())
    events = list(sim.run())
    kinds = [e.kind for e in events]
    assert kinds == [ExitEventType.WORK_BEGIN, ExitEventType.WORK_END,
                     ExitEventType.DONE]
    begin, end = events[0], events[1]
    assert begin.cause == "work_begin_roi" and end.cause == "work_end_roi"
    assert 0 < begin.tick < end.tick <= sim.tick
    # the ROI is measurable from the exits alone
    assert (end.tick - begin.tick) * 1e-9 < sim.result().makespan_s


def test_work_markers_survive_checkpoint():
    tr = _marker_trace()
    sim = Simulator(v5e_pod(), tr)
    ref_kinds = [e.kind for e in sim.run()]
    sim2 = Simulator(v5e_pod(), tr)
    sim2.schedule_checkpoint(1000)    # before the ROI
    kinds = [e.kind for e in sim2.run()]
    assert kinds == [ExitEventType.CHECKPOINT] + ref_kinds
    assert sim2.result().makespan_s == sim.result().makespan_s


# ---------------------------------------------------------------------------
# workloads
# ---------------------------------------------------------------------------

def test_repeat_trace_chains_steps():
    step = _trace(layers=2)
    tr3 = repeat_trace(step, 3)
    assert len(tr3.ops) == 3 * len(step.ops)
    # step 1's root depends on step 0's sink
    n = len(step.ops)
    root_of_step1 = tr3.ops[n]
    assert root_of_step1.deps == (n - 1,)
    # steady state: makespan of k steps == k * one-step makespan
    board = v5e_pod()
    one = board.executor().execute(step).makespan_s
    three = board.executor().execute(tr3).makespan_s
    assert three == pytest.approx(3 * one, rel=1e-9)


def test_steady_state_workload_in_simulator():
    step = _trace(layers=2)
    wl = SteadyStateWorkload(step, 4)
    res = Simulator(v5e_pod(), wl).run_to_completion()
    one = v5e_pod().executor().execute(step).makespan_s
    assert res.makespan_s == pytest.approx(4 * one, rel=1e-9)


def test_repeat_trace_rejects_zero_steps():
    with pytest.raises(ValueError):
        repeat_trace(_trace(), 0)
