"""benchmarks.run --json trajectory file semantics: a *filtered* run
merges into the committed BENCH_desim.json (update matching rows, keep
the rest) instead of clobbering it down to the subset; an unfiltered
run replaces wholesale; the filter is recorded verbatim."""

import json

from benchmarks.run import write_json


def _read(path):
    with open(path) as f:
        return json.load(f)


def _seed(path, benchmarks, pat=""):
    write_json(str(path), benchmarks, pat, [])


def test_filtered_run_merges_and_keeps_unmatched_rows(tmp_path):
    path = tmp_path / "bench.json"
    _seed(path, {"serving_sweep/a": {"us_per_call": 1.0, "derived": "old"},
                 "fidelity/x": {"us_per_call": 2.0, "derived": "keep"}})
    n = write_json(str(path),
                   {"serving_sweep/a": {"us_per_call": 9.0,
                                        "derived": "new"}},
                   "serving", [])
    assert n == 2
    doc = _read(path)
    assert doc["benchmarks"]["serving_sweep/a"]["derived"] == "new"
    assert doc["benchmarks"]["fidelity/x"]["derived"] == "keep"
    assert doc["filter"] == "serving"          # the pattern, verbatim
    assert doc["failed"] == []


def test_unfiltered_run_replaces_wholesale(tmp_path):
    path = tmp_path / "bench.json"
    _seed(path, {"retired/bench": {"us_per_call": 1.0, "derived": ""}})
    n = write_json(str(path),
                   {"fresh/bench": {"us_per_call": 3.0, "derived": ""}},
                   "", [])
    assert n == 1
    doc = _read(path)
    assert set(doc["benchmarks"]) == {"fresh/bench"}   # retired rows gone
    assert doc["filter"] == ""


def test_filtered_run_survives_missing_or_corrupt_existing(tmp_path):
    missing = tmp_path / "none.json"
    rows = {"a/b": {"us_per_call": 1.0, "derived": ""}}
    assert write_json(str(missing), rows, "a", []) == 1
    corrupt = tmp_path / "corrupt.json"
    corrupt.write_text("{not json")
    assert write_json(str(corrupt), rows, "a", []) == 1
    assert set(_read(corrupt)["benchmarks"]) == {"a/b"}


def test_failed_benchmarks_are_recorded(tmp_path):
    path = tmp_path / "bench.json"
    write_json(str(path), {}, "", ["serving_sweep"])
    assert _read(path)["failed"] == ["serving_sweep"]
