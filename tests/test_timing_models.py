"""Pluggable timing models (repro.core.desim.timing): atomic==detailed
on contention-free traces, the gem5-style mid-run switch (atomic
fast-forward + switch-to-detailed == detailed-from-start), checkpoint/
restore across a model switch, dynamic workloads at atomic fidelity,
and the EventQueue negative-tick guards."""

import json
import os

import pytest

from repro.configs import get_config
from repro.core.desim.executor import TraceExecutor
from repro.core.desim.timing import (AtomicTiming, DetailedTiming,
                                     get_timing_model)
from repro.core.desim.trace import analytic_trace
from repro.core.events import EventQueue
from repro.sim import (ExitEventType, ServeSim, ServingCost, Simulator,
                       TrainSim, TrainStepCost, checkpoint_executor,
                       poisson_requests, repeat_trace, restore_executor,
                       v5e_multipod, v5e_pod, v5e_serving, v5e_unreliable)
from repro.train.ft_policy import FTPolicy

COLLS = [{"kind": "all-reduce", "bytes": 2e8, "participants": 256}]
DCN_TAIL = [{"kind": "all-reduce", "bytes": 1e9, "participants": 512,
             "scope": "dcn"}]


def _chain_trace(steps=10, layers=6, tail=False):
    """Chain-dependency trace: contention-free by construction (no two
    collectives ever share a link in flight), the regime where atomic
    and detailed timing are exactly equal."""
    step = analytic_trace("step", layers, 1e12, 1e9, COLLS,
                          tail_collectives=DCN_TAIL if tail else ())
    return repeat_trace(step, steps)


def _stats_sans_links(stats):
    """links_used counts materialized LinkState objects — a detailed-
    implementation detail atomic legitimately reports as 0."""
    return {k: v for k, v in stats.items() if not k.endswith("links_used")}


# ---------------------------------------------------------------------------
# atomic == detailed on contention-free traces
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("board_fn", [
    lambda: v5e_pod(),
    lambda: v5e_multipod(2, quantum_ns=0),
    lambda: v5e_multipod(2, quantum_ns=0, nx=8, ny=8),
])
def test_atomic_equals_detailed_on_contention_free_trace(board_fn):
    trace = _chain_trace(steps=5, tail=board_fn().machine.num_pods > 1)
    det = board_fn().executor(timing="detailed",
                              record_stats=True).execute(trace)
    atm = board_fn().executor(timing="atomic",
                              record_stats=True).execute(trace)
    assert atm.makespan_s == det.makespan_s          # identical final tick
    assert atm.compute_s == det.compute_s
    assert atm.collective_s == det.collective_s
    assert _stats_sans_links(atm.stats) == _stats_sans_links(det.stats)


def test_atomic_with_stragglers_matches_detailed():
    board = v5e_multipod(2, quantum_ns=0)
    trace = _chain_trace(steps=4, tail=True)
    det = board.executor(timing="detailed",
                         straggler_slowdowns=[1.0, 2.5]).execute(trace)
    atm = board.executor(timing="atomic",
                         straggler_slowdowns=[1.0, 2.5]).execute(trace)
    assert atm.makespan_s == det.makespan_s


def test_atomic_fires_vastly_fewer_engine_events():
    """The perf headline: atomic resolves completions on its own batch
    heap — >=10x fewer engine events than detailed (in practice ~zero
    for a static trace)."""
    trace = _chain_trace(steps=10, tail=True)
    det = v5e_multipod(2, quantum_ns=0).executor().execute(trace)
    atm = v5e_multipod(2, quantum_ns=0).executor(
        timing="atomic").execute(trace)
    assert det.events >= 10 * max(atm.events, 1)


def test_atomic_is_a_lower_bound_under_contention():
    """On a CONTENDED trace atomic is approximate: contention-free op
    costs can only finish earlier (never later) than detailed."""
    from repro.core.desim.trace import HloTrace, TraceOp
    t = HloTrace("contend")
    t.ops.append(TraceOp(kind="compute", flops=1e12, bytes=1e9))
    for i in range(3):       # three concurrent whole-pod collectives
        t.ops.append(TraceOp(kind="all-gather", coll_bytes=1e8,
                             participants=256, deps=(0,), name=f"ag{i}"))
    det = v5e_pod().executor().execute(t)
    atm = v5e_pod().executor(timing="atomic").execute(t)
    assert atm.makespan_s < det.makespan_s


def test_contention_false_maps_to_atomic_with_deprecation():
    board = v5e_pod()
    with pytest.warns(DeprecationWarning, match="timing='atomic'"):
        ex = TraceExecutor(board.machine, contention=False)
    assert ex.timing.name == "atomic"
    assert ex.contention is False
    # an explicit timing choice wins without warning
    ex2 = TraceExecutor(board.machine, contention=False, timing="detailed")
    assert ex2.timing.name == "detailed" and ex2.contention is True


def test_boards_carry_a_default_timing_model():
    assert v5e_pod().executor().timing.name == "detailed"
    assert v5e_pod(timing="atomic").executor().timing.name == "atomic"
    # caller overrides the board default
    assert v5e_pod(timing="atomic").executor(
        timing="detailed").timing.name == "detailed"
    sim = Simulator(v5e_pod(timing="atomic"), _chain_trace(steps=1))
    assert sim.timing == "atomic"
    # an explicit contention request (even the legacy True form) beats
    # an atomic board default — it asks for contention simulation
    ex = v5e_pod(timing="atomic").executor(contention=True)
    assert ex.timing.name == "detailed" and ex.contention is True


def test_get_timing_model_resolution():
    assert isinstance(get_timing_model("atomic"), AtomicTiming)
    assert isinstance(get_timing_model(DetailedTiming), DetailedTiming)
    inst = AtomicTiming()
    assert get_timing_model(inst) is inst
    with pytest.raises(ValueError, match="timing model"):
        get_timing_model("psychic")


# ---------------------------------------------------------------------------
# the gem5 switch_cpus move: mid-run switching
# ---------------------------------------------------------------------------

def test_atomic_fast_forward_then_switch_matches_detailed_from_start():
    """The headline invariant: atomic fast-forward to tick T + switch
    to detailed == a detailed-from-start run, final tick AND post-T
    stats (full tree, since atomic==detailed pre-T on this trace)."""
    trace = _chain_trace(steps=10)
    ref = Simulator(v5e_pod(), trace).run_to_completion()

    sim = Simulator(v5e_pod(), trace, timing="atomic")
    T = int(ref.makespan_s * 1e9 * 0.4)
    sim.schedule_max_tick(T)
    saw_switch = False
    for ev in sim.run():
        if ev.kind is ExitEventType.MAX_TICK:
            assert sim.timing == "atomic"
            assert sim.switch_timing("detailed") == "detailed"
            assert sim.timing == "detailed"
            saw_switch = True
    assert saw_switch
    res = sim.result()
    assert res.makespan_s == ref.makespan_s
    assert res.stats == ref.stats


def test_switch_is_idempotent_and_validated():
    sim = Simulator(v5e_pod(), _chain_trace(steps=2))
    assert sim.switch_timing("detailed") == "detailed"   # no-op
    with pytest.raises(ValueError, match="timing model"):
        sim.switch_timing("psychic")
    assert sim.run_to_completion().makespan_s > 0


def test_checkpoint_restores_under_a_different_model(tmp_path):
    """A checkpoint taken under atomic restores under detailed — in
    memory and through the JSON file — bit-identically to the
    in-memory switch and to detailed-from-start."""
    trace = _chain_trace(steps=8)
    board = v5e_pod()
    ref = board.executor(record_stats=True).execute(trace)

    ex = board.executor(timing="atomic", record_stats=True)
    ex.begin(trace)
    ex.advance(max_tick=int(ref.makespan_s * 1e9 * 0.4))
    ex.drain()
    ckpt = checkpoint_executor(ex)
    assert ckpt["executor"]["timing"] == "atomic"
    assert ckpt["state"]["timing"] == "atomic"

    # in-memory cross-model restore
    ex2 = restore_executor(ckpt, record_stats=True, timing="detailed")
    assert ex2.timing.name == "detailed"
    ex2.advance()
    res = ex2.result()
    assert res.makespan_s == ref.makespan_s
    assert res.stats == ref.stats

    # ...and through the file (save -> load -> restore)
    from repro.sim import load_checkpoint, save_checkpoint
    path = save_checkpoint(ckpt, os.path.join(str(tmp_path), "c.json"))
    ex3 = restore_executor(load_checkpoint(path), record_stats=True,
                           timing="detailed")
    ex3.advance()
    assert ex3.result().makespan_s == res.makespan_s
    assert ex3.result().stats == res.stats

    # Simulator.from_checkpoint grows the same switch
    sim = Simulator.from_checkpoint(path, timing="detailed")
    assert sim.timing == "detailed"
    assert sim.run_to_completion().makespan_s == ref.makespan_s


def test_checkpoint_without_timing_override_keeps_model():
    trace = _chain_trace(steps=4)
    ex = v5e_pod().executor(timing="atomic")
    ex.begin(trace)
    ex.advance(max_tick=10_000_000)
    ex.drain()
    ex2 = restore_executor(checkpoint_executor(ex))
    assert ex2.timing.name == "atomic"
    ex2.advance()
    assert ex2.result().makespan_s > 0


def test_atomic_checkpoint_restore_identity():
    """The PR-2 identity invariant holds at atomic fidelity too: a
    paused/drained/serialized/restored atomic run finishes exactly like
    an uninterrupted one (incl. a partial DCN rendezvous)."""
    board = v5e_multipod(2, quantum_ns=0)
    trace = _chain_trace(steps=6, tail=True)
    ref = board.executor(timing="atomic", record_stats=True,
                         straggler_slowdowns=[1.0, 3.0]).execute(trace)
    ex = board.executor(timing="atomic", record_stats=True,
                        straggler_slowdowns=[1.0, 3.0])
    ex.begin(trace)
    ex.advance(max_tick=int(ref.makespan_s * 1e9 * 0.6))
    ex.drain()
    ckpt = checkpoint_executor(ex)
    ex2 = restore_executor(ckpt, record_stats=True)
    ex2.advance()
    res = ex2.result()
    assert res.makespan_s == ref.makespan_s
    assert res.stats == ref.stats


# ---------------------------------------------------------------------------
# dynamic workloads at atomic fidelity
# ---------------------------------------------------------------------------

def _serve(num_requests=30):
    cost = ServingCost.from_params(70e9, layers=80, d_model=8192, chips=64)
    reqs = poisson_requests(num_requests, 30.0, seed=13,
                            prompt_len=(64, 256), decode_len=(8, 48))
    return ServeSim(cost=cost, requests=reqs, slots=4, seq_capacity=512)


def test_servesim_runs_identically_under_atomic():
    """Serving injects pure per-pod compute ops, so atomic is EXACT:
    same makespan, same decision logs, ~zero engine events — the big
    serving sweeps can default to atomic."""
    out = {}
    for timing in ("detailed", "atomic"):
        srv = _serve()
        sim = Simulator(v5e_serving(8, 8), srv, timing=timing)
        res = sim.run_to_completion()
        out[timing] = (res.makespan_s, res.events, srv.summary(),
                       [s.decisions for s in srv.schedulers])
    det, atm = out["detailed"], out["atomic"]
    assert atm[0] == det[0]
    assert atm[2] == det[2]
    assert atm[3] == det[3]
    assert det[1] >= 10 * max(atm[1], 1)


def test_trainsim_runs_identically_under_atomic():
    board = v5e_unreliable(2, seed=3, horizon=300, mtbf=60.0,
                           repair=(10, 30))
    out = {}
    for timing in ("detailed", "atomic"):
        pol = FTPolicy(get_config("deepseek-67b"), num_steps=40,
                       ckpt_interval=8, pods=2,
                       chips_per_pod=board.machine.pod.num_chips,
                       dead_after_misses=1)
        ts = TrainSim(cost=TrainStepCost.from_params(
            7e9, tokens_per_batch=500_000, chips=board.machine.num_chips),
            policy=pol, schedule=board.failure_schedule)
        res = Simulator(board, ts, timing=timing).run_to_completion()
        out[timing] = (res.makespan_s, res.events, ts.summary(),
                       [d.kind for d in pol.decisions])
    det, atm = out["detailed"], out["atomic"]
    assert atm[0] == det[0]
    assert atm[2] == det[2]
    assert atm[3] == det[3] and atm[3]          # decisions happened
    assert det[1] >= 10 * max(atm[1], 1)


def test_dynamic_atomic_checkpoint_roundtrip():
    """ServeSim under atomic checkpoints mid-run and resumes
    bit-identically (the drain/serialize path is model-agnostic)."""
    ref_srv = _serve()
    ref_sim = Simulator(v5e_serving(8, 8), ref_srv, timing="atomic")
    ref_res = ref_sim.run_to_completion()

    srv = _serve()
    sim = Simulator(v5e_serving(8, 8), srv, timing="atomic")
    sim.schedule_checkpoint(int(ref_res.makespan_s * 1e9 * 0.4))
    kinds = [ev.kind for ev in sim.run()]
    assert ExitEventType.CHECKPOINT in kinds
    assert json.dumps(sim.last_checkpoint, allow_nan=False)
    assert sim.result().makespan_s == ref_res.makespan_s
    assert srv.summary() == ref_srv.summary()
    assert [s.decisions for s in srv.schedulers] == \
        [s.decisions for s in ref_srv.schedulers]


# ---------------------------------------------------------------------------
# satellite: EventQueue rejects events landing in the past
# ---------------------------------------------------------------------------

def test_schedule_rejects_negative_tick():
    q = EventQueue()
    with pytest.raises(ValueError, match="negative tick"):
        q.schedule(lambda: None, -1)
    with pytest.raises(ValueError, match="negative tick"):
        q.schedule(lambda: None, -10 ** 12, name="way-back")


def test_schedule_after_rejects_negative_delay():
    q = EventQueue()
    q.schedule(lambda: None, 50)
    q.run()
    assert q.now == 50
    with pytest.raises(ValueError, match="negative delay"):
        q.schedule_after(lambda: None, -5)
    # a negative absolute tick is still caught once now > 0
    with pytest.raises(ValueError, match="negative tick"):
        q.schedule(lambda: None, -5)
    # and scheduling before ``now`` names the past, not negativity
    with pytest.raises(ValueError, match="in the past"):
        q.schedule(lambda: None, 10)


def test_run_max_tick_never_rewinds_now():
    q = EventQueue()
    q.schedule(lambda: None, 100)
    q.run()
    assert q.now == 100
    q.schedule(lambda: None, 200)
    q.run(max_tick=50)          # already past 50: must not go backwards
    assert q.now == 100
    q.run()
    assert q.now == 200
