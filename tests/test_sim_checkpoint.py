"""Acceptance: gem5-style drain-then-serialize checkpointing
(repro.sim.serialize).  Serialize mid-run at a quantum boundary,
restore — same machine or re-parameterized — and the resumed run's
final tick and stats tree are identical to an uninterrupted run."""

import json
import os

import pytest

from repro.core.desim.executor import TraceExecutor
from repro.core.desim.trace import analytic_trace
from repro.sim import (WORKLOAD_KEY, CheckpointError, ExitEventType,
                       ServeSim, ServingCost, Simulator,
                       checkpoint_executor, load_checkpoint,
                       machine_from_dict, poisson_requests,
                       restore_executor, save_checkpoint, v5e_multipod,
                       v5e_pod, v5e_serving)

COLLS = [{"kind": "all-reduce", "bytes": 1e8, "participants": 256}]
TAIL = [{"kind": "all-reduce", "bytes": 1e9, "participants": 512,
         "scope": "dcn"}]


def _trace(layers=6, tail=True):
    return analytic_trace("w", layers, 1e12, 1e9, COLLS,
                          tail_collectives=TAIL if tail else ())


def _reference(board, trace):
    return board.executor(record_stats=True).execute(trace)


# ---------------------------------------------------------------------------
# identity: checkpoint/restore == uninterrupted (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pods", [1, 2])
def test_checkpoint_restore_identity(pods):
    board = v5e_pod() if pods == 1 else v5e_multipod(pods)
    trace = _trace(tail=pods > 1)
    ref = _reference(board, trace)

    quantum = board.machine.quantum_ns
    mid = int(ref.makespan_s * 1e9 * 0.4) // quantum * quantum
    assert 0 < mid < ref.makespan_s * 1e9

    # pause at the quantum boundary, drain, serialize
    ex = board.executor(record_stats=True)
    ex.begin(trace)
    assert not ex.advance(max_tick=mid)
    ex.drain()
    ckpt = checkpoint_executor(ex)
    assert ckpt["tick"] >= mid        # drain may advance past the pause

    # restore on an equivalent machine and run to completion
    ex2 = restore_executor(ckpt, record_stats=True)
    assert ex2.advance()
    res = ex2.result()
    assert res.makespan_s == ref.makespan_s          # identical final tick
    assert res.stats == ref.stats                    # identical stats tree
    assert res.compute_s == ref.compute_s
    assert res.exposed_collective_s == ref.exposed_collective_s


def test_checkpoint_json_file_round_trip(tmp_path):
    board = v5e_multipod(2)
    trace = _trace()
    ref = _reference(board, trace)
    quantum = board.machine.quantum_ns
    mid = int(ref.makespan_s * 1e9 * 0.5) // quantum * quantum

    ex = board.executor(record_stats=True)
    ex.begin(trace)
    ex.advance(max_tick=mid)
    ex.drain()
    path = save_checkpoint(checkpoint_executor(ex),
                           os.path.join(str(tmp_path), "ckpt.json"))
    # the file is one plain-JSON document
    with open(path) as f:
        assert json.load(f)["format"] == "repro.sim.checkpoint"
    res = restore_executor(load_checkpoint(path), record_stats=True)
    res.advance()
    out = res.result()
    assert out.makespan_s == ref.makespan_s
    assert out.stats == ref.stats


def test_simulator_checkpoint_exit_resumes_identically():
    """Simulator's CHECKPOINT exit resumes *through the restore path*
    and still finishes exactly like a run that never checkpointed."""
    board = v5e_multipod(2)
    trace = _trace()
    ref = _reference(board, trace)
    quantum = board.machine.quantum_ns
    mid = int(ref.makespan_s * 1e9 * 0.3) // quantum * quantum

    sim = Simulator(v5e_multipod(2), trace)
    sim.schedule_checkpoint(mid)
    kinds = [ev.kind for ev in sim.run()]
    assert kinds == [ExitEventType.CHECKPOINT, ExitEventType.DONE]
    assert sim.last_checkpoint is not None
    assert sim.result().makespan_s == ref.makespan_s
    assert sim.result().stats == ref.stats


# ---------------------------------------------------------------------------
# restore onto a re-parameterized machine (checkpoint once, sweep hardware)
# ---------------------------------------------------------------------------

def test_restore_onto_reparameterized_machine():
    board = v5e_pod()
    trace = _trace(layers=8, tail=False)
    ref = _reference(board, trace)
    mid = int(ref.makespan_s * 1e9 * 0.4)

    ex = board.executor(record_stats=True)
    ex.begin(trace)
    ex.advance(max_tick=mid)
    ex.drain()
    ckpt = checkpoint_executor(ex)

    # sweep hardware from the one checkpoint: faster chips finish the
    # remaining work sooner, slower chips later; same-machine restore
    # reproduces the reference exactly
    results = {}
    for mult in (0.5, 1.0, 2.0):
        fast = v5e_pod(chip={"peak_flops": 197e12 * mult,
                             "hbm_bw": 819e9 * mult})
        ex2 = restore_executor(ckpt, machine=fast.machine)
        ex2.advance()
        results[mult] = ex2.result().makespan_s
    assert results[1.0] == ref.makespan_s
    assert results[2.0] < results[1.0] < results[0.5]
    # completed pre-checkpoint work keeps its original timing, so even
    # infinitely fast remaining hardware cannot beat the pause tick
    assert results[2.0] * 1e9 >= mid


def test_from_checkpoint_applies_explicit_board_run_knobs():
    """An explicitly-passed board must win wholesale: its collective
    algorithm and stragglers apply to the restored run, not the
    checkpointed ones (a board-based DSE re-sweep over algorithms must
    not silently produce identical numbers)."""
    board = v5e_pod()
    trace = _trace(layers=8, tail=False)
    ref = _reference(board, trace)
    ex = board.executor()
    ex.begin(trace)
    ex.advance(max_tick=int(ref.makespan_s * 1e9 * 0.3))
    ex.drain()
    ckpt = checkpoint_executor(ex)

    ring = Simulator.from_checkpoint(ckpt, board=v5e_pod(algorithm="ring"))
    assert ring._ex.algorithm == "ring"
    torus = Simulator.from_checkpoint(ckpt)
    assert torus._ex.algorithm == "torus2d"
    t_ring = ring.run_to_completion().makespan_s
    t_torus = torus.run_to_completion().makespan_s
    assert t_torus == ref.makespan_s
    assert t_ring != t_torus          # the algorithm actually applied


def test_save_checkpoint_before_first_run_iteration():
    """Checkpointing a never-run Simulator is a valid tick-0 snapshot
    (the run implicitly begins), and the run still completes exactly."""
    trace = _trace(layers=4, tail=False)
    ref = _reference(v5e_pod(), trace)
    sim = Simulator(v5e_pod(), trace)
    ckpt = sim.save_checkpoint()
    assert ckpt["tick"] >= 0
    assert sim.run_to_completion().makespan_s == ref.makespan_s
    # and the tick-0 checkpoint restores to a full identical run
    sim2 = Simulator.from_checkpoint(ckpt)
    assert sim2.run_to_completion().makespan_s == ref.makespan_s


def test_restored_events_accounting_is_continuous():
    """ExecResult.events carries across a checkpoint: pre-pause firings
    are restored, so a resumed run reports at least the uninterrupted
    count (plus one re-issue event per deferred op)."""
    board = v5e_pod()
    trace = _trace(layers=8, tail=False)
    ref = _reference(board, trace)
    ex = board.executor()
    ex.begin(trace)
    ex.advance(max_tick=int(ref.makespan_s * 1e9 * 0.5))
    ex.drain()
    ckpt = checkpoint_executor(ex)
    n_deferred = len(ckpt["state"]["deferred"])
    ex2 = restore_executor(ckpt)
    ex2.advance()
    assert ex2.result().events == ref.events + n_deferred


def test_simulator_from_checkpoint_file(tmp_path):
    board = v5e_pod()
    trace = _trace(layers=6, tail=False)
    ref = _reference(board, trace)
    sim = Simulator(v5e_pod(), trace, checkpoint_dir=str(tmp_path))
    sim.schedule_checkpoint(int(ref.makespan_s * 1e9 * 0.5))
    for _ in sim.run():
        pass
    assert sim.checkpoint_paths and os.path.exists(sim.checkpoint_paths[0])
    sim2 = Simulator.from_checkpoint(sim.checkpoint_paths[0])
    assert sim2.run_to_completion().makespan_s == ref.makespan_s


# ---------------------------------------------------------------------------
# dynamic workloads: snapshot mid-serving, restore bit-identically
# ---------------------------------------------------------------------------

def _serve_workload(slots=4):
    cost = ServingCost.from_params(70e9, layers=80, d_model=8192, chips=64)
    # rate chosen so arrivals span most of the run: a 40% checkpoint
    # catches pending arrivals AND in-flight requests
    reqs = poisson_requests(50, 30.0, seed=13, prompt_len=(64, 256),
                            decode_len=(8, 48))
    return ServeSim(cost=cost, requests=reqs, slots=slots, seq_capacity=512,
                    slo_ttft_s=0.02, slo_latency_s=2.0)


def _serve_reference(board):
    srv = _serve_workload()
    sim = Simulator(board(), srv)
    sim.run_to_completion()
    return srv, sim


def _serving_fingerprint(srv, sim):
    """Everything that must survive a checkpoint bit-identically."""
    return {
        "makespan": sim.result().makespan_s,
        "stats": sim.result().stats,
        "summary": srv.summary(),
        "decisions": [s.decisions for s in srv.schedulers],
        "percentile_state": srv.p_latency.state_dict(),
    }


@pytest.mark.parametrize("board", [lambda: v5e_serving(8, 8),
                                   lambda: v5e_serving(4, 4, replicas=2)])
def test_dynamic_checkpoint_resumes_identically(board):
    """CHECKPOINT mid-serving (in-flight requests, pending arrivals,
    slot occupancy, percentile-stat state) resumes through the restore
    path and finishes exactly like an uninterrupted run."""
    ref_srv, ref_sim = _serve_reference(board)
    ref = _serving_fingerprint(ref_srv, ref_sim)
    assert ref["decisions"][0]            # the run actually scheduled

    srv = _serve_workload()
    sim = Simulator(board(), srv)
    mid = int(ref["makespan"] * 1e9 * 0.4)
    sim.schedule_checkpoint(mid)
    kinds = [ev.kind for ev in sim.run()]
    assert kinds == [ExitEventType.CHECKPOINT, ExitEventType.DONE]
    ckpt = sim.last_checkpoint
    assert WORKLOAD_KEY in ckpt
    # the checkpoint caught the serving mid-flight, not at the edges
    wl = ckpt[WORKLOAD_KEY]
    assert wl["heap"], "checkpoint should still have pending arrivals"
    assert 0 < wl["done_count"] < 50
    assert _serving_fingerprint(srv, sim) == ref


def test_dynamic_checkpoint_file_restores_into_fresh_workload(tmp_path):
    """A serving checkpoint on disk restores into a *rebuilt* workload
    object (same seed => same request stream) and finishes
    bit-identically — the full JSON round trip."""
    ref_srv, ref_sim = _serve_reference(lambda: v5e_serving(8, 8))
    ref = _serving_fingerprint(ref_srv, ref_sim)

    srv = _serve_workload()
    sim = Simulator(v5e_serving(8, 8), srv, checkpoint_dir=str(tmp_path))
    sim.schedule_checkpoint(int(ref["makespan"] * 1e9 * 0.5))
    for _ in sim.run():
        pass
    path = sim.checkpoint_paths[0]
    with open(path) as f:
        assert WORKLOAD_KEY in json.load(f)

    fresh = _serve_workload()
    sim2 = Simulator.from_checkpoint(path, workload=fresh)
    sim2.run_to_completion()
    assert _serving_fingerprint(fresh, sim2) == ref


def test_dynamic_checkpoint_guard_rails():
    srv = _serve_workload()
    sim = Simulator(v5e_serving(8, 8), srv)
    ckpt = sim.save_checkpoint()          # tick-0 dynamic checkpoint
    assert WORKLOAD_KEY in ckpt
    # a tick-0 checkpoint has empty percentile sketches; the file must
    # still be strict RFC 8259 JSON (no Infinity literals)
    json.dumps(ckpt, allow_nan=False)
    # restoring without the workload object is refused
    with pytest.raises(CheckpointError, match="workload"):
        Simulator.from_checkpoint(ckpt)
    # ...and a static trace passed as workload= must not bypass that
    with pytest.raises(CheckpointError, match="DynamicWorkload"):
        Simulator.from_checkpoint(ckpt, workload=_trace(layers=2,
                                                        tail=False))
    # restoring a STATIC checkpoint with a workload is refused too
    # (any workload — a static checkpoint restores its own trace, so a
    # passed one would be silently ignored)
    static = Simulator(v5e_pod(), _trace(layers=4, tail=False))
    sckpt = static.save_checkpoint()
    with pytest.raises(CheckpointError, match="no workload state"):
        Simulator.from_checkpoint(sckpt, workload=_serve_workload())
    with pytest.raises(CheckpointError, match="no workload state"):
        Simulator.from_checkpoint(sckpt, workload=_trace(layers=2,
                                                         tail=False))
    # a mismatched request stream is rejected at load time
    cost = ServingCost.from_params(1e9, layers=4, d_model=128, chips=16)
    other = ServeSim(cost=cost,
                     requests=poisson_requests(3, 10.0, seed=0))
    with pytest.raises(ValueError, match="request"):
        Simulator.from_checkpoint(ckpt, workload=other)


# ---------------------------------------------------------------------------
# machine description + guard rails
# ---------------------------------------------------------------------------

def test_machine_round_trip_through_dict():
    board = v5e_multipod(3, chip={"hbm_bw": 1e12}, ici={"bw": 75e9})
    m2 = machine_from_dict(board.machine.serialize())
    assert m2.num_pods == 3
    assert m2.pod.chip.hbm_bw == 1e12
    assert m2.pod.ici.bw == 75e9
    assert m2.pod.nx == board.machine.pod.nx


def test_snapshot_requires_drain():
    ex = v5e_pod().executor()
    ex.begin(_trace(tail=False))
    with pytest.raises(RuntimeError, match="drain"):
        ex.snapshot()


def test_restore_rejects_pod_count_mismatch():
    board = v5e_multipod(2)
    trace = _trace()
    ex = board.executor()
    ex.begin(trace)
    ex.advance(max_tick=board.machine.quantum_ns)
    ex.drain()
    ckpt = checkpoint_executor(ex)
    with pytest.raises(ValueError, match="pod"):
        restore_executor(ckpt, machine=v5e_multipod(4).machine)


def test_checkpoint_version_check():
    board = v5e_pod()
    ex = board.executor()
    ex.begin(_trace(tail=False))
    ex.advance(max_tick=1000)
    ex.drain()
    ckpt = checkpoint_executor(ex)
    bad = dict(ckpt, version=999)
    with pytest.raises(CheckpointError, match="version"):
        restore_executor(bad)
    with pytest.raises(CheckpointError, match="format"):
        restore_executor({"format": "something-else"})


def test_drained_executor_snapshot_roundtrips_partial_rendezvous():
    """Checkpoint with a cross-pod collective mid-rendezvous (one pod
    arrived, the straggler pod not yet): restore completes it."""
    board = v5e_multipod(2)
    trace = analytic_trace("w", 4, 1e12, 1e9, COLLS,
                           tail_collectives=TAIL)
    ref = board.executor(straggler_slowdowns=[1.0, 3.0],
                         record_stats=True).execute(trace)
    # pause while the fast pod waits on the dcn rendezvous
    quantum = board.machine.quantum_ns
    mid = int(ref.makespan_s * 1e9 * 0.6) // quantum * quantum
    ex = board.executor(straggler_slowdowns=[1.0, 3.0], record_stats=True)
    ex.begin(trace)
    ex.advance(max_tick=mid)
    ex.drain()
    ckpt = checkpoint_executor(ex)
    ex2 = restore_executor(ckpt, record_stats=True)
    ex2.advance()
    out = ex2.result()
    assert out.makespan_s == ref.makespan_s
    assert out.stats == ref.stats


def test_worker_count_validation_is_loud():
    """workers=0 used to be silently coerced to 1 — a config typo that
    LOOKED parallel but ran serial.  Both the board front-end and the
    restore path now reject non-positive counts the way EventQueue
    rejects negative ticks."""
    board = v5e_pod()
    with pytest.raises(ValueError, match="workers=-1"):
        board.executor(workers=-1)
    with pytest.raises(ValueError, match="workers=0"):
        board.executor(workers=0)

    ex = board.executor(record_stats=True)
    trace = analytic_trace("w", 4, 1e12, 1e9, COLLS)
    ex.begin(trace)
    ex.advance()
    ex.drain()
    ckpt = checkpoint_executor(ex)
    with pytest.raises(ValueError, match="workers=0"):
        restore_executor(ckpt, workers=0)
    # None / omitted means the serial engine, exactly as before
    ex2 = restore_executor(ckpt, record_stats=True)
    ex2.advance()
    assert ex2.result().stats == ex.result().stats
