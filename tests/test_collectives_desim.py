"""Collective algorithm plug-ins + event-driven executor (paper §2.12,
§2.13, §2.17 analogues)."""

import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core.desim.collectives import (ALGORITHMS, best_algorithm,
                                          get_algorithm)
from repro.core.desim.executor import TraceExecutor
from repro.core.desim.machine import ClusterModel
from repro.core.desim.network import TorusNetwork, build_networks
from repro.core.desim.trace import analytic_trace


def cluster(pods=1):
    c = ClusterModel("c", num_pods=pods)
    c.instantiate()
    return c


KINDS = ["all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute"]


@given(st.sampled_from(list(ALGORITHMS)), st.sampled_from(KINDS),
       st.floats(1e3, 1e12), st.sampled_from([2, 4, 16, 64, 256]))
@settings(max_examples=60, deadline=None)
def test_cost_nonnegative_and_monotone(alg_name, kind, nbytes, n):
    m = cluster()
    alg = get_algorithm(alg_name)
    t1 = alg.time_s(kind, nbytes, n, m)
    t2 = alg.time_s(kind, nbytes * 2, n, m)
    assert t1 >= 0 and t2 >= t1 * 0.99


def test_bidir_halves_ring_bandwidth_term():
    m = cluster()
    big = 1e9
    ring = get_algorithm("ring").time_s("all-reduce", big, 16, m)
    bidir = get_algorithm("bidir-ring").time_s("all-reduce", big, 16, m)
    assert bidir < ring
    assert bidir == pytest.approx(ring / 2, rel=0.05)


def test_best_algorithm_is_min():
    m = cluster()
    name, t = best_algorithm("all-reduce", 1e8, 256, m)
    for alg in ALGORITHMS.values():
        assert t <= alg.time_s("all-reduce", 1e8, 256, m) + 1e-12


def test_executor_overlap_hides_collectives():
    m = cluster()
    colls = [{"kind": "all-reduce", "bytes": 1e8, "participants": 256}]
    tr_sync = analytic_trace("sync", 8, 1e12, 1e9, colls, overlap=False)
    tr_ovl = analytic_trace("ovl", 8, 1e12, 1e9, colls, overlap=True)
    t_sync = TraceExecutor(m).execute(tr_sync)
    t_ovl = TraceExecutor(m).execute(tr_ovl)
    assert t_ovl.makespan_s <= t_sync.makespan_s
    assert t_ovl.summary()["overlap_efficiency"] >= \
        t_sync.summary()["overlap_efficiency"]


@given(st.floats(1.0, 4.0))
@settings(max_examples=20, deadline=None)
def test_executor_straggler_scales_makespan(slow):
    m = cluster(pods=2)
    tr = analytic_trace("t", 4, 1e12, 1e9, [])
    base = TraceExecutor(m).execute(tr).makespan_s
    slowed = TraceExecutor(m, straggler_slowdowns=[1.0, slow]).execute(tr)
    assert slowed.makespan_s == pytest.approx(base * slow, rel=1e-6)


def test_elastic_trace_property_hbm_doubling():
    """gem5 §2.8 'elastic': same trace, new machine params, new timing."""
    m1, m2 = cluster(), cluster()
    m2.pod.chip._params["hbm_bw"] = m1.pod.chip.hbm_bw * 2
    # memory-bound trace: bytes/hbm >> flops/peak
    tr = analytic_trace("mem", 8, 1e9, 1e12, [])
    t1 = TraceExecutor(m1).execute(tr).makespan_s
    t2 = TraceExecutor(m2).execute(tr).makespan_s
    assert t2 == pytest.approx(t1 / 2, rel=0.01)


def test_torus_routing_and_contention():
    net = TorusNetwork(4, 4, link_bw=1e9, hop_latency=1e-6)
    hops = net.route((0, 0), (2, 3))
    assert len(hops) == 2 + 1          # wrap: dy=3 -> 1 hop backwards
    t1 = net.send(0.0, (0, 0), (1, 0), 1e6)
    t2 = net.send(0.0, (0, 0), (1, 0), 1e6)   # same link -> serializes
    assert t2 > t1
    rep = net.occupancy_report()
    assert rep["links_used"] >= 1 and rep["total_bytes"] == 2e6


def test_dcn_quantum_rounding():
    m = cluster(pods=2)
    tr = analytic_trace("x", 1, 1e10, 1e8, [],
                        tail_collectives=[{"kind": "all-reduce",
                                           "bytes": 1e9,
                                           "participants": 512,
                                           "scope": "dcn"}])
    res = TraceExecutor(m).execute(tr)
    q = m.quantum_ns / 1e9
    # dcn completion snapped to a quantum boundary
    assert (res.makespan_s / q) == pytest.approx(round(res.makespan_s / q),
                                                 abs=1e-6)
