"""Jitted serving steps: prefill (prompt -> cache) and decode (one token
against a donated cache)."""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.models.common import IDENTITY_SHARDER, Sharder


def build_prefill_step(model: Model, sharder: Sharder = IDENTITY_SHARDER,
                       chunk: int = 2048, seq_capacity: int = 0) -> Callable:
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch, sharder=sharder,
                                      chunk=chunk, seq_capacity=seq_capacity)
        return logits, cache
    return prefill_step


def build_decode_step(model: Model, sharder: Sharder = IDENTITY_SHARDER,
                      sample: str = "greedy") -> Callable:
    """decode_step(params, batch) with batch = {tokens, cache, cur_len}.

    Returns (next_tokens (b, 1), logits, new_cache).  The cache is
    functionally updated; jit callers should donate it.
    """
    def decode_step(params, batch):
        logits, cache = model.decode(
            params, {"tokens": batch["tokens"]}, batch["cache"],
            batch["cur_len"], sharder=sharder)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32),
                         axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache
    return decode_step
