"""FleetController: the real-deployment face of ``FleetPolicy``.

The production-shaped wrapper around the pure fleet policy — the exact
counterpart of how ``repro.serve.server.BatchServer`` wraps
``SlotScheduler`` and ``repro.train.trainer.Trainer.run_ft`` wraps
``FTPolicy``.  A deployment wires its event sources to the three
callbacks and its provisioning system to ``on_scale``:

    ctl = FleetController(policy, on_scale=provisioner.apply)
    r = ctl.on_request(tick, rid, tenant="interactive", prefix=7)
    ...dispatch the request to replica r...
    ctl.on_finish(tick, rid, replica=r, ok=met_slo)   # from replica r
    ctl.on_tick(tick)                                 # control heartbeat

All decisions come from the policy; the controller owns only the side
effects (surfacing scale actions to the provisioner) and a safety
cross-check: a finish reported from a replica the policy never routed
that request to is a routing divergence and raises immediately.

Because the policy is pure and tick-indexed, a controller fed the
event stream a ``repro.sim.fleet.FleetSim`` run recorded (its
``feed``) reproduces the DES decision log *bit for bit* — the identity
tests/test_fleet_sim.py enforces.  jax-free by design: the simulator
stack imports this module's package.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from repro.serve.fleet_policy import FleetDecision, FleetPolicy

#: decision kinds the provisioner must act on
SCALE_KINDS = ("replica_up", "scale_up", "scale_down")


class FleetController:
    """Drives a :class:`FleetPolicy` from deployment events."""

    def __init__(self, policy: FleetPolicy,
                 on_scale: Optional[Callable[[FleetDecision], None]] = None):
        self.policy = policy
        self.on_scale = on_scale
        self._assigned: Dict[int, int] = {}
        self._cursor = 0
        policy.start()
        self._fire_scale_actions()

    # -- event callbacks --------------------------------------------------
    def on_request(self, tick: int, rid: int, *, tenant: str = "",
                   prefix: int = -1) -> int:
        """A request arrived: returns the replica to dispatch it to."""
        r = self.policy.route(tick, rid, tenant=tenant, prefix=prefix)
        self._assigned[rid] = r
        self._fire_scale_actions()
        return r

    def on_finish(self, tick: int, rid: int, *, replica: int,
                  ok: bool = True) -> None:
        """Replica ``replica`` reports ``rid`` done (``ok``: met SLO)."""
        expected = self._assigned.pop(rid, None)
        if expected is None:
            raise RuntimeError(f"finish for rid {rid} never routed")
        if replica != expected:
            raise RuntimeError(
                f"rid {rid} finished on replica {replica} but was routed "
                f"to {expected} — routing diverged")
        self.policy.finish(tick, rid, ok=ok)
        self._fire_scale_actions()

    def on_tick(self, tick: int) -> None:
        """Control heartbeat: lets boundaries/promotions fire during
        request lulls.  Call at least as often as
        ``policy.next_wake()`` comes due."""
        self.policy.observe(tick)
        self._fire_scale_actions()

    # -- provisioning -----------------------------------------------------
    def _fire_scale_actions(self) -> None:
        new = self.policy.decisions[self._cursor:]
        self._cursor = len(self.policy.decisions)
        if self.on_scale is None:
            return
        for d in new:
            if d.kind in SCALE_KINDS:
                self.on_scale(d)

    # -- replay (the identity-test driver) --------------------------------
    def replay(self, feed: List[List[Any]],
               requests: Optional[List[Any]] = None) -> None:
        """Drive the controller from a recorded event feed (the
        ``FleetSim.feed`` format): ``["route", tick, rid]``,
        ``["finish", tick, rid, replica, ok]``, ``["tick", tick]``.
        ``requests`` (rid-indexed, with ``tenant``/``prefix_group``)
        recovers routing inputs for route rows."""
        for row in feed:
            kind = row[0]
            if kind == "route":
                _, tick, rid = row
                req = requests[rid] if requests is not None else None
                self.on_request(
                    int(tick), int(rid),
                    tenant=getattr(req, "tenant", ""),
                    prefix=getattr(req, "prefix_group", -1))
            elif kind == "finish":
                _, tick, rid, replica, ok = row
                self.on_finish(int(tick), int(rid), replica=int(replica),
                               ok=bool(ok))
            elif kind == "tick":
                self.on_tick(int(row[1]))
            else:
                raise ValueError(f"unknown feed row kind {kind!r}")
