"""Serving: the real continuous-batching server and its pure policy.

The policy module is deliberately jax-free — the DES
(``repro.sim.workloads``) imports it, and the simulator stack must
stay importable (and fast to import) without jax.  The server/step
modules *do* import jax, so they load lazily (PEP 562) on first
attribute access instead of at package import.
"""

from repro.serve.fleet import FleetController  # noqa: F401 (pure)
from repro.serve.fleet_policy import (FleetDecision,  # noqa: F401 (pure)
                                      FleetPolicy)
from repro.serve.policy import Decision, SlotScheduler  # noqa: F401 (pure)

_LAZY = {
    "BatchServer": "repro.serve.server",
    "Request": "repro.serve.server",
    "build_prefill_step": "repro.serve.step",
    "build_decode_step": "repro.serve.step",
}

__all__ = ["Decision", "SlotScheduler", "FleetPolicy", "FleetDecision",
           "FleetController", *sorted(_LAZY)]


def __getattr__(name):
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(target), name)


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
