from repro.serve.step import build_prefill_step, build_decode_step  # noqa: F401
from repro.serve.server import BatchServer, Request  # noqa: F401
