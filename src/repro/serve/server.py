"""Continuous-batching inference server (vLLM-style slot scheduler,
CPU-scale).

A fixed decode batch of B slots; requests from a queue are prefilled
one at a time (B=1 prefill) and their caches inserted into free slots;
every loop iteration advances ALL active slots by one token with a
single batched decode step (per-slot ``cur_len`` vector).  Finished
slots (max tokens or EOS) are freed.  The server is a SimObject with
throughput/latency stats — and the DES can model the same policy at pod
scale for the dse_sweep benchmark.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simobject import Param, SimObject
from repro.models.api import Model
from repro.serve.policy import SlotScheduler
from repro.serve.step import build_decode_step, build_prefill_step


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_token: Optional[int] = None
    extras: Dict[str, Any] = field(default_factory=dict)
    # filled by the server:
    output: List[int] = field(default_factory=list)
    submit_time: float = 0.0
    finish_time: float = 0.0


class BatchServer(SimObject):
    slots = Param(int, 4, "decode batch size")
    seq_capacity = Param(int, 128, "KV/state capacity per slot")

    def __init__(self, name: str = "server", *, model: Model, params,
                 **kw):
        super().__init__(name, **kw)
        self.model = model
        self.params = params
        self._prefill = jax.jit(build_prefill_step(
            model, seq_capacity=self.seq_capacity))
        self._decode = jax.jit(build_decode_step(model))
        self.s_tokens = self.stats.scalar("tokens_out", "tokens generated")
        self.s_requests = self.stats.scalar("requests", "requests served")
        self.s_latency = self.stats.distribution("latency", unit="s")
        self.s_decode_steps = self.stats.scalar("decode_steps")
        self.s_throughput = self.stats.formula(
            "tokens_per_decode_step",
            lambda: self.s_tokens.value() / max(self.s_decode_steps.value(),
                                                1))

    # ------------------------------------------------------------------
    def serve(self, requests: List[Request]) -> List[Request]:
        """Serve ``requests`` to completion.

        All scheduling (admission order, slot assignment, finish
        detection) is delegated to the pure :class:`SlotScheduler`
        policy — the same object the DES ``ServeSim`` drives at pod
        scale — and the decision log of the run is left on
        ``self.scheduler`` for inspection/equivalence testing.

        Requests must carry **unique rids** (they key the decision
        log) and prompts must fit ``seq_capacity``; the policy raises
        ``ValueError`` otherwise — previously duplicate rids were
        silently tolerated and oversized prompts overflowed the cache.
        """
        B = self.slots
        cap = self.seq_capacity
        cache = self.model.init_cache(B, cap)
        cur_len = np.zeros((B,), np.int32)
        last_tok = np.zeros((B, 1), np.int32)
        by_rid = {r.rid: r for r in requests}
        sched = SlotScheduler(B, cap)
        self.scheduler = sched
        for r in requests:
            r.submit_time = time.perf_counter()
            sched.submit(r.rid, len(r.prompt), r.max_new_tokens)
        done: List[Request] = []

        def insert(slot: int, req: Request) -> None:
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32),
                     **{k: jnp.asarray(v)[None] for k, v in
                        req.extras.items()}}
            logits, rcache = self._prefill(self.params, batch)
            nonlocal cache
            cache = jax.tree.map(
                lambda c, rc: jax.lax.dynamic_update_slice_in_dim(
                    c, rc.astype(c.dtype), slot, 1),
                cache, rcache)
            tok = int(jax.device_get(jnp.argmax(
                logits[0, -1].astype(jnp.float32))))
            req.output.append(tok)
            last_tok[slot, 0] = tok
            cur_len[slot] = len(req.prompt)

        while not sched.idle():
            # fill free slots (prefill emits each request's first token)
            for slot, rid in sched.fill():
                insert(slot, by_rid[rid])
            # one batched decode step for all active slots
            nxt, _, cache = self._decode(self.params, {
                "tokens": jnp.asarray(last_tok),
                "cache": cache,
                "cur_len": jnp.asarray(cur_len),
            })
            nxt = np.asarray(jax.device_get(nxt))
            self.s_decode_steps.inc()
            sched.note_step()
            for slot in sched.active_slots():
                req = by_rid[sched.active[slot]]
                tok = int(nxt[slot, 0])
                req.output.append(tok)
                self.s_tokens.inc()
                cur_len[slot] += 1
                last_tok[slot, 0] = tok
                if sched.complete_token(slot, is_eos=tok == req.eos_token):
                    req.finish_time = time.perf_counter()
                    self.s_requests.inc()
                    self.s_latency.sample(req.finish_time - req.submit_time)
                    done.append(req)
        return done
