"""Pure fleet routing + autoscaling policy (the FleetSim tentpole).

This module factors the *control plane* of a serving fleet — which
replica gets each request, and when replicas are brought up or torn
down — into one pure, tick-indexed state machine, exactly the way
``repro.serve.policy`` factored the slot scheduler out of
``BatchServer`` and ``repro.train.ft_policy`` factored recovery out of
``Trainer``:

* **Routing** — four deterministic routers over the currently-serving
  replica set: ``round_robin``, ``least_loaded`` (fewest outstanding
  requests), ``p2c`` (power-of-two-choices: two candidates drawn by a
  stateless hash of ``(seed, rid)``, the less-loaded one wins), and
  ``prefix_affinity`` (requests sharing a prefix group stick to the
  replica that holds the prefix cache, unless it is overloaded).
* **Autoscaling** — at every control boundary (each
  ``control_period_ticks``) the policy compares outstanding load and
  the window's SLO-violation fraction against its watermarks and
  brings replicas up (they serve only after ``cold_start_ticks`` — the
  cold start is a first-class cost) or retires *idle* replicas after a
  streak of quiet windows.  Retiring only idle replicas means a
  scaled-down replica never holds work, so no drain protocol exists to
  diverge between drivers.
* **Cold start** — ``scale_up`` marks a replica *warming*; it is
  routable immediately (queued work is how the cold start surfaces in
  TTFT) but only *live* — promoted at ``ready = decision_tick +
  cold_start_ticks`` — replicas execute.

Every action is logged as a :class:`FleetDecision`, so "the DES fleet
(``repro.sim.fleet.FleetSim``) and the real controller
(``repro.serve.fleet.FleetController``) scale and route identically"
is a pure list-equality assertion (tests/test_fleet_sim.py) — no
timing, no jax, no event engine in this module.

Driver contract (both drivers follow it verbatim)::

    policy.start()                            # min_replicas live at tick 0
    r = policy.route(tick, rid, tenant=..., prefix=...)   # request arrives
    policy.finish(tick, rid, ok=...)          # request completed on r
    policy.observe(tick)                      # idle clock advance

The policy's clock is the integer tick of the *events fed to it*: on
every call it first catches up all internal triggers (warming→live
promotions, control boundaries) with trigger tick <= the event tick,
in tick order (promotions before boundaries at equal ticks).  Because
the internal schedule is a pure function of the decision history, two
drivers feeding the same tick-stamped event stream produce identical
decision logs — the property the identity tests enforce.
``next_wake()`` tells a driver the next internal trigger so it never
sleeps past one.
"""

from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

ROUTERS = ("round_robin", "least_loaded", "p2c", "prefix_affinity")

#: replica lifecycle states
DOWN, WARMING, LIVE = "down", "warming", "live"


@dataclass(frozen=True)
class FleetDecision:
    """One control-plane action, in decision order (the comparable log).

    ``tick`` is the simulated/wall tick the action logically happened
    at: route/finish carry the event tick, ``scale_up``/``scale_down``
    the control boundary, ``replica_up`` the promotion (ready) tick.
    """

    kind: str          # "replica_up" | "scale_up" | "scale_down" |
    #                    "route" | "finish"
    tick: int
    rid: int = -1
    replica: int = -1
    note: str = ""

    def to_row(self) -> List[Any]:
        return [self.kind, self.tick, self.rid, self.replica, self.note]

    @classmethod
    def from_row(cls, r: Sequence[Any]) -> "FleetDecision":
        return cls(r[0], int(r[1]), int(r[2]), int(r[3]), r[4])


class FleetPolicy:
    """Deterministic router + autoscaler over ``max_replicas`` slots.

    Pure: consumes tick-stamped request events, produces replica
    choices and an ordered :class:`FleetDecision` log.  The driver owns
    all side effects (executing requests, actually provisioning
    replicas, advancing time).

    Autoscaler rule, evaluated at each control boundary over the
    window since the previous boundary:

    * scale **up** to ``ceil(outstanding / slots_per_replica)`` (at
      least one new replica) when outstanding work exceeds
      ``up_queue_frac`` x current capacity, or when more than
      ``up_viol_frac`` of the window's finishes violated their SLO;
    * scale **down** one *idle* (zero outstanding) replica after
      ``down_windows`` consecutive windows with no violations and
      outstanding work under ``down_queue_frac`` of the capacity that
      would remain — never below ``min_replicas``.
    """

    def __init__(self, router: str = "least_loaded", *,
                 min_replicas: int, max_replicas: int,
                 slots_per_replica: int, cold_start_ticks: int,
                 control_period_ticks: int, seed: int = 0,
                 up_queue_frac: float = 1.0, up_viol_frac: float = 0.1,
                 down_queue_frac: float = 0.5, down_windows: int = 3,
                 affinity_overload: float = 2.0):
        if router not in ROUTERS:
            raise ValueError(f"unknown router {router!r}; one of {ROUTERS}")
        if not (1 <= min_replicas <= max_replicas):
            raise ValueError("need 1 <= min_replicas <= max_replicas")
        if slots_per_replica < 1:
            raise ValueError("slots_per_replica must be >= 1")
        if cold_start_ticks < 0 or control_period_ticks < 1:
            raise ValueError("cold_start_ticks >= 0 and "
                             "control_period_ticks >= 1 required")
        self.router = router
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.slots_per_replica = slots_per_replica
        self.cold_start_ticks = int(cold_start_ticks)
        self.control_period_ticks = int(control_period_ticks)
        self.seed = seed
        self.up_queue_frac = up_queue_frac
        self.up_viol_frac = up_viol_frac
        self.down_queue_frac = down_queue_frac
        self.down_windows = down_windows
        self.affinity_overload = affinity_overload
        # mutable state
        self._state: Dict[int, str] = {r: DOWN
                                       for r in range(max_replicas)}
        self._ready: Dict[int, int] = {}      # warming replica -> ready tick
        self._out: Dict[int, int] = {r: 0 for r in range(max_replicas)}
        self._rid_to_rep: Dict[int, int] = {}
        self._prefix: Dict[int, int] = {}     # prefix group -> home replica
        self._rr = 0
        self._next_boundary = self.control_period_ticks
        self._idle_streak = 0
        self._w_finished = 0                  # window accumulators
        self._w_viol = 0
        self._started = False
        self.decisions: List[FleetDecision] = []

    # -- views ------------------------------------------------------------
    def state(self, replica: int) -> str:
        return self._state[replica]

    def serving_replicas(self) -> List[int]:
        """Routable replicas (live + warming), ascending."""
        return [r for r in range(self.max_replicas)
                if self._state[r] != DOWN]

    def live_replicas(self) -> List[int]:
        return [r for r in range(self.max_replicas)
                if self._state[r] == LIVE]

    def outstanding(self, replica: int) -> int:
        return self._out[replica]

    def next_wake(self) -> int:
        """Earliest unprocessed internal trigger (boundary or
        promotion) — a driver must feed an event (or ``observe``) at or
        after this tick or the control plane falls behind."""
        return min([self._next_boundary] + list(self._ready.values()))

    # -- lifecycle --------------------------------------------------------
    def start(self) -> None:
        """Bring up the floor fleet: ``min_replicas`` live at tick 0
        (the deployment's steady-state floor is assumed pre-warmed)."""
        if self._started:
            return
        self._started = True
        for r in range(self.min_replicas):
            self._state[r] = LIVE
            self._log("replica_up", 0, replica=r, note="initial")

    def route(self, tick: int, rid: int, *, tenant: str = "",
              prefix: int = -1) -> int:
        """Pick the replica for request ``rid`` arriving at ``tick``.
        Routes to live *and warming* replicas — queueing on a warming
        replica is how the cold start shows up in that request's TTFT.
        """
        self._require_started()
        self._catch_up(int(tick))
        serving = self.serving_replicas()
        r = self._pick(serving, rid, prefix)
        self._out[r] += 1
        self._rid_to_rep[rid] = r
        self._log("route", tick, rid=rid, replica=r, note=tenant)
        return r

    def finish(self, tick: int, rid: int, *, ok: bool = True) -> int:
        """Request ``rid`` completed at ``tick`` (``ok``: met its SLO).
        Returns the replica it ran on."""
        self._require_started()
        self._catch_up(int(tick))
        r = self._rid_to_rep.pop(rid)
        self._out[r] -= 1
        self._w_finished += 1
        if not ok:
            self._w_viol += 1
        self._log("finish", tick, rid=rid, replica=r,
                  note="ok" if ok else "slo")
        return r

    def observe(self, tick: int) -> None:
        """Advance the control-plane clock with no request event
        (process boundaries/promotions due by ``tick``)."""
        self._require_started()
        self._catch_up(int(tick))

    # -- internals --------------------------------------------------------
    def _require_started(self) -> None:
        if not self._started:
            raise RuntimeError("call start() before feeding events")

    def _log(self, kind: str, tick: int, *, rid: int = -1,
             replica: int = -1, note: str = "") -> None:
        self.decisions.append(
            FleetDecision(kind, int(tick), rid, replica, note))

    def _catch_up(self, t: int) -> None:
        """Process internal triggers due by ``t`` in tick order
        (promotion before boundary at equal ticks: the boundary sees
        the replica live)."""
        while True:
            due = [(rt, r) for r, rt in self._ready.items() if rt <= t]
            promo = min(due) if due else None
            boundary = self._next_boundary if self._next_boundary <= t \
                else None
            if promo is not None and (boundary is None
                                      or promo[0] <= boundary):
                rt, r = promo
                del self._ready[r]
                self._state[r] = LIVE
                self._log("replica_up", rt, replica=r,
                          note=f"warm after {self.cold_start_ticks}")
            elif boundary is not None:
                self._control(boundary)
                self._next_boundary = boundary + self.control_period_ticks
            else:
                return

    def _control(self, b: int) -> None:
        """One autoscaler evaluation at boundary tick ``b``."""
        up = self.serving_replicas()
        cap = len(up) * self.slots_per_replica
        out = sum(self._out[r] for r in up)
        pressure = out > self.up_queue_frac * cap
        slo_bad = (self._w_finished > 0
                   and self._w_viol > self.up_viol_frac * self._w_finished)
        if (pressure or slo_bad) and len(up) < self.max_replicas:
            want = min(self.max_replicas,
                       max(len(up) + 1,
                           math.ceil(out / self.slots_per_replica)))
            why = (f"queue {out}/{cap}" if pressure
                   else f"slo {self._w_viol}/{self._w_finished}")
            for _ in range(want - len(up)):
                r = next(i for i in range(self.max_replicas)
                         if self._state[i] == DOWN)
                self._state[r] = WARMING
                self._ready[r] = b + self.cold_start_ticks
                self._log("scale_up", b, replica=r, note=why)
            self._idle_streak = 0
        elif (not pressure and self._w_viol == 0
              and len(up) > self.min_replicas
              and out <= self.down_queue_frac
              * (len(up) - 1) * self.slots_per_replica):
            self._idle_streak += 1
            if self._idle_streak >= self.down_windows:
                idle = [r for r in up if self._state[r] == LIVE
                        and self._out[r] == 0]
                if idle:
                    r = max(idle)        # retire the newest replica
                    self._state[r] = DOWN
                    self._prefix = {g: h for g, h in self._prefix.items()
                                    if h != r}
                    self._log("scale_down", b, replica=r,
                              note=f"idle x{self._idle_streak}")
                    self._idle_streak = 0
        else:
            self._idle_streak = 0
        self._w_finished = 0
        self._w_viol = 0

    def _pick(self, serving: List[int], rid: int, prefix: int) -> int:
        if self.router == "round_robin":
            r = serving[self._rr % len(serving)]
            self._rr += 1
            return r
        if self.router == "p2c":
            a = serving[self._hash(rid, 0) % len(serving)]
            b = serving[self._hash(rid, 1) % len(serving)]
            return min(a, b, key=lambda r: (self._out[r], r))
        if self.router == "prefix_affinity" and prefix >= 0:
            home = self._prefix.get(prefix)
            if (home is not None and self._state[home] != DOWN
                    and self._out[home] < self.affinity_overload
                    * self.slots_per_replica):
                return home
            r = self._least_loaded(serving)
            self._prefix[prefix] = r
            return r
        return self._least_loaded(serving)

    def _least_loaded(self, serving: List[int]) -> int:
        return min(serving, key=lambda r: (self._out[r], r))

    def _hash(self, rid: int, salt: int) -> int:
        """Stateless candidate draw: no RNG object to checkpoint, and
        both drivers get the same candidates for the same request."""
        h = hashlib.sha1(f"{self.seed}:{rid}:{salt}".encode()).digest()
        return int.from_bytes(h[:8], "big")

    # -- checkpointing ----------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "router": self.router,
            "min_replicas": self.min_replicas,
            "max_replicas": self.max_replicas,
            "slots_per_replica": self.slots_per_replica,
            "cold_start_ticks": self.cold_start_ticks,
            "control_period_ticks": self.control_period_ticks,
            "seed": self.seed,
            "state": [self._state[r] for r in range(self.max_replicas)],
            "ready": sorted([r, t] for r, t in self._ready.items()),
            "out": [self._out[r] for r in range(self.max_replicas)],
            "rid_to_rep": sorted([rid, r] for rid, r
                                 in self._rid_to_rep.items()),
            "prefix": sorted([g, r] for g, r in self._prefix.items()),
            "rr": self._rr,
            "next_boundary": self._next_boundary,
            "idle_streak": self._idle_streak,
            "w_finished": self._w_finished,
            "w_viol": self._w_viol,
            "started": self._started,
            "decisions": [d.to_row() for d in self.decisions],
        }

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        for key in ("router", "min_replicas", "max_replicas",
                    "slots_per_replica", "cold_start_ticks",
                    "control_period_ticks", "seed"):
            if d[key] != getattr(self, key):
                raise ValueError(
                    f"policy shape mismatch: checkpoint {key}={d[key]!r}, "
                    f"this policy {getattr(self, key)!r} — rebuild with "
                    "the same configuration")
        self._state = {r: s for r, s in enumerate(d["state"])}
        self._ready = {int(r): int(t) for r, t in d["ready"]}
        self._out = {r: int(o) for r, o in enumerate(d["out"])}
        self._rid_to_rep = {int(rid): int(r) for rid, r in d["rid_to_rep"]}
        self._prefix = {int(g): int(r) for g, r in d["prefix"]}
        self._rr = int(d["rr"])
        self._next_boundary = int(d["next_boundary"])
        self._idle_streak = int(d["idle_streak"])
        self._w_finished = int(d["w_finished"])
        self._w_viol = int(d["w_viol"])
        self._started = bool(d["started"])
        self.decisions = [FleetDecision.from_row(r) for r in d["decisions"]]
