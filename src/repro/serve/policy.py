"""Pure continuous-batching slot-scheduler policy (vLLM-style).

This is the scheduling brain of ``repro.serve.server.BatchServer``,
factored out as a pure state machine so the pod-scale DES
(``repro.sim.workloads.ServeSim``) can drive the *identical* policy:

* a fixed decode batch of ``num_slots`` KV-cache slots (the contended
  resource);
* waiting requests are admitted FIFO into the lowest-indexed free slot
  at iteration boundaries (``fill``);
* every decode step advances all active slots by one token
  (``note_step`` + ``complete_token``), freeing slots whose requests
  finish (max tokens, EOS, or KV capacity).

The policy records every admission and finish as a :class:`Decision`,
so "the real server and the simulator schedule identically" is a pure
list-equality assertion (tests/test_serving_policy.py) — no timing, no
jax, no event engine in this module.

Engine contract (both engines follow it verbatim):

    sched.submit(rid, prompt_len, max_new_tokens)   # request arrives
    loop:
        admits = sched.fill()                       # iteration start
        <prefill admitted requests; prefill emits the FIRST token>
        <one batched decode step over all active slots>
        sched.note_step()
        for slot in sched.active_slots():           # ascending order
            sched.complete_token(slot, is_eos=...)

Token accounting matches the server exactly: prefill contributes one
output token, each decode step one more; a request finishes when
``tokens_out >= max_new_tokens``, on EOS, or when its context reaches
``seq_capacity - 1``.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Decision:
    """One scheduling decision, in decision order.

    ``step`` is the number of completed decode steps when the decision
    was taken (admissions at iteration k and finishes caused by decode
    step k both carry ``step == k``).
    """

    kind: str          # "admit" | "finish"
    rid: int
    slot: int
    step: int
    reason: str = ""   # finishes: "max_tokens" | "eos" | "capacity"


@dataclass
class _Slot:
    """Per-request scheduling state while queued or active."""

    rid: int
    prompt_len: int
    max_new_tokens: int
    tokens_out: int = 0     # output tokens produced (prefill emits 1)
    decode_steps: int = 0   # decode steps this request took part in

    @property
    def context_len(self) -> int:
        return self.prompt_len + self.decode_steps


class SlotScheduler:
    """Deterministic continuous-batching policy over ``num_slots``."""

    def __init__(self, num_slots: int, seq_capacity: int):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.num_slots = num_slots
        self.seq_capacity = seq_capacity
        self.queue: Deque[int] = deque()
        self.active: List[Optional[int]] = [None] * num_slots
        self.requests: Dict[int, _Slot] = {}
        self.decisions: List[Decision] = []
        self.steps = 0

    # -- request intake -------------------------------------------------
    def submit(self, rid: int, prompt_len: int, max_new_tokens: int) -> None:
        if rid in self.requests:
            raise ValueError(f"duplicate rid {rid}")
        if prompt_len >= self.seq_capacity:
            raise ValueError(
                f"rid {rid}: prompt_len {prompt_len} does not fit "
                f"seq_capacity {self.seq_capacity}")
        self.requests[rid] = _Slot(rid, int(prompt_len), int(max_new_tokens))
        self.queue.append(rid)

    # -- iteration boundary ---------------------------------------------
    def fill(self) -> List[Tuple[int, int]]:
        """Admit waiting requests into free slots (FIFO queue, lowest
        slot first — the server's fill loop).  Returns ``(slot, rid)``
        admissions in decision order.  Admission models the prefill:
        the request's first output token is accounted here."""
        out: List[Tuple[int, int]] = []
        for slot in range(self.num_slots):
            if self.active[slot] is None and self.queue:
                rid = self.queue.popleft()
                self.active[slot] = rid
                self.requests[rid].tokens_out = 1
                self.decisions.append(Decision("admit", rid, slot, self.steps))
                out.append((slot, rid))
        return out

    def note_step(self) -> None:
        """One batched decode step completed (before ``complete_token``
        calls for its slots)."""
        self.steps += 1

    def complete_token(self, slot: int, is_eos: bool = False
                       ) -> Optional[Decision]:
        """Account one decoded token for ``slot``; frees the slot and
        returns the finish Decision if the request completed."""
        rid = self.active[slot]
        if rid is None:
            raise ValueError(f"slot {slot} is not active")
        st = self.requests[rid]
        st.tokens_out += 1
        st.decode_steps += 1
        reason = ""
        if st.tokens_out >= st.max_new_tokens:
            reason = "max_tokens"
        elif is_eos:
            reason = "eos"
        elif st.context_len >= self.seq_capacity - 1:
            reason = "capacity"
        if not reason:
            return None
        self.active[slot] = None
        d = Decision("finish", rid, slot, self.steps, reason)
        self.decisions.append(d)
        return d

    # -- views -----------------------------------------------------------
    def active_slots(self) -> List[int]:
        """Occupied slot indices, ascending (the decode batch)."""
        return [s for s in range(self.num_slots) if self.active[s] is not None]

    def context_len(self, slot: int) -> int:
        rid = self.active[slot]
        if rid is None:
            raise ValueError(f"slot {slot} is not active")
        return self.requests[rid].context_len

    def idle(self) -> bool:
        return not self.queue and not self.active_slots()

    # -- checkpointing ----------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "num_slots": self.num_slots,
            "seq_capacity": self.seq_capacity,
            "queue": list(self.queue),
            "active": list(self.active),
            "steps": self.steps,
            "requests": {str(rid): [st.prompt_len, st.max_new_tokens,
                                    st.tokens_out, st.decode_steps]
                         for rid, st in self.requests.items()},
            "decisions": [[d.kind, d.rid, d.slot, d.step, d.reason]
                          for d in self.decisions],
        }

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        if (int(d["num_slots"]) != self.num_slots
                or int(d["seq_capacity"]) != self.seq_capacity):
            raise ValueError(
                "scheduler shape mismatch: checkpoint is "
                f"{d['num_slots']} slots x {d['seq_capacity']} capacity, "
                f"this scheduler {self.num_slots} x {self.seq_capacity}")
        self.queue = deque(int(r) for r in d["queue"])
        self.active = [None if a is None else int(a) for a in d["active"]]
        self.steps = int(d["steps"])
        self.requests = {
            int(rid): _Slot(int(rid), int(p), int(m), int(t), int(s))
            for rid, (p, m, t, s) in d["requests"].items()}
        self.decisions = [Decision(k, int(r), int(sl), int(st), re)
                          for k, r, sl, st, re in d["decisions"]]
