"""Logical-axis sharding rules for the production meshes.

Models declare *logical* axes on every parameter and activation
("embed", "mlp", "heads", ...; see ``repro.models.common.param``).  This
module owns the single mapping from logical axes to *mesh* axes, so
model code never mentions a mesh:

* ``make_rules(cfg, shape, mesh)`` derives a :class:`Rules` table for
  one (architecture x input shape x mesh) cell, applying the
  divisibility fallbacks the production configs need (head counts that
  don't divide the model axis fall back to context parallelism, GQA
  kv-head counts that don't divide fall back to kv-sequence sharding
  for decode, batch=1 cells stay unsharded, ...).
* ``Rules.spec(logical_axes)`` resolves a tuple of logical axis names
  to a ``PartitionSpec``, dropping duplicate mesh axes (a mesh axis may
  appear at most once in a spec).
* :class:`MeshSharder` is the ``repro.models.common.Sharder``
  implementation used under ``pjit``: it applies
  ``with_sharding_constraint`` from logical names and builds
  ``NamedSharding`` trees for parameters and batches.

Only :class:`MeshSharder` touches jax device state; ``Rules`` and
``make_rules`` read nothing but axis names/sizes, so unit tests can use
mock meshes.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.common import Sharder

PartitionSpec = jax.sharding.PartitionSpec

# logical axis name -> tuple of mesh axis names (None = replicated)
Mapping = Dict[str, Optional[Tuple[str, ...]]]


def _mesh_sizes(mesh: Any) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


@dataclass
class Rules:
    """Logical-axis -> mesh-axis mapping for one cell."""

    mapping: Mapping = field(default_factory=dict)
    axis_sizes: Dict[str, int] = field(default_factory=dict)

    def spec(self, logical_axes: Tuple[Optional[str], ...]) -> PartitionSpec:
        """PartitionSpec for a tuple of logical axis names.

        A mesh axis may shard at most one dimension; later uses of an
        already-consumed mesh axis are dropped (replicated) so specs
        built from arbitrary logical tuples are always valid.
        """
        used: set = set()
        entries = []
        for name in logical_axes:
            mesh_axes = self.mapping.get(name) if name else None
            if mesh_axes:
                mesh_axes = tuple(a for a in mesh_axes if a not in used)
            if not mesh_axes:
                entries.append(None)
                continue
            used.update(mesh_axes)
            entries.append(mesh_axes[0] if len(mesh_axes) == 1
                           else tuple(mesh_axes))
        return PartitionSpec(*entries)

    def size(self, logical: str) -> int:
        """Number of shards a logical axis is split into."""
        mesh_axes = self.mapping.get(logical)
        if not mesh_axes:
            return 1
        return math.prod(self.axis_sizes.get(a, 1) for a in mesh_axes)

    def describe(self) -> Dict[str, Any]:
        return {k: (list(v) if v else None) for k, v in self.mapping.items()}


def make_rules(cfg: ArchConfig, shape: ShapeConfig, mesh: Any) -> Rules:
    """Derive the sharding rules for one (arch x shape x mesh) cell.

    Fallback ladder (each rung used only when the one above does not
    divide the mesh axis):

    * attention heads  : TP over "model"  -> context parallel ("q_seq")
    * GQA kv heads     : TP over "model"  -> kv-cache sequence sharding
                         ("kv_seq", decode only; capacity is the
                         sliding window when the arch has one)
    * batch            : hierarchical DP over ("pod", "data") -> None
                         when the global batch does not divide the DP
                         ranks (e.g. long_500k batch=1)
    """
    sizes = _mesh_sizes(mesh)
    model = sizes.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    dp = math.prod(sizes[a] for a in dp_axes) if dp_axes else 1

    def fits(n: int) -> bool:
        return n > 0 and n % model == 0

    heads_tp = fits(cfg.n_heads)
    kv_tp = fits(cfg.n_kv_heads)

    # decode kv-cache capacity: sliding-window archs cap the cache
    cache_len = shape.seq_len
    if cfg.sliding_window:
        cache_len = min(cache_len, cfg.sliding_window)

    mapping: Mapping = {
        "batch": (dp_axes if dp_axes and shape.global_batch % dp == 0
                  else None),
        "seq": None,
        "embed": None,
        "mlp": ("model",) if fits(cfg.d_ff) else None,
        "heads": ("model",) if heads_tp else None,
        "kv_heads": ("model",) if kv_tp else None,
        "kv_heads_c": ("model",) if kv_tp else None,
        "vocab": ("model",) if fits(cfg.vocab_size) else None,
        # context parallelism replaces head TP when heads don't divide
        "q_seq": (("model",) if not heads_tp and fits(shape.seq_len)
                  else None),
        # kv-cache sequence sharding replaces kv-head TP for decode
        "kv_seq": (("model",) if shape.kind == "decode" and not kv_tp
                   and fits(cache_len) else None),
        "experts": ("model",) if fits(cfg.n_experts) else None,
    }
    return Rules(mapping=mapping, axis_sizes=sizes)


class MeshSharder(Sharder):
    """``Sharder`` that applies the rules on a real jax mesh."""

    def __init__(self, mesh: jax.sharding.Mesh, rules: Rules):
        self.mesh = mesh
        self.rules = rules

    # -- Sharder interface (called from inside jitted model code) -------
    def ac(self, x, axes: Tuple[Optional[str], ...]):
        if getattr(x, "ndim", None) != len(axes):
            return x
        spec = self._spec_for_shape(x.shape, axes)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))

    def axis_size(self, logical: str) -> int:
        return self.rules.size(logical)

    # -- sharding trees for jit in_shardings ----------------------------
    def sharding(self, axes: Tuple[Optional[str], ...]
                 ) -> jax.sharding.NamedSharding:
        return jax.sharding.NamedSharding(self.mesh, self.rules.spec(axes))

    def param_shardings(self, axes_tree: Any) -> Any:
        """NamedSharding tree from a logical-axes tree (tuple leaves)."""
        return jax.tree.map(
            lambda axes: self.sharding(tuple(axes)), axes_tree,
            is_leaf=lambda x: isinstance(x, tuple))

    def batch_shardings(self, batch_specs: Any,
                        cfg: Optional[ArchConfig] = None) -> Any:
        """Data-parallel shardings for a batch ShapeDtypeStruct tree.

        The leading dimension of every array is the (global) batch; it
        is sharded over the DP axes when divisible, everything else is
        replicated.  ``cfg`` is accepted for arch-specific overrides
        (none needed currently).
        """
        dp_axes = self.rules.mapping.get("batch")
        dp = self.rules.size("batch")

        def one(s):
            ndim = len(s.shape)
            if (dp_axes and ndim >= 1 and s.shape[0] % dp == 0
                    and s.shape[0] > 0):
                entry = dp_axes[0] if len(dp_axes) == 1 else tuple(dp_axes)
                spec = PartitionSpec(entry, *([None] * (ndim - 1)))
            else:
                spec = PartitionSpec()
            return jax.sharding.NamedSharding(self.mesh, spec)

        return jax.tree.map(one, batch_specs)

    # -- internals -------------------------------------------------------
    def _spec_for_shape(self, shape: Tuple[int, ...],
                        axes: Tuple[Optional[str], ...]) -> PartitionSpec:
        """Like ``rules.spec`` but drops mesh axes whose size does not
        divide the concrete dimension (uneven activation shapes stay
        replicated on that dim instead of erroring)."""
        used: set = set()
        entries = []
        for dim, name in zip(shape, axes):
            mesh_axes = self.rules.mapping.get(name) if name else None
            if mesh_axes:
                mesh_axes = tuple(a for a in mesh_axes if a not in used)
                nshards = math.prod(self.rules.axis_sizes.get(a, 1)
                                    for a in mesh_axes)
                if nshards and dim % nshards != 0:
                    mesh_axes = ()
            if not mesh_axes:
                entries.append(None)
                continue
            used.update(mesh_axes)
            entries.append(mesh_axes[0] if len(mesh_axes) == 1
                           else tuple(mesh_axes))
        return PartitionSpec(*entries)
