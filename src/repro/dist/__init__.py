"""Distribution layer: logical-axis sharding rules + mesh sharders."""

from repro.dist.sharding import MeshSharder, Rules, make_rules  # noqa: F401
