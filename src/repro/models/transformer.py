"""Decoder LM assembly for all families: dense / moe / vlm (standard
blocks), ssm (RWKV6 blocks), hybrid (Jamba period-8 Mamba+attention+MoE
pattern).

Layers execute under ``jax.lax.scan`` with stacked parameters so the
HLO size is O(1) in depth (deepseek-67b = 95 layers compiles as one
while loop).  Hybrid archs scan over *periods* (Jamba: 4 periods of 8
sublayers each, attention at position 4, MoE on odd positions).

Three modes share the block code:
  train   -> logits over all positions (activation-rematerialized)
  prefill -> logits at the last position + KV/state cache
  decode  -> one-token step updating the cache
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as ll
from repro.models import mamba as mm
from repro.models import moe as me
from repro.models import rwkv as rw
from repro.models.common import (IDENTITY_SHARDER, Sharder, param,
                                 split_key, stack_inits, unzip)


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def layer_kind(cfg, layer_idx: int) -> str:
    if cfg.family == "ssm":
        return "rwkv"
    if cfg.family == "hybrid" and not cfg.is_attn_layer(layer_idx):
        return "mamba"
    return "attn"


def init_layer(key, cfg, layer_idx: int) -> Dict:
    """One decoder layer (norms + mixer + ffn) as a marker tree."""
    kind = layer_kind(cfg, layer_idx)
    ks = split_key(key, 4)
    if kind == "rwkv":
        blk = rw.init_rwkv_block(ks[0], cfg)
        return {
            "norm1": ll.init_norm(ks[1], cfg, cfg.d_model),
            "mixer": blk["time_mix"],
            "norm2": ll.init_norm(ks[2], cfg, cfg.d_model),
            "ffn": blk["channel_mix"],
        }
    mixer = (ll.init_attention(ks[0], cfg) if kind == "attn"
             else mm.init_mamba_block(ks[0], cfg))
    ffn = (me.init_moe(ks[3], cfg) if cfg.is_moe_layer(layer_idx)
           else ll.init_mlp(ks[3], cfg))
    return {
        "norm1": ll.init_norm(ks[1], cfg, cfg.d_model),
        "mixer": mixer,
        "norm2": ll.init_norm(ks[2], cfg, cfg.d_model),
        "ffn": ffn,
    }


def init_decoder_layers(key, cfg) -> Any:
    """Stacked layer params: period-1 archs -> one stacked tree;
    hybrid -> tuple of per-position stacked trees (stacked over periods).
    """
    if cfg.family == "hybrid":
        period = cfg.attn_every
        n_periods = cfg.n_layers // period
        assert n_periods * period == cfg.n_layers
        out = []
        for pos in range(period):
            k = jax.random.fold_in(key, pos)
            out.append(stack_inits(
                lambda kk, _pos=pos: init_layer(kk, cfg, _pos), k, n_periods))
        return tuple(out)
    return stack_inits(lambda kk: init_layer(kk, cfg, 0), key, cfg.n_layers)


# ---------------------------------------------------------------------------
# Cache construction (zeros / specs)
# ---------------------------------------------------------------------------

def kv_capacity(cfg, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def layer_cache_shape(cfg, layer_idx: int, batch: int, seq_len: int,
                      dtype=jnp.bfloat16) -> Dict:
    kind = layer_kind(cfg, layer_idx)
    if kind == "attn":
        S = kv_capacity(cfg, seq_len)
        shp = (batch, cfg.n_kv_heads, S, cfg.head_dim)
        return {"k": jax.ShapeDtypeStruct(shp, dtype),
                "v": jax.ShapeDtypeStruct(shp, dtype)}
    if kind == "mamba":
        return {
            "conv": jax.ShapeDtypeStruct(
                (batch, cfg.d_conv - 1, cfg.d_inner), dtype),
            "ssm": jax.ShapeDtypeStruct(
                (batch, cfg.d_inner, cfg.d_state), jnp.float32),
        }
    h = cfg.n_rwkv_heads
    n = cfg.rwkv_head_size
    return {
        "shift_tm": jax.ShapeDtypeStruct((batch, 1, cfg.d_model), dtype),
        "shift_cm": jax.ShapeDtypeStruct((batch, 1, cfg.d_model), dtype),
        "wkv": jax.ShapeDtypeStruct((batch, h, n, n), jnp.float32),
    }


def _stack_specs(specs):
    """List of identical-structure ShapeDtypeStruct trees -> stacked."""
    n = len(specs)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), specs[0])


def cache_spec(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct pytree of the full decode cache."""
    if cfg.family == "hybrid":
        period = cfg.attn_every
        n_periods = cfg.n_layers // period
        return tuple(
            _stack_specs([layer_cache_shape(cfg, pos, batch, seq_len, dtype)
                          for _ in range(n_periods)])
            for pos in range(period))
    return _stack_specs([layer_cache_shape(cfg, 0, batch, seq_len, dtype)
                         for _ in range(cfg.n_layers)])


def init_cache(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16) -> Any:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, seq_len, dtype))


# ---------------------------------------------------------------------------
# Per-layer apply
# ---------------------------------------------------------------------------

def apply_layer(p: Dict, x, cfg, layer_idx: int, sharder: Sharder,
                positions, mode: str, cache: Optional[Dict], cur_len,
                chunk: int, seq_capacity: int) -> Tuple:
    """Returns (x, new_cache_entry, aux_loss)."""
    kind = layer_kind(cfg, layer_idx)
    rs = cfg.residual_scale
    aux = jnp.zeros((), jnp.float32)
    h = ll.apply_norm(p["norm1"], x, cfg)

    if kind == "rwkv":
        st = cache or {}
        mix, new_shift_tm, new_wkv = rw.apply_time_mix(
            p["mixer"], h, cfg, sharder,
            shift_state=st.get("shift_tm"), wkv_state=st.get("wkv"))
        x = x + rs * mix
        x = sharder.ac(x, ("batch", "seq", None))
        h2 = ll.apply_norm(p["norm2"], x, cfg)
        f, new_shift_cm = rw.apply_channel_mix(
            p["ffn"], h2, cfg, shift_state=st.get("shift_cm"))
        x = x + rs * f
        x = sharder.ac(x, ("batch", "seq", None))
        new_cache = None
        if mode != "train":
            new_cache = {"shift_tm": new_shift_tm, "shift_cm": new_shift_cm,
                         "wkv": new_wkv}
        return x, new_cache, aux

    if kind == "mamba":
        st = cache or {}
        mix, new_conv, new_ssm = mm.apply_mamba(
            p["mixer"], h, cfg, sharder,
            conv_state=st.get("conv"), ssm_state=st.get("ssm"),
            remat=(mode == "train"))
        new_cache = None
        if mode != "train":
            new_cache = {"conv": new_conv, "ssm": new_ssm}
    else:  # attention
        if mode == "decode":
            mix, new_cache = ll.attention_decode(
                p["mixer"], h, cfg, cache, cur_len, sharder)
        elif mode == "prefill":
            mix, (k_raw, v_raw) = ll.attention_train(
                p["mixer"], h, cfg, positions, sharder, chunk=chunk,
                return_kv=True)
            new_cache = ll.kv_to_cache(
                k_raw, v_raw, kv_capacity(cfg, seq_capacity), sharder)
        else:
            mix = ll.attention_train(p["mixer"], h, cfg, positions, sharder,
                                     chunk=chunk)
            new_cache = None

    x = x + rs * mix
    x = sharder.ac(x, ("batch", "seq", None))
    h2 = ll.apply_norm(p["norm2"], x, cfg)
    if cfg.is_moe_layer(layer_idx):
        f, aux = me.apply_moe(p["ffn"], h2, cfg, sharder)
    else:
        f = ll.apply_mlp(p["ffn"], h2, cfg, sharder)
    x = x + rs * f
    x = sharder.ac(x, ("batch", "seq", None))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Decoder stack (scan over layers / periods)
# ---------------------------------------------------------------------------

def decoder_forward(layers_params: Any, x, cfg, sharder: Sharder, positions,
                    mode: str = "train", cache: Any = None, cur_len=None,
                    chunk: int = 2048, seq_capacity: int = 0
                    ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Run the full decoder stack.  Returns (x, new_cache, aux_loss)."""
    seq_capacity = seq_capacity or x.shape[1]
    hybrid = cfg.family == "hybrid"
    period = cfg.attn_every if hybrid else 1

    def one_layer(pos):
        def fn(x, p, c):
            return apply_layer(p, x, cfg, pos, sharder, positions, mode,
                               c, cur_len, chunk, seq_capacity)
        return fn

    n_steps = (cfg.n_layers // period)

    if mode == "decode":
        # Decode carries the WHOLE cache through the scan and updates it
        # in place with dynamic_update_index: XLA aliases while-loop
        # carries, so the multi-GB cache exists ONCE.  (Passing it as
        # scan xs/ys double-buffers it — measured +12.8 GB/device at
        # deepseek decode_32k scale.)
        def ds(tree_, li):
            return jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, li, 0,
                                                       keepdims=False),
                tree_)

        def dus(tree_, new, li):
            return jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), li, 0), tree_, new)

        def dbody(carry, lp):
            x, aux, cache_all, li = carry
            if hybrid:
                for pos in range(period):
                    lc = ds(cache_all[pos], li)
                    x, nc, a = one_layer(pos)(x, lp[pos], lc)
                    aux = aux + a
                    cache_all = (cache_all[:pos]
                                 + (dus(cache_all[pos], nc, li),)
                                 + cache_all[pos + 1:])
            else:
                lc = ds(cache_all, li)
                x, nc, a = one_layer(0)(x, lp, lc)
                aux = aux + a
                cache_all = dus(cache_all, nc, li)
            return (x, aux, cache_all, li + 1), None

        (x, aux, cache, _), _ = jax.lax.scan(
            dbody, (x, jnp.zeros((), jnp.float32), cache, 0),
            layers_params, length=n_steps)
        return x, cache, aux

    def body2(carry, xs):
        x, aux = carry
        lp = xs
        if hybrid:
            ncs = []
            for pos in range(period):
                fn = one_layer(pos)
                if mode == "train":
                    fn = jax.checkpoint(fn)
                x, nc, a = fn(x, lp[pos], None)
                aux = aux + a
                ncs.append(nc)
            ys = tuple(ncs) if mode != "train" else 0.0
        else:
            fn = one_layer(0)
            if mode == "train":
                fn = jax.checkpoint(fn)
            x, nc, a = fn(x, lp, None)
            aux = aux + a
            ys = nc if mode != "train" else 0.0
        return (x, aux), ys

    (x, aux), caches = jax.lax.scan(body2, (x, jnp.zeros((), jnp.float32)),
                                    layers_params, length=n_steps)
    new_cache = caches if mode != "train" else None
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------

def init_lm(key, cfg) -> Dict:
    ks = split_key(key, 3)
    return {
        "embed": ll.init_embedding(ks[0], cfg),
        "layers": init_decoder_layers(ks[1], cfg),
        "final_norm": ll.init_norm(ks[2], cfg, cfg.d_model),
    }


def make_positions(cfg, b: int, s: int, n_vis: int = 0, offset: int = 0):
    """Sequential positions; M-RoPE 3-D positions for the vlm family."""
    if cfg.pos_scheme != "mrope":
        return jnp.broadcast_to(jnp.arange(offset, offset + s), (b, s))
    # vision tokens: (t=0, h, w) over the patch grid; text tokens: all
    # three coordinates equal the sequence index (so a decode step at
    # cur_len uses position cur_len without knowing n_vis).
    grid = max(1, int(math.sqrt(max(n_vis, 1))))
    pos = []
    for i in range(3):
        vis = {
            0: jnp.zeros((n_vis,), jnp.int32),
            1: jnp.arange(n_vis) // grid,
            2: jnp.arange(n_vis) % grid,
        }[i]
        txt = jnp.arange(n_vis, s)
        pos.append(jnp.concatenate([vis, txt]) + offset)
    p3 = jnp.stack(pos, axis=-1)                      # (s, 3)
    return jnp.broadcast_to(p3, (b, s, 3))


def lm_apply(params: Dict, batch: Dict, cfg, sharder: Sharder = IDENTITY_SHARDER,
             mode: str = "train", cache: Any = None, cur_len=None,
             chunk: int = 2048, seq_capacity: int = 0,
             compute_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Unified LM entry.  Returns (logits, new_cache, aux_loss).

    train  : logits (b, s, Vp)
    prefill: logits (b, 1, Vp) at the last position, + cache
    decode : logits (b, 1, Vp), + updated cache
    """
    from repro.models.common import cast
    params = cast(params, compute_dtype)
    tokens = batch["tokens"]
    b = tokens.shape[0]
    embed_pos = batch.get("positions")
    if cfg.pos_scheme == "learned" and embed_pos is None:
        if mode == "decode":
            embed_pos = jnp.broadcast_to(
                jnp.reshape(jnp.asarray(cur_len, jnp.int32), (-1, 1)),
                (b, 1))
        else:
            embed_pos = jnp.broadcast_to(
                jnp.arange(tokens.shape[1]), tokens.shape)
    x = ll.embed_tokens(params["embed"], tokens, cfg, positions=embed_pos)
    n_vis = 0
    if "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(x.dtype)
        n_vis = vis.shape[1]
        x = jnp.concatenate([vis, x], axis=1)
    s = x.shape[1]
    if mode == "decode":
        positions = None                 # decode builds its own from cur_len
    else:
        positions = make_positions(cfg, b, s, n_vis=n_vis)
    x = sharder.ac(x, ("batch", "seq", None))
    x, new_cache, aux = decoder_forward(
        params["layers"], x, cfg, sharder, positions, mode=mode, cache=cache,
        cur_len=cur_len, chunk=chunk, seq_capacity=seq_capacity)
    if mode != "train":
        x = x[:, -1:]
    x = ll.apply_norm(params["final_norm"], x, cfg)
    logits = ll.unembed(params["embed"], x, cfg, sharder)
    return logits, new_cache, aux
