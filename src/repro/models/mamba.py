"""Mamba (S6) selective-state-space block [arXiv:2312.00752], used by the
Jamba hybrid architecture [arXiv:2403.19887].

Training path: chunked parallel scan (outer ``lax.scan`` over chunks
carrying the (d_inner, d_state) state, inner ``associative_scan`` over
the chunk).  Decode path: O(1) single-step recurrence with a carried
(conv_state, ssm_state) — what makes ``long_500k`` runnable for the
hybrid family.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Sharder, IDENTITY_SHARDER, param, split_key


def dt_rank(cfg) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def init_mamba_block(key, cfg) -> Dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    r = dt_rank(cfg)
    ks = split_key(key, 8)
    return {
        "in_proj": param(ks[0], (d, 2 * di), ("embed", "mlp")),
        "conv_w": param(ks[1], (cfg.d_conv, di), (None, "mlp"), scale=0.5),
        "conv_b": param(ks[2], (di,), ("mlp",), init="zeros"),
        "x_proj": param(ks[3], (di, r + 2 * n), ("mlp", None)),
        "dt_proj": param(ks[4], (r, di), (None, "mlp"), scale=0.1),
        "dt_bias": param(ks[5], (di,), ("mlp",), init="zeros"),
        # S4D-real init: A = -(1..n) per channel
        "A_log": {"v": jnp.broadcast_to(
            jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), (di, n)).copy(),
            "axes": ("mlp", None)},
        "D": param(ks[6], (di,), ("mlp",), init="ones"),
        "out_proj": param(ks[7], (di, d), ("mlp", "embed")),
    }


def _causal_conv(p: Dict, x, conv_state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv via shifted adds.  x: (b, s, di).

    conv_state: (b, d_conv-1, di) trailing inputs from the previous
    segment (decode); returns (y, new_conv_state).
    """
    taps = p["conv_w"].shape[0]
    b, s, di = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((b, taps - 1, di), x.dtype)
    ext = jnp.concatenate([conv_state, x], axis=1)     # (b, s+taps-1, di)
    y = jnp.zeros_like(x)
    for i in range(taps):
        y = y + ext[:, i:i + s] * p["conv_w"][i]
    y = y + p["conv_b"]
    new_state = ext[:, -(taps - 1):] if taps > 1 else conv_state
    return y, new_state


def _ssm_params(p: Dict, xc, cfg):
    """xc: (b, s, di) post-conv.  Returns decay, drive, C."""
    r = dt_rank(cfg)
    n = cfg.d_state
    proj = jnp.einsum("bsd,dk->bsk", xc, p["x_proj"])
    dt_r, B, C = jnp.split(proj, [r, r + n], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_r, p["dt_proj"]) + p["dt_bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))       # (b,s,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # (di,n)
    decay = jnp.exp(dt[..., None] * A)                  # (b,s,di,n)
    drive = (dt * xc.astype(jnp.float32))[..., None] \
        * B[:, :, None, :].astype(jnp.float32)          # (b,s,di,n)
    return decay, drive, C.astype(jnp.float32)


def selective_scan_chunked(p: Dict, xc, cfg, h0=None, chunk: int = 256,
                           remat: bool = True):
    """Chunked selective scan computing SSM params per chunk.

    xc: (b, s, di) post-conv activations.  The (b, s, di, n) decay/drive
    tensors are 2*d_state times larger than the activations, so they are
    built INSIDE the chunk loop (and rematerialized in the backward
    pass) — materializing them for the whole sequence would dominate
    training memory (measured: ~17 GB/layer at jamba train_4k scale).

    Returns (y (b, s, di) f32  = sum_n h * C, h_last (b, di, n)).
    """
    b, s, di = xc.shape
    n = cfg.d_state
    if s % chunk:
        chunk = s
    nc = s // chunk
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    # slice chunks in-body (a staged (nc, b, chunk, di) transpose copy
    # of xc per mamba sublayer dominated prefill_32k memory) and emit
    # bf16 chunk outputs (f32 kept only for the recurrence itself).
    def body(carry, _):
        h, i = carry
        xck = jax.lax.dynamic_slice_in_dim(xc, i * chunk, chunk, axis=1)
        decay, drive, C = _ssm_params(p, xck, cfg)     # (b,chunk,di,n)
        ca, cb = jax.lax.associative_scan(combine, (decay, drive), axis=1)
        h_all = ca * h[:, None] + cb
        y = jnp.einsum("bsdn,bsn->bsd", h_all, C)
        return (h_all[:, -1], i + 1), y.astype(xc.dtype)

    scan_body = jax.checkpoint(body) if remat else body
    (h_last, _), ys = jax.lax.scan(scan_body, (h0, 0), None, length=nc)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di).astype(jnp.float32)
    return y, h_last


def apply_mamba(p: Dict, x, cfg, sharder: Sharder = IDENTITY_SHARDER,
                conv_state=None, ssm_state=None, chunk: int = 256,
                remat: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (b, s, d) -> (out, new_conv_state, new_ssm_state)."""
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xz = sharder.ac(xz, ("batch", None, "mlp"))
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = _causal_conv(p, xin, conv_state)
    xc = jax.nn.silu(xc)

    if x.shape[1] == 1 and ssm_state is not None:
        decay, drive, C = _ssm_params(p, xc, cfg)
        h = decay[:, 0] * ssm_state + drive[:, 0]       # (b,di,n)
        new_ssm = h
        y = jnp.einsum("bdn,bn->bd", h, C[:, 0])[:, None]
    else:
        y, new_ssm = selective_scan_chunked(p, xc, cfg, ssm_state, chunk,
                                            remat=remat)

    y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    # sequence-parallel out-projection: reshard (seq <- model, di full)
    # BEFORE contracting over di.  Keeping di sharded here makes XLA
    # materialize a full-sequence f32 partial-sum of (b, s, d_model) per
    # sublayer and all-reduce it — measured ~2 GB/sublayer at
    # prefill_32k; the all-to-all reshard moves bf16 and the contraction
    # becomes local.
    if x.shape[1] > 1:
        y = sharder.ac(y, ("batch", "seq", None))
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    return out, new_conv, new_ssm
