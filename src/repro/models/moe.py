"""Mixture-of-Experts FFN: top-k routing with sort-based, group-local
dispatch (dropless up to a capacity factor).

Design (TPU-adapted, see DESIGN.md):

* Tokens are grouped by batch row; groups are sharded over the
  ("pod","data") mesh axes, so all routing/sorting/gathering below is
  *local to a shard* — no token ever crosses the data axis.  Expert
  weights are sharded (embed -> data [FSDP], mlp -> model [TP]) so the
  expert compute is tensor-parallel; XLA inserts the FSDP all-gather
  and the TP reduce exactly as for a dense MLP.
* Dispatch is sort-based (MegaBlocks/MaxText style), NOT the GShard
  one-hot einsum: a one-hot dispatch tensor costs O(tokens*E*C*D) FLOPs
  (~3x the expert compute at OLMoE scale); sorting costs
  O(tokens*k*log) scalar work and the gathers are pure data movement.
* Capacity C = ceil(top_k * T * capacity_factor / E) per group.  Slots
  beyond C drop (standard GShard semantics); the aux load-balance loss
  pushes the router toward balance.

Everything is differentiable where it must be: gathers carry gradients
to token activations and expert outputs; `argsort`/`searchsorted`
operate on integer routing metadata only.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Sharder, IDENTITY_SHARDER, param, split_key


def init_moe(key, cfg) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = split_key(key, 4)
    p = {
        "router": param(ks[0], (d, e), ("embed", None), scale=0.02),
        "wi": param(ks[1], (e, d, f), ("experts", "embed", "mlp")),
        "wo": param(ks[2], (e, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.act == "swiglu":
        p["wg"] = param(ks[3], (e, d, f), ("experts", "embed", "mlp"))
    return p


def route_topk(logits, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """logits (..., E) -> (gates (..., k) renormalized, idx (..., k))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx


def load_balance_loss(probs, idx, n_experts: int):
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    # f_e: fraction of (token, k) assignments to expert e
    one_hot = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32)
    f = jnp.mean(jnp.sum(one_hot, axis=-2), axis=tuple(range(one_hot.ndim - 2)))
    f = f / one_hot.shape[-2]
    P = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return n_experts * jnp.sum(f * P)


MAX_GROUP_TOKENS = 4096


def apply_moe(p: Dict, x, cfg, sharder: Sharder = IDENTITY_SHARDER
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    # groups = batch rows, subdivided so a group never exceeds
    # MAX_GROUP_TOKENS (prefill_32k would otherwise build 8x-capacity
    # dispatch blocks; finer groups shrink every intermediate by the
    # same factor at identical FLOPs)
    sub = max(1, S // MAX_GROUP_TOKENS) if S % MAX_GROUP_TOKENS == 0 else 1
    G, T = B * sub, S // sub
    x = x.reshape(G, T, D)
    TK = T * K
    C = max(1, math.ceil(K * T * cfg.capacity_factor / E))
    C = min(C, TK)

    logits = jnp.einsum("gtd,de->gte", x, p["router"]).astype(jnp.float32)
    # routing metadata is tiny: pin it to batch-only sharding so the
    # partitioner never inserts model-axis rendezvous collectives for
    # the sort/gather index chain (hillclimb cell 1: these accounted
    # for the bulk of olmoe's 637 GB/dev of per-token all-reduces)
    logits = sharder.ac(logits, ("batch", None, None))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = route_topk(logits, K)           # (G,T,K)
    aux = load_balance_loss(probs, eidx, E)

    flat_e = eidx.reshape(G, TK)
    flat_e = sharder.ac(flat_e, ("batch", None))
    sort_idx = jnp.argsort(flat_e, axis=-1)                       # (G,TK)
    sort_idx = sharder.ac(sort_idx, ("batch", None))
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=-1)
    # per-group start offset of each expert's segment in sorted order
    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E), side="left"))(sorted_e)
    ends = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E), side="right"))(sorted_e)

    # --- dispatch: gather tokens into (G, E, C, D) capacity blocks -----
    pos = starts[:, :, None] + jnp.arange(C)[None, None, :]       # (G,E,C)
    valid = pos < ends[:, :, None]
    pos_c = jnp.minimum(pos, TK - 1).reshape(G, E * C)
    slot_src = jnp.take_along_axis(sort_idx, pos_c, axis=-1)      # (G,EC)
    tok_src = slot_src // K                                       # (G,EC)
    xin = jnp.take_along_axis(
        x, tok_src[:, :, None].astype(jnp.int32), axis=1)         # (G,EC,D)
    xin = xin * valid.reshape(G, E * C, 1).astype(x.dtype)
    xin = xin.reshape(G, E, C, D)
    xin = sharder.ac(xin, ("batch", None, None, None))

    # --- expert compute (tensor-parallel over "mlp") --------------------
    h = jnp.einsum("gecd,edf->gecf", xin, p["wi"])
    if cfg.act == "swiglu":
        u = jnp.einsum("gecd,edf->gecf", xin, p["wg"])
        h = jax.nn.silu(h) * u
    elif cfg.act == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    h = sharder.ac(h, ("batch", None, None, "mlp"))
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"])                # (G,E,C,D)
    # the down-projection contracts over the model-sharded d_ff: ask for
    # a D-sharded ("mlp") output so the partial sums REDUCE-SCATTER
    # (1/model_size the bytes of the all-reduce the replicated layout
    # forced — hillclimb cell 1, iteration 5).  The combine gathers and
    # the final residual reshard move bf16 over all-to-all.
    out = sharder.ac(out, ("batch", None, None, "moe_d"))

    # --- combine: gather each (token, k) slot's output, weight by gate --
    inv = jnp.argsort(sort_idx, axis=-1)                          # (G,TK)
    c_of = inv - jnp.take_along_axis(starts, flat_e, axis=-1)     # (G,TK)
    within = (c_of >= 0) & (c_of < C)
    flat_slot = flat_e * C + jnp.clip(c_of, 0, C - 1)             # (G,TK)
    out_flat = out.reshape(G, E * C, D)
    per_k = jnp.take_along_axis(
        out_flat, flat_slot[:, :, None].astype(jnp.int32), axis=1)
    per_k = per_k * within[:, :, None].astype(x.dtype)
    per_k = per_k.reshape(G, T, K, D)
    y = jnp.einsum("gtkd,gtk->gtd", per_k, gates.astype(x.dtype))
    return y.reshape(B, S, D), aux
