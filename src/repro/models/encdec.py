"""Whisper-style encoder-decoder [arXiv:2212.04356].

The conv/mel frontend is a STUB per the assignment: the encoder input
is precomputed frame embeddings (batch, enc_seq, d_model) provided by
``input_specs()``.  The encoder is a bidirectional transformer; the
decoder adds causal self-attention plus cross-attention whose K/V come
from the encoder output (cached at prefill for decode).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as ll
from repro.models.common import (IDENTITY_SHARDER, Sharder, cast, split_key,
                                 stack_inits)
from repro.models.transformer import kv_capacity


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_enc_layer(key, cfg) -> Dict:
    ks = split_key(key, 4)
    return {
        "norm1": ll.init_norm(ks[0], cfg, cfg.d_model),
        "attn": ll.init_attention(ks[1], cfg),
        "norm2": ll.init_norm(ks[2], cfg, cfg.d_model),
        "ffn": ll.init_mlp(ks[3], cfg),
    }


def _init_dec_layer(key, cfg) -> Dict:
    ks = split_key(key, 6)
    return {
        "norm1": ll.init_norm(ks[0], cfg, cfg.d_model),
        "self_attn": ll.init_attention(ks[1], cfg),
        "norm_x": ll.init_norm(ks[2], cfg, cfg.d_model),
        "cross_attn": ll.init_attention(ks[3], cfg),
        "norm2": ll.init_norm(ks[4], cfg, cfg.d_model),
        "ffn": ll.init_mlp(ks[5], cfg),
    }


def init_encdec(key, cfg) -> Dict:
    ks = split_key(key, 6)
    return {
        "embed": ll.init_embedding(ks[0], cfg),
        "enc_pos": {"v": 0.02 * jax.random.normal(
            ks[1], (cfg.enc_seq, cfg.d_model), jnp.float32),
            "axes": (None, "embed")},
        "enc_layers": stack_inits(lambda k: _init_enc_layer(k, cfg), ks[2],
                                  cfg.enc_layers),
        "enc_norm": ll.init_norm(ks[3], cfg, cfg.d_model),
        "dec_layers": stack_inits(lambda k: _init_dec_layer(k, cfg), ks[4],
                                  cfg.n_layers),
        "final_norm": ll.init_norm(ks[5], cfg, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(params: Dict, enc_embeds, cfg, sharder: Sharder,
           chunk: int = 2048):
    """enc_embeds: (b, enc_seq, d) stub frontend output."""
    x = enc_embeds + params["enc_pos"]
    x = sharder.ac(x, ("batch", "seq", None))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, lp):
        def fn(x, lp):
            h = ll.apply_norm(lp["norm1"], x, cfg)
            # bidirectional: reuse attention_train with cross=True trick
            k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
            k = ll._repeat_kv(k, cfg.n_heads)
            v = ll._repeat_kv(v, cfg.n_heads)
            a = ll.attention_train(lp["attn"], h, cfg, positions, sharder,
                                   kv=(k, v, positions), chunk=chunk)
            x = x + a
            h2 = ll.apply_norm(lp["norm2"], x, cfg)
            x = x + ll.apply_mlp(lp["ffn"], h2, cfg, sharder)
            return sharder.ac(x, ("batch", "seq", None))
        return jax.checkpoint(fn)(x, lp), 0.0

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return ll.apply_norm(params["enc_norm"], x, cfg)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _cross_kv(lp, enc_out, cfg):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
    return k, v


def _decode_cross(lp, h, cfg, cross_cache, sharder):
    """Cross-attention read during decode (cache: (b, h, enc_seq, hd))."""
    b = h.shape[0]
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"])
    kvh = cfg.n_kv_heads
    g = cfg.n_heads // kvh
    qg = q.reshape(b, kvh, g, hd)
    sc = jnp.einsum("bkgd,bksd->bkgs", qg, cross_cache["k"])
    sc = (sc / jnp.sqrt(jnp.asarray(hd, jnp.float32))).astype(jnp.float32)
    probs = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", probs.astype(h.dtype),
                     cross_cache["v"])
    out = out.reshape(b, 1, cfg.n_heads, hd)
    return jnp.einsum("bshk,hkd->bsd", out, lp["cross_attn"]["wo"])


def dec_forward(params: Dict, x, enc_out, cfg, sharder: Sharder, positions,
                mode: str, cache: Any = None, cur_len=None,
                chunk: int = 2048, seq_capacity: int = 0):
    """Decoder stack.  cache per layer:
    {"self": {k,v}, "cross": {k,v (b, kvh, enc_seq, hd)}}."""
    seq_capacity = seq_capacity or x.shape[1]

    def body(carry, xs):
        x, = carry
        lp, lc = xs

        def fn(x, lp, lc):
            h = ll.apply_norm(lp["norm1"], x, cfg)
            new_cache = None
            if mode == "decode":
                a, new_self = ll.attention_decode(
                    lp["self_attn"], h, cfg, lc["self"], cur_len, sharder)
            elif mode == "prefill":
                a, (kr, vr) = ll.attention_train(
                    lp["self_attn"], h, cfg, positions, sharder, chunk=chunk,
                    return_kv=True)
                new_self = ll.kv_to_cache(kr, vr,
                                          kv_capacity(cfg, seq_capacity),
                                          sharder)
            else:
                a = ll.attention_train(lp["self_attn"], h, cfg, positions,
                                       sharder, chunk=chunk)
                new_self = None
            x = x + a
            hx = ll.apply_norm(lp["norm_x"], x, cfg)
            if mode == "decode":
                c = _decode_cross(lp, hx, cfg, lc["cross"], sharder)
                new_cross = lc["cross"]
            else:
                ck, cv = _cross_kv(lp, enc_out, cfg)
                enc_pos = jnp.broadcast_to(
                    jnp.arange(enc_out.shape[1]), enc_out.shape[:2])
                c = ll.attention_train(
                    lp["cross_attn"], hx, cfg, positions, sharder,
                    kv=(ll._repeat_kv(ck, cfg.n_heads),
                        ll._repeat_kv(cv, cfg.n_heads), enc_pos),
                    chunk=chunk)
                new_cross = {"k": ck.transpose(0, 2, 1, 3),
                             "v": cv.transpose(0, 2, 1, 3)}
            x = x + c
            h2 = ll.apply_norm(lp["norm2"], x, cfg)
            x = x + ll.apply_mlp(lp["ffn"], h2, cfg, sharder)
            x = sharder.ac(x, ("batch", "seq", None))
            if mode == "prefill":
                new_cache = {"self": new_self, "cross": new_cross}
            elif mode == "decode":
                new_cache = {"self": new_self, "cross": new_cross}
            return x, new_cache

        if mode == "train":
            x, nc = jax.checkpoint(fn)(x, lp, lc)
            return (x,), 0.0
        x, nc = fn(x, lp, lc)
        return (x,), nc

    if mode == "decode":
        # carry the cache: single aliased buffer (see transformer.py)
        def dbody(carry, lp):
            x, cache_all, li = carry
            lc = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, li, 0,
                                                       keepdims=False),
                cache_all)
            (x,), nc = body((x,), (lp, lc))
            cache_all = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), li, 0), cache_all, nc)
            return (x, cache_all, li + 1), None

        (x, cache, _), _ = jax.lax.scan(
            dbody, (x, cache, 0), params["dec_layers"],
            length=cfg.n_layers)
        return x, cache

    xs = (params["dec_layers"], cache)
    (x,), caches = jax.lax.scan(body, (x,), xs, length=cfg.n_layers)
    return x, (caches if mode != "train" else None)


def encdec_apply(params: Dict, batch: Dict, cfg,
                 sharder: Sharder = IDENTITY_SHARDER, mode: str = "train",
                 cache: Any = None, cur_len=None, chunk: int = 2048,
                 seq_capacity: int = 0, compute_dtype=jnp.bfloat16
                 ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Returns (logits, cache, aux).  batch: tokens + enc_embeds (stub)."""
    params = cast(params, compute_dtype)
    tokens = batch["tokens"]
    b = tokens.shape[0]
    if mode == "decode":
        enc_out = None
        positions = None
        embed_pos = jnp.broadcast_to(
            jnp.reshape(jnp.asarray(cur_len, jnp.int32), (-1, 1)), (b, 1))
    else:
        enc_out = encode(params, batch["enc_embeds"].astype(compute_dtype),
                         cfg, sharder, chunk=chunk)
        s = tokens.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        embed_pos = positions
    x = ll.embed_tokens(params["embed"], tokens, cfg, positions=embed_pos)
    x = sharder.ac(x, ("batch", "seq", None))
    x, new_cache = dec_forward(params, x, enc_out, cfg, sharder, positions,
                               mode, cache=cache, cur_len=cur_len,
                               chunk=chunk, seq_capacity=seq_capacity)
    if mode != "train":
        x = x[:, -1:]
    x = ll.apply_norm(params["final_norm"], x, cfg)
    logits = ll.unembed(params["embed"], x, cfg, sharder)
    return logits, new_cache, jnp.zeros((), jnp.float32)


def encdec_cache_spec(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16):
    S = kv_capacity(cfg, seq_len)
    self_shp = (cfg.n_layers, batch, cfg.n_kv_heads, S, cfg.head_dim)
    cross_shp = (cfg.n_layers, batch, cfg.n_kv_heads, cfg.enc_seq,
                 cfg.head_dim)
    return {
        "self": {"k": jax.ShapeDtypeStruct(self_shp, dtype),
                 "v": jax.ShapeDtypeStruct(self_shp, dtype)},
        "cross": {"k": jax.ShapeDtypeStruct(cross_shp, dtype),
                  "v": jax.ShapeDtypeStruct(cross_shp, dtype)},
    }
