"""Unified model API: every assigned architecture behind one interface.

``build_model(cfg)`` dispatches on family and returns a ``Model`` whose
methods are pure functions suitable for jit/pjit:

    init(key)                          -> params (f32 master)
    param_specs()                      -> (ShapeDtypeStruct tree, logical-axes tree)
    train_logits(params, batch, ...)   -> (logits, aux_loss)
    prefill(params, batch, ...)        -> (last_logits, cache)
    decode(params, batch, cache, cur_len, ...) -> (logits, cache)
    cache_spec(batch, seq_len)         -> ShapeDtypeStruct tree
    input_specs(shape, kind)           -> batch ShapeDtypeStruct dict

This is the gem5 'modular port interface' idea applied to models: any
architecture plugs into the same train/serve/dry-run drivers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec as ed
from repro.models import transformer as tf
from repro.models.common import IDENTITY_SHARDER, Sharder, unzip


@dataclass
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------------
    def _init_fn(self) -> Callable:
        if self.cfg.family == "audio":
            return ed.init_encdec
        return tf.init_lm

    def init(self, key) -> Any:
        vals, _ = unzip(self._init_fn()(key, self.cfg))
        return vals

    def param_specs(self) -> Tuple[Any, Any]:
        box: Dict[str, Any] = {}

        def f(key):
            t = self._init_fn()(key, self.cfg)
            vals, axes = unzip(t)
            box["axes"] = axes
            return vals

        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, box["axes"]

    # ------------------------------------------------------------------
    def train_logits(self, params, batch, sharder: Sharder = IDENTITY_SHARDER,
                     chunk: int = 2048) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if self.cfg.family == "audio":
            logits, _, aux = ed.encdec_apply(params, batch, self.cfg, sharder,
                                             mode="train", chunk=chunk)
        else:
            logits, _, aux = tf.lm_apply(params, batch, self.cfg, sharder,
                                         mode="train", chunk=chunk)
        return logits, aux

    def prefill(self, params, batch, sharder: Sharder = IDENTITY_SHARDER,
                chunk: int = 2048, seq_capacity: int = 0):
        if self.cfg.family == "audio":
            logits, cache, _ = ed.encdec_apply(
                params, batch, self.cfg, sharder, mode="prefill", chunk=chunk,
                seq_capacity=seq_capacity)
        else:
            logits, cache, _ = tf.lm_apply(
                params, batch, self.cfg, sharder, mode="prefill", chunk=chunk,
                seq_capacity=seq_capacity)
        return logits, cache

    def decode(self, params, batch, cache, cur_len,
               sharder: Sharder = IDENTITY_SHARDER):
        if self.cfg.family == "audio":
            logits, cache, _ = ed.encdec_apply(
                params, batch, self.cfg, sharder, mode="decode", cache=cache,
                cur_len=cur_len)
        else:
            logits, cache, _ = tf.lm_apply(
                params, batch, self.cfg, sharder, mode="decode", cache=cache,
                cur_len=cur_len)
        return logits, cache

    # ------------------------------------------------------------------
    def cache_spec(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        if self.cfg.family == "audio":
            return ed.encdec_cache_spec(self.cfg, batch, seq_len, dtype)
        return tf.cache_spec(self.cfg, batch, seq_len, dtype)

    def init_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_spec(batch, seq_len, dtype))

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig, kind: Optional[str] = None
                    ) -> Dict[str, Any]:
        """Batch ShapeDtypeStructs for one assigned (arch x shape) cell.

        kind defaults to shape.kind.  Vision/audio frontends are stubs:
        precomputed embeddings appear as inputs (assignment spec).
        """
        cfg = self.cfg
        kind = kind or shape.kind
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        bf16 = jnp.bfloat16
        sds = jax.ShapeDtypeStruct

        def extras() -> Dict[str, Any]:
            out: Dict[str, Any] = {}
            if cfg.family == "vlm" and kind != "decode":
                out["vision_embeds"] = sds((B, cfg.n_vis, cfg.d_model), bf16)
            if cfg.family == "audio" and kind != "decode":
                out["enc_embeds"] = sds((B, cfg.enc_seq, cfg.d_model), bf16)
            return out

        if kind == "train":
            s_text = S - (cfg.n_vis if cfg.family == "vlm" else 0)
            return {
                "tokens": sds((B, s_text), i32),
                "labels": sds((B, S), i32),
                "mask": sds((B, S), jnp.float32),
                **extras(),
            }
        if kind == "prefill":
            s_text = S - (cfg.n_vis if cfg.family == "vlm" else 0)
            return {"tokens": sds((B, s_text), i32), **extras()}
        # decode: one new token against a seq_len-capacity cache
        return {
            "tokens": sds((B, 1), i32),
            "cache": self.cache_spec(B, S),
            "cur_len": sds((), i32),
        }


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
