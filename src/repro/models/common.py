"""Parameter-tree helpers shared by all model families.

Models are pure-JAX pytrees (nested dicts of arrays).  Every parameter
is created through ``param(...)`` which records its *logical axes*
(names like "embed", "mlp", "heads", "vocab").  ``repro.dist.sharding``
maps logical axes to mesh axes; models never mention mesh axes.

``init`` functions build a tree whose leaves are ``{"v": array,
"axes": (...)}`` markers; ``unzip`` splits that into (params, axes)
trees with identical structure.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Leaf = Dict[str, Any]


def param(key, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
          dtype=jnp.float32, scale: Optional[float] = None,
          init: str = "normal") -> Leaf:
    """One parameter leaf with logical-axis metadata."""
    assert len(shape) == len(axes), (shape, axes)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        if scale is None:
            # fan-in scaled normal (truncation unnecessary for smoke scale)
            fan_in = shape[0] if len(shape) == 1 else int(
                math.prod(shape[:-1]))
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        v = scale * jax.random.normal(key, shape, dtype)
    return {"v": v, "axes": axes}


def is_leaf_marker(x: Any) -> bool:
    return isinstance(x, dict) and set(x.keys()) == {"v", "axes"}


def unzip(tree: Any) -> Tuple[Any, Any]:
    """Split a marker tree into (values, axes) trees."""
    values = jax.tree.map(lambda l: l["v"], tree, is_leaf=is_leaf_marker)
    axes = jax.tree.map(lambda l: l["axes"], tree, is_leaf=is_leaf_marker)
    return values, axes


def split_key(key, n: int):
    return list(jax.random.split(key, n))


def stack_inits(init_fn, key, n: int) -> Any:
    """Stack ``n`` independent inits of one layer along a leading axis.

    ``init_fn(key) -> marker tree``.  Uses vmap so tracing cost is O(1)
    in ``n`` (important: deepseek-67b has 95 layers and init is only
    ever *traced* for the dry-run via eval_shape).  The leading stacked
    axis gets logical axis ``None`` (layers are never sharded; they are
    the scan dimension).
    """
    keys = jax.random.split(key, n)

    def values_only(k):
        t = init_fn(k)
        return jax.tree.map(lambda m: m["v"], t, is_leaf=is_leaf_marker)

    vals = jax.vmap(values_only)(keys)
    proto = init_fn(keys[0])
    flat_vals, _ = jax.tree.flatten(vals)
    flat_proto, treedef = jax.tree.flatten(proto, is_leaf=is_leaf_marker)
    markers = [{"v": v, "axes": (None,) + tuple(m["axes"])}
               for v, m in zip(flat_vals, flat_proto)]
    return jax.tree.unflatten(treedef, markers)


def cast(tree: Any, dtype) -> Any:
    """Cast float leaves (compute precision policy: bf16 matmuls)."""
    def _c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(_c, tree)


class Sharder:
    """Activation-constraint hook threaded through model code.

    ``ac(x, logical_axes)`` applies ``with_sharding_constraint`` when a
    mesh is active; the default instance is the identity so model code
    runs unsharded (smoke tests) without any mesh.
    """

    def ac(self, x, axes: Tuple[Optional[str], ...]):
        return x

    # logical->mesh queries models may use for layout decisions
    def axis_size(self, logical: str) -> int:
        return 1


IDENTITY_SHARDER = Sharder()
