"""RWKV-6 (Finch): attention-free blocks with data-dependent decay
[arXiv:2404.05892].

Time-mix uses the WKV6 linear recurrence per 64-wide head:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t         (S: (n_k, n_v) per head)
    y_t = r_t S_{t-1} + (r_t . u . k_t) v_t      (u: per-head bonus)

The training path is *chunked*: within a chunk, pairwise decay factors
are exponentials of cumulative-log-decay *differences*, which are all
<= 0 for causal pairs — numerically safe by construction (no unbounded
exp(-cumsum) rescaling).  The chunk math is the oracle for
``repro.kernels.rwkv6_wkv``.  Decode uses the O(1)-state recurrence,
which is what makes the ``long_500k`` cell runnable for this family.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Sharder, IDENTITY_SHARDER, param, split_key

LORA_R = 32       # low-rank size of the data-dependent mix/decay MLPs
MIX_KINDS = 5     # r, k, v, g, w


def init_rwkv_block(key, cfg) -> Dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    h = d // hs
    ks = split_key(key, 16)
    tm = {
        "mu_x": param(ks[0], (d,), (None,), init="zeros"),
        "mu": param(ks[1], (MIX_KINDS, d), (None, None), init="zeros"),
        "lora_a": param(ks[2], (d, MIX_KINDS, LORA_R), ("embed", None, None),
                        scale=0.02),
        "lora_b": param(ks[3], (MIX_KINDS, LORA_R, d), (None, None, None),
                        scale=0.02),
        "wr": param(ks[4], (d, h, hs), ("embed", "heads", None)),
        "wk": param(ks[5], (d, h, hs), ("embed", "heads", None)),
        "wv": param(ks[6], (d, h, hs), ("embed", "heads", None)),
        "wg": param(ks[7], (d, h, hs), ("embed", "heads", None)),
        "wo": param(ks[8], (h, hs, d), ("heads", None, "embed")),
        "w0": param(ks[9], (h, hs), ("heads", None), init="zeros"),
        "w_lora_a": param(ks[10], (d, LORA_R), ("embed", None), scale=0.02),
        "w_lora_b": param(ks[11], (LORA_R, h, hs), (None, "heads", None),
                          scale=0.02),
        "u": param(ks[12], (h, hs), ("heads", None), init="zeros"),
        "ln_x_scale": param(ks[13], (h, hs), ("heads", None), init="ones"),
        "ln_x_bias": param(ks[13], (h, hs), ("heads", None), init="zeros"),
    }
    cm = {
        "mu_k": param(ks[14], (d,), (None,), init="zeros"),
        "mu_r": param(ks[14], (d,), (None,), init="zeros"),
        "wk": param(ks[14], (d, cfg.d_ff), ("embed", "mlp")),
        "wv": param(ks[15], (cfg.d_ff, d), ("mlp", "embed")),
        "wr": param(ks[15], (d, d), ("embed", None)),
    }
    return {"time_mix": tm, "channel_mix": cm}


def _token_shift(x, prev: Optional[jnp.ndarray]):
    """xx_t = x_{t-1}; prev: (b, 1, d) carried state (zeros at start)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


# ---------------------------------------------------------------------------
# WKV6 core
# ---------------------------------------------------------------------------

def wkv6_chunked(r, k, v, lw, u, state0=None, chunk: int = 32):
    """Chunked WKV6 scan.

    r/k/v/lw: (b, s, h, n) with lw = log(decay) <= 0; u: (h, n).
    Returns (y (b, s, h, n), state (b, h, n, n)).
    """
    b, s, h, n = r.shape
    if s % chunk:
        chunk = s
    nc = s // chunk
    L = chunk
    f32 = jnp.float32

    def to_chunks(x):
        return x.reshape(b, nc, L, h, n).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, lw))
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), f32)

    causal = jnp.tril(jnp.ones((L, L), bool), k=-1)   # strictly lower

    def body(S, xs):
        rr, kk, vv, ww = (x.astype(f32) for x in xs)   # (b,L,h,n)
        cum = jnp.cumsum(ww, axis=1)                   # (b,L,h,n), <= 0
        cum_prev = cum - ww                            # cum_{t-1}
        # pairwise decay exp(cum_{l-1} - cum_m) for m < l: always <= 0 arg
        dmat = cum_prev[:, :, None] - cum[:, None, :, :, :]   # (b,L,L,h,n)
        dmat = jnp.where(causal[None, :, :, None, None], dmat, -jnp.inf)
        scores = jnp.einsum("blhn,bmhn,blmhn->bhlm", rr, kk, jnp.exp(dmat))
        intra = jnp.einsum("bhlm,bmhn->blhn", scores, vv)
        diag = jnp.einsum("blhn,hn,blhn->blh", rr, u.astype(f32), kk)
        intra = intra + diag[..., None] * vv
        # inter-chunk: r_t * a_{t-1} applied to carried state
        r_hat = rr * jnp.exp(cum_prev)
        inter = jnp.einsum("blhn,bhnm->blhm", r_hat, S)
        y = inter + intra
        # state update: S' = diag(a_L) S + sum_m (a_L/a_m) k_m (x) v_m
        a_L = jnp.exp(cum[:, -1])                      # (b,h,n)
        k_tail = kk * jnp.exp(cum[:, -1:, :, :] - cum)  # <= multiplier 1
        S_new = a_L[..., None] * S + jnp.einsum(
            "bmhn,bmhv->bhnv", k_tail, vv)
        return S_new, y

    S, ys = jax.lax.scan(body, state0, (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, n)
    return y.astype(r.dtype), S


def wkv6_step(r, k, v, lw, u, state):
    """One decode step.  r/k/v/lw: (b, 1, h, n); state (b, h, n, n)."""
    f32 = jnp.float32
    rr, kk, vv, ww = (x[:, 0].astype(f32) for x in (r, k, v, lw))
    y = jnp.einsum("bhn,bhnm->bhm", rr, state) \
        + jnp.einsum("bhn,hn,bhn->bh", rr, u.astype(f32), kk)[..., None] \
        * vv
    state = jnp.exp(ww)[..., None] * state + jnp.einsum(
        "bhn,bhv->bhnv", kk, vv)
    return y[:, None].astype(r.dtype), state


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _ddlerp(tm, x, xx):
    """RWKV6 data-dependent token-shift mixes for r,k,v,g,w."""
    base = x + (xx - x) * tm["mu_x"]
    lo = jnp.einsum("bsd,dkr->bskr", base, tm["lora_a"])
    lo = jnp.tanh(lo)
    delta = jnp.einsum("bskr,krd->bskd", lo, tm["lora_b"])
    mixes = tm["mu"][None, None] + delta                   # (b,s,5,d)
    return [x + (xx - x) * mixes[:, :, i] for i in range(MIX_KINDS)]


def _head_groupnorm(tm, y, eps=64e-5):
    f = y.astype(jnp.float32)
    mean = jnp.mean(f, axis=-1, keepdims=True)
    var = jnp.var(f, axis=-1, keepdims=True)
    f = (f - mean) * jax.lax.rsqrt(var + eps)
    return (f * tm["ln_x_scale"] + tm["ln_x_bias"]).astype(y.dtype)


def apply_time_mix(tm: Dict, x, cfg, sharder: Sharder = IDENTITY_SHARDER,
                   shift_state=None, wkv_state=None, chunk: int = 32
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (out, new_shift_state, new_wkv_state)."""
    xx = _token_shift(x, shift_state)
    xr, xk, xv, xg, xw = _ddlerp(tm, x, xx)
    r = jnp.einsum("bsd,dhn->bshn", xr, tm["wr"])
    k = jnp.einsum("bsd,dhn->bshn", xk, tm["wk"])
    v = jnp.einsum("bsd,dhn->bshn", xv, tm["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,dhn->bshn", xg, tm["wg"]))
    wdel = jnp.einsum("bsd,dr->bsr", xw, tm["w_lora_a"])
    wdel = jnp.einsum("bsr,rhn->bshn", jnp.tanh(wdel), tm["w_lora_b"])
    lw = -jnp.exp(tm["w0"][None, None].astype(jnp.float32)
                  + wdel.astype(jnp.float32))      # log decay, < 0
    for t in (r, k, v):
        pass
    r = sharder.ac(r, ("batch", None, "heads", None))
    k = sharder.ac(k, ("batch", None, "heads", None))
    v = sharder.ac(v, ("batch", None, "heads", None))
    if x.shape[1] == 1 and wkv_state is not None:
        y, new_state = wkv6_step(r, k, v, lw, tm["u"], wkv_state)
    else:
        y, new_state = wkv6_chunked(r, k, v, lw, tm["u"], wkv_state,
                                    chunk=chunk)
    y = _head_groupnorm(tm, y) * g
    out = jnp.einsum("bshn,hnd->bsd", y, tm["wo"])
    return out, x[:, -1:], new_state


def apply_channel_mix(cm: Dict, x, cfg, shift_state=None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xx = _token_shift(x, shift_state)
    xk = x + (xx - x) * cm["mu_k"]
    xr = x + (xx - x) * cm["mu_r"]
    k = jnp.einsum("bsd,df->bsf", xk, cm["wk"])
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, cm["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, cm["wr"]))
    return r * kv, x[:, -1:]
