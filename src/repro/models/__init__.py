"""Model zoo: pure-JAX pytree models for all assigned architectures."""

from repro.models.api import Model, build_model  # noqa: F401
