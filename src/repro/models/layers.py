"""Layer library: norms, rotary (RoPE / M-RoPE), GQA attention
(blockwise-online-softmax train path + KV-cache decode path), MLPs,
embeddings and the cross-entropy loss.

Everything is a pure function over parameter pytrees created with
``repro.models.common.param`` (which carries logical sharding axes).
Attention over long sequences uses a pure-jnp blockwise online-softmax
(the oracle for ``repro.kernels.flash_attention``); the naive path is
kept for short sequences and as a test reference.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Sharder, IDENTITY_SHARDER, param, split_key

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(key, cfg, d: int) -> Dict:
    p = {"scale": param(key, (d,), (None,), init="ones")}
    if cfg.norm == "layernorm":
        p["bias"] = param(key, (d,), (None,), init="zeros")
    return p


def apply_norm(p: Dict, x, cfg):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE, partial RoPE, M-RoPE)
# ---------------------------------------------------------------------------

def _rot_dims(cfg) -> int:
    rot = int(cfg.head_dim * cfg.rope_pct)
    return rot - rot % 2


def _inv_freq(rot: int, theta: float):
    return theta ** (-jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)


def rope_angles(cfg, positions):
    """positions (..., ) or (..., 3) for mrope -> angles (..., rot//2)."""
    rot = _rot_dims(cfg)
    inv = _inv_freq(rot, cfg.rope_theta)          # (rot//2,)
    if cfg.pos_scheme == "mrope":
        # split the frequency dims into t/h/w sections (2:3:3, Qwen2-VL)
        nf = rot // 2
        s1 = nf // 4
        s2 = (nf - s1) // 2
        sections = (s1, s2, nf - s1 - s2)
        pos = positions.astype(jnp.float32)       # (..., 3)
        parts = []
        start = 0
        for i, sec in enumerate(sections):
            parts.append(pos[..., i:i + 1] * inv[start:start + sec])
            start += sec
        return jnp.concatenate(parts, axis=-1)    # (..., nf)
    pos = positions.astype(jnp.float32)
    return pos[..., None] * inv


def apply_rope(cfg, x, positions):
    """x: (b, s, h, hd); positions: (b, s) or (b, s, 3)."""
    if cfg.pos_scheme in ("learned", "none"):
        return x
    rot = _rot_dims(cfg)
    if rot == 0:
        return x
    ang = rope_angles(cfg, positions)             # (b, s, rot//2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    if xp.shape[-1]:
        out = jnp.concatenate([out, xp], axis=-1)
    return out


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim
    ks = split_key(key, 6)
    p = {
        "wq": param(ks[0], (d, cfg.n_heads, hd), ("embed", "heads", None)),
        "wk": param(ks[1], (d, cfg.n_kv_heads, hd),
                    ("embed", "kv_heads", None)),
        "wv": param(ks[2], (d, cfg.n_kv_heads, hd),
                    ("embed", "kv_heads", None)),
        "wo": param(ks[3], (cfg.n_heads, hd, d), ("heads", None, "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = param(ks[4], (hd,), (None,), init="ones")
        p["k_norm"] = param(ks[5], (hd,), (None,), init="ones")
    return p


def _qk_norm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _repeat_kv(k, n_heads: int):
    """(b, s, kvh, hd) -> (b, s, h, hd) by repeating each kv head."""
    b, s, kvh, hd = k.shape
    if kvh == n_heads:
        return k
    rep = n_heads // kvh
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kvh, rep, hd))
    return k.reshape(b, s, n_heads, hd)


def qkv_project(p: Dict, x, cfg, positions, sharder: Sharder):
    """Returns q (b,s,h,hd), k/v (b,s,h,hd) (kv repeated), post-RoPE."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(cfg, q, positions)
    k = apply_rope(cfg, k, positions)
    k = _repeat_kv(k, cfg.n_heads)
    v = _repeat_kv(v, cfg.n_heads)
    q = sharder.ac(q, ("batch", None, "heads", None))
    k = sharder.ac(k, ("batch", None, "heads", None))
    v = sharder.ac(v, ("batch", None, "heads", None))
    return q, k, v


def naive_causal_attention(q, k, v, q_pos, kv_pos, window: int = 0,
                           cross: bool = False):
    """Reference attention.  q/k/v: (b, s, h, hd); positions (b, s)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if not cross:
        mask = kv_pos[:, None, None, :] <= q_pos[:, None, :, None]
        if window:
            mask &= kv_pos[:, None, None, :] > (
                q_pos[:, None, :, None] - window)
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshk->bqhk", probs.astype(q.dtype), v)
    return out


def blockwise_attention(q, k, v, q_pos, kv_pos, window: int = 0,
                        chunk: int = 1024, cross: bool = False):
    """Online-softmax attention, scanning KV chunks (flash-style).

    Pure jnp (runs everywhere); the oracle for the Pallas kernel.
    q: (b, sq, h, hd); k/v: (b, skv, h, hd).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    if skv % chunk:
        chunk = skv          # fall back to single chunk
    n_chunks = skv // chunk
    scale = 1.0 / math.sqrt(hd)
    qf = q * jnp.asarray(scale, q.dtype)

    kc = k.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        acc, m, l = carry                       # (b,h,sq,hd) f32, (b,h,sq)
        kci, vci, pci = xs
        s = jnp.einsum("bqhk,bshk->bhqs", qf, kci).astype(jnp.float32)
        if not cross:
            mask = pci[:, None, None, :] <= q_pos[:, None, :, None]
            if window:
                mask &= pci[:, None, None, :] > (
                    q_pos[:, None, :, None] - window)
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqs,bshk->bhqk", p.astype(q.dtype), vci).astype(jnp.float32)
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    # checkpoint the chunk body: the backward pass recomputes the f32
    # score/probability tensors per chunk instead of saving them across
    # the whole KV axis (flash-attention-backward memory behavior;
    # saving them costs ~4 GB/layer at deepseek train_4k scale).
    (acc, m, l), _ = jax.lax.scan(jax.checkpoint(body), (acc0, m0, l0),
                                  (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (b, sq, h, hd)


def attention_train(p: Dict, x, cfg, positions, sharder: Sharder,
                    kv: Optional[Tuple] = None, chunk: int = 2048,
                    return_kv: bool = False):
    """Full training/prefill attention with output projection.

    ``kv``: optional externally-computed (k, v, kv_pos) for
    cross-attention (whisper decoder); positions then only drive q RoPE.
    ``return_kv``: also return the pre-repeat (b, kvh, s, hd) cache
    tensors (prefill).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
    q = apply_rope(cfg, q, positions)
    kv_raw = None
    if kv is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if cfg.qk_norm:
            k = _qk_norm(k, p["k_norm"], cfg.norm_eps)
        k = apply_rope(cfg, k, positions)
        if return_kv:
            kv_raw = (k, v)
        # reshard BEFORE the GQA head-repeat: when n_kv_heads doesn't
        # divide the model axis the repeat's broadcast would otherwise
        # trigger XLA's "involuntary full rematerialization" fallback
        # (replicate + re-partition); an explicit constraint makes the
        # all-gather deliberate and schedulable.
        k = sharder.ac(k, ("batch", None, "kv_heads", None))
        v = sharder.ac(v, ("batch", None, "kv_heads", None))
        k = _repeat_kv(k, cfg.n_heads)
        v = _repeat_kv(v, cfg.n_heads)
        kv_pos = positions if positions.ndim == 2 else positions[..., 0]
        cross = False
    else:
        k, v, kv_pos = kv
        cross = True
    q = sharder.ac(q, ("batch", "q_seq", "heads", None))
    k = sharder.ac(k, ("batch", None, "heads", None))
    v = sharder.ac(v, ("batch", None, "heads", None))
    q_pos = positions if positions.ndim == 2 else positions[..., 0]
    if k.shape[1] > chunk:
        out = blockwise_attention(q, k, v, q_pos, kv_pos,
                                  window=cfg.sliding_window, chunk=chunk,
                                  cross=cross)
    else:
        out = naive_causal_attention(q, k, v, q_pos, kv_pos,
                                     window=cfg.sliding_window, cross=cross)
    # sequence-parallel out-projection (see mamba.apply_mamba): reshard
    # (seq <- model, heads full) before contracting over the sharded
    # head axis, replacing a full-sequence f32 partial-sum + all-reduce
    # with a bf16 all-to-all.
    out = sharder.ac(out, ("batch", "seq", None, None))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if return_kv:
        return y, kv_raw
    return y


def kv_to_cache(k, v, capacity: int, sharder: Sharder):
    """Prefill KV (b, s, kvh, hd) -> ring-buffer cache (b, kvh, S, hd).

    When s > capacity (sliding window), keeps the last ``capacity``
    entries rolled so that token t occupies slot t % capacity —
    consistent with ``attention_decode``'s ring-buffer writes.
    """
    s = k.shape[1]
    if s > capacity:
        k, v = k[:, -capacity:], v[:, -capacity:]
        shift = s % capacity
        if shift:
            k = jnp.roll(k, shift, axis=1)
            v = jnp.roll(v, shift, axis=1)
    elif s < capacity:
        pad = capacity - s
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ck = k.transpose(0, 2, 1, 3)
    cv = v.transpose(0, 2, 1, 3)
    ck = sharder.ac(ck, ("batch", "kv_heads_c", "kv_seq", None))
    cv = sharder.ac(cv, ("batch", "kv_heads_c", "kv_seq", None))
    return {"k": ck, "v": cv}


def attention_decode(p: Dict, x, cfg, cache: Dict, cur_len,
                     sharder: Sharder, update_cache: bool = True):
    """Single-token decode with a (possibly ring-buffered) KV cache.

    x: (b, 1, d).  cache: {"k": (b, kvh, S, hd), "v": ...}.  cur_len:
    scalar int32 (uniform batch) OR (b,) int32 (continuous batching:
    per-slot lengths).  Returns (out (b,1,d), new_cache).  The cache seq
    axis carries logical axis "kv_seq" (sharded over the model axis per
    the uniform KV rule).
    """
    b = x.shape[0]
    hd = cfg.head_dim
    S = cache["k"].shape[2]
    cur_len = jnp.asarray(cur_len, jnp.int32)
    per_slot = cur_len.ndim == 1
    if per_slot:
        pos_now = cur_len[:, None]
    else:
        pos_now = jnp.full((b, 1), cur_len, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        k_new = _qk_norm(k_new, p["k_norm"], cfg.norm_eps)
    q = apply_rope(cfg, q, pos_now if cfg.pos_scheme != "mrope"
                   else jnp.broadcast_to(pos_now[..., None], (b, 1, 3)))
    k_new = apply_rope(cfg, k_new, pos_now if cfg.pos_scheme != "mrope"
                       else jnp.broadcast_to(pos_now[..., None], (b, 1, 3)))
    slot = jnp.mod(cur_len, S)                 # ring buffer (sliding window)
    if update_cache:
        knc = k_new.transpose(0, 2, 1, 3)      # (b, kvh, 1, hd)
        vnc = v_new.transpose(0, 2, 1, 3)
        if per_slot:
            hit = (jnp.arange(S)[None, :] == slot[:, None])   # (b, S)
            hit = hit[:, None, :, None]
            ck = jnp.where(hit, knc, cache["k"])
            cv = jnp.where(hit, vnc, cache["v"])
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], knc, slot, 2)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vnc, slot, 2)
    else:
        ck, cv = cache["k"], cache["v"]
    ck = sharder.ac(ck, ("batch", "kv_heads_c", "kv_seq", None))
    cv = sharder.ac(cv, ("batch", "kv_heads_c", "kv_seq", None))

    kvh = cfg.n_kv_heads
    g = cfg.n_heads // kvh
    qg = q.reshape(b, kvh, g, hd)              # (b, kvh, g, hd)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg, ck).astype(jnp.float32)
    scores = scores / math.sqrt(hd)

    # slot validity: slot index < number of tokens written (incl. new one)
    n_valid = jnp.minimum(cur_len + 1, S)
    slot_ids = jnp.arange(S)
    if per_slot:
        valid = slot_ids[None, None, None, :] < n_valid[:, None, None, None]
    else:
        valid = slot_ids[None, None, None, :] < n_valid
    scores = jnp.where(valid, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bksd->bkgd", probs.astype(x.dtype), cv)
    out = out.reshape(b, 1, cfg.n_heads, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff: Optional[int] = None) -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = split_key(key, 3)
    p = {
        "wi": param(ks[0], (d, f), ("embed", "mlp")),
        "wo": param(ks[1], (f, d), ("mlp", "embed")),
    }
    if cfg.act == "swiglu":
        p["wg"] = param(ks[2], (d, f), ("embed", "mlp"))
    return p


def apply_mlp(p: Dict, x, cfg, sharder: Sharder = IDENTITY_SHARDER):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = jax.nn.silu(h) * g
    elif cfg.act == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:  # gelu
        h = jax.nn.gelu(h)
    h = sharder.ac(h, ("batch", None, "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------

def padded_vocab(cfg) -> int:
    v = cfg.vocab_size
    return v if v % 128 == 0 else (v // 128 + 1) * 128


def init_embedding(key, cfg) -> Dict:
    vp = padded_vocab(cfg)
    ks = split_key(key, 2)
    p = {"table": param(ks[0], (vp, cfg.d_model), (None, "embed"),
                        scale=1.0)}
    if not cfg.tie_embeddings:
        p["head"] = param(ks[1], (cfg.d_model, vp), ("embed", "vocab"))
    if cfg.pos_scheme == "learned":
        p["pos_table"] = param(
            key, (8192 if cfg.enc_seq == 0 else max(8192, cfg.enc_seq),
                  cfg.d_model),
            (None, "embed"), scale=0.02)
    return p


def embed_tokens(p: Dict, tokens, cfg, positions=None):
    x = jnp.take(p["table"], tokens, axis=0)
    if cfg.tie_embeddings:
        x = x * math.sqrt(cfg.d_model)    # minicpm-style embedding scale
    if cfg.pos_scheme == "learned" and positions is not None:
        pos = positions if positions.ndim == 2 else positions[..., 0]
        x = x + jnp.take(p["pos_table"], pos, axis=0)
    return x


def unembed(p: Dict, x, cfg, sharder: Sharder = IDENTITY_SHARDER):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["table"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["head"])
    return sharder.ac(logits, ("batch", None, "vocab"))


def cross_entropy(logits, labels, cfg, mask=None):
    """Mean next-token xent; handles vocab padding; logits (b, s, Vp)."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp != cfg.vocab_size:
        pad_mask = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, NEG_INF)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
