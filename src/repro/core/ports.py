"""Modular port interface (gem5-20 paper §1.3.1 ③).

gem5's port system lets "any component that implements the port API be
connected to any other component implementing the same API".  Ports are
what make gem5 configurations *composable*: the Python script wires a
CPU's memory port to a cache's CPU-side port with ``a.port = b.port``.

g5x uses ports to wire framework components: the data pipeline's output
port to the trainer's input port, the trainer's checkpoint port to the
checkpoint manager, desim machine components to network links, etc.
Ports are typed by a *protocol* string; only matching protocols connect
(the analogue of gem5's requestor/responder packet protocol check).
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional


class PortError(RuntimeError):
    pass


class Port:
    """One endpoint.  ``role`` is 'requestor' or 'responder'."""

    def __init__(self, owner: Any, name: str, protocol: str, role: str):
        if role not in ("requestor", "responder"):
            raise PortError(f"bad role {role!r}")
        self.owner = owner
        self.name = name
        self.protocol = protocol
        self.role = role
        self.peer: Optional[Port] = None
        self._handler: Optional[Callable[[Any], Any]] = None

    # -- wiring ------------------------------------------------------------
    def connect(self, other: "Port") -> None:
        if self.protocol != other.protocol:
            raise PortError(
                f"protocol mismatch: {self.protocol!r} vs {other.protocol!r}")
        if self.role == other.role:
            raise PortError(f"cannot connect two {self.role} ports")
        if self.peer is not None or other.peer is not None:
            raise PortError("port already connected")
        self.peer = other
        other.peer = self

    def __mod__(self, other: "Port") -> "Port":  # a.port % b.port sugar
        self.connect(other)
        return self

    def connected(self) -> bool:
        return self.peer is not None

    # -- transport -----------------------------------------------------------
    def set_handler(self, fn: Callable[[Any], Any]) -> None:
        """Responder side: install the request handler."""
        if self.role != "responder":
            raise PortError("handlers live on responder ports")
        self._handler = fn

    def send(self, payload: Any) -> Any:
        """Requestor side: deliver ``payload`` to the peer's handler.

        This is gem5's *atomic* protocol (call-through, returns the
        response immediately).  The desim layer adds the *timing*
        protocol on top by scheduling events.
        """
        if self.role != "requestor":
            raise PortError("send() from a responder port")
        if self.peer is None:
            raise PortError(f"port {self.name} is not connected")
        if self.peer._handler is None:
            raise PortError(f"peer port {self.peer.name} has no handler")
        return self.peer._handler(payload)


class PortSet:
    """Helper mixing ports into a SimObject."""

    def __init__(self, owner: Any):
        self.owner = owner
        self._ports: List[Port] = []

    def requestor(self, name: str, protocol: str) -> Port:
        p = Port(self.owner, name, protocol, "requestor")
        self._ports.append(p)
        return p

    def responder(self, name: str, protocol: str,
                  handler: Optional[Callable[[Any], Any]] = None) -> Port:
        p = Port(self.owner, name, protocol, "responder")
        if handler is not None:
            p.set_handler(handler)
        self._ports.append(p)
        return p

    def all_connected(self) -> bool:
        return all(p.connected() for p in self._ports)

    def unconnected(self) -> List[str]:
        return [p.name for p in self._ports if not p.connected()]
