"""Hierarchical statistics database (gem5-20 paper §2.21.1).

gem5's new statistics API introduced *statistics groups*: stats are
bound to their SimObject's group and the groups form a tree matching
the SimObject graph, enabling subtree dumps and structured (HDF5)
output.  g5x reproduces that design:

* ``Scalar`` / ``Vector`` / ``Distribution`` / ``Formula`` stat kinds
  (the gem5 kinds used by virtually every model).
* ``StatGroup`` trees with dotted-path resolution and subtree dumps —
  "the ability to dump statistics for a subset of the object graph".
* Time-series sampling into an N-dimensional structure dumped as JSON
  (the container has no HDF5; JSON with the same time-major layout is
  the stand-in, and the writer is pluggable).
"""

from __future__ import annotations

import json
import math
from typing import Any, Callable, Dict, List, Optional


class Stat:
    kind = "stat"

    def __init__(self, name: str, desc: str = "", unit: str = ""):
        self.name = name
        self.desc = desc
        self.unit = unit

    def value(self) -> Any:  # pragma: no cover - abstract
        raise NotImplementedError

    def reset(self) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    def as_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "name": self.name, "desc": self.desc,
                "unit": self.unit, "value": self.value()}

    # -- checkpointing (repro.sim.serialize) ---------------------------
    def state_dict(self) -> Dict[str, Any]:
        """Internal accumulator state, not just the rendered value —
        restoring it and continuing must be bit-identical to never
        having paused (gem5 serializes stats the same way)."""
        return {}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        pass

    # -- merging (repro.core.desim.parallel, sweep shards) -------------
    def merge(self, other: "Stat") -> None:
        """Fold ``other``'s accumulators into this stat, as if both
        sample streams had been fed to one stat.  Counts, sums, bins
        and extrema combine exactly; a ``Distribution``'s mean/m2 use
        the parallel Welford (Chan) update, which is exact in count and
        equal up to float rounding in mean/variance.  Merging into an
        *empty* stat adopts ``other``'s state verbatim (bit-exact) —
        the property the parallel engine's disjoint per-pod subtrees
        rely on."""
        if type(other) is not type(self):
            raise TypeError(f"cannot merge {type(other).__name__} into "
                            f"{type(self).__name__} stat {self.name!r}")


class Scalar(Stat):
    kind = "scalar"

    def __init__(self, name: str, desc: str = "", unit: str = ""):
        super().__init__(name, desc, unit)
        self._v = 0.0

    def inc(self, by: float = 1.0) -> None:
        self._v += by

    def set(self, v: float) -> None:
        self._v = float(v)

    def value(self) -> float:
        return self._v

    def reset(self) -> None:
        self._v = 0.0

    def state_dict(self) -> Dict[str, Any]:
        return {"v": self._v}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self._v = float(d["v"])

    def merge(self, other: "Stat") -> None:
        super().merge(other)
        self._v += other._v


class Vector(Stat):
    kind = "vector"

    def __init__(self, name: str, size: int, desc: str = "", unit: str = "",
                 labels: Optional[List[str]] = None):
        super().__init__(name, desc, unit)
        self._v = [0.0] * size
        self.labels = labels or [str(i) for i in range(size)]

    def inc(self, idx: int, by: float = 1.0) -> None:
        self._v[idx] += by

    def set(self, idx: int, v: float) -> None:
        self._v[idx] = float(v)

    def value(self) -> List[float]:
        return list(self._v)

    def total(self) -> float:
        return sum(self._v)

    def reset(self) -> None:
        self._v = [0.0] * len(self._v)

    def state_dict(self) -> Dict[str, Any]:
        return {"v": list(self._v)}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        if len(d["v"]) != len(self._v):
            raise ValueError(f"vector {self.name}: size mismatch "
                             f"{len(d['v'])} != {len(self._v)}")
        self._v = [float(x) for x in d["v"]]

    def merge(self, other: "Stat") -> None:
        super().merge(other)
        if len(other._v) != len(self._v):
            raise ValueError(f"vector {self.name}: size mismatch "
                             f"{len(other._v)} != {len(self._v)}")
        self._v = [a + b for a, b in zip(self._v, other._v)]


class Distribution(Stat):
    """Streaming distribution: count/mean/var/min/max (Welford)."""

    kind = "distribution"

    def __init__(self, name: str, desc: str = "", unit: str = ""):
        super().__init__(name, desc, unit)
        self.reset()

    def sample(self, v: float, n: int = 1) -> None:
        for _ in range(n):
            self._count += 1
            d = v - self._mean
            self._mean += d / self._count
            self._m2 += d * (v - self._mean)
        self._min = min(self._min, v)
        self._max = max(self._max, v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def mean(self) -> float:
        return self._mean

    @property
    def stddev(self) -> float:
        return math.sqrt(self._m2 / self._count) if self._count else 0.0

    def value(self) -> Dict[str, float]:
        return {"count": self._count, "mean": self._mean,
                "stddev": self.stddev,
                "min": self._min if self._count else 0.0,
                "max": self._max if self._count else 0.0}

    def reset(self) -> None:
        self._count = 0
        self._mean = 0.0
        self._m2 = 0.0
        self._min = float("inf")
        self._max = float("-inf")

    def state_dict(self) -> Dict[str, Any]:
        # Welford accumulators, so a restored run keeps streaming into
        # the same distribution (mean/m2 continue exactly).  min/max of
        # an empty distribution are +-inf sentinels, which are not
        # RFC 8259 JSON — store None instead so checkpoint files stay
        # strictly parseable everywhere.
        return {"count": self._count, "mean": self._mean, "m2": self._m2,
                "min": self._min if self._count else None,
                "max": self._max if self._count else None}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self._count = int(d["count"])
        self._mean = float(d["mean"])
        self._m2 = float(d["m2"])
        self._min = float("inf") if d["min"] is None else float(d["min"])
        self._max = float("-inf") if d["max"] is None else float(d["max"])

    def merge(self, other: "Stat") -> None:
        super().merge(other)
        if other._count == 0:
            return
        if self._count == 0:
            # adopt verbatim: merging into an empty stat is bit-exact
            self._count = other._count
            self._mean = other._mean
            self._m2 = other._m2
            self._min = other._min
            self._max = other._max
            return
        # Chan et al. parallel Welford update
        na, nb = self._count, other._count
        delta = other._mean - self._mean
        n = na + nb
        self._mean += delta * nb / n
        self._m2 += other._m2 + delta * delta * na * nb / n
        self._count = n
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)


class Percentiles(Stat):
    """Streaming quantile sketch (bounded-memory, serializable).

    DDSketch-style logarithmic binning: a sample ``v > 0`` lands in bin
    ``ceil(log_gamma(v))`` with ``gamma = (1 + rel_err)/(1 - rel_err)``,
    which guarantees every reported quantile is within ``rel_err``
    *relative* error of the exact sample quantile — the right error
    model for latency tails, where p99 may be 100x p50 and a fixed
    absolute-bin histogram would need millions of buckets.

    The accumulator state (sparse bin counts + count/sum/min/max) is a
    plain dict, so ``state_dict``/``load_state_dict`` round-trips through
    JSON checkpoints and a restored run keeps streaming into the same
    sketch bit-identically (the serving checkpoint test enforces this).
    Non-positive samples are clamped into a dedicated zero bin (serving
    metrics are non-negative; a 0.0 TTFT is representable).
    """

    kind = "percentiles"

    def __init__(self, name: str, desc: str = "", unit: str = "",
                 rel_err: float = 0.01):
        super().__init__(name, desc, unit)
        if not 0.0 < rel_err < 1.0:
            raise ValueError(f"rel_err must be in (0, 1), got {rel_err}")
        self.rel_err = rel_err
        self._gamma = (1.0 + rel_err) / (1.0 - rel_err)
        self._log_gamma = math.log(self._gamma)
        self.reset()

    # -- accumulation ---------------------------------------------------
    def _key(self, v: float) -> int:
        return int(math.ceil(math.log(v) / self._log_gamma))

    def sample(self, v: float, n: int = 1) -> None:
        # clamp applies to ALL accumulators (sum/min/max too), so the
        # reported mean/min never drop below every quantile
        v = max(float(v), 0.0)
        if v == 0.0:
            self._zero += n
        else:
            k = self._key(v)
            self._bins[k] = self._bins.get(k, 0) + n
        self._count += n
        self._sum += v * n
        self._min = min(self._min, v)
        self._max = max(self._max, v)

    # -- queries --------------------------------------------------------
    @property
    def count(self) -> int:
        return self._count

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1], within ``rel_err`` relative
        error of the exact sample quantile (0.0 on an empty sketch)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self._count == 0:
            return 0.0
        rank = q * (self._count - 1)
        seen = self._zero
        if rank < seen:
            return 0.0
        for k in sorted(self._bins):
            seen += self._bins[k]
            if rank < seen:
                # midpoint of the bin (gamma^(k-1), gamma^k]
                return (2.0 * self._gamma ** k) / (self._gamma + 1.0)
        return self._max

    def value(self) -> Dict[str, float]:
        return {"count": self._count, "mean": self.mean,
                "min": self._min if self._count else 0.0,
                "max": self._max if self._count else 0.0,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p95": self.quantile(0.95), "p99": self.quantile(0.99)}

    def reset(self) -> None:
        self._bins: Dict[int, int] = {}
        self._zero = 0
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")

    # -- checkpointing (repro.sim.serialize) ----------------------------
    def state_dict(self) -> Dict[str, Any]:
        # JSON object keys must be strings; bin keys are ints.  min/max
        # of an empty sketch are +-inf sentinels — stored as None to
        # keep checkpoint JSON strictly RFC 8259 (no Infinity literals).
        return {"rel_err": self.rel_err,
                "bins": {str(k): n for k, n in self._bins.items()},
                "zero": self._zero, "count": self._count, "sum": self._sum,
                "min": self._min if self._count else None,
                "max": self._max if self._count else None}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        if float(d["rel_err"]) != self.rel_err:
            raise ValueError(
                f"percentiles {self.name}: rel_err mismatch "
                f"{d['rel_err']} != {self.rel_err} (bins not comparable)")
        self._bins = {int(k): int(n) for k, n in d["bins"].items()}
        self._zero = int(d["zero"])
        self._count = int(d["count"])
        self._sum = float(d["sum"])
        self._min = float("inf") if d["min"] is None else float(d["min"])
        self._max = float("-inf") if d["max"] is None else float(d["max"])

    def merge(self, other: "Stat") -> None:
        super().merge(other)
        if other.rel_err != self.rel_err:
            raise ValueError(
                f"percentiles {self.name}: rel_err mismatch "
                f"{other.rel_err} != {self.rel_err} (bins not comparable)")
        if other._count == 0:
            return
        if self._count == 0:
            self._bins = dict(other._bins)
            self._zero = other._zero
            self._count = other._count
            self._sum = other._sum
            self._min = other._min
            self._max = other._max
            return
        for k, n in other._bins.items():
            self._bins[k] = self._bins.get(k, 0) + n
        self._zero += other._zero
        self._count += other._count
        self._sum += other._sum
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)


class Formula(Stat):
    """Lazily-evaluated derived stat (gem5 ``Formula``)."""

    kind = "formula"

    def __init__(self, name: str, fn: Callable[[], float], desc: str = "",
                 unit: str = ""):
        super().__init__(name, desc, unit)
        self._fn = fn

    def value(self) -> float:
        try:
            return self._fn()
        except ZeroDivisionError:
            return 0.0

    def reset(self) -> None:
        pass


def _rehydrate(like: Stat, sd: Dict[str, Any]) -> Stat:
    """Build a scratch stat of ``like``'s kind holding ``sd``'s state."""
    if isinstance(like, Vector):
        tmp: Stat = Vector(like.name, len(sd["v"]))
    elif isinstance(like, Percentiles):
        tmp = Percentiles(like.name, rel_err=float(sd["rel_err"]))
    else:
        tmp = type(like)(like.name)
    tmp.load_state_dict(sd)
    return tmp


class StatGroup:
    """A named group of stats; groups form a tree mirroring SimObjects."""

    def __init__(self, name: str):
        self.name = name
        self._stats: Dict[str, Stat] = {}
        self._children: List[StatGroup] = []

    # -- construction ---------------------------------------------------
    def scalar(self, name: str, desc: str = "", unit: str = "") -> Scalar:
        return self._add(Scalar(name, desc, unit))

    def vector(self, name: str, size: int, desc: str = "", unit: str = "",
               labels: Optional[List[str]] = None) -> Vector:
        return self._add(Vector(name, size, desc, unit, labels))

    def distribution(self, name: str, desc: str = "",
                     unit: str = "") -> Distribution:
        return self._add(Distribution(name, desc, unit))

    def percentiles(self, name: str, desc: str = "", unit: str = "",
                    rel_err: float = 0.01) -> Percentiles:
        return self._add(Percentiles(name, desc, unit, rel_err=rel_err))

    def formula(self, name: str, fn: Callable[[], float], desc: str = "",
                unit: str = "") -> Formula:
        return self._add(Formula(name, fn, desc, unit))

    def _add(self, stat: Stat) -> Any:
        if stat.name in self._stats:
            raise ValueError(f"duplicate stat {stat.name!r} in {self.name}")
        self._stats[stat.name] = stat
        return stat

    def add_child(self, group: "StatGroup") -> None:
        if group not in self._children:
            self._children.append(group)

    # -- access -----------------------------------------------------------
    def __getitem__(self, dotted: str) -> Stat:
        parts = dotted.split(".")
        grp: StatGroup = self
        for p in parts[:-1]:
            match = [c for c in grp._children if c.name == p]
            if not match:
                raise KeyError(f"no stat group {p!r} under {grp.name!r}")
            grp = match[0]
        return grp._stats[parts[-1]]

    def stats(self) -> Dict[str, Stat]:
        return dict(self._stats)

    # -- dumping -----------------------------------------------------------
    def as_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "stats": {k: s.as_dict() for k, s in self._stats.items()},
            "children": [c.as_dict() for c in self._children],
        }

    def flat(self, prefix: str = "") -> Dict[str, Any]:
        """Flatten to ``path.stat -> value`` (gem5 stats.txt style)."""
        path = f"{prefix}{self.name}"
        out = {f"{path}.{k}": s.value() for k, s in self._stats.items()}
        for c in self._children:
            out.update(c.flat(prefix=f"{path}."))
        return out

    def dump_text(self) -> str:
        lines = ["---------- Begin Simulation Statistics ----------"]
        for k, v in self.flat().items():
            lines.append(f"{k:<60} {v}")
        lines.append("---------- End Simulation Statistics ----------")
        return "\n".join(lines)

    def dump_json(self, path: Optional[str] = None) -> str:
        s = json.dumps(self.as_dict(), indent=1, default=str)
        if path:
            with open(path, "w") as f:
                f.write(s)
        return s

    def reset(self) -> None:
        for s in self._stats.values():
            s.reset()
        for c in self._children:
            c.reset()

    # -- checkpointing (repro.sim.serialize) ----------------------------
    def state_dict(self) -> Dict[str, Any]:
        """Recursive accumulator snapshot keyed by stat/child name.
        Child names must be unique within a group (they are: the stats
        tree mirrors the SimObject tree, whose children are attributes).
        """
        return {
            "stats": {k: s.state_dict() for k, s in self._stats.items()},
            "children": {c.name: c.state_dict() for c in self._children},
        }

    def load_state_dict(self, d: Dict[str, Any],
                        strict: bool = False) -> None:
        """Restore a ``state_dict``.  Stats/children present in the dict
        but missing from this tree (or vice versa) are skipped unless
        ``strict`` — restoring onto a re-parameterized machine keeps the
        overlap."""
        for k, sd in d.get("stats", {}).items():
            if k in self._stats:
                self._stats[k].load_state_dict(sd)
            elif strict:
                raise KeyError(f"no stat {k!r} in group {self.name!r}")
        by_name = {c.name: c for c in self._children}
        for k, cd in d.get("children", {}).items():
            if k in by_name:
                by_name[k].load_state_dict(cd, strict=strict)
            elif strict:
                raise KeyError(f"no child group {k!r} under {self.name!r}")

    # -- merging (repro.core.desim.parallel, sweep shards) --------------
    def merge(self, other: "StatGroup", strict: bool = False) -> "StatGroup":
        """Fold ``other``'s tree into this one, matching stats and child
        groups by name and calling :meth:`Stat.merge` on each pair.  The
        result is as if both trees had accumulated one combined sample
        stream: counts/sums/bins combine exactly, Welford mean/m2 via the
        parallel (Chan) update.  Disjoint subtrees — the parallel
        engine's per-pod shards — merge bit-exactly, because merging into
        an untouched (zero/empty) stat adopts the source verbatim.
        Names present on only one side are skipped unless ``strict``.
        ``Formula`` stats carry no accumulator state and are ignored.
        Returns ``self`` so merges chain across sweep shards."""
        for k, st in other._stats.items():
            mine = self._stats.get(k)
            if mine is None:
                if strict:
                    raise KeyError(f"no stat {k!r} in group {self.name!r}")
                continue
            if isinstance(st, Formula):
                continue
            mine.merge(st)
        by_name = {c.name: c for c in self._children}
        for c in other._children:
            mine = by_name.get(c.name)
            if mine is None:
                if strict:
                    raise KeyError(
                        f"no child group {c.name!r} under {self.name!r}")
                continue
            mine.merge(c, strict=strict)
        return self

    def merge_state_dict(self, d: Dict[str, Any],
                         strict: bool = False) -> "StatGroup":
        """:meth:`merge`, but the right-hand side is a ``state_dict``
        (the wire format workers ship across process pipes) instead of a
        live tree.  Each entry is rehydrated into a scratch stat of the
        matching kind and merged, so the exactness guarantees of
        :meth:`Stat.merge` apply unchanged."""
        for k, sd in d.get("stats", {}).items():
            st = self._stats.get(k)
            if st is None:
                if strict:
                    raise KeyError(f"no stat {k!r} in group {self.name!r}")
                continue
            if isinstance(st, Formula):
                continue
            st.merge(_rehydrate(st, sd))
        by_name = {c.name: c for c in self._children}
        for k, cd in d.get("children", {}).items():
            mine = by_name.get(k)
            if mine is None:
                if strict:
                    raise KeyError(
                        f"no child group {k!r} under {self.name!r}")
                continue
            mine.merge_state_dict(cd, strict=strict)
        return self


class TimeSeries:
    """Sampled time-series store (the paper's HDF5 backend stand-in).

    Stores one row per ``sample()`` call; each row is the flat stat dict
    of the attached group.  Layout is time-major like gem5's HDF5 files
    ("we use one dimension for time and the remaining dimensions for the
    statistic").
    """

    def __init__(self, group: StatGroup):
        self.group = group
        self.times: List[float] = []
        self.rows: List[Dict[str, Any]] = []

    def sample(self, t: float) -> None:
        self.times.append(t)
        self.rows.append(self.group.flat())

    def column(self, key: str) -> List[Any]:
        return [r.get(key) for r in self.rows]

    def dump_json(self, path: Optional[str] = None) -> str:
        s = json.dumps({"time": self.times, "rows": self.rows}, default=str)
        if path:
            with open(path, "w") as f:
                f.write(s)
        return s
