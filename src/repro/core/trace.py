"""gem5-style DebugFlags + DPRINTF event tracing (paper §2.20).

gem5's first debugging tool is its tracing facility: every model is
sprinkled with ``DPRINTF(Flag, "...", ...)`` statements that compile to
nothing unless the flag is enabled (``--debug-flags=Exec,DRAM``), and
enabled flags stream one formatted line per event — tick, object path,
message — to the trace output.  This module reproduces that for the
desim stack:

* a registry of **hierarchical flags** (``Wire`` enables
  ``Wire.Contention``; ``All`` enables everything),
* :func:`dprintf` — the DPRINTF analogue.  Disabled tracing costs one
  module-attribute read and a branch: the format string is *never*
  rendered and the message never built unless the flag is on.  The
  hottest call sites additionally guard on :data:`_ACTIVE` so a fully
  disabled run does not even pay the call.
* selection via API (:func:`enable` / :func:`disable` /
  :func:`flag_context`), environment (``G5X_DEBUG_FLAGS=Dcn,Exec``,
  ``G5X_DEBUG_FILE=trace.out``), or CLI (e.g. ``examples/quickstart.py
  --debug-flags``).

House rule (test-enforced in ``tests/test_observability.py``): tracing
only *reads* simulation state — a run with every flag enabled is
bit-identical to a silent one.  Output goes to stdout by default (like
gem5's ``simout``), so nothing ever reaches stdout unless a flag was
explicitly enabled.

The flag catalog lives here (not per-module) so ``flags()`` can print
it for CLI help; modules may :func:`register_flag` more.
"""

from __future__ import annotations

import os
import sys
from contextlib import contextmanager
from typing import Dict, Iterable, List, Optional, Set, TextIO, Union

# ---------------------------------------------------------------------------
# flag registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, str] = {}

#: names enabled right now (exact names as passed to ``enable``)
_ENABLED: Set[str] = set()
#: per-flag resolution cache (flag -> effective on/off), cleared on change
_CACHE: Dict[str, bool] = {}

#: fast kill-switch read by every call site: False unless at least one
#: flag is enabled (or counting mode is measuring the disabled path)
_ACTIVE: bool = False

#: counting mode (benchmarks/observability.py): dprintf calls whose flag
#: is disabled increment ``_SUPPRESSED`` instead of vanishing, which is
#: how the <5%-overhead CI assertion knows how many guarded call sites a
#: reference lap actually reaches
_COUNTING: bool = False
_SUPPRESSED: int = 0

_SINK: Optional[TextIO] = None   # None -> sys.stdout at write time


def register_flag(name: str, desc: str = "") -> str:
    """Add a flag to the catalog (idempotent; later desc wins if
    non-empty).  Dotted names are hierarchical: enabling ``Wire`` also
    enables ``Wire.Contention``."""
    if not name or any(not part for part in name.split(".")):
        raise ValueError(f"bad debug flag name {name!r}")
    if desc or name not in _REGISTRY:
        _REGISTRY[name] = desc
    return name


# the standard catalog (gem5: Exec, Cache, DRAM, ...; ours mirrors the
# desim SimObject layers)
register_flag("Exec", "op issue / completion on each pod (executor)")
register_flag("Chip", "compute-resource arbitration (ChipSim.acquire)")
register_flag("Wire", "intra-pod collectives on the ICI torus (WireSim)")
register_flag("Wire.Contention", "only collectives that waited on a "
                                 "contended link")
register_flag("Dcn", "cross-pod rendezvous and fabric transactions "
                     "(DcnSim / AtomicTiming)")
register_flag("Quantum", "dist-gem5 quantum barriers and cross-queue "
                         "deliveries (QuantumSync)")
register_flag("Ckpt", "drain / snapshot / restore lifecycle")
register_flag("Sim", "Simulator exit events and stat dumps")
register_flag("Parallel", "multiprocess engine: worker spawn, barriers, "
                          "collect")


def flags() -> Dict[str, str]:
    """The flag catalog: name -> description."""
    return dict(_REGISTRY)


def enabled_flags() -> List[str]:
    """Exact names currently enabled (sorted; ship to worker procs)."""
    return sorted(_ENABLED)


def _refresh() -> None:
    global _ACTIVE
    _CACHE.clear()
    _ACTIVE = bool(_ENABLED) or _COUNTING


def _parse(spec: Union[str, Iterable[str]]) -> List[str]:
    if isinstance(spec, str):
        return [s.strip() for s in spec.split(",") if s.strip()]
    return [str(s) for s in spec]


def enable(spec: Union[str, Iterable[str]]) -> None:
    """Enable flags: ``enable("Dcn,Exec")`` or ``enable(["Wire"])``.
    ``"All"`` enables everything.  Unknown names raise with the
    catalog (gem5 errors the same way)."""
    for name in _parse(spec):
        if name != "All" and name not in _REGISTRY:
            raise ValueError(
                f"unknown debug flag {name!r}; known flags: "
                f"{', '.join(sorted(_REGISTRY))} (or All)")
        _ENABLED.add(name)
    _refresh()


def disable(spec: Union[None, str, Iterable[str]] = None) -> None:
    """Disable the given flags, or every flag when called bare."""
    if spec is None:
        _ENABLED.clear()
    else:
        for name in _parse(spec):
            _ENABLED.discard(name)
    _refresh()


def enabled(flag: str) -> bool:
    """Effective state of ``flag``: on when the flag itself, any dotted
    prefix of it, or ``All`` is enabled."""
    hit = _CACHE.get(flag)
    if hit is None:
        hit = False
        if _ENABLED:
            if "All" in _ENABLED or flag in _ENABLED:
                hit = True
            else:
                parts = flag.split(".")
                for i in range(1, len(parts)):
                    if ".".join(parts[:i]) in _ENABLED:
                        hit = True
                        break
        _CACHE[flag] = hit
    return hit


@contextmanager
def flag_context(spec: Union[str, Iterable[str]]):
    """Temporarily enable flags (tests / scoped debugging)."""
    before = set(_ENABLED)
    enable(spec)
    try:
        yield
    finally:
        _ENABLED.clear()
        _ENABLED.update(before)
        _refresh()


# ---------------------------------------------------------------------------
# output
# ---------------------------------------------------------------------------

def set_output(dst: Union[None, str, TextIO]) -> None:
    """Route trace lines to a file path or open stream (None -> stdout,
    the gem5 ``simout`` default)."""
    global _SINK
    if isinstance(dst, str):
        _SINK = open(dst, "a")
    else:
        _SINK = dst


def _name_of(obj) -> str:
    if obj is None:
        return "-"
    if isinstance(obj, str):
        return obj
    path = getattr(obj, "path", None)
    if isinstance(path, str):
        return path
    name = getattr(obj, "name", None)
    if isinstance(name, str):
        return name
    return type(obj).__name__


def dprintf(flag: str, obj, fmt: str, *args, tick: Optional[int] = None
            ) -> None:
    """gem5 ``DPRINTF``: when ``flag`` is enabled, write one trace line
    ``<tick>: <obj>: <message>``.  Formatting (``fmt % args``) is
    deferred until after the flag check, so a disabled call never
    renders anything — it must also never *evaluate* anything: pass
    raw values via ``args``, not pre-built f-strings."""
    if not _ACTIVE:
        return
    if not enabled(flag):
        if _COUNTING:
            global _SUPPRESSED
            _SUPPRESSED += 1
        return
    msg = (fmt % args) if args else fmt
    t = "-" if tick is None else str(int(tick))
    sink = _SINK if _SINK is not None else sys.stdout
    sink.write(f"{t:>10}: {_name_of(obj)}: {msg}\n")


# ---------------------------------------------------------------------------
# disabled-path accounting (the ci.sh trace tier's overhead model)
# ---------------------------------------------------------------------------

@contextmanager
def counting():
    """Count suppressed dprintf calls without emitting anything: the
    overhead benchmark multiplies the count by the measured disabled-
    call cost to bound what tracing adds to a flags-off run."""
    global _COUNTING, _SUPPRESSED
    _COUNTING, _SUPPRESSED = True, 0
    _refresh()
    try:
        yield
    finally:
        _COUNTING = False
        _refresh()


def suppressed_calls() -> int:
    return _SUPPRESSED


# ---------------------------------------------------------------------------
# environment selection
# ---------------------------------------------------------------------------

ENV_FLAGS = "G5X_DEBUG_FLAGS"
ENV_FILE = "G5X_DEBUG_FILE"


def init_from_env(environ=None) -> List[str]:
    """Apply ``G5X_DEBUG_FLAGS`` / ``G5X_DEBUG_FILE`` (called once at
    import; call again after mutating os.environ in tests).  Returns
    the flags enabled.  Unknown env flags raise — a typo'd flag that
    silently traces nothing is worse than a crash at startup."""
    env = os.environ if environ is None else environ
    spec = env.get(ENV_FLAGS, "")
    path = env.get(ENV_FILE, "")
    if path:
        set_output(path)
    if spec:
        enable(spec)
    return _parse(spec)


init_from_env()
