"""SimObject: parameterized, hierarchical, Python-composed components.

gem5's key usability contribution (§1.3) is that systems are *composed
dynamically in Python*: every model is a ``SimObject`` with declared,
type-checked ``Param``s; users instantiate and wire objects in a script,
then call ``instantiate()``.  g5x reproduces that model and uses it for
*everything*: meshes, machine models, architectures, optimizers, data
pipelines, trainers and servers are all SimObjects.

Key mechanics mirrored from gem5:

* ``Param`` descriptors with defaults, type coercion and validation
  (gem5's ``Param.Int``, ``Param.MemorySize``, ...).
* parent/child hierarchy with dotted paths (``system.trainer.optimizer``)
  — children are discovered by attribute assignment, exactly like gem5.
* a per-object ``StatGroup`` bound into the tree (paper §2.21.1: "there
  is a tree of statistics groups that match the SimObject graph").
* ``instantiate()`` walks the tree, validates params, calls ``startup()``
  bottom-up, and freezes the hierarchy.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, Optional, Type

from repro.core.stats import StatGroup


class ParamError(TypeError):
    pass


class Param:
    """Typed, validated parameter descriptor (gem5 ``Param.*`` analogue).

    >>> class Cache(SimObject):
    ...     size_kb = Param(int, 32, "cache size in KiB", check=lambda v: v > 0)
    >>> c = Cache(size_kb=64)
    >>> c.size_kb
    64
    """

    def __init__(self, ptype: type, default: Any = None, desc: str = "",
                 check: Optional[Callable[[Any], bool]] = None,
                 choices: Optional[tuple] = None):
        self.ptype = ptype
        self.default = default
        self.desc = desc
        self.check = check
        self.choices = choices
        self.name: str = "?"

    def __set_name__(self, owner, name):
        self.name = name

    def coerce(self, value: Any) -> Any:
        if value is None:
            return None
        if self.ptype is float and isinstance(value, int):
            value = float(value)
        if not isinstance(value, self.ptype):
            try:
                value = self.ptype(value)
            except Exception as e:  # pragma: no cover - error path
                raise ParamError(
                    f"param {self.name}: cannot coerce {value!r} to "
                    f"{self.ptype.__name__}") from e
        if self.choices is not None and value not in self.choices:
            raise ParamError(
                f"param {self.name}: {value!r} not in {self.choices}")
        if self.check is not None and not self.check(value):
            raise ParamError(f"param {self.name}: {value!r} failed validation")
        return value

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return obj._params.get(self.name, self.default)

    def __set__(self, obj, value):
        if getattr(obj, "_frozen", False):
            raise ParamError(
                f"cannot set param {self.name} after instantiate()")
        obj._params[self.name] = self.coerce(value)


class SimObject:
    """Base class for every parameterized g5x component."""

    def __init__(self, name: Optional[str] = None, **params):
        object.__setattr__(self, "_params", {})
        object.__setattr__(self, "_children", {})
        object.__setattr__(self, "_parent", None)
        object.__setattr__(self, "_frozen", False)
        self._name = name or type(self).__name__.lower()
        self.stats = StatGroup(self._name)
        declared = self._declared_params()
        for k, v in params.items():
            if k not in declared:
                raise ParamError(
                    f"{type(self).__name__} has no param {k!r} "
                    f"(declared: {sorted(declared)})")
            setattr(self, k, v)

    # -- params --------------------------------------------------------
    @classmethod
    def _declared_params(cls) -> Dict[str, Param]:
        out: Dict[str, Param] = {}
        for klass in reversed(cls.__mro__):
            for k, v in vars(klass).items():
                if isinstance(v, Param):
                    out[k] = v
        return out

    def params_dict(self) -> Dict[str, Any]:
        return {k: getattr(self, k) for k in self._declared_params()}

    # -- hierarchy ------------------------------------------------------
    def __setattr__(self, key, value):
        if isinstance(value, SimObject) and not key.startswith("_"):
            if self._frozen:
                raise ParamError("cannot attach children after instantiate()")
            self._children[key] = value
            object.__setattr__(value, "_parent", self)
            value._name = key
            value.stats.name = key
        object.__setattr__(self, key, value)

    @property
    def name(self) -> str:
        return self._name

    @property
    def path(self) -> str:
        if self._parent is None:
            return self._name
        return f"{self._parent.path}.{self._name}"

    def children(self) -> Dict[str, "SimObject"]:
        return dict(self._children)

    def descendants(self) -> Iterator["SimObject"]:
        for child in self._children.values():
            yield child
            yield from child.descendants()

    def find(self, path: str) -> "SimObject":
        obj: SimObject = self
        for part in path.split("."):
            try:
                obj = obj._children[part]
            except KeyError:
                raise KeyError(
                    f"no child {part!r} under {obj.path!r} (resolving "
                    f"{path!r}; children: {sorted(obj._children)})"
                    ) from None
        return obj

    # -- lifecycle -------------------------------------------------------
    def startup(self) -> None:
        """Called bottom-up at instantiate() time; override for setup."""

    def instantiate(self) -> "SimObject":
        """Validate + freeze the whole tree rooted here (gem5
        ``m5.instantiate()``)."""
        for child in self._children.values():
            child.instantiate()
            self.stats.add_child(child.stats)
        # re-coerce all params (validates defaults overridden post-init)
        for pname, p in self._declared_params().items():
            self._params[pname] = p.coerce(getattr(self, pname))
        self.startup()
        object.__setattr__(self, "_frozen", True)
        return self

    # -- checkpointing (repro.sim.serialize) -------------------------------
    def serialize(self) -> Dict[str, Any]:
        """Params + children as a plain JSON-able tree (gem5's
        ``config.ini`` analogue, used by ``repro.sim.serialize`` so a
        checkpoint records the machine it was taken on)."""
        return {
            "class": type(self).__name__,
            "name": self._name,
            "params": dict(self.params_dict()),
            "children": {k: c.serialize() for k, c in self._children.items()},
        }

    def load_serialized(self, d: Dict[str, Any], strict: bool = True) -> None:
        """Apply a :meth:`serialize` dict onto this (unfrozen) tree.

        The tree must already have the same shape — this restores
        *parameters*, it does not construct objects (class registries
        are the caller's business; see ``repro.sim.serialize.
        machine_from_dict`` for the machine-model instance)."""
        declared = self._declared_params()
        for k, v in d.get("params", {}).items():
            if k in declared:
                setattr(self, k, v)
            elif strict:
                raise ParamError(
                    f"{type(self).__name__} has no param {k!r}")
        for k, cd in d.get("children", {}).items():
            child = self._children.get(k)
            if child is not None:
                child.load_serialized(cd, strict=strict)
            elif strict:
                raise KeyError(f"no child {k!r} under {self.path!r}")

    # -- introspection -----------------------------------------------------
    def describe(self, indent: int = 0) -> str:
        pad = "  " * indent
        lines = [f"{pad}{self._name}: {type(self).__name__}"]
        for k, v in sorted(self.params_dict().items()):
            lines.append(f"{pad}  .{k} = {v!r}")
        for child in self._children.values():
            lines.append(child.describe(indent + 1))
        return "\n".join(lines)

    def __repr__(self):
        return f"<{type(self).__name__} {self.path}>"


def simobject_from_dataclass(dc: Any, name: str = "cfg") -> SimObject:
    """Wrap a plain dataclass as a SimObject (for arch configs)."""
    cls_attrs: Dict[str, Any] = {}
    for f in dataclasses.fields(dc):
        cls_attrs[f.name] = Param(object if f.type is Any else type(getattr(dc, f.name)),
                                  getattr(dc, f.name), f.name)
    klass: Type[SimObject] = type(f"{type(dc).__name__}SimObject",
                                  (SimObject,), cls_attrs)
    return klass(name=name)
