"""Fidelity spectrum: interchangeable execution backends.

gem5's CPU models span a fidelity/performance spectrum (§1.3.1 ②):
"simple" atomic models, detailed in-order/O3 timing models, and the
KVM-based model that executes natively.  The *same* system description
runs under any of them.

g5x reproduces this for a JAX step function.  A ``StepProgram`` (the
system under test: jitted step + input specs + shardings + mesh) can be
executed by:

* ``NativeBackend``   — really run it (gem5's KVM mode: host execution,
                        no timing model, fastest, real numbers).
* ``DryRunBackend``   — ``.lower().compile()`` only; produces the
                        compiled artifact, memory/cost analysis, and the
                        HLO text (gem5's "atomic" functional mode:
                        correct structure, no timing).
* ``DesimBackend``    — parse the compiled HLO into an elastic trace and
                        replay it on the discrete-event TPU machine
                        model (gem5's detailed timing mode).

All three return a ``StepReport`` so drivers and benchmarks can switch
fidelity with one flag — exactly how gem5 users swap CPU models.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax


@dataclass
class StepProgram:
    """The system under test, in gem5 terms: the workload + config."""

    name: str
    fn: Callable                      # the step function (pure)
    input_specs: Any                  # pytree of ShapeDtypeStruct
    in_shardings: Any = None
    out_shardings: Any = None
    mesh: Optional[jax.sharding.Mesh] = None
    donate_argnums: tuple = ()
    static_argnums: tuple = ()

    def jitted(self):
        kw: Dict[str, Any] = {}
        if self.in_shardings is not None:
            kw["in_shardings"] = self.in_shardings
        if self.out_shardings is not None:
            kw["out_shardings"] = self.out_shardings
        if self.donate_argnums:
            kw["donate_argnums"] = self.donate_argnums
        if self.static_argnums:
            kw["static_argnums"] = self.static_argnums
        return jax.jit(self.fn, **kw)

    def lower(self):
        # input_specs is a tuple of positional args; each arg may be a
        # pytree of ShapeDtypeStructs.
        if self.mesh is not None:
            with self.mesh:
                return self.jitted().lower(*self.input_specs)
        return self.jitted().lower(*self.input_specs)


@dataclass
class StepReport:
    backend: str
    name: str
    wall_s: float = 0.0                       # host wall time of the call
    predicted_step_s: Optional[float] = None  # desim/roofline prediction
    outputs: Any = None
    flops: Optional[float] = None
    bytes_accessed: Optional[float] = None
    collective_bytes: Optional[float] = None
    memory: Optional[Dict[str, float]] = None
    detail: Dict[str, Any] = field(default_factory=dict)


class Backend:
    kind = "abstract"

    def run(self, prog: StepProgram, *args, **kw) -> StepReport:
        raise NotImplementedError


class NativeBackend(Backend):
    """Execute for real (gem5 KVM mode)."""

    kind = "native"

    def run(self, prog: StepProgram, *args, iters: int = 1) -> StepReport:
        f = prog.jitted()
        ctx = prog.mesh or _nullcontext()
        with ctx:
            out = f(*args)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(iters):
                out = f(*args)
            jax.block_until_ready(out)
            dt = (time.perf_counter() - t0) / max(iters, 1)
        return StepReport(self.kind, prog.name, wall_s=dt, outputs=out)


class DryRunBackend(Backend):
    """Lower + compile only; extract compiled-artifact analyses."""

    kind = "dryrun"

    def run(self, prog: StepProgram) -> StepReport:
        t0 = time.perf_counter()
        lowered = prog.lower()
        compiled = lowered.compile()
        dt = time.perf_counter() - t0
        rep = StepReport(self.kind, prog.name, wall_s=dt)
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # older jax returns [dict]
            ca = ca[0] if ca else {}
        ma = compiled.memory_analysis()
        rep.memory = {
            "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": float(getattr(ma, "alias_size_in_bytes", 0)),
            "code_bytes": float(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
        rep.detail["compiled"] = compiled
        rep.detail["hlo"] = compiled.as_text()
        # loop-corrected analysis (XLA's cost_analysis counts scan
        # bodies once; see repro.core.desim.hlo_cost)
        from repro.core.desim.hlo_cost import analyze_hlo
        cost = analyze_hlo(rep.detail["hlo"])
        rep.flops = cost.flops
        rep.bytes_accessed = cost.bytes
        rep.collective_bytes = cost.collective_bytes
        rep.detail["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}
        return rep


class DesimBackend(Backend):
    """Discrete-event timing replay of the compiled step.

    Runs through the ``repro.sim.Simulator`` front-end (the gem5-stdlib
    layer), so the same run can be scripted with exit events,
    checkpointed, or sampled by driving a ``Simulator``/``Board``
    directly — this backend is the one-shot convenience path.
    ``board`` accepts a prebuilt ``repro.sim.boards.Board`` (or use
    ``machine=`` with a raw ClusterModel, as before).

    ``record_stats=True`` additionally dumps the run's gem5-style
    statistics tree (per-chip/per-wire/fabric counters) into
    ``report.detail["stats"]`` (flat dict) and
    ``report.detail["stats_text"]`` (gem5 stats.txt-style dump).

    ``workers=N`` (N>1) shards the machine's pods across N worker
    processes (dist-gem5 multiprocess simulation, ``repro.core.desim.
    parallel``) — same numbers, less wall clock on multipod boards.
    """

    kind = "desim"

    def __init__(self, machine=None, record_stats: bool = False,
                 board=None, workers: int = 1):
        # machine: repro.core.desim.machine.ClusterModel (built lazily)
        self.machine = machine
        self.board = board
        self.record_stats = record_stats
        self.workers = int(workers or 1)

    def run(self, prog: StepProgram,
            dryrun_report: Optional[StepReport] = None) -> StepReport:
        from repro.core.desim import machine as mc
        from repro.core.desim.trace import HloTrace
        from repro.sim import Board, Simulator

        if dryrun_report is None:
            dryrun_report = DryRunBackend().run(prog)
        board = self.board or Board(
            machine=self.machine or mc.default_cluster(prog.mesh))
        t0 = time.perf_counter()
        trace = HloTrace.from_hlo_text(
            dryrun_report.detail["hlo"], name=prog.name,
            total_flops=dryrun_report.flops or 0.0,
            total_bytes=dryrun_report.bytes_accessed or 0.0)
        sim = Simulator(board, trace, record_stats=self.record_stats,
                        workers=self.workers)
        result = sim.run_to_completion()
        dt = time.perf_counter() - t0
        rep = StepReport(self.kind, prog.name, wall_s=dt,
                         predicted_step_s=result.makespan_s,
                         flops=dryrun_report.flops,
                         bytes_accessed=dryrun_report.bytes_accessed,
                         collective_bytes=dryrun_report.collective_bytes,
                         memory=dryrun_report.memory)
        rep.detail["desim"] = result
        rep.detail["hlo"] = dryrun_report.detail["hlo"]
        if self.record_stats and sim.sim_root is not None:
            rep.detail["stats"] = result.stats
            rep.detail["stats_text"] = sim.sim_root.stats.dump_text()
        return rep


BACKENDS = {
    "native": NativeBackend,
    "dryrun": DryRunBackend,
    "desim": DesimBackend,
}


def get_backend(kind: str, **kw) -> Backend:
    try:
        return BACKENDS[kind](**kw)
    except KeyError:
        raise ValueError(f"unknown backend {kind!r}; one of {list(BACKENDS)}")


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
