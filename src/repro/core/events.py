"""Deterministic discrete-event simulation engine.

This is the g5x analogue of gem5's event-driven simulation core
(gem5-20 paper §1.3.1: "At its core, gem5 contains an event-driven
simulation engine").  Every timing model in ``repro.core.desim`` is built
on top of this engine.

Design goals, mirroring gem5:

* **Determinism** — events scheduled for the same tick execute in
  (priority, insertion-sequence) order, so a simulation is a pure
  function of its inputs.  gem5 relies on this for reproducible research
  results; we rely on it for reproducible roofline/DSE numbers and for
  the quantum-based multi-pod synchronization of dist-gem5 (§2.17).
* **Cheap scheduling** — a binary heap keyed by ``(tick, priority,
  seq)``; O(log n) insert/pop.
* **Multiple queues** — dist-gem5 runs one event queue per process and
  synchronizes them on quantum boundaries.  ``QuantumSync`` reproduces
  that: each pod owns an ``EventQueue`` and queues may only diverge by
  at most one quantum.

Ticks are integers (like gem5, which uses picosecond ticks).  The desim
layer uses 1 tick = 1 nanosecond, which comfortably resolves both ICI
hop latencies (~1 us) and multi-second training steps.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

# gem5-style well-known priorities (smaller runs first at equal tick).
PRI_MAXTICK = -100          # simulation-control events
PRI_STAT_DUMP = -50
PRI_DEFAULT = 0
PRI_PROGRESS = 50


def quantum_boundary(tick: int, quantum: int) -> int:
    """First quantum boundary >= ``tick`` (ceiling to a multiple).

    Shared by :class:`QuantumSync` and the multiprocess coordinator in
    :mod:`repro.core.desim.parallel`, which must agree bit-for-bit on
    barrier placement for parallel runs to be tick-exact."""
    return -(-int(tick) // quantum) * quantum


def quantum_delivery(src_now: int, latency: int, quantum: int) -> int:
    """Delivery tick for a cross-queue message sent at ``src_now``:
    the first quantum boundary >= ``src_now + max(latency, quantum)``.
    The one-quantum floor is what makes quantum sync correct — within a
    quantum no queue can observe another queue's events (dist-gem5
    §2.17), so nothing may be delivered sooner."""
    return quantum_boundary(src_now + max(int(latency), quantum), quantum)


def rendezvous_horizon(last_arrival_lb: int, quantum: int) -> int:
    """Earliest tick an *incomplete* rendezvous could possibly deliver,
    given a lower bound on its final arrival tick.

    ``quantum_delivery`` floors every delivery at one quantum past the
    last arrival, so any queue position ``<= rendezvous_horizon(lb)`` is
    provably safe: the eventual delivery lands strictly later.  This is
    the lookahead bound ``ParallelEngine`` uses to grant multi-quantum
    advances (dist-gem5 barrier elision) without ever letting a queue
    with undelivered traffic run past a delivery it has not seen."""
    return quantum_delivery(int(last_arrival_lb), 0, quantum)


class SimExit(Exception):
    """Raised by an event to stop the simulation (gem5's exit event)."""

    def __init__(self, cause: str = "exit", code: int = 0):
        super().__init__(cause)
        self.cause = cause
        self.code = code


@dataclass(order=True)
class _HeapEntry:
    tick: int
    priority: int
    seq: int
    callback: Callable[[], None] = field(compare=False)
    cancelled: bool = field(default=False, compare=False)
    name: str = field(default="", compare=False)


class Event:
    """Handle for a scheduled event; supports gem5-style ``squash()``."""

    __slots__ = ("_entry",)

    def __init__(self, entry: _HeapEntry):
        self._entry = entry

    @property
    def tick(self) -> int:
        return self._entry.tick

    @property
    def name(self) -> str:
        return self._entry.name

    def scheduled(self) -> bool:
        return not self._entry.cancelled

    def squash(self) -> None:
        """Cancel the event (it stays in the heap but will not fire)."""
        self._entry.cancelled = True


class EventQueue:
    """A single deterministic event queue.

    >>> q = EventQueue("main")
    >>> order = []
    >>> _ = q.schedule(lambda: order.append("b"), 10)
    >>> _ = q.schedule(lambda: order.append("a"), 10, priority=-1)
    >>> q.run()
    >>> order
    ['a', 'b']
    """

    def __init__(self, name: str = "main"):
        self.name = name
        self._heap: list[_HeapEntry] = []
        self._seq = 0
        self._now = 0
        self.events_fired = 0

    # ------------------------------------------------------------------
    @property
    def now(self) -> int:
        return self._now

    def empty(self) -> bool:
        # Lazily drop cancelled heads (like next_tick) instead of scanning
        # the whole heap: the executor's drain loop polls empty() per
        # queue pass, so an O(n) scan goes quadratic in squashed events.
        return self.next_tick() is None

    def next_tick(self) -> Optional[int]:
        while self._heap and self._heap[0].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0].tick if self._heap else None

    def pending(self) -> int:
        """Heap entries still stored (cancelled included) — leak probe."""
        return len(self._heap)

    def snapshot(self) -> dict:
        """Tick snapshot for checkpointing (repro.sim.serialize).

        Only bookkeeping is captured — scheduled callbacks are Python
        closures and cannot be serialized, which is why checkpointing
        *drains* the simulation first (gem5 ``drain()`` then
        ``serialize()``): a drained queue has no pending events, so
        ``now`` + ``events_fired`` fully describe it.
        """
        return {"now": self._now, "events_fired": self.events_fired}

    # ------------------------------------------------------------------
    def schedule(self, callback: Callable[[], None], tick: int,
                 priority: int = PRI_DEFAULT, name: str = "") -> Event:
        """Schedule ``callback`` at absolute ``tick`` (>= ``now`` and
        never negative — an event in the past would violate the
        tick-ordered merge the executor runs over its pod queues)."""
        if tick < 0:
            raise ValueError(
                f"cannot schedule event {name!r} at negative tick {tick} "
                "(ticks are absolute simulation time, >= 0)")
        if tick < self._now:
            raise ValueError(
                f"cannot schedule event {name!r} in the past: "
                f"tick={tick} < now={self._now}")
        entry = _HeapEntry(int(tick), priority, self._seq, callback,
                           name=name)
        self._seq += 1
        heapq.heappush(self._heap, entry)
        return Event(entry)

    def schedule_after(self, callback: Callable[[], None], delay: int,
                       priority: int = PRI_DEFAULT, name: str = "") -> Event:
        """Schedule ``callback`` ``delay`` ticks from ``now`` (delay
        must be >= 0: negative delays would land the event in the
        past)."""
        if delay < 0:
            raise ValueError(
                f"cannot schedule event {name!r} with negative delay "
                f"{delay} (use a tick >= now via schedule())")
        return self.schedule(callback, self._now + int(delay), priority, name)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Fire the next event.  Returns False when the queue is empty."""
        while self._heap:
            entry = heapq.heappop(self._heap)
            if entry.cancelled:
                continue
            if entry.tick < self._now:  # pragma: no cover - invariant
                raise RuntimeError("event queue time went backwards")
            self._now = entry.tick
            self.events_fired += 1
            entry.callback()
            return True
        return False

    def run(self, max_tick: Optional[int] = None,
            max_events: Optional[int] = None) -> str:
        """Run until empty / ``SimExit`` / ``max_tick``.  Returns the cause."""
        fired = 0
        try:
            while True:
                nt = self.next_tick()
                if nt is None:
                    return "queue empty"
                if max_tick is not None and nt > max_tick:
                    # never rewind: a max_tick already behind ``now``
                    # must not move simulation time backwards
                    self._now = max(self._now, max_tick)
                    return "max tick"
                if max_events is not None and fired >= max_events:
                    return "max events"
                self.step()
                fired += 1
        except SimExit as e:
            return e.cause

    def run_until(self, tick: int) -> None:
        """Advance exactly to ``tick`` (fires all events with t <= tick)."""
        while True:
            nt = self.next_tick()
            if nt is None or nt > tick:
                break
            self.step()
        self._now = max(self._now, tick)


class QuantumSync:
    """dist-gem5-style quantum-based synchronization of several queues.

    Each queue simulates one pod (gem5 process).  Queues run
    independently inside a quantum and barrier at quantum boundaries —
    the same scheme dist-gem5 uses over TCP (§2.17), here in-process.
    Cross-queue messages (e.g. DCN packets) are delivered with at least
    one quantum of latency, which is what makes the parallel simulation
    correct: within a quantum no queue can observe another queue's
    in-quantum events.
    """

    def __init__(self, queues: Iterable[EventQueue], quantum: int):
        self.queues = list(queues)
        if quantum <= 0:
            raise ValueError("quantum must be positive")
        self.quantum = int(quantum)
        self.barriers = 0
        self._pending: list[tuple[int, EventQueue, Callable[[], None]]] = []
        #: read-only observer called as ``observer(t, delivered)`` after
        #: each barrier step (instrumentation: Quantum DPRINTF, Perfetto
        #: barrier track).  Must not mutate queues — it runs after every
        #: queue has reached ``t``, so a pure read cannot perturb.
        self.observer: Optional[Callable[[int, int], None]] = None

    @property
    def pending_messages(self) -> int:
        """Cross-queue messages not yet delivered (0 when drained)."""
        return len(self._pending)

    def send(self, src_now: int, dst: EventQueue, callback: Callable[[], None],
             latency: int) -> None:
        """Cross-queue message: delivered at the first quantum boundary
        >= src_now + latency (models dist-gem5 packet forwarding)."""
        deliver = quantum_delivery(src_now, latency, self.quantum)
        self._pending.append((deliver, dst, callback))

    def _advance_to(self, t: int) -> None:
        """One barrier step: deliver due messages, run all queues to ``t``."""
        due = [p for p in self._pending if p[0] <= t]
        self._pending = [p for p in self._pending if p[0] > t]
        for deliver, dst, cb in due:
            dst.schedule(cb, max(deliver, dst.now))
        for q in self.queues:
            q.run_until(t)
        self.barriers += 1
        if self.observer is not None:
            self.observer(t, len(due))

    def run(self, max_tick: int) -> int:
        """Run all queues to ``max_tick`` in lockstep quanta.

        Returns the number of barrier synchronizations performed.
        """
        t = 0
        while t < max_tick:
            t = min(t + self.quantum, max_tick)
            self._advance_to(t)
        return self.barriers

    def run_until_drained(self, max_tick: Optional[int] = None,
                          stop_check: Optional[Callable[[], bool]] = None
                          ) -> int:
        """Run lockstep quanta until every queue is empty and no cross-
        queue message is pending.  Returns the final synchronized tick.

        Unlike :meth:`run`, empty quanta are skipped (the boundary jumps
        straight to the next quantum containing work), so ``barriers``
        counts only synchronizations that had something to do.  The
        quantum *semantics* are identical: no queue observes another
        queue's in-quantum events, and deliveries land exactly on the
        boundary ``send`` computed for them.

        ``stop_check`` is evaluated at every quantum boundary (the only
        points where global state is observable in dist-gem5); returning
        True pauses the run there — the caller may resume by calling
        ``run_until_drained`` again.  This is how ``repro.sim.Simulator``
        delivers exit events without breaking quantum semantics.
        """
        t = (max(q.now for q in self.queues) // self.quantum) * self.quantum
        while True:
            if stop_check is not None and stop_check():
                return t
            upcoming = [nt for nt in (q.next_tick() for q in self.queues)
                        if nt is not None]
            if self._pending:
                upcoming.append(min(p[0] for p in self._pending))
            if not upcoming:
                return t
            target = min(upcoming)
            # next boundary that covers ``target`` (and is ahead of us)
            t = max(quantum_boundary(target, self.quantum), t + self.quantum)
            if max_tick is not None and t > max_tick:
                # clamp like run(): fire everything due by max_tick,
                # leave later events unfired
                if target <= max_tick:
                    self._advance_to(max_tick)
                return max_tick
            self._advance_to(t)
