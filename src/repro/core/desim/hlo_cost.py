"""Loop-aware cost analysis of compiled (post-SPMD) HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` counts a ``while``
body ONCE, ignoring the trip count (verified experimentally: a
10-iteration ``lax.scan`` reports exactly 1/10th of the unrolled
FLOPs).  Every g5x model scans over layers, so cost_analysis would
undercount a 95-layer model by ~95x — and, worse, would miss 95/96ths
of the FSDP all-gather bytes that live inside the scanned layer body.

This module re-derives the three roofline inputs from the compiled
module text with correct loop multipliers:

  * flops            — dot (2*M*N*K from output shape x contraction
                       dims), elementwise/reduce approximations, fused
                       computations recursed, while bodies x trip count.
  * bytes accessed   — operand+output bytes at *fusion granularity*
                       (internals of a fusion stay in registers/VMEM,
                       matching XLA's own memory model), x trip count.
  * collective bytes — per collective kind, operand bytes (these are
                       LOCAL/per-device shard bytes in the post-SPMD
                       module), x trip count.

All results are PER-DEVICE (the compiled module is the per-partition
program).  Trip counts are parsed from the while condition's integer
constant (scan loops compare the induction variable against a
constant); the heuristic is validated against unrolled references in
tests/test_hlo_cost.py.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.desim.dtypes import SHAPE_RE as _SHAPE_RE
from repro.core.desim.dtypes import shape_elems_bytes  # noqa: F401 (re-export)

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# opcodes that move no data / cost nothing
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "opt-barrier", "partition-id",
             "replica-id"}

_ELEMENTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "compare", "select", "and", "or", "not", "xor", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "sign", "cosine", "sine",
    "atan2", "clamp", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "exponential-minus-one", "log-plus-one",
    "logistic", "cbrt", "erf",
}


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Instr:
    name: str
    opcode: str
    out_type: str
    args: List[str]
    attrs: str
    raw: str


@dataclass
class Computation:
    name: str
    param_types: Dict[str, str]
    instrs: List[Instr] = field(default_factory=list)


_COMP_HDR = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")


def _split_top_level(s: str) -> List[str]:
    """Split on commas at paren/brace depth 0 (tuple-typed params)."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9\[\]{},\s]*?))\s*"
    r"([a-z][a-z0-9\-]*)\((.*)$")
_REF = re.compile(r"%([\w.\-]+)")


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], str]:
    """Parse computations.  Returns ({name: comp}, entry_name)."""
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{"):
                m = _COMP_HDR.match(stripped.lstrip("%"))
                if m:
                    name, params = m.group(1), m.group(2)
                    ptypes = {}
                    for p in _split_top_level(params):
                        p = p.strip()
                        if ":" in p:
                            pname, ptype = p.split(":", 1)
                            ptypes[pname.strip().lstrip("%")] = ptype.strip()
                    cur = Computation(name, ptypes)
                    if stripped.startswith("ENTRY"):
                        entry = name
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rettype, opcode, rest = m.groups()
        # split call args from attrs: find matching close paren
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args = _REF.findall(rest[:end])
        attrs = rest[end + 1:]
        cur.instrs.append(Instr(name, opcode, rettype.strip(), args, attrs,
                                line))
    return comps, entry


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: float = 0.0
    # bytes moved by pure data movement (copy / copy-only fusions):
    # real on the CPU backend, aliased away by TPU while-carry buffer
    # assignment -> reported separately so the roofline can show both.
    copy_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    top_dots: List[Tuple[float, str]] = field(default_factory=list)
    top_bytes: List[Tuple[float, str]] = field(default_factory=list)

    def note_bytes(self, nbytes: float, label: str) -> None:
        self.top_bytes.append((nbytes, label))
        self.top_bytes = sorted(self.top_bytes, reverse=True)[:12]

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.copy_bytes += other.copy_bytes * mult
        self.transcendentals += other.transcendentals * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collectives.items():
            s = self.collectives.setdefault(k, {"count": 0.0, "bytes": 0.0})
            s["count"] += v["count"] * mult
            s["bytes"] += v["bytes"] * mult
        self.top_dots.extend(
            (f * mult, d) for f, d in other.top_dots)
        self.top_dots = sorted(self.top_dots, reverse=True)[:8]
        self.top_bytes.extend(
            (b * mult, d) for b, d in other.top_bytes)
        self.top_bytes = sorted(self.top_bytes, reverse=True)[:12]


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        self._cache: Dict[Tuple[str, bool], Cost] = {}
        self.while_trips: List[Tuple[str, int]] = []

    # -- trip count ------------------------------------------------------
    def trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for ins in comp.instrs:
            for m in re.finditer(r"constant\((\d+)\)", ins.raw):
                best = max(best, int(m.group(1)))
        # fused compare: constants may live in a called computation
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                if cm and cm.group(1) in self.comps:
                    for ins2 in self.comps[cm.group(1)].instrs:
                        for m in re.finditer(r"constant\((\d+)\)", ins2.raw):
                            best = max(best, int(m.group(1)))
        return best

    def _is_pure_copy(self, comp_name: str) -> bool:
        comp = self.comps.get(comp_name)
        if comp is None:
            return False
        movement = {"parameter", "copy", "bitcast", "tuple",
                    "get-tuple-element", "reshape", "transpose"}
        return all(i.opcode in movement for i in comp.instrs)

    # -- fusion I/O bytes ---------------------------------------------------
    def _fusion_io_bytes(self, comp_name: str, types_at_site: Dict[str, str],
                         ins: Instr) -> float:
        """HBM bytes moved by one fusion call, slice-aware.

        A fusion that dynamic-slices a big loop-invariant array (the
        stacked scanned weights) only READS the slice; charging the full
        operand would overcount a 95-layer scan by 95x.  Rule: a fusion
        parameter consumed *only* by dynamic-slice/gather ops is charged
        the sum of those ops' outputs; otherwise the full parameter.
        A fusion whose root is dynamic-update-slice writes only the
        update region (in-place semantics), not the whole buffer.
        """
        comp = self.comps.get(comp_name)
        _, out_bytes = shape_elems_bytes(ins.out_type)
        if comp is None:
            return out_bytes + sum(
                shape_elems_bytes(types_at_site.get(a, ""))[1]
                for a in ins.args)
        # parameter order
        param_order: List[str] = []
        for i2 in comp.instrs:
            if i2.opcode == "parameter":
                param_order.append(i2.name)
        reads = 0.0
        for idx, pname in enumerate(param_order):
            arg = ins.args[idx] if idx < len(ins.args) else None
            full = shape_elems_bytes(
                types_at_site.get(arg, comp.param_types.get(pname, "")))[1]
            consumers = [i2 for i2 in comp.instrs if pname in i2.args]
            if consumers and all(i2.opcode in ("dynamic-slice", "gather")
                                 or (i2.opcode == "dynamic-update-slice"
                                     and i2.args and i2.args[0] == pname)
                                 for i2 in consumers):
                sliced = 0.0
                for i2 in consumers:
                    if i2.opcode == "dynamic-update-slice":
                        continue        # pass-through buffer, charged below
                    sliced += shape_elems_bytes(i2.out_type)[1]
                reads += min(sliced, full)
            else:
                reads += full
        # root DUS: write = update region only
        root = comp.instrs[-1] if comp.instrs else None
        if root is not None:
            chain = root
            # peel pure per-element wrappers to find a DUS root (the
            # decode cache-carry pattern fuses as convert(dus(...)))
            local = {i2.name: i2 for i2 in comp.instrs}
            for _ in range(4):
                if chain.opcode in ("bitcast", "copy", "convert") \
                        and chain.args:
                    nxt = local.get(chain.args[0])
                    if nxt is None:
                        break
                    chain = nxt
            if chain.opcode == "dynamic-update-slice" and len(chain.args) > 1:
                upd = local.get(chain.args[1])
                if upd is not None:
                    out_bytes = shape_elems_bytes(upd.out_type)[1]
                else:
                    out_bytes = shape_elems_bytes(
                        comp.param_types.get(chain.args[1], ""))[1]
        return reads + out_bytes

    # -- per-instruction flops -------------------------------------------
    def _dot_flops(self, ins: Instr, types: Dict[str, str]) -> float:
        out_elems, _ = shape_elems_bytes(ins.out_type)
        lhs_type = types.get(ins.args[0], "") if ins.args else ""
        lhs_dims = _shape_dims(lhs_type)
        m = re.search(r"lhs_contracting_dims=\{([0-9, ]*)\}", ins.attrs)
        k = 1
        if m and lhs_dims:
            for d in m.group(1).split(","):
                d = d.strip()
                if d:
                    idx = int(d)
                    if idx < len(lhs_dims):
                        k *= lhs_dims[idx]
        return 2.0 * out_elems * k

    # -- computation cost ---------------------------------------------------
    def comp_cost(self, name: str, fused: bool) -> Cost:
        """fused=True: computation runs inside a fusion -> its internal
        ops contribute flops but NOT memory traffic."""
        key = (name, fused)
        if key in self._cache:
            return self._cache[key]
        self._cache[key] = Cost()          # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return Cost()
        types: Dict[str, str] = dict(comp.param_types)
        total = Cost()
        for ins in comp.instrs:
            types[ins.name] = ins.out_type
            op = ins.opcode
            out_elems, out_bytes = shape_elems_bytes(ins.out_type)
            arg_bytes = sum(shape_elems_bytes(types.get(a, ""))[1]
                            for a in ins.args)

            if op in _FREE_OPS:
                continue

            # control flow / calls ------------------------------------
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                # XLA annotates scans with known_trip_count
                ktc = re.search(r'known_trip_count[^0-9]*(\d+)', ins.raw)
                if ktc:
                    trips = int(ktc.group(1))
                else:
                    trips = self.trip_count(cm.group(1)) if cm else 1
                self.while_trips.append((ins.name, trips))
                if bm:
                    total.add(self.comp_cost(bm.group(1), fused), trips)
                continue
            if op in ("call", "async-start"):
                cm = re.search(r"(?:calls|called_computation)=%?([\w.\-]+)",
                               ins.attrs)
                if cm:
                    total.add(self.comp_cost(cm.group(1), fused))
                continue
            if op == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                      ins.attrs)
                names = _REF.findall(branches[0]) if branches else []
                if names:
                    costs = [self.comp_cost(n, fused) for n in names]
                    total.add(max(costs, key=lambda c: c.flops))
                continue
            if op == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                if cm:
                    total.add(self.comp_cost(cm.group(1), True))
                if not fused:
                    fb = self._fusion_io_bytes(
                        cm.group(1) if cm else "", types, ins)
                    total.bytes += fb
                    total.note_bytes(fb, f"{name}/{ins.name}")
                    if cm and self._is_pure_copy(cm.group(1)):
                        total.copy_bytes += fb
                continue

            # collectives ------------------------------------------------
            base = next((k for k in COLLECTIVE_KINDS
                         if op == k or op == k + "-start"), None)
            if base is not None:
                nbytes = arg_bytes or out_bytes
                total.collective_bytes += nbytes
                s = total.collectives.setdefault(
                    base, {"count": 0.0, "bytes": 0.0})
                s["count"] += 1
                s["bytes"] += nbytes
                if not fused:
                    total.bytes += arg_bytes + out_bytes
                continue
            if op.endswith("-done"):
                continue

            # compute ------------------------------------------------------
            if op == "dot":
                f = self._dot_flops(ins, types)
                total.flops += f
                meta = re.search(r'op_name="([^"]*)"', ins.attrs)
                total.top_dots.append((f, meta.group(1) if meta
                                       else ins.name))
                total.top_dots = sorted(total.top_dots, reverse=True)[:8]
            elif op == "convolution":
                # approximate: 2 * out_elems * (arg_elems0 / spatial_out)
                lhs_elems, _ = shape_elems_bytes(types.get(
                    ins.args[0], "")) if ins.args else (0.0, 0.0)
                total.flops += 2.0 * out_elems * max(lhs_elems, 1) ** 0.5
            elif op in ("reduce", "reduce-window", "scatter", "select-and-scatter"):
                in_elems = sum(shape_elems_bytes(types.get(a, ""))[0]
                               for a in ins.args[:1])
                total.flops += in_elems
            elif op in _ELEMENTWISE_1FLOP:
                total.flops += out_elems
                if op in ("exponential", "log", "tanh", "logistic", "power",
                          "cosine", "sine", "erf", "cbrt",
                          "exponential-minus-one", "log-plus-one"):
                    total.transcendentals += out_elems

            if not fused:
                if op == "copy":
                    total.copy_bytes += arg_bytes + out_bytes
                # slice-aware top-level accounting (same rationale as
                # _fusion_io_bytes)
                if op in ("dynamic-slice", "gather", "slice"):
                    total.bytes += 2 * out_bytes
                    total.note_bytes(2 * out_bytes, f"{name}/{ins.name}")
                elif op == "dynamic-update-slice" and len(ins.args) > 1:
                    upd = shape_elems_bytes(types.get(ins.args[1], ""))[1]
                    total.bytes += 2 * upd
                    total.note_bytes(2 * upd, f"{name}/{ins.name}")
                else:
                    total.bytes += arg_bytes + out_bytes
                    total.note_bytes(arg_bytes + out_bytes,
                                     f"{name}/{ins.name}")

        self._cache[key] = total
        return total

    def analyze(self) -> Cost:
        return self.comp_cost(self.entry, False)


def analyze_hlo(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).analyze()
