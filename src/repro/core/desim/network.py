"""ICI torus + DCN topology with link occupancy (the Garnet analogue).

gem5's Garnet models router microarchitecture, link contention and flow
control at cycle level (§2.13).  The TPU analogue is the 2-D ICI torus
inside a pod and the DCN between pods.  We model:

* explicit links with per-direction bandwidth and occupancy windows —
  two transfers crossing the same link serialize (contention),
* dimension-ordered routing on the torus (X then Y, shortest wrap),
* a bisection model for all-to-all style traffic,
* DCN as a per-host bottleneck link (dist-gem5's TCP forwarding).

The collective *algorithms* (repro.core.desim.collectives) produce
phases; this module answers "how long does phase X take given who else
is on the wire", which is what turns a cost model into a network model.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.core.desim.machine import ClusterModel


@dataclass
class LinkState:
    """Occupancy bookkeeping for one directed link."""

    busy_until: float = 0.0
    bytes_carried: float = 0.0
    transfers: int = 0

    def acquire(self, now: float, duration: float, nbytes: float) -> float:
        """Serialize on the link; returns completion time."""
        start = max(now, self.busy_until)
        self.busy_until = start + duration
        self.bytes_carried += nbytes
        self.transfers += 1
        return self.busy_until


class TorusNetwork:
    """2-D torus of (nx, ny) chips; 4 directed links per chip."""

    def __init__(self, nx: int, ny: int, link_bw: float, hop_latency: float):
        self.nx, self.ny = nx, ny
        self.link_bw = link_bw
        self.hop_latency = hop_latency
        self.links: Dict[Tuple[int, int, str], LinkState] = {}

    def _link(self, x: int, y: int, direction: str) -> LinkState:
        key = (x % self.nx, y % self.ny, direction)
        if key not in self.links:
            self.links[key] = LinkState()
        return self.links[key]

    def route(self, src: Tuple[int, int], dst: Tuple[int, int]
              ) -> List[Tuple[int, int, str]]:
        """Dimension-ordered (X then Y) shortest-wrap route."""
        (sx, sy), (dx, dy) = src, dst
        hops: List[Tuple[int, int, str]] = []
        # X dimension
        fwd = (dx - sx) % self.nx
        bwd = (sx - dx) % self.nx
        step, d = (1, "+x") if fwd <= bwd else (-1, "-x")
        x = sx
        for _ in range(min(fwd, bwd)):
            hops.append((x, sy, d))
            x = (x + step) % self.nx
        # Y dimension
        fwd = (dy - sy) % self.ny
        bwd = (sy - dy) % self.ny
        step, d = (1, "+y") if fwd <= bwd else (-1, "-y")
        y = sy
        for _ in range(min(fwd, bwd)):
            hops.append((x, y, d))
            y = (y + step) % self.ny
        return hops

    def send(self, now: float, src: Tuple[int, int], dst: Tuple[int, int],
             nbytes: float) -> float:
        """Point-to-point transfer; returns completion time (contention-
        aware store-and-forward at message granularity)."""
        t = now
        for (x, y, d) in self.route(src, dst):
            link = self._link(x, y, d)
            dur = self.hop_latency + nbytes / self.link_bw
            t = link.acquire(t, dur, nbytes)
        return t

    def occupancy_report(self) -> Dict[str, float]:
        if not self.links:
            return {"links_used": 0, "max_busy_s": 0.0, "total_bytes": 0.0}
        return {
            "links_used": len(self.links),
            "max_busy_s": max(l.busy_until for l in self.links.values()),
            "total_bytes": sum(l.bytes_carried for l in self.links.values()),
        }


class DcnFabric:
    """Inter-pod fabric: per-pod uplink bottleneck (dist-gem5 TCP model)."""

    def __init__(self, num_pods: int, bw: float, latency: float,
                 hosts_per_pod: int = 64):
        self.num_pods = num_pods
        self.bw = bw * hosts_per_pod   # pod aggregate uplink
        self.latency = latency
        self.uplinks: List[LinkState] = [LinkState() for _ in range(num_pods)]

    def exchange(self, now: float, nbytes_per_pod: float) -> float:
        """All pods exchange ``nbytes_per_pod`` (e.g. cross-pod AR shard).
        Returns completion time of the slowest pod."""
        done = now
        for link in self.uplinks:
            dur = self.latency + nbytes_per_pod / self.bw
            done = max(done, link.acquire(now, dur, nbytes_per_pod))
        return done


def build_networks(machine: ClusterModel
                   ) -> Tuple[TorusNetwork, DcnFabric]:
    pod = machine.pod
    torus = TorusNetwork(pod.nx, pod.ny, pod.ici.bw, pod.ici.latency_s)
    dcn = DcnFabric(machine.num_pods, machine.dcn.bw, machine.dcn.latency_s)
    return torus, dcn
