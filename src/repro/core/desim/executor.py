"""Event-driven trace replay on a machine model.

This is where the pillars meet (the gem5 'detailed CPU + Ruby + Garnet'
configuration): an elastic trace (trace.py) is replayed on a
parameterized cluster (machine.py) through pluggable collective
algorithms (collectives.py), driven by the deterministic event engine
(core/events.py), with dist-gem5 quantum synchronization between pods
(§2.17) and straggler injection (per-chip ``slowdown``).

Every run builds a SimObject tree (simnodes.py) — one :class:`ChipSim`
and :class:`WireSim` per pod plus one shared :class:`DcnSim`, wired
through ports — and replays the trace as events on per-pod
``EventQueue``s (1 tick = 1 ns).  There are no float resource clocks:
all arbitration happens in integer ticks on the queue.

Timing is **pluggable** (``repro.core.desim.timing`` — the gem5
CPU-model fidelity ladder): ``DetailedTiming`` gives the semantics
below; ``AtomicTiming`` costs ops contention-free with batch-resolved
completions (the fast-forward model), and a drained run may be
restored under the *other* model — gem5's ``switch_cpus``.

Detailed timing semantics per chip:

* ``compute`` ops serialize on the chip's compute resource at the
  roofline time ``max(flops/peak, bytes/hbm_bw) * slowdown``.
* intra-pod collectives occupy the concrete torus links of their
  ``region`` (default: the whole pod) on the pod's wire; collectives
  whose regions share a link serialize, disjoint regions run in
  parallel (the Garnet contention model, §2.13).  An ``overlap=True``
  collective occupies the wire but its time is not counted as exposed —
  this models async collectives / comm-compute overlap, the
  distributed-optimization trick the train step is structured around.
* cross-pod (dcn) collectives rendezvous on the shared fabric and
  complete at a quantum boundary delivered through ``QuantumSync``,
  reproducing dist-gem5's quantum-based synchronization error model.

Execution is **resumable** (gem5 §2.7 checkpoint/restore): ``execute``
is sugar for ``begin`` / ``advance`` / ``result``, and a paused run can
be gem5-style **drained** (in-flight events complete, newly-ready ops
are deferred instead of issued), snapshotted to a plain dict, and
**restored** — on the same machine or a re-parameterized one — with
``TraceExecutor.restore``.  The ``repro.sim`` front-end builds the
checkpoint file format and the exit-event loop on top of these hooks.

The trace is **not frozen**: ``inject_op`` appends ops to a live run
(dynamic workloads — gem5's "full application" mode, used by
``repro.sim.workloads.ServeSim`` for request-level serving).  Injected
ops execute on one pod, report completion through ``injection_hook``,
and ride the same drain/snapshot/restore path as static ops.

Pass ``record_stats=True`` to get the gem5-style statistics tree of the
run in ``ExecResult.stats`` (flat ``sim.chip0.ops_executed`` keys; the
full tree object is on ``TraceExecutor.sim_root`` after ``execute``).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import trace as dbg
from repro.core.desim.collectives import get_algorithm
from repro.core.desim.machine import ClusterModel
from repro.core.desim.simnodes import (ChipSim, ClusterSim, DcnSim,
                                       TICKS_PER_S, WireSim)
from repro.core.desim.timing import (AtomicTiming, DetailedTiming,
                                     TimingModel, get_timing_model)
from repro.core.desim.trace import HloTrace, TraceOp
from repro.core.events import EventQueue, QuantumSync


@dataclass
class ExecResult:
    makespan_s: float
    compute_s: float
    collective_s: float
    exposed_collective_s: float     # collective time NOT hidden by overlap
    per_chip_busy_s: List[float]
    events: int                     # == engine events_fired (all queues)
    timeline: List[Dict] = field(default_factory=list)
    stats: Optional[Dict[str, Any]] = None   # flat gem5-style stats dump
    # exact integer makespan tick: makespan_s is this / TICKS_PER_S, and
    # round-tripping the float back to ticks can drift by ±1 on long runs
    final_tick: int = 0

    def summary(self) -> Dict[str, float]:
        return {
            "makespan_s": self.makespan_s,
            "compute_s": self.compute_s,
            "collective_s": self.collective_s,
            "exposed_collective_s": self.exposed_collective_s,
            "overlap_efficiency": (
                1.0 - self.exposed_collective_s / self.collective_s
                if self.collective_s > 0 else 1.0),
        }


# hook invoked on pod-0 op completion: (op, op_idx, start, end) -> None
OpHook = Callable[[TraceOp, int, int, int], None]

# hook invoked when an *injected* op completes on its owning pod:
# (op, op_idx, pod, start, end) -> None
InjectionHook = Callable[[TraceOp, int, int, int, int], None]


class TraceExecutor:
    """Replays an HloTrace on a ClusterModel.

    The model is SPMD: every chip executes the same trace (that is what
    a pjit program is), so we simulate one *representative chip per pod*
    plus shared wire resources, with stragglers making pods
    heterogeneous.  This keeps the DES cost O(ops x pods), which is what
    lets DSE sweeps run thousands of variants (the gem5 use case).

    ``timing`` selects the fidelity model (gem5's CPU-model ladder):
    ``"detailed"`` (default — link contention, quantum sync, engine
    events) or ``"atomic"`` (contention-free analytical costing, the
    fast-forward model; see ``repro.core.desim.timing``).  The old
    ``contention=False`` ablation is deprecated and maps to
    ``AtomicTiming`` — the contention-free baseline for measuring how
    much of a makespan is queueing.

    Lifecycle::

        ex.execute(trace)                    # one-shot (begin+advance+result)

        ex.begin(trace)                      # resumable
        while not ex.advance(max_tick=t):    # pause at tick boundaries
            t += ...
        res = ex.result()

        ex.drain(); state = ex.snapshot()    # gem5 drain-then-serialize
        ex2 = TraceExecutor(machine2, **cfg).restore(trace, state)
    """

    def __init__(self, machine: ClusterModel, algorithm: str = "torus2d",
                 record_timeline: bool = False,
                 straggler_slowdowns: Optional[List[float]] = None,
                 record_stats: bool = False,
                 contention: Optional[bool] = None, timing=None,
                 pod_labels: Optional[List[int]] = None,
                 dcn_capture: Optional[Callable[[dict], None]] = None,
                 instrument=None):
        self.machine = machine
        self.algorithm = algorithm
        self.alg = get_algorithm(algorithm)
        self.dcn_alg = get_algorithm("hierarchical")
        self.record_timeline = record_timeline
        self.record_stats = record_stats
        # fidelity selection: an explicit ``timing`` wins; the legacy
        # ``contention=False`` ablation maps to AtomicTiming (which has
        # the same contention-free op costs, minus the quantum error
        # model and the per-op engine events)
        if timing is None:
            if contention is False:
                warnings.warn(
                    "TraceExecutor(contention=False) is deprecated; use "
                    "timing='atomic' (the contention-free fidelity model)",
                    DeprecationWarning, stacklevel=2)
                timing = "atomic"
            else:
                timing = "detailed"
        self.timing: TimingModel = get_timing_model(timing)
        # legacy attribute: True iff link contention is simulated
        self.contention = self.timing.detailed
        pods = machine.num_pods
        self.slow = (straggler_slowdowns or [1.0] * pods)[:pods]
        while len(self.slow) < pods:
            self.slow.append(1.0)
        # Shard support (repro.core.desim.parallel): a worker process
        # simulates a SLICE of a larger machine, so its local pod p is
        # globally ``pod_labels[p]`` — SimObject/queue names use the
        # global label (stats subtrees land at their global path), and
        # run-wide accounting (totals/timeline/op_hook, once per static
        # op) happens on whichever local pod carries global label 0.
        if pod_labels is None:
            pod_labels = list(range(pods))
        if len(pod_labels) != pods:
            raise ValueError(f"pod_labels has {len(pod_labels)} entries "
                             f"for a {pods}-pod machine")
        self.pod_labels = [int(g) for g in pod_labels]
        self._account_local = (self.pod_labels.index(0)
                               if 0 in self.pod_labels else -1)
        # When set, cross-pod (dcn) arrivals are handed to this callback
        # instead of the in-process rendezvous — the parallel engine's
        # coordinator owns the shared fabric.
        self._dcn_capture = dcn_capture
        # Optional timeline recorder (repro.sim.instrument.
        # TraceEventRecorder duck-type): ``op_event`` fires once per
        # completed op per pod; read-only — tracing on vs off is
        # bit-identical (test-enforced)
        self.instrument = instrument
        self.sim_root: Optional[ClusterSim] = None
        self.op_hook: Optional[OpHook] = None
        self.injection_hook: Optional[InjectionHook] = None
        self._trace: Optional[HloTrace] = None

    # ------------------------------------------------------------------
    def _build(self, queues: List[EventQueue],
               sync: Optional[QuantumSync]) -> ClusterSim:
        """Assemble and wire the per-run SimObject tree."""
        m = self.machine
        root = ClusterSim("sim", num_pods=m.num_pods,
                          quantum_ns=m.quantum_ns)
        dcn = DcnSim("dcn", m, self.dcn_alg, queues, sync,
                     num_pods=m.num_pods, contention=self.contention,
                     capture=self._dcn_capture)
        root.dcn = dcn
        chips: List[ChipSim] = []
        wires: List[WireSim] = []
        for p in range(m.num_pods):
            g = self.pod_labels[p]
            chip = ChipSim(f"chip{g}", m.pod.chip, queues[p],
                           pod_id=p, slowdown=self.slow[p])
            wire = WireSim(f"wire{g}", m, self.alg, queues[p],
                           pod_id=p, contention=self.contention)
            chip.coll_port.connect(wire.chip_port)
            wire.dcn_port.connect(dcn.pod_ports[p])
            setattr(root, f"chip{g}", chip)
            setattr(root, f"wire{g}", wire)
            chips.append(chip)
            wires.append(wire)
        root.instantiate()
        self._chips, self._wires, self._dcn = chips, wires, dcn
        return root

    def _routes_dcn(self, op) -> bool:
        chips_per_pod = self.machine.pod.num_chips
        participants = op.participants or chips_per_pod
        return op.kind != "compute" and (op.scope == "dcn"
                                         or participants > chips_per_pod)

    # -- lifecycle: begin ------------------------------------------------
    def _setup(self, trace: HloTrace) -> None:
        """Common state for begin() and restore()."""
        m = self.machine
        pods = m.num_pods
        nops = len(trace.ops)
        self._trace = trace
        self._queues = [EventQueue(f"pod{self.pod_labels[p]}")
                        for p in range(pods)]
        self.timing.reset(self)
        needs_dcn = any(self._routes_dcn(op) for op in trace.ops)
        # quantum_ns == 0 means "no quantum error model": dcn ops then
        # complete at their exact tick instead of a sync boundary.
        # AtomicTiming never applies the quantum model (dcn ops complete
        # at their exact analytical tick).
        self._sync = (QuantumSync(self._queues, m.quantum_ns)
                      if needs_dcn and m.quantum_ns > 0
                      and self.timing.detailed else None)
        if self._sync is not None:
            # read-only barrier observer (Quantum DPRINTF + Perfetto
            # barrier track); runs after every queue reached the
            # boundary, so it cannot perturb event order
            self._sync.observer = self._sync_observe
        self.sim_root = self._build(self._queues, self._sync)
        # dependency bookkeeping (per pod: SPMD replicas diverge only
        # through stragglers and the shared dcn fabric)
        self._dependents: List[List[int]] = [[] for _ in range(nops)]
        for idx, op in enumerate(trace.ops):
            for d in op.deps:
                self._dependents[d].append(idx)
        self._remaining = [[len(op.deps) for op in trace.ops]
                           for _ in range(pods)]
        self._op_end: List[List[int]] = [[-1] * nops for _ in range(pods)]
        self._ncomplete = 0
        self._totals = {"compute": 0.0, "coll": 0.0, "exposed": 0.0}
        self._timeline: List[Dict] = []
        self._draining = False
        self._deferred: List[Tuple[int, int, int]] = []
        # dynamically injected ops: op_idx -> owning pod.  An injected op
        # runs on ONE pod only (the trace stops being SPMD there); the
        # other pods' rows are marked complete at injection time so the
        # done()/dependents bookkeeping stays uniform.
        self._injected: Dict[int, int] = {}
        # op_idx -> requested ready floor, for injected ops still
        # waiting on deps at injection time
        self._inject_floor: Dict[int, int] = {}

    def begin(self, trace: HloTrace) -> "TraceExecutor":
        """Build the SimObject tree and issue the trace's root ops.
        Call ``advance`` to make progress, ``result`` when done."""
        self._setup(trace)
        # roots of the DAG start at tick 0, in trace order per pod
        for p in range(self.machine.num_pods):
            for idx, op in enumerate(trace.ops):
                if not op.deps:
                    self._issue(p, idx, 0)
        return self

    # -- dynamic workloads: op injection into a live run ------------------
    def inject_op(self, op: TraceOp, ready: int, pod: int = 0) -> int:
        """Append ``op`` to the live trace and issue it on ``pod`` at tick
        >= ``ready`` (dynamic workloads: ops generated in *response to*
        events, not frozen up front — the gem5 'full application' mode).

        Unlike the static trace, an injected op executes on exactly one
        pod; its deps may reference any earlier op (static or injected)
        but must resolve on the owning pod.  Completion is reported
        through :attr:`injection_hook` as ``(op, idx, pod, start, end)``.
        Injection while draining defers the issue like any newly-ready
        op, so checkpoints taken mid-serving restore exactly.
        Returns the op's trace index.
        """
        if self._trace is None:
            raise RuntimeError("inject_op() before begin()/restore()")
        pods = self.machine.num_pods
        if not 0 <= pod < pods:
            raise ValueError(f"pod {pod} out of range (machine has {pods})")
        if self._routes_dcn(op):
            raise ValueError(
                f"cannot inject dcn-routed op {op.name or op.kind!r}: it "
                "would rendezvous on pods that never issue it (injected "
                "ops run on exactly one pod)")
        idx = len(self._trace.ops)
        for d in op.deps:
            if not 0 <= d < idx:
                raise ValueError(f"injected op dep {d} out of range")
            owner = self._injected.get(d)
            if owner is not None and owner != pod:
                raise ValueError(
                    f"injected op dep {d} belongs to pod {owner}, not {pod}")
        self._trace.ops.append(op)
        self._dependents.append([])
        for d in op.deps:
            self._dependents[d].append(idx)
        rem = sum(1 for d in op.deps if self._op_end[pod][d] < 0)
        ready = int(ready)
        for p in range(pods):
            self._op_end[p].append(-1)
            self._remaining[p].append(rem)
        self._injected[idx] = pod
        if dbg._ACTIVE:
            dbg.dprintf("Exec", self._queues[pod], "inject %s op=%d",
                        op.name or op.kind, idx, tick=ready)
        for p in range(pods):
            if p != pod:
                # non-owning pods never run the op: mark complete now
                self._op_end[p][idx] = ready
                self._ncomplete += 1
        if rem == 0:
            at = max([ready] + [self._op_end[pod][d] for d in op.deps])
            self._issue(pod, idx, at)
        else:
            # deps still in flight: remember the requested floor so the
            # dependent-issue path honors ``ready`` (dep end ticks alone
            # could issue the op earlier than asked)
            self._inject_floor[idx] = ready
        return idx

    # -- issue / completion ---------------------------------------------
    def _payload(self, p: int, idx: int, ready: int) -> dict:
        op = self._trace.ops[idx]
        payload = {"pod": p, "op_idx": idx, "ready": ready,
                   "name": op.name or op.kind, "done": self._on_done}
        if op.kind != "compute":
            payload.update(kind=op.kind, nbytes=op.coll_bytes,
                           participants=(op.participants
                                         or self.machine.pod.num_chips),
                           region=op.region,
                           dcn=self._routes_dcn(op))
        return payload

    def _sync_observe(self, t: int, delivered: int) -> None:
        if dbg._ACTIVE:
            dbg.dprintf("Quantum", "sync", "barrier delivered=%d",
                        delivered, tick=t)
        ins = self.instrument
        if ins is not None:
            ins.barrier_event(t)

    def _issue(self, p: int, idx: int, ready: int) -> None:
        if self._draining:
            # gem5 drain(): newly-ready work is deferred, in-flight
            # events complete.  The deferred frontier is what snapshot()
            # serializes and restore() re-schedules.
            self._deferred.append((p, idx, int(ready)))
            if dbg._ACTIVE:
                dbg.dprintf("Ckpt", self._queues[p],
                            "defer op=%d (draining)", idx, tick=ready)
            return
        if dbg._ACTIVE:
            op = self._trace.ops[idx]
            dbg.dprintf("Exec", self._queues[p], "issue %s op=%d kind=%s",
                        op.name or op.kind, idx, op.kind, tick=ready)
        self.timing.issue(self, p, idx, ready)

    def _on_done(self, start: int, end: int, payload: dict) -> None:
        p, idx = payload["pod"], payload["op_idx"]
        op = self._trace.ops[idx]
        ins = self.instrument
        if ins is not None:
            ins.op_event(self.pod_labels[p], payload, start, end)
        if dbg._ACTIVE:
            dbg.dprintf("Exec", self._queues[p], "complete %s op=%d",
                        payload.get("name", op.kind), idx, tick=end)
        if self._op_end[p][idx] < 0:
            self._ncomplete += 1
        self._op_end[p][idx] = end
        # snapshot the dependent list BEFORE any hook runs: a hook may
        # inject_op() a new op depending on this one, which appends to
        # _dependents[idx] — but inject_op already saw op_end >= 0 and
        # excluded this op from the new op's remaining count, so
        # processing the appended entry here would double-decrement
        dependents = list(self._dependents[idx])
        # totals/timeline count each op once: on pod 0 for static SPMD
        # ops (every pod runs a replica; in a parallel shard, on the
        # local pod carrying global label 0 — other shards skip), on
        # the owning pod for injected ops (they run exactly once)
        owner = self._injected.get(idx)
        if p == (self._account_local if owner is None else owner):
            dur = payload.get("dur")
            dur_s = (dur if dur is not None else end - start) \
                / TICKS_PER_S
            if op.kind == "compute":
                self._totals["compute"] += dur_s
            else:
                self._totals["coll"] += dur_s
                if not op.overlap:
                    # exposed = time the compute resource sat idle
                    # waiting for this collective
                    idle_from = max(self._chips[p].free_tick,
                                    payload["ready"])
                    self._totals["exposed"] += max(0, end - idle_from) \
                        / TICKS_PER_S
            if self.record_timeline:
                self._timeline.append({"op": op.name or op.kind,
                                       "kind": op.kind,
                                       "start": start / TICKS_PER_S,
                                       "end": end / TICKS_PER_S})
            if self.op_hook is not None and owner is None:
                # work-item markers are a static-trace concept; injected
                # ops report through injection_hook below
                self.op_hook(op, idx, start, end)
        if owner is not None and self.injection_hook is not None \
                and p == owner:
            self.injection_hook(op, idx, p, start, end)
        for dep_idx in dependents:
            if self._op_end[p][dep_idx] >= 0:
                # injected op owned by another pod: this pod's row was
                # marked complete at injection time — nothing to issue
                continue
            self._remaining[p][dep_idx] -= 1
            if self._remaining[p][dep_idx] == 0:
                ready = max(self._op_end[p][d]
                            for d in self._trace.ops[dep_idx].deps)
                floor = self._inject_floor.pop(dep_idx, None)
                if floor is not None:
                    ready = max(ready, floor)
                self._issue(p, dep_idx, ready)

    # -- lifecycle: advance ----------------------------------------------
    @property
    def now(self) -> int:
        """Latest tick any pod queue has reached."""
        if self._trace is None:
            return 0
        return max(q.now for q in self._queues)

    def done(self) -> bool:
        return (self._trace is not None and self._ncomplete ==
                len(self._trace.ops) * self.machine.num_pods)

    def advance(self, max_tick: Optional[int] = None,
                stop_check: Optional[Callable[[], bool]] = None) -> bool:
        """Fire events until the run completes, no event at tick
        <= ``max_tick`` remains, or ``stop_check()`` returns True
        (checked at quantum boundaries under QuantumSync, per event
        otherwise).  Returns ``done()``; call again to resume."""
        if self._trace is None:
            raise RuntimeError("advance() before begin()/restore()")
        self.timing.advance(self, max_tick, stop_check)
        return self.done()

    def _advance_nosync(self, max_tick: Optional[int],
                        stop_check: Optional[Callable[[], bool]]) -> None:
        """Globally tick-ordered merge over the pod queues (without a
        quantum model the queues are one logical timeline; cross-pod
        dcn deliveries land at their exact tick).  Ties break on pod
        index — deterministic."""
        queues = self._queues
        while True:
            if stop_check is not None and stop_check():
                return
            best_q = None
            best_nt = None
            for q in queues:
                nt = q.next_tick()
                if nt is None:
                    continue
                if best_nt is None or nt < best_nt:
                    best_nt, best_q = nt, q
            if best_q is None:
                return
            if max_tick is not None and best_nt > max_tick:
                return
            best_q.step()

    # -- lifecycle: drain / snapshot / restore ----------------------------
    def drain(self) -> bool:
        """gem5-style drain: suppress new issues, run until no in-flight
        event or cross-queue message remains.  After drain() the run is
        quiescent — ``snapshot()`` can serialize it.  A drained executor
        does not resume in place: rebuild with ``restore`` (the drain
        may have advanced pods far past the deferred frontier's ready
        ticks, and only a rebuild replays the frontier at its true
        ticks).  Returns ``done()``."""
        dbg.dprintf("Ckpt", "executor", "drain begin", tick=self.now)
        self._draining = True
        done = self.advance()
        dbg.dprintf("Ckpt", "executor", "drain complete deferred=%d",
                    len(self._deferred), tick=self.now)
        return done

    def drained(self) -> bool:
        return (self._trace is not None and self._draining
                and all(q.empty() for q in self._queues)
                and self.timing.quiescent(self)
                and (self._sync is None
                     or self._sync.pending_messages == 0))

    def snapshot(self) -> Dict[str, Any]:
        """Serializable (plain JSON-able dict) state of a drained run.
        See ``repro.sim.serialize`` for the versioned on-disk format."""
        if not self.drained():
            raise RuntimeError("snapshot() requires drain() first "
                               "(gem5: drain-then-serialize)")
        wires = []
        for w in self._wires:
            wires.append([[x, y, d, l.busy_until, l.bytes_carried,
                           l.transfers]
                          for (x, y, d), l in sorted(w._net.links.items())])
        rendezvous = self.timing.rendezvous_state(self)
        return {
            "tick": self.now,
            "timing": self.timing.name,
            "pod_dims": [self.machine.pod.nx, self.machine.pod.ny],
            "queues": [q.snapshot() for q in self._queues],
            "op_end": [list(row) for row in self._op_end],
            "deferred": [list(t) for t in self._deferred],
            "injected": sorted([idx, pod]
                               for idx, pod in self._injected.items()),
            "inject_floor": sorted([idx, f] for idx, f
                                   in self._inject_floor.items()),
            "rendezvous": rendezvous,
            "chip_free": [c.free_tick for c in self._chips],
            "wires": wires,
            "wire_busy": [w.busy_tick() for w in self._wires],
            "dcn_uplinks": [[l.busy_until, l.bytes_carried, l.transfers]
                            for l in self._dcn.uplinks],
            "stats": self.sim_root.stats.state_dict(),
            "totals": dict(self._totals),
            "timeline": list(self._timeline),
        }

    def restore(self, trace: HloTrace,
                state: Dict[str, Any]) -> "TraceExecutor":
        """Rebuild a drained run from ``snapshot()`` state and resume.

        The machine this executor wraps may be *re-parameterized*
        relative to the one the snapshot was taken on (the gem5 DSE
        trick: checkpoint once, sweep hardware from the checkpoint) —
        pod count must match (the trace is per-pod state); torus link
        occupancy transfers only when the pod dimensions match too.
        On the *same* machine, a restored run's final tick and stats
        tree are identical to one that never paused: the deferred
        frontier is re-scheduled at its exact ready ticks on fresh
        queues, so event order replays deterministically.

        The executor's ``timing`` model may also differ from the one
        the snapshot was taken under — the gem5 ``switch_cpus`` move:
        atomic fast-forward to a checkpoint, restore under detailed
        for the region of interest (``Simulator.switch_timing`` wraps
        this).  Switching detailed→atomic discards link-occupancy
        state (atomic does not model it).
        """
        pods = self.machine.num_pods
        if pods != len(state["op_end"]):
            raise ValueError(
                f"cannot restore a {len(state['op_end'])}-pod snapshot "
                f"onto a {pods}-pod machine (re-parameterize speeds, "
                "not the pod count)")
        self._setup(trace)
        nops = len(trace.ops)
        self._injected = {int(idx): int(p)
                          for idx, p in state.get("injected", [])}
        self._inject_floor = {int(idx): int(f)
                              for idx, f in state.get("inject_floor", [])}
        self._op_end = [[int(e) for e in row] for row in state["op_end"]]
        self._ncomplete = sum(1 for row in self._op_end
                              for e in row if e >= 0)
        for p in range(pods):
            for idx, op in enumerate(trace.ops):
                self._remaining[p][idx] = sum(
                    1 for d in op.deps if self._op_end[p][d] < 0)
        self._totals = {k: float(v) for k, v in state["totals"].items()}
        self._timeline = list(state.get("timeline", []))
        # carry the event accounting across the checkpoint: a restored
        # run's ExecResult.events then counts pre-pause + post-restore
        # firings (plus one re-issue event per deferred frontier op —
        # the only events a never-paused run does not have)
        for q, qsnap in zip(self._queues, state.get("queues", [])):
            q.events_fired = int(qsnap["events_fired"])
        self.sim_root.stats.load_state_dict(state["stats"])
        for p, free in enumerate(state["chip_free"]):
            self._chips[p]._free = int(free)
        same_dims = (list(state.get("pod_dims", [])) ==
                     [self.machine.pod.nx, self.machine.pod.ny])
        if same_dims and self.timing.detailed:
            for p, rows in enumerate(state["wires"]):
                net = self._wires[p]._net
                for x, y, d, busy, nbytes, transfers in rows:
                    link = net._link(int(x), int(y), d)
                    link.busy_until = busy
                    link.bytes_carried = nbytes
                    link.transfers = int(transfers)
        # wire-occupancy high-water mark: keeps per_chip_busy_s honest
        # across restores that cannot carry link state (atomic runs,
        # cross-model switches, re-dimensioned pods)
        for p, busy in enumerate(state.get("wire_busy", [])):
            if p < len(self._wires):
                self._wires[p]._busy_hwm = int(busy)
        for i, (busy, nbytes, transfers) in enumerate(state["dcn_uplinks"]):
            if i < len(self._dcn.uplinks):
                link = self._dcn.uplinks[i]
                link.busy_until = busy
                link.bytes_carried = nbytes
                link.transfers = int(transfers)
        # partial cross-pod rendezvous: re-arrive the pods that had
        # already reached the fabric (the transaction completes when
        # the remaining pods arrive)
        for r in state["rendezvous"]:
            idx = int(r["op_idx"])
            for p, ready in r["arrivals"]:
                self.timing.restore_arrival(self, int(p), idx, int(ready))
        # the deferred frontier replays at its exact ready ticks:
        # arbitration order interleaves with post-restore completions
        # exactly as in an uninterrupted run
        for p, idx, ready in state["deferred"]:
            self.timing.restore_issue(self, int(p), int(idx), int(ready))
        dbg.dprintf("Ckpt", "executor",
                    "restored deferred=%d rendezvous=%d timing=%s",
                    len(state["deferred"]), len(state["rendezvous"]),
                    self.timing.name, tick=int(state["tick"]))
        return self

    # -- lifecycle: result -------------------------------------------------
    def result(self) -> ExecResult:
        trace = self._trace
        if trace is None:
            raise RuntimeError("result() before begin()")
        pods = self.machine.num_pods
        nops = len(trace.ops)
        if not self.done():
            incomplete = [idx for idx in range(nops)
                          if any(self._op_end[p][idx] < 0
                                 for p in range(pods))]
            raise RuntimeError(
                f"trace deadlock: ops {incomplete[:5]} never completed "
                "(cyclic or dangling deps)")
        makespan_tick = max((max(ends) for ends in self._op_end),
                            default=0) if nops else 0
        per_pod_end = [max(self._chips[p].free_tick,
                           self._wires[p].busy_tick())
                       / TICKS_PER_S for p in range(pods)]
        return ExecResult(
            final_tick=makespan_tick,
            makespan_s=makespan_tick / TICKS_PER_S,
            compute_s=self._totals["compute"],
            collective_s=self._totals["coll"],
            exposed_collective_s=min(self._totals["exposed"],
                                     self._totals["coll"]),
            per_chip_busy_s=per_pod_end,
            events=sum(q.events_fired for q in self._queues),
            timeline=self._timeline,
            stats=(self.sim_root.stats.flat()
                   if self.record_stats else None),
        )

    # ------------------------------------------------------------------
    def execute(self, trace: HloTrace) -> ExecResult:
        self.begin(trace)
        self.advance()
        return self.result()


def predict_step_time(machine: ClusterModel, trace: HloTrace,
                      algorithm: str = "torus2d",
                      straggler_slowdowns: Optional[List[float]] = None
                      ) -> float:
    return TraceExecutor(machine, algorithm=algorithm,
                         straggler_slowdowns=straggler_slowdowns
                         ).execute(trace).makespan_s
