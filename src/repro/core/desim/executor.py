"""Event-driven trace replay on a machine model.

This is where the pillars meet (the gem5 'detailed CPU + Ruby + Garnet'
configuration): an elastic trace (trace.py) is replayed on a
parameterized cluster (machine.py) through pluggable collective
algorithms (collectives.py), driven by the deterministic event engine
(core/events.py), with dist-gem5 quantum synchronization between pods
(§2.17) and straggler injection (per-chip ``slowdown``).

Every run builds a SimObject tree (simnodes.py) — one :class:`ChipSim`
and :class:`WireSim` per pod plus one shared :class:`DcnSim`, wired
through ports — and replays the trace as events on per-pod
``EventQueue``s (1 tick = 1 ns).  There are no float resource clocks:
all arbitration happens in integer ticks on the queue.

Timing semantics per chip:

* ``compute`` ops serialize on the chip's compute resource at the
  roofline time ``max(flops/peak, bytes/hbm_bw) * slowdown``.
* intra-pod collectives occupy the concrete torus links of their
  ``region`` (default: the whole pod) on the pod's wire; collectives
  whose regions share a link serialize, disjoint regions run in
  parallel (the Garnet contention model, §2.13).  An ``overlap=True``
  collective occupies the wire but its time is not counted as exposed —
  this models async collectives / comm-compute overlap, the
  distributed-optimization trick the train step is structured around.
* cross-pod (dcn) collectives rendezvous on the shared fabric and
  complete at a quantum boundary delivered through ``QuantumSync``,
  reproducing dist-gem5's quantum-based synchronization error model.

Pass ``record_stats=True`` to get the gem5-style statistics tree of the
run in ``ExecResult.stats`` (flat ``sim.chip0.ops_executed`` keys; the
full tree object is on ``TraceExecutor.sim_root`` after ``execute``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.desim.collectives import get_algorithm
from repro.core.desim.machine import ClusterModel
from repro.core.desim.simnodes import (ChipSim, ClusterSim, DcnSim,
                                       TICKS_PER_S, WireSim)
from repro.core.desim.trace import HloTrace
from repro.core.events import EventQueue, QuantumSync


@dataclass
class ExecResult:
    makespan_s: float
    compute_s: float
    collective_s: float
    exposed_collective_s: float     # collective time NOT hidden by overlap
    per_chip_busy_s: List[float]
    events: int                     # == engine events_fired (all queues)
    timeline: List[Dict] = field(default_factory=list)
    stats: Optional[Dict[str, Any]] = None   # flat gem5-style stats dump

    def summary(self) -> Dict[str, float]:
        return {
            "makespan_s": self.makespan_s,
            "compute_s": self.compute_s,
            "collective_s": self.collective_s,
            "exposed_collective_s": self.exposed_collective_s,
            "overlap_efficiency": (
                1.0 - self.exposed_collective_s / self.collective_s
                if self.collective_s > 0 else 1.0),
        }


class TraceExecutor:
    """Replays an HloTrace on a ClusterModel.

    The model is SPMD: every chip executes the same trace (that is what
    a pjit program is), so we simulate one *representative chip per pod*
    plus shared wire resources, with stragglers making pods
    heterogeneous.  This keeps the DES cost O(ops x pods), which is what
    lets DSE sweeps run thousands of variants (the gem5 use case).

    ``contention=False`` disables link/uplink serialization (every
    transfer sees an idle wire) — the contention-free baseline for
    measuring how much of a makespan is queueing.
    """

    def __init__(self, machine: ClusterModel, algorithm: str = "torus2d",
                 record_timeline: bool = False,
                 straggler_slowdowns: Optional[List[float]] = None,
                 record_stats: bool = False, contention: bool = True):
        self.machine = machine
        self.alg = get_algorithm(algorithm)
        self.dcn_alg = get_algorithm("hierarchical")
        self.record_timeline = record_timeline
        self.record_stats = record_stats
        self.contention = contention
        pods = machine.num_pods
        self.slow = (straggler_slowdowns or [1.0] * pods)[:pods]
        while len(self.slow) < pods:
            self.slow.append(1.0)
        self.sim_root: Optional[ClusterSim] = None

    # ------------------------------------------------------------------
    def _build(self, queues: List[EventQueue],
               sync: Optional[QuantumSync]) -> ClusterSim:
        """Assemble and wire the per-run SimObject tree."""
        m = self.machine
        root = ClusterSim("sim", num_pods=m.num_pods,
                          quantum_ns=m.quantum_ns)
        dcn = DcnSim("dcn", m, self.dcn_alg, queues, sync,
                     num_pods=m.num_pods, contention=self.contention)
        root.dcn = dcn
        chips: List[ChipSim] = []
        wires: List[WireSim] = []
        for p in range(m.num_pods):
            chip = ChipSim(f"chip{p}", m.pod.chip, queues[p],
                           pod_id=p, slowdown=self.slow[p])
            wire = WireSim(f"wire{p}", m, self.alg, queues[p],
                           pod_id=p, contention=self.contention)
            chip.coll_port.connect(wire.chip_port)
            wire.dcn_port.connect(dcn.pod_ports[p])
            setattr(root, f"chip{p}", chip)
            setattr(root, f"wire{p}", wire)
            chips.append(chip)
            wires.append(wire)
        root.instantiate()
        self._chips, self._wires, self._dcn = chips, wires, dcn
        return root

    def _routes_dcn(self, op) -> bool:
        chips_per_pod = self.machine.pod.num_chips
        participants = op.participants or chips_per_pod
        return op.kind != "compute" and (op.scope == "dcn"
                                         or participants > chips_per_pod)

    # ------------------------------------------------------------------
    def execute(self, trace: HloTrace) -> ExecResult:
        m = self.machine
        pods = m.num_pods
        chips_per_pod = m.pod.num_chips
        nops = len(trace.ops)

        queues = [EventQueue(f"pod{p}") for p in range(pods)]
        needs_dcn = any(self._routes_dcn(op) for op in trace.ops)
        # quantum_ns == 0 means "no quantum error model": dcn ops then
        # complete at their exact tick instead of a sync boundary
        sync = (QuantumSync(queues, m.quantum_ns)
                if needs_dcn and m.quantum_ns > 0 else None)
        root = self._build(queues, sync)
        self.sim_root = root
        chips, wires = self._chips, self._wires

        # dependency bookkeeping (per pod: SPMD replicas diverge only
        # through stragglers and the shared dcn fabric)
        dependents: List[List[int]] = [[] for _ in range(nops)]
        for idx, op in enumerate(trace.ops):
            for d in op.deps:
                dependents[d].append(idx)
        remaining = [[len(op.deps) for op in trace.ops]
                     for _ in range(pods)]
        op_end: List[List[int]] = [[-1] * nops for _ in range(pods)]

        totals = {"compute": 0.0, "coll": 0.0, "exposed": 0.0}
        timeline: List[Dict] = []

        def on_done(start: int, end: int, payload: dict) -> None:
            p, idx = payload["pod"], payload["op_idx"]
            op = trace.ops[idx]
            op_end[p][idx] = end
            if p == 0:
                dur = payload.get("dur")
                dur_s = (dur if dur is not None else end - start) \
                    / TICKS_PER_S
                if op.kind == "compute":
                    totals["compute"] += dur_s
                else:
                    totals["coll"] += dur_s
                    if not op.overlap:
                        # exposed = time the compute resource sat idle
                        # waiting for this collective
                        idle_from = max(chips[p].free_tick,
                                        payload["ready"])
                        totals["exposed"] += max(0, end - idle_from) \
                            / TICKS_PER_S
                if self.record_timeline:
                    timeline.append({"op": op.name or op.kind,
                                     "kind": op.kind,
                                     "start": start / TICKS_PER_S,
                                     "end": end / TICKS_PER_S})
            for dep_idx in dependents[idx]:
                remaining[p][dep_idx] -= 1
                if remaining[p][dep_idx] == 0:
                    ready = max(op_end[p][d]
                                for d in trace.ops[dep_idx].deps)
                    issue(p, dep_idx, ready)

        def issue(p: int, idx: int, ready: int) -> None:
            op = trace.ops[idx]
            payload = {"pod": p, "op_idx": idx, "ready": ready,
                       "name": op.name or op.kind, "done": on_done}
            if op.kind == "compute":
                # service time is end - start (wait precedes start)
                chips[p].exec_compute(ready, op.flops, op.bytes, payload)
            else:
                payload.update(kind=op.kind, nbytes=op.coll_bytes,
                               participants=(op.participants
                                             or chips_per_pod),
                               region=op.region,
                               dcn=self._routes_dcn(op))
                chips[p].issue_collective(payload)

        # roots of the DAG start at tick 0, in trace order per pod
        for p in range(pods):
            for idx, op in enumerate(trace.ops):
                if not op.deps:
                    issue(p, idx, 0)

        if sync is not None:
            sync.run_until_drained()
        else:
            # without a quantum sync, queues are independent except for
            # exact-time dcn deliveries, which may land in a queue that
            # already drained — iterate until globally quiescent
            progressed = True
            while progressed:
                progressed = False
                for q in queues:
                    if not q.empty():
                        q.run()
                        progressed = True

        incomplete = [idx for idx in range(nops)
                      if any(op_end[p][idx] < 0 for p in range(pods))]
        if incomplete:
            raise RuntimeError(
                f"trace deadlock: ops {incomplete[:5]} never completed "
                "(cyclic or dangling deps)")

        makespan_tick = max((max(ends) for ends in op_end), default=0) \
            if nops else 0
        per_pod_end = [max(chips[p].free_tick, wires[p].busy_tick())
                       / TICKS_PER_S for p in range(pods)]

        return ExecResult(
            makespan_s=makespan_tick / TICKS_PER_S,
            compute_s=totals["compute"],
            collective_s=totals["coll"],
            exposed_collective_s=min(totals["exposed"], totals["coll"]),
            per_chip_busy_s=per_pod_end,
            events=sum(q.events_fired for q in queues),
            timeline=timeline,
            stats=(root.stats.flat() if self.record_stats else None),
        )


def predict_step_time(machine: ClusterModel, trace: HloTrace,
                      algorithm: str = "torus2d",
                      straggler_slowdowns: Optional[List[float]] = None
                      ) -> float:
    return TraceExecutor(machine, algorithm=algorithm,
                         straggler_slowdowns=straggler_slowdowns
                         ).execute(trace).makespan_s
