"""Event-driven trace replay on a machine model.

This is where the pillars meet (the gem5 'detailed CPU + Ruby + Garnet'
configuration): an elastic trace (trace.py) is replayed on a
parameterized cluster (machine.py) through pluggable collective
algorithms (collectives.py), driven by the deterministic event engine
(core/events.py), with dist-gem5 quantum synchronization between pods
(§2.17) and straggler injection (per-chip ``slowdown``).

Timing semantics per chip:

* ``compute`` ops serialize on the chip's compute resource at the
  roofline time ``max(flops/peak, bytes/hbm_bw) * slowdown``.
* collectives serialize on the wire resource of their scope (ici/dcn);
  an ``overlap=True`` collective occupies the wire but does NOT block
  the next compute op unless a later op depends on it — this models
  async collectives / comm-compute overlap, the distributed-optimization
  trick the train step is structured around.
* cross-pod (dcn) collectives only complete at a quantum boundary,
  reproducing dist-gem5's quantum-based synchronization error model.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.desim.collectives import get_algorithm
from repro.core.desim.machine import ClusterModel
from repro.core.desim.trace import HloTrace, TraceOp

TICKS_PER_S = 1_000_000_000  # 1 tick = 1 ns


@dataclass
class ExecResult:
    makespan_s: float
    compute_s: float
    collective_s: float
    exposed_collective_s: float     # collective time NOT hidden by overlap
    per_chip_busy_s: List[float]
    events: int
    timeline: List[Dict] = field(default_factory=list)

    def summary(self) -> Dict[str, float]:
        return {
            "makespan_s": self.makespan_s,
            "compute_s": self.compute_s,
            "collective_s": self.collective_s,
            "exposed_collective_s": self.exposed_collective_s,
            "overlap_efficiency": (
                1.0 - self.exposed_collective_s / self.collective_s
                if self.collective_s > 0 else 1.0),
        }


class TraceExecutor:
    """Replays an HloTrace on a ClusterModel.

    The model is SPMD: every chip executes the same trace (that is what
    a pjit program is), so we simulate one *representative chip per pod*
    plus shared wire resources, with stragglers making pods
    heterogeneous.  This keeps the DES cost O(ops x pods), which is what
    lets DSE sweeps run thousands of variants (the gem5 use case).
    """

    def __init__(self, machine: ClusterModel, algorithm: str = "torus2d",
                 record_timeline: bool = False,
                 straggler_slowdowns: Optional[List[float]] = None):
        self.machine = machine
        self.alg = get_algorithm(algorithm)
        self.dcn_alg = get_algorithm("hierarchical")
        self.record_timeline = record_timeline
        pods = machine.num_pods
        self.slow = (straggler_slowdowns or [1.0] * pods)[:pods]
        while len(self.slow) < pods:
            self.slow.append(1.0)

    # ------------------------------------------------------------------
    def execute(self, trace: HloTrace) -> ExecResult:
        m = self.machine
        pods = m.num_pods
        chips_per_pod = m.pod.num_chips
        quantum_s = m.quantum_ns / TICKS_PER_S

        # per-pod resource clocks (ns are overkill here; float seconds
        # with deterministic op order gives the same result as the tick
        # engine for a linear trace — the tick engine is used by the
        # network-level simulation and QuantumSync tests)
        compute_free = [0.0] * pods
        wire_free = [0.0] * pods          # ici wire per pod
        dcn_free = 0.0                    # shared dcn fabric
        op_done: List[List[float]] = [[0.0] * len(trace.ops)
                                      for _ in range(pods)]

        compute_total = 0.0
        coll_total = 0.0
        exposed_total = 0.0
        timeline: List[Dict] = []
        events = 0

        for idx, op in enumerate(trace.ops):
            for pod in range(pods):
                dep_ready = max((op_done[pod][d] for d in op.deps),
                                default=0.0)
                if op.kind == "compute":
                    dur = m.pod.chip.compute_time_s(op.flops, op.bytes)
                    dur *= self.slow[pod]
                    start = max(dep_ready, compute_free[pod])
                    end = start + dur
                    compute_free[pod] = end
                    if pod == 0:
                        compute_total += dur
                else:
                    participants = op.participants or chips_per_pod
                    if op.scope == "dcn" or participants > chips_per_pod:
                        dur = self.dcn_alg.time_s(
                            op.kind, op.coll_bytes, participants, m)
                        start = max(dep_ready, dcn_free)
                        end = start + dur
                        # dist-gem5 quantum rounding on cross-pod traffic
                        if quantum_s > 0:
                            q = quantum_s
                            end = ((end + q - 1e-18) // q) * q
                        dcn_free = end
                    else:
                        dur = self.alg.time_s(
                            op.kind, op.coll_bytes, participants, m)
                        start = max(dep_ready, wire_free[pod])
                        end = start + dur
                        wire_free[pod] = end
                    if pod == 0:
                        coll_total += dur
                        # exposed = time the compute resource sat idle
                        # waiting for this collective
                        if not op.overlap:
                            exposed_total += max(0.0, end - max(
                                compute_free[pod], dep_ready))
                op_done[pod][idx] = end
                events += 1
                if self.record_timeline and pod == 0:
                    timeline.append({"op": op.name or op.kind,
                                     "kind": op.kind, "start": start,
                                     "end": end})

        # cross-pod barrier at step end (gradient sync / pjit semantics):
        # the step completes when the slowest pod completes.
        per_pod_end = [max(compute_free[p], wire_free[p]) for p in range(pods)]
        makespan = max(max(per_pod_end), dcn_free)

        return ExecResult(
            makespan_s=makespan,
            compute_s=compute_total,
            collective_s=coll_total,
            exposed_collective_s=min(exposed_total, coll_total),
            per_chip_busy_s=per_pod_end,
            events=events,
            timeline=timeline,
        )


def predict_step_time(machine: ClusterModel, trace: HloTrace,
                      algorithm: str = "torus2d",
                      straggler_slowdowns: Optional[List[float]] = None
                      ) -> float:
    return TraceExecutor(machine, algorithm=algorithm,
                         straggler_slowdowns=straggler_slowdowns
                         ).execute(trace).makespan_s
