"""Elastic execution traces parsed from compiled HLO (gem5-20 §2.8).

gem5's elastic traces capture *dependency-carrying* instruction traces
from the detailed O3 model once, then replay them under different
memory-system parameters without re-running the expensive model.  The
g5x analogue: parse the **compiled** HLO of a jitted step once, extract
the op-level structure (compute regions, collectives with byte counts,
dependencies), and replay that trace on any parameterized machine model
(`repro.core.desim.machine`) without recompiling — change HBM bandwidth,
ICI speed, or the collective algorithm and re-run the trace in
milliseconds.  The "elastic" property is identical: the trace respects
true dependencies (program order per partition + collective barriers)
while timing comes from the machine model under test.

This module is also the §Roofline data source: ``collective_bytes_from_hlo``
sums operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute in the lowered module text (the
assignment's prescribed method — these bytes are *not* in
``cost_analysis()``).
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field, asdict
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.desim.dtypes import shape_bytes  # noqa: F401 (re-export)

# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

# an HLO instruction line:  ``  %name = <ret-type(s)> opcode(...), attrs``
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
    r"([a-z][a-z0-9\-]*)\((.*)$")

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# async forms: all-gather-start, all-reduce-start, collective-permute-start...
_COLLECTIVE_PREFIXES = tuple(COLLECTIVE_OPS)


def _base_collective(opcode: str) -> Optional[str]:
    """Map e.g. ``all-reduce-start`` -> ``all-reduce`` (None if not coll)."""
    for base in _COLLECTIVE_PREFIXES:
        if opcode == base or opcode == base + "-start":
            return base
    return None


@dataclass
class HloInstr:
    name: str
    opcode: str
    out_bytes: float
    operand_bytes: float
    replica_groups: int = 0          # participants per group (0 = unknown)
    raw: str = ""


def parse_hlo_instructions(hlo_text: str) -> List[HloInstr]:
    """Parse instruction lines of an HLO module dump (text format)."""
    out: List[HloInstr] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rettype, opcode, rest = m.groups()
        # operand types appear inside the call parens; parse shapes from
        # the portion before any attribute list.  HLO operands are
        # ``%op`` references without inline types in the compiled dump,
        # so operand bytes must be resolved via the def table below.
        out.append(HloInstr(name=name, opcode=opcode,
                            out_bytes=shape_bytes(rettype),
                            operand_bytes=0.0, raw=line))
    # resolve operand byte counts from the definition table
    defs: Dict[str, HloInstr] = {i.name: i for i in out}
    ref_re = re.compile(r"%([\w.\-]+)")
    for instr in out:
        # references after the opcode's open paren
        call = instr.raw.split(instr.opcode + "(", 1)
        if len(call) != 2:
            continue
        body = call[1]
        # cut off attributes that follow the closing paren of the call
        depth, end = 1, len(body)
        for i, ch in enumerate(body):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        for ref in ref_re.findall(body[:end]):
            d = defs.get(ref)
            if d is not None:
                instr.operand_bytes += d.out_bytes
        # replica group size: count ids in the first {..} group of
        # replica_groups={{0,1,..},{..}} or replica_groups=[N,M]<=...
        rg = re.search(r"replica_groups=\{\{([0-9, ]+)\}", instr.raw)
        if rg:
            instr.replica_groups = len(rg.group(1).split(","))
        else:
            rg2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.raw)
            if rg2:
                instr.replica_groups = int(rg2.group(2))
    return out


def collectives_from_hlo(hlo_text: str) -> List[Dict]:
    """Every collective op with kind, operand bytes, and participants."""
    colls: List[Dict] = []
    for instr in parse_hlo_instructions(hlo_text):
        base = _base_collective(instr.opcode)
        if base is None:
            continue
        nbytes = instr.operand_bytes
        if nbytes <= 0:      # fall back to output size (e.g. all-gather-start
            nbytes = instr.out_bytes   # tuples hide operand refs)
        colls.append({"kind": base, "bytes": nbytes,
                      "participants": instr.replica_groups or 0,
                      "name": instr.name})
    return colls


def collective_bytes_from_hlo(hlo_text: str) -> float:
    """Sum of operand bytes over all collective ops (§Roofline source)."""
    return float(sum(c["bytes"] for c in collectives_from_hlo(hlo_text)))


def collective_schedule_summary(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-kind count/bytes summary, for EXPERIMENTS.md §Dry-run."""
    summary: Dict[str, Dict[str, float]] = {}
    for c in collectives_from_hlo(hlo_text):
        s = summary.setdefault(c["kind"], {"count": 0, "bytes": 0.0})
        s["count"] += 1
        s["bytes"] += c["bytes"]
    return summary


# ---------------------------------------------------------------------------
# Elastic trace
# ---------------------------------------------------------------------------

@dataclass
class TraceOp:
    """One node of the elastic trace.

    kind      : 'compute' | one of COLLECTIVE_OPS
    flops     : FLOPs of a compute region (per participating chip)
    bytes     : HBM bytes touched by a compute region (per chip)
    coll_bytes: global payload bytes of a collective
    participants : chips taking part in the collective
    deps      : indices of TraceOps that must complete first
    overlap   : collective may overlap the *next* compute region
                (models async collectives / comm-compute overlap)
    scope     : 'ici' (intra-pod) or 'dcn' (inter-pod) for collectives
    region    : optional (x0, y0, w, h) sub-grid of the torus the
                collective's ring occupies.  None = the whole pod (every
                collective contends for the same links, the conservative
                default).  Disjoint regions can proceed in parallel;
                overlapping regions serialize on the shared links.
    """

    kind: str
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    participants: int = 1
    deps: Tuple[int, ...] = ()
    overlap: bool = False
    scope: str = "ici"
    name: str = ""
    region: Optional[Tuple[int, int, int, int]] = None


@dataclass
class HloTrace:
    """A dependency-carrying, machine-independent trace of one step."""

    name: str
    ops: List[TraceOp] = field(default_factory=list)
    meta: Dict[str, float] = field(default_factory=dict)

    # -- constructors --------------------------------------------------
    @classmethod
    def from_hlo_text(cls, hlo_text: str, name: str = "step",
                      total_flops: float = 0.0,
                      total_bytes: float = 0.0) -> "HloTrace":
        """Build a trace from compiled HLO text.

        Compute regions between consecutive collectives become single
        ``compute`` ops.  Because ``cost_analysis`` only reports module
        totals, per-region flops/bytes are apportioned by the region's
        share of non-collective output bytes — the same granularity
        trade-off gem5's elastic traces make (they record memory-order
        dependencies, not per-uop microarchitecture state).
        """
        instrs = parse_hlo_instructions(hlo_text)
        # region split
        regions: List[List[HloInstr]] = [[]]
        colls: List[Optional[HloInstr]] = []
        for instr in instrs:
            if _base_collective(instr.opcode):
                colls.append(instr)
                regions.append([])
            else:
                regions[-1].append(instr)
        region_w = [sum(i.out_bytes for i in r) for r in regions]
        wsum = sum(region_w) or 1.0

        trace = cls(name=name,
                    meta={"total_flops": total_flops,
                          "total_bytes": total_bytes})
        prev = -1
        for ridx, region in enumerate(regions):
            share = region_w[ridx] / wsum
            cop = TraceOp(kind="compute", flops=total_flops * share,
                          bytes=total_bytes * share,
                          deps=(prev,) if prev >= 0 else (),
                          name=f"region{ridx}")
            trace.ops.append(cop)
            prev = len(trace.ops) - 1
            if ridx < len(colls):
                ci = colls[ridx]
                base = _base_collective(ci.opcode) or "all-reduce"
                nbytes = ci.operand_bytes or ci.out_bytes
                trace.ops.append(TraceOp(
                    kind=base, coll_bytes=nbytes,
                    participants=ci.replica_groups or 0,
                    deps=(prev,), overlap=ci.opcode.endswith("-start"),
                    name=ci.name))
                prev = len(trace.ops) - 1
        return trace

    # -- persistence -----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({"name": self.name, "meta": self.meta,
                           "ops": [asdict(o) for o in self.ops]})

    @classmethod
    def from_json(cls, s: str) -> "HloTrace":
        d = json.loads(s)
        ops = [TraceOp(**{**o, "deps": tuple(o["deps"]),
                          "region": (tuple(o["region"])
                                     if o.get("region") else None)})
               for o in d["ops"]]
        return cls(name=d["name"], ops=ops, meta=d.get("meta", {}))

    # -- stats -------------------------------------------------------------
    def collective_bytes(self) -> float:
        return sum(o.coll_bytes for o in self.ops if o.kind != "compute")

    def compute_flops(self) -> float:
        return sum(o.flops for o in self.ops)


def analytic_trace(name: str, layers: int, layer_flops: float,
                   layer_bytes: float, layer_collectives: Iterable[Dict],
                   tail_collectives: Iterable[Dict] = (),
                   overlap: bool = False) -> HloTrace:
    """Build a trace from a *model-level* cost description.

    This is the gem5 'parameterized model' path: when we know the math
    of a layer (flops, bytes, the collectives its sharding implies) we
    can synthesize the trace directly — useful for DSE sweeps over
    configs that were never compiled (and for testing the executor).
    ``layer_collectives``/``tail_collectives``: dicts with keys
    kind/bytes/participants/scope.
    """
    t = HloTrace(name=name)
    prev = -1
    for l in range(layers):
        t.ops.append(TraceOp(kind="compute", flops=layer_flops,
                             bytes=layer_bytes,
                             deps=(prev,) if prev >= 0 else (),
                             name=f"layer{l}"))
        prev = len(t.ops) - 1
        for c in layer_collectives:
            region = c.get("region")
            t.ops.append(TraceOp(kind=c["kind"], coll_bytes=c["bytes"],
                                 participants=c.get("participants", 0),
                                 scope=c.get("scope", "ici"),
                                 region=tuple(region) if region else None,
                                 deps=(prev,), overlap=overlap,
                                 name=f"layer{l}/{c['kind']}"))
            prev = len(t.ops) - 1
    for c in tail_collectives:
        region = c.get("region")
        t.ops.append(TraceOp(kind=c["kind"], coll_bytes=c["bytes"],
                             participants=c.get("participants", 0),
                             scope=c.get("scope", "dcn"),
                             region=tuple(region) if region else None,
                             deps=(prev,), overlap=overlap,
                             name=f"tail/{c['kind']}"))
        prev = len(t.ops) - 1
    return t
