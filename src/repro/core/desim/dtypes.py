"""The one HLO dtype-width table (shared by trace.py and hlo_cost.py).

Both HLO parsers — the elastic-trace extractor (``trace.py``) and the
loop-aware cost model (``hlo_cost.py``) — size tensors from the textual
HLO type syntax (``bf16[256,4096]{1,0}``, ``f32[]``, tuples).  They must
agree byte-for-byte or the roofline and the DES would drift apart, so
the dtype table and the shape lexer live here exactly once.

Widths are *bytes per element* and may be fractional: ``s4``/``u4`` are
half a byte (two elements per byte, how XLA packs int4), and zero-width
types (``token``, ``opaque``) carry no payload.  Unknown dtypes are
skipped by the helpers (conservative: contribute 0 bytes) — the same
behaviour both parsers always had.
"""

from __future__ import annotations

import re
from typing import Iterator, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

# one tensor type, e.g. ``bf16[256,4096]{1,0}`` or ``f32[]``; matches
# every element of a tuple type ``(f32[2,3], s4[8])`` one by one
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def iter_shapes(type_str: str) -> Iterator[Tuple[float, float]]:
    """Yield ``(elements, bytes)`` per tensor in an HLO type string.

    Tensors of unknown dtype are skipped entirely (not yielded), so both
    element and byte totals stay consistent between callers that sum
    elements and callers that sum bytes.
    """
    for m in SHAPE_RE.finditer(type_str):
        dtype, dims = m.groups()
        per = DTYPE_BYTES.get(dtype)
        if per is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        yield float(n), n * per


def shape_bytes(type_str: str) -> float:
    """Total bytes over all tensors in an HLO type string."""
    return sum(b for _, b in iter_shapes(type_str))


def shape_elems_bytes(type_str: str) -> Tuple[float, float]:
    """(elements, bytes) totals over all tensors in an HLO type string."""
    elems = 0.0
    nbytes = 0.0
    for e, b in iter_shapes(type_str):
        elems += e
        nbytes += b
    return elems, nbytes
