"""dist-gem5-style multiprocess pod sharding (gem5-20 paper §2.17, §4).

The paper credits dist-gem5 — partitioning the simulated system across
parallel gem5 processes that exchange network traffic only at
synchronization quanta — as what makes cluster-scale simulation
practical.  Our engine has the exact decomposition dist-gem5 needs (one
``EventQueue`` per pod, all cross-pod traffic batched onto quantum
boundaries by ``QuantumSync``, a drain/serialize cut with no in-flight
messages), so :class:`ParallelEngine` shards the machine's pods across
N worker processes:

* Each **worker** owns a contiguous pod range and runs a real
  :class:`TraceExecutor` over a shard-sized copy of the machine
  (``pod_labels`` keeps the global pod identities).  Between quantum
  barriers the worker advances its local queues with zero coordination.
* The **coordinator** (this process) owns the one true DCN fabric: it
  mirrors ``QuantumSync.run_until_drained``'s boundary arithmetic
  bit-for-bit (the shared helpers in ``repro.core.events``), collects
  cross-pod arrivals that workers capture via the ``DcnSim`` capture
  hook, replays the rendezvous/uplink/stat updates in the serial
  engine's canonical order, and broadcasts completion deliveries back —
  pipes carry only rendezvous metadata, never simulation objects.
* **Batched barriers + lookahead elision** (dist-gem5's quantum
  batching, gem5-20 §4): one message per worker per *grant* carries all
  of the shard's arrivals (one row per clone class, expanded by the
  coordinator) plus per-queue next-event ticks, and the coordinator
  grants multi-quantum advances across rendezvous-free gaps — a queue
  free-runs until it either captures a new DCN arrival (it then stops
  on its own) or reaches the safe horizon protecting queues with
  undelivered completions (``rendezvous_horizon``).  Dense-quantum
  configs collapse from one barrier per quantum to ~two per DCN
  collective; ``ParallelEngine.sync_stats`` exports barrier/message
  counters so the win is observable and test-assertable.
* **SPMD clone folding**: within a shard, pods whose straggler slowdown
  (and, on restore, whole serialized per-pod state) are identical evolve
  identically — per-pod evolution is a pure function of (trace, machine,
  slowdown, dcn completion schedule), and the completion schedule is
  broadcast to every pod.  Each class is simulated once and its results
  replicated, so a homogeneous 16-pod board costs 16/N pod-simulations
  across N workers.  This is what delivers wall-clock speedup even on a
  single core; on multicore the processes additionally run concurrently.

Exactness (test-enforced, see docs/parallel.md): with detailed timing
and a positive quantum, final tick, full stats tree, checkpoint dicts
and decision logs are bit-identical to the serial engine.  The engine
falls back to the in-process serial path when sharding cannot be exact:
dynamic workloads (``inject_op`` feedback couples pods through the
host), dcn traffic under atomic timing or ``quantum_ns == 0`` (exact-
tick delivery needs the global tick-ordered merge), or fewer than 2
pods/workers.  The ``hierarchical`` collective algorithm shards too:
shard machines carry ``global_num_pods`` so its intra-pod RS/AG and
DCN-ring phases cost identically to the full machine.

Checkpoints are worker-count-agnostic: collection loads worker state
into a dormant serial facade executor and calls its ``snapshot()``
verbatim, so a ``workers=4`` checkpoint restores under ``workers=1``
and vice versa (the restore path slices the same serial format).
"""

from __future__ import annotations

import json
import multiprocessing as mp
import sys
import time
import traceback
import weakref
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import trace as dbg
from repro.core.desim.executor import ExecResult, TraceExecutor
from repro.core.desim.machine import ClusterModel
from repro.core.desim.simnodes import TICKS_PER_S, to_ticks
from repro.core.desim.trace import HloTrace
from repro.core.events import (quantum_boundary, quantum_delivery,
                               rendezvous_horizon)
from repro.core.stats import StatGroup

__all__ = ["ParallelEngine", "default_mp_context", "plan_shards",
           "fold_pods", "PARALLEL_PROTOCOL"]

#: wire-protocol version of the coordinator<->worker pipe messages and
#: of the parallel checkpoint layout.  v1: one barrier per quantum, one
#: arrival row per member pod.  v2: batched grants with lookahead
#: elision, one arrival row per clone class.  Embedded in checkpoint
#: documents (``repro.sim.serialize``) for forensics — checkpoints
#: themselves stay serial-format and protocol-agnostic.
PARALLEL_PROTOCOL = 2


def default_mp_context() -> str:
    """Start method for simulation worker processes.

    fork is cheap (~ms/worker) and preferred where available — but
    fork()ing a process whose JAX runtime is initialized deadlocks its
    multithreaded backend (CPython warns ``os.fork() was called ...
    likely lead to a deadlock``), and any benchmark or test that
    imported a kernel module has JAX loaded.  Spawn is fully supported
    here (init payloads are plain data, worker entry points are
    module-level), so pick it automatically whenever ``jax`` is in
    ``sys.modules``; an explicit ``mp_context=`` always wins.
    """
    if "jax" in sys.modules:
        return "spawn"
    return ("fork" if "fork" in mp.get_all_start_methods()
            else "spawn")


# ---------------------------------------------------------------------------
# shard planning / clone folding
# ---------------------------------------------------------------------------

def plan_shards(num_pods: int, workers: int) -> List[List[int]]:
    """Contiguous, balanced pod ranges — one per worker (clamped to
    ``num_pods``: a worker needs at least one pod)."""
    workers = max(1, min(int(workers), int(num_pods)))
    base, extra = divmod(num_pods, workers)
    shards, lo = [], 0
    for w in range(workers):
        size = base + (1 if w < extra else 0)
        shards.append(list(range(lo, lo + size)))
        lo += size
    return shards


def fold_pods(shard: List[int], keys: Dict[int, Any]
              ) -> Tuple[List[int], List[List[int]]]:
    """Group a shard's pods into SPMD clone classes by fold key.

    Returns ``(reps, members)``: ``reps[i]`` is the representative
    (first) pod of class ``i`` — the one actually simulated — and
    ``members[i]`` the ascending global pod ids its results replicate
    to.  Pods with distinct keys (different slowdown, or different
    restored state) never fold."""
    reps: List[int] = []
    members: List[List[int]] = []
    index: Dict[Any, int] = {}
    for g in shard:
        k = keys[g]
        i = index.get(k)
        if i is None:
            index[k] = len(reps)
            reps.append(g)
            members.append([g])
        else:
            members[i].append(g)
    return reps, members


def _pod_state_key(state: Dict[str, Any], g: int) -> str:
    """Canonical fingerprint of pod ``g``'s slice of a serial snapshot —
    pods may fold on restore only when their entire state matches."""
    children = state.get("stats", {}).get("children", {})
    row = {
        "op_end": state["op_end"][g],
        "queue": state["queues"][g],
        "chip_free": state["chip_free"][g],
        "wires": state["wires"][g] if g < len(state.get("wires", [])) else [],
        "wire_busy": state.get("wire_busy", [0] * (g + 1))[g],
        "deferred": [[idx, r] for p, idx, r in state.get("deferred", [])
                     if p == g],
        "rendezvous": [[r["op_idx"], a[1]] for r in state.get("rendezvous", [])
                       for a in r["arrivals"] if a[0] == g],
        "chip_stats": children.get(f"chip{g}"),
        "wire_stats": children.get(f"wire{g}"),
    }
    return json.dumps(row, sort_keys=True)


def _slice_state(state: Dict[str, Any], reps: List[int],
                 owns0: bool) -> Dict[str, Any]:
    """Shard-shaped serial snapshot holding only the representative
    pods' rows (the worker restores it through the ordinary
    ``TraceExecutor.restore``).  Run-wide accumulators (totals,
    timeline) go to the worker owning global pod 0; the shared-fabric
    state (dcn uplinks, rendezvous metadata, dcn stats) stays with the
    coordinator."""
    local = {g: i for i, g in enumerate(reps)}
    children = state.get("stats", {}).get("children", {})
    out: Dict[str, Any] = {
        "tick": state["tick"],
        "timing": state["timing"],
        "pod_dims": list(state.get("pod_dims", [])),
        "queues": [dict(state["queues"][g]) for g in reps],
        "op_end": [list(state["op_end"][g]) for g in reps],
        "deferred": [[local[p], int(idx), int(r)]
                     for p, idx, r in state.get("deferred", [])
                     if p in local],
        "injected": [],
        "inject_floor": [],
        "rendezvous": [],
        "chip_free": [state["chip_free"][g] for g in reps],
        "wires": [state["wires"][g] for g in reps],
        "wire_busy": [int(state["wire_busy"][g]) for g in reps]
        if state.get("wire_busy") else [],
        "dcn_uplinks": [],
        "stats": {"stats": {},
                  "children": {f"{kind}{g}": children[f"{kind}{g}"]
                               for g in reps for kind in ("chip", "wire")
                               if f"{kind}{g}" in children}},
        "totals": (dict(state["totals"]) if owns0
                   else {"compute": 0.0, "coll": 0.0, "exposed": 0.0}),
        "timeline": list(state.get("timeline", [])) if owns0 else [],
    }
    for r in state.get("rendezvous", []):
        arr = [[local[p], int(rd)] for p, rd in r["arrivals"] if p in local]
        if arr:
            out["rendezvous"].append({"op_idx": r["op_idx"],
                                      "arrivals": arr})
    return out


# ---------------------------------------------------------------------------
# worker process
# ---------------------------------------------------------------------------

class _WorkerRecorder:
    """Worker-side timeline recorder: same op-row layout as
    ``repro.sim.instrument.TraceEventRecorder`` (which merges these rows
    at collect time), but defined here so ``repro.core`` never imports
    ``repro.sim``.  Rows are keyed by representative pod label; the
    coordinator expands SPMD clones."""

    def __init__(self):
        self.rows: List[list] = []

    def op_event(self, pod: int, payload: dict, start: int,
                 end: int) -> None:
        self.rows.append([
            pod, payload.get("op_idx", -1), payload.get("name", "op"),
            payload.get("kind", "compute"), payload.get("ready", start),
            start, end, bool(payload.get("dcn")), payload.get("dur"),
        ])

    def barrier_event(self, tick: int) -> None:
        pass   # barriers belong to the coordinator's lane


class _ShardRuntime:
    """Worker-side state: a shard TraceExecutor plus the capture/report
    bookkeeping that turns it into a dist-gem5 node."""

    def __init__(self, init: Dict[str, Any]):
        labels: List[int] = list(init["labels"])
        self.members: List[List[int]] = [list(m) for m in init["members"]]
        self.labels = labels
        self.barrier_mode: bool = bool(init["barrier_mode"])
        self.seq = 0                      # worker-local event sequence
        self.outbox: List[Dict[str, Any]] = []
        self.markers: List[List[int]] = []
        self.stash: Dict[Tuple[int, int], dict] = {}
        self.defer_tags: List[Tuple[int, int]] = []
        self._suppress = False            # restored arrivals: stash only
        self.hwm = 0                      # max tick actually *fired*
        # per-local-pod count of captured-but-undelivered dcn arrivals:
        # a queue with outstanding arrivals must respect the grant
        # horizon; one with none may free-run to its next capture
        self._outstanding: List[int] = []
        self._hit: List[bool] = []        # "captured a NEW arrival" latch
        # debug flags don't inherit under spawn: re-apply the parent's
        self._flags = list(init.get("debug_flags") or [])
        if self._flags:
            dbg.enable(self._flags)
        self.recorder = _WorkerRecorder() if init.get("instrument") \
            else None

        m = ClusterModel(init["machine"].get("name", "cluster"))
        m.load_serialized(init["machine"], strict=False)
        m.num_pods = len(labels)          # shard-sized machine
        # cost context: collective algorithms that read the pod count
        # (hierarchical) must see the *global* machine, not the shard
        m.global_num_pods = int(init["global_pods"])
        m.instantiate()
        self.quantum = int(m.quantum_ns)
        self.ex = TraceExecutor(
            m, algorithm=init["algorithm"],
            record_timeline=init["record_timeline"],
            straggler_slowdowns=list(init["slowdowns"]),
            record_stats=init["record_stats"],
            timing=init["timing"],
            pod_labels=labels,
            dcn_capture=self._capture,
            instrument=self.recorder)
        if 0 in labels:
            # run-wide markers fire on the pod carrying global label 0;
            # the coordinator replays them into the real op_hook
            self.ex.op_hook = (lambda op, idx, start, end:
                               self.markers.append([idx, start, end]))
        self._outstanding = [0] * len(labels)
        self._hit = [False] * len(labels)
        # tag deferred-frontier entries as they are appended, so the
        # coordinator can reassemble the serial engine's chronological
        # deferred order.  Under barriers the serial engine defers in
        # (barrier round, pod, order) order; the round of a deferral is
        # the quantum boundary of the event that triggered it, which is
        # computable locally even when a lookahead grant spans many
        # rounds.  Free-run mode uses the raw tick (the serial no-sync
        # merge is globally tick-ordered).
        orig_issue = self.ex._issue

        def tagged_issue(p: int, idx: int, ready: int) -> None:
            before = len(self.ex._deferred)
            orig_issue(p, idx, ready)
            if len(self.ex._deferred) > before:
                now = self.ex._queues[p].now
                mark = quantum_boundary(now, self.quantum) \
                    if self.barrier_mode else now
                self.defer_tags.append((int(mark), self.seq))
                self.seq += 1

        self.ex._issue = tagged_issue

        trace = HloTrace.from_json(init["trace"])
        state = init.get("restore")
        if state is None:
            self.ex.begin(trace)
        else:
            self._suppress = True
            try:
                self.ex.restore(trace, state)
            finally:
                self._suppress = False

    # -- dcn capture -----------------------------------------------------
    def _capture(self, payload: dict) -> None:
        p = payload["pod"]
        self.stash[(payload["op_idx"], p)] = payload
        self._outstanding[p] += 1
        if self._suppress:
            return                        # restored arrival: the
            # coordinator already holds it in its rendezvous map
        self._hit[p] = True               # lookahead stop-at-arrival
        # ONE row per clone class — the coordinator expands it to the
        # member pods (it planned the folding), keeping pipe traffic
        # O(classes) instead of O(pods)
        self.outbox.append({
            "op": payload["op_idx"], "rep": p,
            "ready": payload["ready"], "seq": self.seq,
            "kind": payload.get("kind"),
            "name": payload.get("name"),
            "nbytes": payload.get("nbytes"),
            "participants": payload.get("participants")})
        self.seq += 1

    # -- reporting -------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        ex = self.ex
        nts = [q.next_tick() for q in ex._queues]
        nt = min((t for t in nts if t is not None), default=None)
        rep = {
            "ok": True,
            "arrivals": self.outbox,
            "markers": self.markers,
            "next_tick": nt,
            "nexts": nts,                 # per clone class, for lookahead
            "nows": [q.now for q in ex._queues],
            "hwm": self.hwm,              # max tick actually fired
            "done": ex.done(),
            "now": max(q.now for q in ex._queues),
            "idle": (all(q.empty() for q in ex._queues)
                     and ex.timing.quiescent(ex)),
        }
        self.outbox, self.markers = [], []
        return rep

    # -- commands --------------------------------------------------------
    def _deliver(self, completions: List[Dict[str, Any]]) -> None:
        """Schedule due dcn completion deliveries at their exact
        delivery ticks (mirrors ``QuantumSync._advance_to``; the grant
        horizon guarantees no recipient queue has run past them)."""
        for c in completions:
            for p in range(len(self.labels)):
                w = self.stash.pop((c["op"], p), None)
                if w is None:
                    continue
                self._outstanding[p] -= 1
                w.update(start=c["start"], dur=c["dur"])
                q = self.ex._queues[p]
                done = w["done"]
                at = max(int(c["deliver"]), q.now)
                q.schedule(
                    (lambda w=w, q=q, done=done, start=c["start"]:
                     done(start, q.now, w)),
                    at, name=w.get("name", "dcn"))

    def _step_to(self, q, limit: Optional[int]) -> None:
        """Fire events without pushing ``q.now`` past them (unlike
        ``run_until``), so a queue stopped mid-grant reports its true
        position and later deliveries land at their exact ticks."""
        while True:
            nt = q.next_tick()
            if nt is None or (limit is not None and nt > limit):
                return
            q.step()
            if q.now > self.hwm:
                self.hwm = q.now

    def cmd_advance(self, cmd: Dict[str, Any]) -> Dict[str, Any]:
        """One batched grant: deliver due completions, then either run
        every queue to an explicit barrier tick (``align`` — the classic
        serial-schedule barrier, also used for the final queue-position
        alignment) or free-run each queue under lookahead: a queue stops
        on its own when it captures a NEW dcn arrival, queues holding
        undelivered arrivals additionally respect ``horizon``, and
        ``limit`` (advance's max_tick) caps everyone."""
        self._deliver(cmd["completions"])
        align = cmd.get("align")
        if align is not None:
            t = int(align)
            for q in self.ex._queues:
                self._step_to(q, t)       # fire (tracking hwm) ...
                q.run_until(t)            # ... then clamp now = t
            return self.report()
        horizon = cmd.get("horizon")
        limit = cmd.get("limit")
        for p, q in enumerate(self.ex._queues):
            lim = limit
            if self._outstanding[p] > 0 and horizon is not None:
                lim = horizon if lim is None else min(lim, horizon)
            self._hit[p] = False
            while True:
                nt = q.next_tick()
                if nt is None or (lim is not None and nt > lim):
                    break
                q.step()
                if q.now > self.hwm:
                    self.hwm = q.now
                if self._hit[p]:
                    break                 # stopped at a fresh arrival
        return self.report()

    def cmd_advance_free(self, cmd: Dict[str, Any]) -> Dict[str, Any]:
        """No-dcn mode: advance the shard independently (exact — pods
        in different workers cannot interact without dcn traffic)."""
        self.ex.advance(max_tick=cmd["max_tick"])
        return self.report()

    def cmd_drain(self, cmd: Dict[str, Any]) -> Dict[str, Any]:
        self.ex._draining = True
        return {"ok": True}

    def cmd_collect(self, cmd: Dict[str, Any]) -> Dict[str, Any]:
        """Everything the coordinator needs to reassemble the serial
        engine's snapshot/result, per representative pod."""
        ex = self.ex
        wires = []
        for w in ex._wires:
            wires.append([[x, y, d, l.busy_until, l.bytes_carried,
                           l.transfers]
                          for (x, y, d), l in sorted(w._net.links.items())])
        children = ex.sim_root.stats.state_dict()["children"]
        return {
            "ok": True,
            "labels": self.labels,
            "members": self.members,
            "op_end": [list(row) for row in ex._op_end],
            "chip_free": [c.free_tick for c in ex._chips],
            "wires": wires,
            "wire_busy": [w.busy_tick() for w in ex._wires],
            "queues": [q.snapshot() for q in ex._queues],
            "chip_stats": [children.get(f"chip{g}") for g in self.labels],
            "wire_stats": [children.get(f"wire{g}") for g in self.labels],
            "deferred": [list(t) for t in ex._deferred],
            "defer_tags": [list(t) for t in self.defer_tags],
            "totals": dict(ex._totals),
            "timeline": list(ex._timeline),
            "trace_rows": (self.recorder.rows if self.recorder is not None
                           else []),
        }


def _worker_main(conn) -> None:
    """Worker process entry point (module-level: spawn-safe).

    Processes stay warm across laps: an ``init`` command rebuilds the
    shard runtime in place (spawn-context startup re-imports heavy
    modules once, not once per ``begin()``/``restore()``)."""
    rt = None
    try:
        while True:
            cmd = conn.recv()
            op = cmd.get("cmd")
            if op == "exit":
                break
            if op == "init":
                rt = _ShardRuntime(cmd["init"])
                conn.send(rt.report())
            else:
                conn.send(getattr(rt, f"cmd_{op}")(cmd))
    except EOFError:
        pass
    except BaseException:
        try:
            conn.send({"error": traceback.format_exc()})
        except Exception:
            pass
    finally:
        try:
            conn.close()
        except Exception:
            pass


def _shutdown(conns, procs) -> None:
    for conn in conns:
        try:
            conn.send({"cmd": "exit"})
        except Exception:
            pass
        try:
            conn.close()
        except Exception:
            pass
    for p in procs:
        try:
            p.join(timeout=5)
            if p.is_alive():
                p.terminate()
        except Exception:
            pass


# ---------------------------------------------------------------------------
# coordinator
# ---------------------------------------------------------------------------

class ParallelEngine:
    """Multiprocess drop-in for :class:`TraceExecutor` (``workers=N``).

    Wraps a dormant serial *facade* executor over the full machine: the
    facade's SimObject tree carries the run's stats/fabric state, and
    ``snapshot()``/``result()`` are the facade's own — which is what
    makes parallel results and checkpoints bit-identical to serial ones
    and worker-count-agnostic.  When sharding cannot be exact (see
    module docstring) the facade simply runs the workload itself
    (``serial`` mode) and every call delegates.
    """

    def __init__(self, machine: ClusterModel, workers: int = 2,
                 mp_context: Optional[str] = None,
                 algorithm: str = "torus2d",
                 record_timeline: bool = False,
                 straggler_slowdowns: Optional[List[float]] = None,
                 record_stats: bool = False,
                 contention: Optional[bool] = None, timing=None,
                 instrument=None):
        self._facade = TraceExecutor(
            machine, algorithm=algorithm,
            record_timeline=record_timeline,
            straggler_slowdowns=straggler_slowdowns,
            record_stats=record_stats,
            contention=contention, timing=timing,
            instrument=instrument)
        self.workers = max(1, int(workers))
        if mp_context is None:
            mp_context = default_mp_context()
        self.mp_context = mp_context
        self._mode: Optional[str] = None   # "serial" | "sync" | "free"
        self._procs: List[Any] = []
        self._conns: List[Any] = []
        self._winfo: List[Dict[str, Any]] = []
        self._pending: List[Tuple[int, Dict[str, Any]]] = []
        self._t_now = 0
        self._draining = False
        self._collected: Optional[List[Dict[str, Any]]] = None
        self._finalizer: Optional[weakref.finalize] = None
        # lookahead bookkeeping (sync mode)
        self._hwm = 0                      # max tick fired by any worker
        self._aligned_to = 0               # last alignment barrier tick
        self._align_goal = 0               # serial end-of-advance position
        self._wmembers: List[List[List[int]]] = []   # per worker: members
        self._owner: Dict[int, Tuple[int, int]] = {}  # pod -> (widx, rep)
        self._reset_lap_stats()

    def _reset_lap_stats(self) -> None:
        """Coordinator-local counters + phase timers, fresh per lap.
        Deliberately NOT part of the facade stats tree: barrier counts
        are a property of the parallel schedule, and the facade tree
        must stay bit-identical to a serial run."""
        s = StatGroup("parallel")
        self.st_barriers = s.scalar(
            "barriers", "coordinator round trips (grants + alignments)")
        self.st_grants = s.scalar(
            "lookahead_grants", "multi-quantum lookahead grants")
        self.st_aligns = s.scalar(
            "alignment_barriers", "classic run_until-style barriers")
        self.st_msgs_out = s.scalar(
            "pipe_msgs_sent", "messages coordinator -> workers")
        self.st_msgs_in = s.scalar(
            "pipe_msgs_recv", "messages workers -> coordinator")
        self.st_arrival_rows = s.scalar(
            "arrival_rows", "dcn arrival rows received (per clone class)")
        self.st_completions = s.scalar(
            "completion_rows", "dcn completion rows delivered")
        self.st_elided = s.scalar(
            "quanta_elided", "quantum boundaries crossed without a barrier")
        self.sync_stats = s
        #: wall-clock seconds per coordination phase (benchmark probe)
        self.phase_wall: Dict[str, float] = {
            "spawn": 0.0, "barrier_wait": 0.0, "collect": 0.0}

    def sync_counters(self) -> Dict[str, int]:
        """Plain-dict view of ``sync_stats`` (benchmarks, CI asserts)."""
        return {name: int(st.value())
                for name, st in self.sync_stats.stats().items()}

    # -- facade delegation ----------------------------------------------
    def __getattr__(self, name: str):
        facade = self.__dict__.get("_facade")
        if facade is None or name.startswith("__"):
            raise AttributeError(name)
        return getattr(facade, name)

    @property
    def op_hook(self):
        return self._facade.op_hook

    @op_hook.setter
    def op_hook(self, fn) -> None:
        self._facade.op_hook = fn

    @property
    def injection_hook(self):
        return self._facade.injection_hook

    @injection_hook.setter
    def injection_hook(self, fn) -> None:
        self._facade.injection_hook = fn

    @property
    def instrument(self):
        return self._facade.instrument

    @instrument.setter
    def instrument(self, rec) -> None:
        # must be set before begin()/restore(): workers learn whether to
        # record at spawn time (serial-fallback mode uses it directly)
        self._facade.instrument = rec

    @property
    def now(self) -> int:
        if self._mode in (None, "serial"):
            return self._facade.now
        return max([self._t_now] + [w["now"] for w in self._winfo])

    # -- mode selection ---------------------------------------------------
    def _parallel_plan(self, trace: HloTrace,
                       state: Optional[Dict[str, Any]]) -> Optional[str]:
        """Return "sync"/"free" when sharding is exact, None for the
        serial fallback."""
        f = self._facade
        n = f.machine.num_pods
        if self.workers <= 1 or n < 2:
            return None
        if state is not None and (state.get("injected")
                                  or state.get("inject_floor")):
            return None                   # dynamic workload checkpoint
        needs_dcn = any(f._routes_dcn(op) for op in trace.ops)
        if not needs_dcn:
            return "free"
        if f.timing.parallel_dcn_ok and f.machine.quantum_ns > 0:
            return "sync"
        return None                       # exact-tick dcn delivery

    # -- lifecycle: begin / restore ---------------------------------------
    def _reset_lap(self) -> None:
        """Per-lap coordinator state (the warm worker pool survives)."""
        self._winfo = []
        self._pending = []
        self._t_now = 0
        self._hwm = 0
        self._aligned_to = 0
        self._align_goal = 0
        self._draining = False
        self._collected = None
        self._reset_lap_stats()

    def begin(self, trace: HloTrace) -> "ParallelEngine":
        self._reset_lap()
        mode = self._parallel_plan(trace, None)
        if mode is None:
            self._mode = "serial"
            self._facade.begin(trace)
            return self
        self._mode = mode
        self._facade._setup(trace)        # dormant: never issues ops
        self._spawn(trace, None)
        return self

    def restore(self, trace: HloTrace,
                state: Dict[str, Any]) -> "ParallelEngine":
        self._reset_lap()
        mode = self._parallel_plan(trace, state)
        if mode is None:
            self._mode = "serial"
            self._facade.restore(trace, state)
            return self
        f = self._facade
        if f.machine.num_pods != len(state["op_end"]):
            raise ValueError(
                f"cannot restore a {len(state['op_end'])}-pod snapshot "
                f"onto a {f.machine.num_pods}-pod machine "
                "(re-parameterize speeds, not the pod count)")
        self._mode = mode
        f._setup(trace)
        # the coordinator owns the shared fabric: uplink occupancy, dcn
        # stats and partial rendezvous.  Per-pod (chip/wire) stat
        # subtrees are NOT loaded here — the workers continue them from
        # the sliced restore state and merge them back at collect time,
        # and a merge into untouched stats is what stays bit-exact
        for i, (busy, nbytes, transfers) in enumerate(state["dcn_uplinks"]):
            if i < len(f._dcn.uplinks):
                link = f._dcn.uplinks[i]
                link.busy_until = busy
                link.bytes_carried = nbytes
                link.transfers = int(transfers)
        sd = state["stats"]
        f.sim_root.stats.load_state_dict(
            {"stats": sd.get("stats", {}),
             "children": {k: v for k, v in sd.get("children", {}).items()
                          if not (k.startswith("chip")
                                  or k.startswith("wire"))}})
        for r in state.get("rendezvous", []):
            arr = r["arrivals"]
            f._dcn._rendezvous[int(r["op_idx"])] = {
                "arrived": len(arr),
                "first": min(rd for _, rd in arr),
                "last": max(rd for _, rd in arr),
                "waiters": [{"pod": int(p), "ready": int(rd)}
                            for p, rd in arr],
            }
        self._spawn(trace, state)
        return self

    def _ensure_pool(self, nworkers: int) -> None:
        """Spawn (or reuse) the warm worker pool: processes persist
        across ``begin()``/``restore()`` laps — an ``init`` command
        rebuilds the shard runtime in the existing process, skipping
        the spawn-context interpreter+import cost per lap."""
        if self._procs and len(self._procs) == nworkers \
                and all(p.is_alive() for p in self._procs):
            return
        self.close()
        ctx = mp.get_context(self.mp_context)
        for _ in range(nworkers):
            parent, child = ctx.Pipe()
            proc = ctx.Process(target=_worker_main, args=(child,),
                               daemon=True)
            proc.start()
            child.close()
            self._procs.append(proc)
            self._conns.append(parent)
        self._finalizer = weakref.finalize(self, _shutdown,
                                           self._conns, self._procs)

    def _spawn(self, trace: HloTrace, state: Optional[Dict[str, Any]]
               ) -> None:
        t0 = time.perf_counter()
        f = self._facade
        n = f.machine.num_pods
        if state is None:
            keys: Dict[int, Any] = {g: repr(f.slow[g]) for g in range(n)}
        else:
            keys = {g: (repr(f.slow[g]), _pod_state_key(state, g))
                    for g in range(n)}
        machine_dict = f.machine.serialize()
        trace_json = trace.to_json()
        shards = plan_shards(n, self.workers)
        self._ensure_pool(len(shards))
        self._wmembers = []
        self._owner = {}
        for widx, shard in enumerate(shards):
            reps, members = fold_pods(shard, keys)
            init = {
                "machine": machine_dict,
                "trace": trace_json,
                "labels": reps,
                "members": members,
                "global_pods": n,
                "slowdowns": [f.slow[g] for g in reps],
                "algorithm": f.algorithm,
                "timing": f.timing.name,
                "record_stats": f.record_stats,
                "record_timeline": f.record_timeline,
                "barrier_mode": self._mode == "sync",
                "instrument": f.instrument is not None,
                "debug_flags": dbg.enabled_flags(),
            }
            if state is not None:
                init["restore"] = _slice_state(state, reps,
                                               owns0=0 in shard)
            self._conns[widx].send({"cmd": "init", "init": init})
            self.st_msgs_out.inc()
            self._wmembers.append([list(m) for m in members])
            for i, mm in enumerate(members):
                for g in mm:
                    self._owner[g] = (widx, i)
        for i, conn in enumerate(self._conns):
            self._winfo.append(self._recv(conn, i))
        self.phase_wall["spawn"] += time.perf_counter() - t0
        dbg.dprintf("Parallel", "engine", "launched %d workers mode=%s",
                    len(self._conns), self._mode, tick=self._t_now)

    def _recv(self, conn, i: int) -> Dict[str, Any]:
        try:
            rep = conn.recv()
        except EOFError:
            raise RuntimeError(f"parallel worker {i} died "
                               "(pipe closed mid-run)") from None
        if "error" in rep:
            raise RuntimeError(
                f"parallel worker {i} failed:\n{rep['error']}")
        self.st_msgs_in.inc()
        return rep

    def _broadcast(self, cmd: Dict[str, Any],
                   phase: str = "barrier_wait") -> List[Dict[str, Any]]:
        t0 = time.perf_counter()
        for conn in self._conns:
            conn.send(cmd)
        self.st_msgs_out.inc(len(self._conns))
        replies = [self._recv(conn, i)
                   for i, conn in enumerate(self._conns)]
        self.phase_wall[phase] += time.perf_counter() - t0
        return replies

    # -- advance ----------------------------------------------------------
    def _merge_reply(self, i: int, rep: Dict[str, Any],
                     rows: List[Dict[str, Any]]) -> None:
        w = self._winfo[i]
        w.update(next_tick=rep["next_tick"], done=rep["done"],
                 now=rep["now"], idle=rep["idle"],
                 nexts=rep.get("nexts"), nows=rep.get("nows"))
        hwm = int(rep.get("hwm", 0))
        if hwm > self._hwm:
            self._hwm = hwm
        # expand per-clone-class arrival rows to their member pods (the
        # wire carries one row per class; members share tick and seq)
        self.st_arrival_rows.inc(len(rep["arrivals"]))
        members = self._wmembers[i] if self._wmembers else None
        for a in rep["arrivals"]:
            for g in members[a["rep"]]:
                row = dict(a)
                row["pod"] = g
                rows.append(row)
        if rep["markers"] and self._facade.op_hook is not None:
            ops = self._facade._trace.ops
            for idx, start, end in rep["markers"]:
                self._facade.op_hook(ops[idx], idx, start, end)

    def _after_barrier(self, replies: List[Dict[str, Any]]) -> None:
        rows: List[Dict[str, Any]] = []
        for i, rep in enumerate(replies):
            self._merge_reply(i, rep, rows)
        if rows:
            self._process_arrivals(rows)

    def _process_arrivals(self, rows: List[Dict[str, Any]]) -> None:
        """Replay ``DcnSim._on_arrive`` on the facade's fabric, in the
        serial engine's canonical order: serially, an arrival at tick
        ``e`` happens in barrier round ``quantum_boundary(e)``, and
        within a round ``_advance_to`` runs queue 0 fully, then queue 1,
        ... — i.e. arrivals ordered by (round, global pod, per-pod event
        sequence).  The round key matters under lookahead: one batched
        grant can span many serial rounds, and two in-flight rendezvous
        must complete in the serial (chronological) order because uplink
        contention arithmetic is order-dependent."""
        f = self._facade
        dcn = f._dcn
        quantum = f.machine.quantum_ns
        for a in sorted(rows, key=lambda a: (
                quantum_boundary(a["ready"], quantum), a["pod"], a["seq"])):
            r = dcn._rendezvous.setdefault(
                a["op"], {"arrived": 0, "first": a["ready"], "last": 0,
                          "waiters": []})
            r["arrived"] += 1
            r["first"] = min(r["first"], a["ready"])
            r["last"] = max(r["last"], a["ready"])
            r["waiters"].append({"pod": a["pod"], "ready": a["ready"]})
            r["kind"] = a["kind"]
            r["name"] = a.get("name") or a["kind"]
            r["nbytes"] = a["nbytes"]
            r["participants"] = a["participants"]
            if r["arrived"] < f.machine.num_pods:
                continue
            del dcn._rendezvous[a["op"]]
            dur = to_ticks(f.dcn_alg.time_s(r["kind"], r["nbytes"],
                                            r["participants"], f.machine))
            if dcn.contention:
                start = max([r["last"]]
                            + [int(l.busy_until) for l in dcn.uplinks])
            else:
                start = r["last"]
            end = start + dur
            for l in dcn.uplinks:
                l.busy_until = max(l.busy_until, end)
                l.bytes_carried += r["nbytes"] / len(dcn.uplinks)
                l.transfers += 1
            dcn.st_colls.inc()
            dcn.st_bytes.inc(r["nbytes"])
            dcn.st_busy.inc(dur / TICKS_PER_S)
            dcn.st_skew.sample((r["last"] - r["first"]) / TICKS_PER_S)
            deliver = quantum_delivery(r["last"], end - r["last"], quantum)
            if dbg._ACTIVE:
                dbg.dprintf("Dcn", "coordinator",
                            "%s op=%d fire start=%d dur=%d deliver=%d",
                            r["name"], a["op"], start, dur, deliver,
                            tick=end)
            ins = f.instrument
            if ins is not None:
                ins.dcn_event(a["op"], r["name"], start, dur, deliver,
                              [(w["pod"], w["ready"])
                               for w in r["waiters"]])
            self._pending.append((deliver, {"op": a["op"], "start": start,
                                            "dur": dur,
                                            "deliver": deliver}))

    def _due(self, t: Optional[int]) -> List[Dict[str, Any]]:
        """Pop pending completion deliveries with deliver <= t."""
        if t is None:
            due = [c for _, c in self._pending]
            self._pending = []
        else:
            due = [c for d, c in self._pending if d <= t]
            self._pending = [(d, c) for d, c in self._pending if d > t]
        self.st_completions.inc(len(due))
        return due

    def _safe_horizon(self) -> Optional[int]:
        """Largest tick every queue *holding undelivered arrivals* may
        safely reach: the min over (a) exact pending delivery ticks and
        (b) ``rendezvous_horizon`` of each incomplete rendezvous, seeded
        with a lower bound on its final arrival (its last arrival so
        far, and each missing pod's next event tick).  Every bound is an
        under-estimate of the true delivery tick, so no bounded queue
        can ever run past a delivery it has not seen.  ``None`` =
        unbounded (no rendezvous in flight at all)."""
        f = self._facade
        quantum = f.machine.quantum_ns
        pend_min = min((d for d, _ in self._pending), default=None)
        bounds: List[int] = [] if pend_min is None else [pend_min]
        for r in f._dcn._rendezvous.values():
            arrived = {w["pod"] for w in r["waiters"]}
            lb = r["last"]
            for g in range(f.machine.num_pods):
                if g in arrived:
                    continue
                widx, rep = self._owner[g]
                w = self._winfo[widx]
                nt = (w.get("nexts") or [None] * (rep + 1))[rep]
                if nt is None:
                    now = (w.get("nows") or [w["now"]] * (rep + 1))[rep]
                    nt = now if pend_min is None else max(now, pend_min)
                if nt > lb:
                    lb = nt
            bounds.append(rendezvous_horizon(lb, quantum))
        return min(bounds) if bounds else None

    def _grant(self, horizon: Optional[int],
               limit: Optional[int]) -> bool:
        """One batched lookahead round trip: ship due completions, let
        every queue free-run (stop-at-arrival; ``horizon`` bounds
        stash-holders, ``limit`` bounds everyone).  Returns whether any
        simulation progress happened (events fired, arrivals captured,
        or completions delivered)."""
        cap = horizon
        if limit is not None:
            cap = limit if cap is None else min(cap, limit)
        due = self._due(cap)
        before = self._hwm
        arrivals0 = int(self.st_arrival_rows.value())
        replies = self._broadcast({"cmd": "advance", "completions": due,
                                   "horizon": horizon, "limit": limit})
        self.st_barriers.inc()
        self.st_grants.inc()
        self._after_barrier(replies)
        if dbg._ACTIVE:
            dbg.dprintf("Parallel", "engine",
                        "grant horizon=%s limit=%s delivered=%d",
                        horizon, limit, len(due), tick=self._hwm)
        return (bool(due) or self._hwm > before
                or int(self.st_arrival_rows.value()) > arrivals0)

    def _align(self, t: int) -> None:
        """Classic barrier: deliver due completions and run every queue
        to ``t`` (the serial engine's ``_advance_to``) — used as the
        no-progress fallback and to land queues on the exact serial
        end-of-advance position before drain/snapshot."""
        due = self._due(t)
        replies = self._broadcast({"cmd": "advance", "completions": due,
                                   "align": t})
        self.st_barriers.inc()
        self.st_aligns.inc()
        self._t_now = max(self._t_now, t)
        self._after_barrier(replies)
        if dbg._ACTIVE:
            dbg.dprintf("Parallel", "engine", "barrier delivered=%d",
                        len(due), tick=t)
        ins = self._facade.instrument
        if ins is not None:
            ins.barrier_event(t)

    def _advance_sync(self, max_tick: Optional[int],
                      stop_check: Optional[Callable[[], bool]]) -> None:
        """Coordinator-as-clock with dist-gem5 lookahead elision.

        Instead of mirroring ``QuantumSync.run_until_drained`` barrier
        for barrier, the coordinator issues multi-quantum *grants*: each
        queue free-runs until it captures a new DCN arrival (at which
        point it stops on its own — every rendezvous it could be party
        to needs its arrival, and the delivery lands at least one
        quantum later), bounded by ``_safe_horizon`` while it holds
        undelivered traffic.  Exactness argument in docs/parallel.md:
        every event fires at the same tick as serially, arrivals are
        replayed in (round, pod, seq) order, and a final alignment
        barrier lands all queues on the serial end-of-advance position
        ``quantum_boundary(last fired tick)`` (clamped by the max_tick
        of the advance call that fired it, exactly as the serial clamp
        does)."""
        quantum = self._facade.machine.quantum_ns
        hwm0, bar0 = self._hwm, int(self.st_barriers.value())
        while True:
            if stop_check is not None and stop_check():
                self._update_align_goal(max_tick, quantum)
                return                    # paused: no alignment yet
            upcoming = [w["next_tick"] for w in self._winfo
                        if w["next_tick"] is not None]
            if self._pending:
                upcoming.append(min(d for d, _ in self._pending))
            if not upcoming:
                break
            target = min(upcoming)
            if max_tick is not None and target > max_tick:
                break
            if not self._grant(self._safe_horizon(), max_tick):
                # conservative horizon pinned every queue below its next
                # event: take one classic serial-schedule barrier.  It
                # fires at least the earliest event, and it is always
                # delivery-safe: any not-yet-computed completion's last
                # arrival is an unfired event >= target, so its delivery
                # lands >= quantum_boundary(target) + quantum > t.
                self._align(quantum_boundary(target, quantum))
        self._update_align_goal(max_tick, quantum)
        if self._align_goal > self._aligned_to:
            self._align(self._align_goal)
            self._aligned_to = self._align_goal
        crossed = (self._aligned_to - (hwm0 // quantum) * quantum) \
            // quantum
        executed = int(self.st_barriers.value()) - bar0
        if crossed > executed:
            self.st_elided.inc(crossed - executed)

    def _update_align_goal(self, max_tick: Optional[int],
                           quantum: int) -> None:
        """Track the serial engine's end-of-advance queue position:
        ``quantum_boundary(max tick fired)``, clamped by the max_tick of
        the call in which those events fired (the serial loop's final
        ``_advance_to(max_tick)`` clamp)."""
        if self._hwm <= 0:
            return
        goal = quantum_boundary(self._hwm, quantum)
        if max_tick is not None:
            goal = min(goal, max_tick)
        if goal > self._align_goal:
            self._align_goal = goal

    def _advance_free(self, max_tick: Optional[int],
                      stop_check: Optional[Callable[[], bool]]) -> None:
        if stop_check is not None and stop_check():
            return
        replies = self._broadcast({"cmd": "advance_free",
                                   "max_tick": max_tick})
        self._after_barrier(replies)

    def advance(self, max_tick: Optional[int] = None,
                stop_check: Optional[Callable[[], bool]] = None) -> bool:
        if self._mode is None:
            raise RuntimeError("advance() before begin()/restore()")
        if self._mode == "serial":
            return self._facade.advance(max_tick, stop_check)
        if self._collected is not None:
            if self.done() or self._draining:
                return self.done()
            raise RuntimeError("cannot advance a collected parallel run "
                               "(restore from its checkpoint instead)")
        if self._mode == "sync":
            self._advance_sync(max_tick, stop_check)
        else:
            self._advance_free(max_tick, stop_check)
        return self.done()

    def done(self) -> bool:
        if self._mode in (None, "serial"):
            return self._facade.done()
        return all(w["done"] for w in self._winfo)

    # -- drain / snapshot / result ----------------------------------------
    def drain(self) -> bool:
        if self._mode == "serial":
            return self._facade.drain()
        self._draining = True
        self._facade._draining = True
        if self._collected is None:
            self._broadcast({"cmd": "drain"})
            return self.advance()
        return self.done()

    def drained(self) -> bool:
        if self._mode == "serial":
            return self._facade.drained()
        return (self._mode is not None and self._draining
                and not self._pending
                and all(w.get("idle") for w in self._winfo))

    def snapshot(self) -> Dict[str, Any]:
        if self._mode == "serial":
            return self._facade.snapshot()
        if not self.drained():
            raise RuntimeError("snapshot() requires drain() first "
                               "(gem5: drain-then-serialize)")
        self._collect()
        return self._facade.snapshot()

    def result(self) -> ExecResult:
        if self._mode == "serial":
            return self._facade.result()
        self._collect()
        return self._facade.result()

    def _collect(self) -> None:
        """Pull worker shard state into the facade executor (expanding
        folded clones), after which the facade's own ``snapshot()`` /
        ``result()`` produce serial-format, serial-identical output.
        The warm worker pool survives — a collected engine answers any
        number of snapshot/result calls, cannot advance, but its next
        ``begin()``/``restore()`` reuses the live processes."""
        if self._collected is not None:
            return
        # a run can end mid-grant (stop_check fired on the advance that
        # fired the last event): land the deferred alignment barrier so
        # collected queue positions match the serial engine's
        if self._mode == "sync" and self._align_goal > self._aligned_to:
            self._align(self._align_goal)
            self._aligned_to = self._align_goal
        replies = self._broadcast({"cmd": "collect"}, phase="collect")
        f = self._facade
        ins = f.instrument
        if ins is not None:
            for widx, rep in enumerate(replies):
                ins.add_worker(widx, rep["labels"], rep["members"],
                               rep.get("trace_rows", []))
        dbg.dprintf("Parallel", "engine", "collected %d workers",
                    len(replies), tick=self.now)
        deferred: List[Tuple[Tuple[int, int], int, int, int]] = []
        for rep in replies:
            members = rep["members"]
            for i in range(len(rep["labels"])):
                for g in members[i]:
                    f._op_end[g] = list(rep["op_end"][i])
                    f._chips[g]._free = int(rep["chip_free"][i])
                    net = f._wires[g]._net
                    for x, y, d, busy, nbytes, transfers in rep["wires"][i]:
                        link = net._link(int(x), int(y), d)
                        link.busy_until = busy
                        link.bytes_carried = nbytes
                        link.transfers = int(transfers)
                    f._wires[g]._busy_hwm = int(rep["wire_busy"][i])
                    q = f._queues[g]
                    q.events_fired = int(rep["queues"][i]["events_fired"])
                    q.run_until(int(rep["queues"][i]["now"]))
                    # per-pod stats subtrees are disjoint across pods, so
                    # this merge is exact (merge into untouched == adopt)
                    for kind, sds in (("chip", rep["chip_stats"]),
                                      ("wire", rep["wire_stats"])):
                        if sds[i] is not None:
                            f.sim_root.stats.merge_state_dict(
                                {"children": {f"{kind}{g}": sds[i]}})
            for (p, idx, ready), tag in zip(rep["deferred"],
                                            rep["defer_tags"]):
                for g in members[p]:
                    deferred.append(((int(tag[0]), int(tag[1])),
                                     g, int(idx), int(ready)))
            if any(0 in mm for mm in members):
                f._totals = {k: float(v) for k, v in rep["totals"].items()}
                f._timeline = list(rep["timeline"])
        # serial chronological order: (barrier era | tick, pod, seq)
        deferred.sort(key=lambda e: (e[0][0], e[1], e[0][1]))
        f._deferred = [(g, idx, ready) for _, g, idx, ready in deferred]
        f._ncomplete = sum(1 for row in f._op_end for e in row if e >= 0)
        self._collected = replies

    # -- teardown ----------------------------------------------------------
    def close(self) -> None:
        """Shut worker processes down (idempotent; the facade and any
        collected state stay usable)."""
        conns, procs = self._conns, self._procs
        self._conns, self._procs = [], []
        if self._finalizer is not None:
            self._finalizer.detach()
            self._finalizer = None
        if conns or procs:
            _shutdown(conns, procs)

    # -- one-shot ----------------------------------------------------------
    def execute(self, trace: HloTrace) -> ExecResult:
        """Run a trace to completion.  Workers stay warm afterwards so
        back-to-back laps on one engine skip the spawn cost; call
        ``close()`` (or let ``run_parallel``'s finally do it) to tear
        the pool down."""
        self.begin(trace)
        self.advance()
        return self.result()

    # -- dynamic workloads -------------------------------------------------
    def inject_op(self, op, ready: int, pod: int = 0) -> int:
        if self._mode == "serial":
            return self._facade.inject_op(op, ready, pod)
        raise RuntimeError(
            "inject_op() on a sharded parallel run: dynamic workloads "
            "run serially (repro.sim.Simulator arranges this)")
