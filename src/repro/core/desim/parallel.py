"""dist-gem5-style multiprocess pod sharding (gem5-20 paper §2.17, §4).

The paper credits dist-gem5 — partitioning the simulated system across
parallel gem5 processes that exchange network traffic only at
synchronization quanta — as what makes cluster-scale simulation
practical.  Our engine has the exact decomposition dist-gem5 needs (one
``EventQueue`` per pod, all cross-pod traffic batched onto quantum
boundaries by ``QuantumSync``, a drain/serialize cut with no in-flight
messages), so :class:`ParallelEngine` shards the machine's pods across
N worker processes:

* Each **worker** owns a contiguous pod range and runs a real
  :class:`TraceExecutor` over a shard-sized copy of the machine
  (``pod_labels`` keeps the global pod identities).  Between quantum
  barriers the worker advances its local queues with zero coordination.
* The **coordinator** (this process) owns the one true DCN fabric: it
  mirrors ``QuantumSync.run_until_drained``'s boundary arithmetic
  bit-for-bit (the shared helpers in ``repro.core.events``), collects
  cross-pod arrivals that workers capture via the ``DcnSim`` capture
  hook, replays the rendezvous/uplink/stat updates in the serial
  engine's canonical order, and broadcasts completion deliveries back —
  pipes carry only rendezvous metadata, never simulation objects.
* **SPMD clone folding**: within a shard, pods whose straggler slowdown
  (and, on restore, whole serialized per-pod state) are identical evolve
  identically — per-pod evolution is a pure function of (trace, machine,
  slowdown, dcn completion schedule), and the completion schedule is
  broadcast to every pod.  Each class is simulated once and its results
  replicated, so a homogeneous 16-pod board costs 16/N pod-simulations
  across N workers.  This is what delivers wall-clock speedup even on a
  single core; on multicore the processes additionally run concurrently.

Exactness (test-enforced, see docs/parallel.md): with detailed timing
and a positive quantum, final tick, full stats tree, checkpoint dicts
and decision logs are bit-identical to the serial engine.  The engine
falls back to the in-process serial path when sharding cannot be exact:
dynamic workloads (``inject_op`` feedback couples pods through the
host), dcn traffic under atomic timing or ``quantum_ns == 0`` (exact-
tick delivery needs the global tick-ordered merge), the
``hierarchical`` intra-pod algorithm (its cost depends on the global
pod count), or fewer than 2 pods/workers.

Checkpoints are worker-count-agnostic: collection loads worker state
into a dormant serial facade executor and calls its ``snapshot()``
verbatim, so a ``workers=4`` checkpoint restores under ``workers=1``
and vice versa (the restore path slices the same serial format).
"""

from __future__ import annotations

import json
import multiprocessing as mp
import sys
import traceback
import weakref
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import trace as dbg
from repro.core.desim.executor import ExecResult, TraceExecutor
from repro.core.desim.machine import ClusterModel
from repro.core.desim.simnodes import TICKS_PER_S, to_ticks
from repro.core.desim.trace import HloTrace
from repro.core.events import quantum_boundary, quantum_delivery

__all__ = ["ParallelEngine", "default_mp_context", "plan_shards",
           "fold_pods"]


def default_mp_context() -> str:
    """Start method for simulation worker processes.

    fork is cheap (~ms/worker) and preferred where available — but
    fork()ing a process whose JAX runtime is initialized deadlocks its
    multithreaded backend (CPython warns ``os.fork() was called ...
    likely lead to a deadlock``), and any benchmark or test that
    imported a kernel module has JAX loaded.  Spawn is fully supported
    here (init payloads are plain data, worker entry points are
    module-level), so pick it automatically whenever ``jax`` is in
    ``sys.modules``; an explicit ``mp_context=`` always wins.
    """
    if "jax" in sys.modules:
        return "spawn"
    return ("fork" if "fork" in mp.get_all_start_methods()
            else "spawn")


# ---------------------------------------------------------------------------
# shard planning / clone folding
# ---------------------------------------------------------------------------

def plan_shards(num_pods: int, workers: int) -> List[List[int]]:
    """Contiguous, balanced pod ranges — one per worker (clamped to
    ``num_pods``: a worker needs at least one pod)."""
    workers = max(1, min(int(workers), int(num_pods)))
    base, extra = divmod(num_pods, workers)
    shards, lo = [], 0
    for w in range(workers):
        size = base + (1 if w < extra else 0)
        shards.append(list(range(lo, lo + size)))
        lo += size
    return shards


def fold_pods(shard: List[int], keys: Dict[int, Any]
              ) -> Tuple[List[int], List[List[int]]]:
    """Group a shard's pods into SPMD clone classes by fold key.

    Returns ``(reps, members)``: ``reps[i]`` is the representative
    (first) pod of class ``i`` — the one actually simulated — and
    ``members[i]`` the ascending global pod ids its results replicate
    to.  Pods with distinct keys (different slowdown, or different
    restored state) never fold."""
    reps: List[int] = []
    members: List[List[int]] = []
    index: Dict[Any, int] = {}
    for g in shard:
        k = keys[g]
        i = index.get(k)
        if i is None:
            index[k] = len(reps)
            reps.append(g)
            members.append([g])
        else:
            members[i].append(g)
    return reps, members


def _pod_state_key(state: Dict[str, Any], g: int) -> str:
    """Canonical fingerprint of pod ``g``'s slice of a serial snapshot —
    pods may fold on restore only when their entire state matches."""
    children = state.get("stats", {}).get("children", {})
    row = {
        "op_end": state["op_end"][g],
        "queue": state["queues"][g],
        "chip_free": state["chip_free"][g],
        "wires": state["wires"][g] if g < len(state.get("wires", [])) else [],
        "wire_busy": state.get("wire_busy", [0] * (g + 1))[g],
        "deferred": [[idx, r] for p, idx, r in state.get("deferred", [])
                     if p == g],
        "rendezvous": [[r["op_idx"], a[1]] for r in state.get("rendezvous", [])
                       for a in r["arrivals"] if a[0] == g],
        "chip_stats": children.get(f"chip{g}"),
        "wire_stats": children.get(f"wire{g}"),
    }
    return json.dumps(row, sort_keys=True)


def _slice_state(state: Dict[str, Any], reps: List[int],
                 owns0: bool) -> Dict[str, Any]:
    """Shard-shaped serial snapshot holding only the representative
    pods' rows (the worker restores it through the ordinary
    ``TraceExecutor.restore``).  Run-wide accumulators (totals,
    timeline) go to the worker owning global pod 0; the shared-fabric
    state (dcn uplinks, rendezvous metadata, dcn stats) stays with the
    coordinator."""
    local = {g: i for i, g in enumerate(reps)}
    children = state.get("stats", {}).get("children", {})
    out: Dict[str, Any] = {
        "tick": state["tick"],
        "timing": state["timing"],
        "pod_dims": list(state.get("pod_dims", [])),
        "queues": [dict(state["queues"][g]) for g in reps],
        "op_end": [list(state["op_end"][g]) for g in reps],
        "deferred": [[local[p], int(idx), int(r)]
                     for p, idx, r in state.get("deferred", [])
                     if p in local],
        "injected": [],
        "inject_floor": [],
        "rendezvous": [],
        "chip_free": [state["chip_free"][g] for g in reps],
        "wires": [state["wires"][g] for g in reps],
        "wire_busy": [int(state["wire_busy"][g]) for g in reps]
        if state.get("wire_busy") else [],
        "dcn_uplinks": [],
        "stats": {"stats": {},
                  "children": {f"{kind}{g}": children[f"{kind}{g}"]
                               for g in reps for kind in ("chip", "wire")
                               if f"{kind}{g}" in children}},
        "totals": (dict(state["totals"]) if owns0
                   else {"compute": 0.0, "coll": 0.0, "exposed": 0.0}),
        "timeline": list(state.get("timeline", [])) if owns0 else [],
    }
    for r in state.get("rendezvous", []):
        arr = [[local[p], int(rd)] for p, rd in r["arrivals"] if p in local]
        if arr:
            out["rendezvous"].append({"op_idx": r["op_idx"],
                                      "arrivals": arr})
    return out


# ---------------------------------------------------------------------------
# worker process
# ---------------------------------------------------------------------------

class _WorkerRecorder:
    """Worker-side timeline recorder: same op-row layout as
    ``repro.sim.instrument.TraceEventRecorder`` (which merges these rows
    at collect time), but defined here so ``repro.core`` never imports
    ``repro.sim``.  Rows are keyed by representative pod label; the
    coordinator expands SPMD clones."""

    def __init__(self):
        self.rows: List[list] = []

    def op_event(self, pod: int, payload: dict, start: int,
                 end: int) -> None:
        self.rows.append([
            pod, payload.get("op_idx", -1), payload.get("name", "op"),
            payload.get("kind", "compute"), payload.get("ready", start),
            start, end, bool(payload.get("dcn")), payload.get("dur"),
        ])

    def barrier_event(self, tick: int) -> None:
        pass   # barriers belong to the coordinator's lane


class _ShardRuntime:
    """Worker-side state: a shard TraceExecutor plus the capture/report
    bookkeeping that turns it into a dist-gem5 node."""

    def __init__(self, init: Dict[str, Any]):
        labels: List[int] = list(init["labels"])
        self.members: List[List[int]] = [list(m) for m in init["members"]]
        self.labels = labels
        self.barrier_mode: bool = bool(init["barrier_mode"])
        self.seq = 0                      # worker-local event sequence
        self.era = 0                      # barrier index (sync mode)
        self.outbox: List[Dict[str, Any]] = []
        self.markers: List[List[int]] = []
        self.stash: Dict[Tuple[int, int], dict] = {}
        self.defer_tags: List[Tuple[int, int]] = []
        self._suppress = False            # restored arrivals: stash only
        # debug flags don't inherit under spawn: re-apply the parent's
        self._flags = list(init.get("debug_flags") or [])
        if self._flags:
            dbg.enable(self._flags)
        self.recorder = _WorkerRecorder() if init.get("instrument") \
            else None

        m = ClusterModel(init["machine"].get("name", "cluster"))
        m.load_serialized(init["machine"], strict=False)
        m.num_pods = len(labels)          # shard-sized machine
        m.instantiate()
        self.ex = TraceExecutor(
            m, algorithm=init["algorithm"],
            record_timeline=init["record_timeline"],
            straggler_slowdowns=list(init["slowdowns"]),
            record_stats=init["record_stats"],
            timing=init["timing"],
            pod_labels=labels,
            dcn_capture=self._capture,
            instrument=self.recorder)
        if 0 in labels:
            # run-wide markers fire on the pod carrying global label 0;
            # the coordinator replays them into the real op_hook
            self.ex.op_hook = (lambda op, idx, start, end:
                               self.markers.append([idx, start, end]))
        # tag deferred-frontier entries as they are appended, so the
        # coordinator can reassemble the serial engine's chronological
        # deferred order: (era, seq) under barriers, (tick, seq) in
        # free-run mode (global pod id disambiguates across workers)
        orig_issue = self.ex._issue

        def tagged_issue(p: int, idx: int, ready: int) -> None:
            before = len(self.ex._deferred)
            orig_issue(p, idx, ready)
            if len(self.ex._deferred) > before:
                mark = self.era if self.barrier_mode \
                    else self.ex._queues[p].now
                self.defer_tags.append((int(mark), self.seq))
                self.seq += 1

        self.ex._issue = tagged_issue

        trace = HloTrace.from_json(init["trace"])
        state = init.get("restore")
        if state is None:
            self.ex.begin(trace)
        else:
            self._suppress = True
            try:
                self.ex.restore(trace, state)
            finally:
                self._suppress = False

    # -- dcn capture -----------------------------------------------------
    def _capture(self, payload: dict) -> None:
        p = payload["pod"]
        self.stash[(payload["op_idx"], p)] = payload
        if self._suppress:
            return                        # restored arrival: the
            # coordinator already holds it in its rendezvous map
        for g in self.members[p]:
            self.outbox.append({
                "op": payload["op_idx"], "pod": g,
                "ready": payload["ready"], "seq": self.seq,
                "kind": payload.get("kind"),
                "name": payload.get("name"),
                "nbytes": payload.get("nbytes"),
                "participants": payload.get("participants")})
        self.seq += 1

    # -- reporting -------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        ex = self.ex
        nts = [q.next_tick() for q in ex._queues]
        nt = min((t for t in nts if t is not None), default=None)
        rep = {
            "ok": True,
            "arrivals": self.outbox,
            "markers": self.markers,
            "next_tick": nt,
            "done": ex.done(),
            "now": max(q.now for q in ex._queues),
            "idle": (all(q.empty() for q in ex._queues)
                     and ex.timing.quiescent(ex)),
        }
        self.outbox, self.markers = [], []
        return rep

    # -- commands --------------------------------------------------------
    def cmd_advance(self, cmd: Dict[str, Any]) -> Dict[str, Any]:
        """One quantum barrier: schedule due dcn completion deliveries,
        run every local queue to the boundary (mirrors
        ``QuantumSync._advance_to``)."""
        self.era += 1
        for c in cmd["completions"]:
            for p in range(len(self.labels)):
                w = self.stash.pop((c["op"], p), None)
                if w is None:
                    continue
                w.update(start=c["start"], dur=c["dur"])
                q = self.ex._queues[p]
                done = w["done"]
                at = max(int(c["deliver"]), q.now)
                q.schedule(
                    (lambda w=w, q=q, done=done, start=c["start"]:
                     done(start, q.now, w)),
                    at, name=w.get("name", "dcn"))
        t = int(cmd["t"])
        for q in self.ex._queues:
            q.run_until(t)
        return self.report()

    def cmd_advance_free(self, cmd: Dict[str, Any]) -> Dict[str, Any]:
        """No-dcn mode: advance the shard independently (exact — pods
        in different workers cannot interact without dcn traffic)."""
        self.ex.advance(max_tick=cmd["max_tick"])
        return self.report()

    def cmd_drain(self, cmd: Dict[str, Any]) -> Dict[str, Any]:
        self.ex._draining = True
        return {"ok": True}

    def cmd_collect(self, cmd: Dict[str, Any]) -> Dict[str, Any]:
        """Everything the coordinator needs to reassemble the serial
        engine's snapshot/result, per representative pod."""
        ex = self.ex
        wires = []
        for w in ex._wires:
            wires.append([[x, y, d, l.busy_until, l.bytes_carried,
                           l.transfers]
                          for (x, y, d), l in sorted(w._net.links.items())])
        children = ex.sim_root.stats.state_dict()["children"]
        return {
            "ok": True,
            "labels": self.labels,
            "members": self.members,
            "op_end": [list(row) for row in ex._op_end],
            "chip_free": [c.free_tick for c in ex._chips],
            "wires": wires,
            "wire_busy": [w.busy_tick() for w in ex._wires],
            "queues": [q.snapshot() for q in ex._queues],
            "chip_stats": [children.get(f"chip{g}") for g in self.labels],
            "wire_stats": [children.get(f"wire{g}") for g in self.labels],
            "deferred": [list(t) for t in ex._deferred],
            "defer_tags": [list(t) for t in self.defer_tags],
            "totals": dict(ex._totals),
            "timeline": list(ex._timeline),
            "trace_rows": (self.recorder.rows if self.recorder is not None
                           else []),
        }


def _worker_main(conn) -> None:
    """Worker process entry point (module-level: spawn-safe)."""
    rt = None
    try:
        init = conn.recv()
        rt = _ShardRuntime(init)
        conn.send(rt.report())
        while True:
            cmd = conn.recv()
            op = cmd.get("cmd")
            if op == "exit":
                break
            conn.send(getattr(rt, f"cmd_{op}")(cmd))
    except EOFError:
        pass
    except BaseException:
        try:
            conn.send({"error": traceback.format_exc()})
        except Exception:
            pass
    finally:
        try:
            conn.close()
        except Exception:
            pass


def _shutdown(conns, procs) -> None:
    for conn in conns:
        try:
            conn.send({"cmd": "exit"})
        except Exception:
            pass
        try:
            conn.close()
        except Exception:
            pass
    for p in procs:
        try:
            p.join(timeout=5)
            if p.is_alive():
                p.terminate()
        except Exception:
            pass


# ---------------------------------------------------------------------------
# coordinator
# ---------------------------------------------------------------------------

class ParallelEngine:
    """Multiprocess drop-in for :class:`TraceExecutor` (``workers=N``).

    Wraps a dormant serial *facade* executor over the full machine: the
    facade's SimObject tree carries the run's stats/fabric state, and
    ``snapshot()``/``result()`` are the facade's own — which is what
    makes parallel results and checkpoints bit-identical to serial ones
    and worker-count-agnostic.  When sharding cannot be exact (see
    module docstring) the facade simply runs the workload itself
    (``serial`` mode) and every call delegates.
    """

    def __init__(self, machine: ClusterModel, workers: int = 2,
                 mp_context: Optional[str] = None,
                 algorithm: str = "torus2d",
                 record_timeline: bool = False,
                 straggler_slowdowns: Optional[List[float]] = None,
                 record_stats: bool = False,
                 contention: Optional[bool] = None, timing=None,
                 instrument=None):
        self._facade = TraceExecutor(
            machine, algorithm=algorithm,
            record_timeline=record_timeline,
            straggler_slowdowns=straggler_slowdowns,
            record_stats=record_stats,
            contention=contention, timing=timing,
            instrument=instrument)
        self.workers = max(1, int(workers))
        if mp_context is None:
            mp_context = default_mp_context()
        self.mp_context = mp_context
        self._mode: Optional[str] = None   # "serial" | "sync" | "free"
        self._procs: List[Any] = []
        self._conns: List[Any] = []
        self._winfo: List[Dict[str, Any]] = []
        self._pending: List[Tuple[int, Dict[str, Any]]] = []
        self._t_now = 0
        self._draining = False
        self._collected: Optional[List[Dict[str, Any]]] = None
        self._finalizer: Optional[weakref.finalize] = None

    # -- facade delegation ----------------------------------------------
    def __getattr__(self, name: str):
        facade = self.__dict__.get("_facade")
        if facade is None or name.startswith("__"):
            raise AttributeError(name)
        return getattr(facade, name)

    @property
    def op_hook(self):
        return self._facade.op_hook

    @op_hook.setter
    def op_hook(self, fn) -> None:
        self._facade.op_hook = fn

    @property
    def injection_hook(self):
        return self._facade.injection_hook

    @injection_hook.setter
    def injection_hook(self, fn) -> None:
        self._facade.injection_hook = fn

    @property
    def instrument(self):
        return self._facade.instrument

    @instrument.setter
    def instrument(self, rec) -> None:
        # must be set before begin()/restore(): workers learn whether to
        # record at spawn time (serial-fallback mode uses it directly)
        self._facade.instrument = rec

    @property
    def now(self) -> int:
        if self._mode in (None, "serial"):
            return self._facade.now
        return max([self._t_now] + [w["now"] for w in self._winfo])

    # -- mode selection ---------------------------------------------------
    def _parallel_plan(self, trace: HloTrace,
                       state: Optional[Dict[str, Any]]) -> Optional[str]:
        """Return "sync"/"free" when sharding is exact, None for the
        serial fallback."""
        f = self._facade
        n = f.machine.num_pods
        if self.workers <= 1 or n < 2:
            return None
        if f.algorithm == "hierarchical":
            return None                   # intra-pod cost reads num_pods
        if state is not None and (state.get("injected")
                                  or state.get("inject_floor")):
            return None                   # dynamic workload checkpoint
        needs_dcn = any(f._routes_dcn(op) for op in trace.ops)
        if not needs_dcn:
            return "free"
        if f.timing.parallel_dcn_ok and f.machine.quantum_ns > 0:
            return "sync"
        return None                       # exact-tick dcn delivery

    # -- lifecycle: begin / restore ---------------------------------------
    def begin(self, trace: HloTrace) -> "ParallelEngine":
        mode = self._parallel_plan(trace, None)
        if mode is None:
            self._mode = "serial"
            self._facade.begin(trace)
            return self
        self._mode = mode
        self._facade._setup(trace)        # dormant: never issues ops
        self._spawn(trace, None)
        return self

    def restore(self, trace: HloTrace,
                state: Dict[str, Any]) -> "ParallelEngine":
        mode = self._parallel_plan(trace, state)
        if mode is None:
            self._mode = "serial"
            self._facade.restore(trace, state)
            return self
        f = self._facade
        if f.machine.num_pods != len(state["op_end"]):
            raise ValueError(
                f"cannot restore a {len(state['op_end'])}-pod snapshot "
                f"onto a {f.machine.num_pods}-pod machine "
                "(re-parameterize speeds, not the pod count)")
        self._mode = mode
        f._setup(trace)
        # the coordinator owns the shared fabric: uplink occupancy, dcn
        # stats and partial rendezvous.  Per-pod (chip/wire) stat
        # subtrees are NOT loaded here — the workers continue them from
        # the sliced restore state and merge them back at collect time,
        # and a merge into untouched stats is what stays bit-exact
        for i, (busy, nbytes, transfers) in enumerate(state["dcn_uplinks"]):
            if i < len(f._dcn.uplinks):
                link = f._dcn.uplinks[i]
                link.busy_until = busy
                link.bytes_carried = nbytes
                link.transfers = int(transfers)
        sd = state["stats"]
        f.sim_root.stats.load_state_dict(
            {"stats": sd.get("stats", {}),
             "children": {k: v for k, v in sd.get("children", {}).items()
                          if not (k.startswith("chip")
                                  or k.startswith("wire"))}})
        for r in state.get("rendezvous", []):
            arr = r["arrivals"]
            f._dcn._rendezvous[int(r["op_idx"])] = {
                "arrived": len(arr),
                "first": min(rd for _, rd in arr),
                "last": max(rd for _, rd in arr),
                "waiters": [{"pod": int(p), "ready": int(rd)}
                            for p, rd in arr],
            }
        self._spawn(trace, state)
        return self

    def _spawn(self, trace: HloTrace, state: Optional[Dict[str, Any]]
               ) -> None:
        f = self._facade
        n = f.machine.num_pods
        if state is None:
            keys: Dict[int, Any] = {g: repr(f.slow[g]) for g in range(n)}
        else:
            keys = {g: (repr(f.slow[g]), _pod_state_key(state, g))
                    for g in range(n)}
        machine_dict = f.machine.serialize()
        trace_json = trace.to_json()
        ctx = mp.get_context(self.mp_context)
        shards = plan_shards(n, self.workers)
        for shard in shards:
            reps, members = fold_pods(shard, keys)
            init = {
                "machine": machine_dict,
                "trace": trace_json,
                "labels": reps,
                "members": members,
                "slowdowns": [f.slow[g] for g in reps],
                "algorithm": f.algorithm,
                "timing": f.timing.name,
                "record_stats": f.record_stats,
                "record_timeline": f.record_timeline,
                "barrier_mode": self._mode == "sync",
                "instrument": f.instrument is not None,
                "debug_flags": dbg.enabled_flags(),
            }
            if state is not None:
                init["restore"] = _slice_state(state, reps,
                                               owns0=0 in shard)
            parent, child = ctx.Pipe()
            proc = ctx.Process(target=_worker_main, args=(child,),
                               daemon=True)
            proc.start()
            child.close()
            parent.send(init)
            self._procs.append(proc)
            self._conns.append(parent)
        self._finalizer = weakref.finalize(self, _shutdown,
                                           self._conns, self._procs)
        for i, conn in enumerate(self._conns):
            self._winfo.append(self._recv(conn, i))
        dbg.dprintf("Parallel", "engine", "spawned %d workers mode=%s",
                    len(self._conns), self._mode, tick=self._t_now)

    def _recv(self, conn, i: int) -> Dict[str, Any]:
        try:
            rep = conn.recv()
        except EOFError:
            raise RuntimeError(f"parallel worker {i} died "
                               "(pipe closed mid-run)") from None
        if "error" in rep:
            raise RuntimeError(
                f"parallel worker {i} failed:\n{rep['error']}")
        return rep

    def _broadcast(self, cmd: Dict[str, Any]) -> List[Dict[str, Any]]:
        for conn in self._conns:
            conn.send(cmd)
        return [self._recv(conn, i) for i, conn in enumerate(self._conns)]

    # -- advance ----------------------------------------------------------
    def _merge_reply(self, i: int, rep: Dict[str, Any],
                     rows: List[Dict[str, Any]]) -> None:
        w = self._winfo[i]
        w.update(next_tick=rep["next_tick"], done=rep["done"],
                 now=rep["now"], idle=rep["idle"])
        rows.extend(rep["arrivals"])
        if rep["markers"] and self._facade.op_hook is not None:
            ops = self._facade._trace.ops
            for idx, start, end in rep["markers"]:
                self._facade.op_hook(ops[idx], idx, start, end)

    def _after_barrier(self, replies: List[Dict[str, Any]]) -> None:
        rows: List[Dict[str, Any]] = []
        for i, rep in enumerate(replies):
            self._merge_reply(i, rep, rows)
        if rows:
            self._process_arrivals(rows)

    def _process_arrivals(self, rows: List[Dict[str, Any]]) -> None:
        """Replay ``DcnSim._on_arrive`` on the facade's fabric, in the
        serial engine's canonical order: within a barrier the serial
        ``_advance_to`` runs queue 0 fully, then queue 1, ... — i.e.
        arrivals ordered by (global pod, per-pod event sequence)."""
        f = self._facade
        dcn = f._dcn
        quantum = f.machine.quantum_ns
        for a in sorted(rows, key=lambda a: (a["pod"], a["seq"])):
            r = dcn._rendezvous.setdefault(
                a["op"], {"arrived": 0, "first": a["ready"], "last": 0,
                          "waiters": []})
            r["arrived"] += 1
            r["first"] = min(r["first"], a["ready"])
            r["last"] = max(r["last"], a["ready"])
            r["waiters"].append({"pod": a["pod"], "ready": a["ready"]})
            r["kind"] = a["kind"]
            r["name"] = a.get("name") or a["kind"]
            r["nbytes"] = a["nbytes"]
            r["participants"] = a["participants"]
            if r["arrived"] < f.machine.num_pods:
                continue
            del dcn._rendezvous[a["op"]]
            dur = to_ticks(f.dcn_alg.time_s(r["kind"], r["nbytes"],
                                            r["participants"], f.machine))
            if dcn.contention:
                start = max([r["last"]]
                            + [int(l.busy_until) for l in dcn.uplinks])
            else:
                start = r["last"]
            end = start + dur
            for l in dcn.uplinks:
                l.busy_until = max(l.busy_until, end)
                l.bytes_carried += r["nbytes"] / len(dcn.uplinks)
                l.transfers += 1
            dcn.st_colls.inc()
            dcn.st_bytes.inc(r["nbytes"])
            dcn.st_busy.inc(dur / TICKS_PER_S)
            dcn.st_skew.sample((r["last"] - r["first"]) / TICKS_PER_S)
            deliver = quantum_delivery(r["last"], end - r["last"], quantum)
            if dbg._ACTIVE:
                dbg.dprintf("Dcn", "coordinator",
                            "%s op=%d fire start=%d dur=%d deliver=%d",
                            r["name"], a["op"], start, dur, deliver,
                            tick=end)
            ins = f.instrument
            if ins is not None:
                ins.dcn_event(a["op"], r["name"], start, dur, deliver,
                              [(w["pod"], w["ready"])
                               for w in r["waiters"]])
            self._pending.append((deliver, {"op": a["op"], "start": start,
                                            "dur": dur,
                                            "deliver": deliver}))

    def _barrier(self, t: int) -> None:
        due = [c for d, c in self._pending if d <= t]
        self._pending = [(d, c) for d, c in self._pending if d > t]
        replies = self._broadcast({"cmd": "advance", "t": t,
                                   "completions": due})
        self._t_now = t
        self._after_barrier(replies)
        if dbg._ACTIVE:
            dbg.dprintf("Parallel", "engine", "barrier delivered=%d",
                        len(due), tick=t)
        ins = self._facade.instrument
        if ins is not None:
            ins.barrier_event(t)

    def _advance_sync(self, max_tick: Optional[int],
                      stop_check: Optional[Callable[[], bool]]) -> None:
        """Coordinator-as-clock: the exact loop of
        ``QuantumSync.run_until_drained``, with worker-reported next
        ticks standing in for ``q.next_tick()``."""
        quantum = self._facade.machine.quantum_ns
        t = (self._t_now // quantum) * quantum
        while True:
            if stop_check is not None and stop_check():
                return
            upcoming = [w["next_tick"] for w in self._winfo
                        if w["next_tick"] is not None]
            if self._pending:
                upcoming.append(min(d for d, _ in self._pending))
            if not upcoming:
                return
            target = min(upcoming)
            t = max(quantum_boundary(target, quantum), t + quantum)
            if max_tick is not None and t > max_tick:
                if target <= max_tick:
                    self._barrier(max_tick)
                return
            self._barrier(t)

    def _advance_free(self, max_tick: Optional[int],
                      stop_check: Optional[Callable[[], bool]]) -> None:
        if stop_check is not None and stop_check():
            return
        replies = self._broadcast({"cmd": "advance_free",
                                   "max_tick": max_tick})
        self._after_barrier(replies)

    def advance(self, max_tick: Optional[int] = None,
                stop_check: Optional[Callable[[], bool]] = None) -> bool:
        if self._mode is None:
            raise RuntimeError("advance() before begin()/restore()")
        if self._mode == "serial":
            return self._facade.advance(max_tick, stop_check)
        if self._collected is not None:
            if self.done() or self._draining:
                return self.done()
            raise RuntimeError("cannot advance a collected parallel run "
                               "(restore from its checkpoint instead)")
        if self._mode == "sync":
            self._advance_sync(max_tick, stop_check)
        else:
            self._advance_free(max_tick, stop_check)
        return self.done()

    def done(self) -> bool:
        if self._mode in (None, "serial"):
            return self._facade.done()
        return all(w["done"] for w in self._winfo)

    # -- drain / snapshot / result ----------------------------------------
    def drain(self) -> bool:
        if self._mode == "serial":
            return self._facade.drain()
        self._draining = True
        self._facade._draining = True
        if self._collected is None:
            self._broadcast({"cmd": "drain"})
            return self.advance()
        return self.done()

    def drained(self) -> bool:
        if self._mode == "serial":
            return self._facade.drained()
        return (self._mode is not None and self._draining
                and not self._pending
                and all(w.get("idle") for w in self._winfo))

    def snapshot(self) -> Dict[str, Any]:
        if self._mode == "serial":
            return self._facade.snapshot()
        if not self.drained():
            raise RuntimeError("snapshot() requires drain() first "
                               "(gem5: drain-then-serialize)")
        self._collect()
        return self._facade.snapshot()

    def result(self) -> ExecResult:
        if self._mode == "serial":
            return self._facade.result()
        self._collect()
        return self._facade.result()

    def _collect(self) -> None:
        """Pull worker shard state into the facade executor (expanding
        folded clones), after which the facade's own ``snapshot()`` /
        ``result()`` produce serial-format, serial-identical output.
        Workers are released afterwards — a collected engine answers
        any number of snapshot/result calls but cannot advance."""
        if self._collected is not None:
            return
        replies = self._broadcast({"cmd": "collect"})
        f = self._facade
        ins = f.instrument
        if ins is not None:
            for widx, rep in enumerate(replies):
                ins.add_worker(widx, rep["labels"], rep["members"],
                               rep.get("trace_rows", []))
        dbg.dprintf("Parallel", "engine", "collected %d workers",
                    len(replies), tick=self.now)
        deferred: List[Tuple[Tuple[int, int], int, int, int]] = []
        for rep in replies:
            members = rep["members"]
            for i in range(len(rep["labels"])):
                for g in members[i]:
                    f._op_end[g] = list(rep["op_end"][i])
                    f._chips[g]._free = int(rep["chip_free"][i])
                    net = f._wires[g]._net
                    for x, y, d, busy, nbytes, transfers in rep["wires"][i]:
                        link = net._link(int(x), int(y), d)
                        link.busy_until = busy
                        link.bytes_carried = nbytes
                        link.transfers = int(transfers)
                    f._wires[g]._busy_hwm = int(rep["wire_busy"][i])
                    q = f._queues[g]
                    q.events_fired = int(rep["queues"][i]["events_fired"])
                    q.run_until(int(rep["queues"][i]["now"]))
                    # per-pod stats subtrees are disjoint across pods, so
                    # this merge is exact (merge into untouched == adopt)
                    for kind, sds in (("chip", rep["chip_stats"]),
                                      ("wire", rep["wire_stats"])):
                        if sds[i] is not None:
                            f.sim_root.stats.merge_state_dict(
                                {"children": {f"{kind}{g}": sds[i]}})
            for (p, idx, ready), tag in zip(rep["deferred"],
                                            rep["defer_tags"]):
                for g in members[p]:
                    deferred.append(((int(tag[0]), int(tag[1])),
                                     g, int(idx), int(ready)))
            if any(0 in mm for mm in members):
                f._totals = {k: float(v) for k, v in rep["totals"].items()}
                f._timeline = list(rep["timeline"])
        # serial chronological order: (barrier era | tick, pod, seq)
        deferred.sort(key=lambda e: (e[0][0], e[1], e[0][1]))
        f._deferred = [(g, idx, ready) for _, g, idx, ready in deferred]
        f._ncomplete = sum(1 for row in f._op_end for e in row if e >= 0)
        self._collected = replies
        self.close()

    # -- teardown ----------------------------------------------------------
    def close(self) -> None:
        """Shut worker processes down (idempotent; the facade and any
        collected state stay usable)."""
        conns, procs = self._conns, self._procs
        self._conns, self._procs = [], []
        if self._finalizer is not None:
            self._finalizer.detach()
            self._finalizer = None
        if conns or procs:
            _shutdown(conns, procs)

    # -- one-shot ----------------------------------------------------------
    def execute(self, trace: HloTrace) -> ExecResult:
        self.begin(trace)
        self.advance()
        res = self.result()
        self.close()
        return res

    # -- dynamic workloads -------------------------------------------------
    def inject_op(self, op, ready: int, pod: int = 0) -> int:
        if self._mode == "serial":
            return self._facade.inject_op(op, ready, pod)
        raise RuntimeError(
            "inject_op() on a sharded parallel run: dynamic workloads "
            "run serially (repro.sim.Simulator arranges this)")
