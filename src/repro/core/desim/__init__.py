"""Discrete-event timing models of TPU pods (gem5's detailed models).

This package is the g5x analogue of gem5's model library: parameterized
machine components (``machine``), a pluggable network/collective layer
(``network``, ``collectives`` — the Ruby/Garnet analogue), elastic
execution traces (``trace`` — §2.8), and the event-driven executor that
replays a trace on a machine (``executor``), including dist-gem5-style
quantum-synchronized multi-pod simulation (§2.17).
"""

from repro.core.desim.machine import (  # noqa: F401
    ChipModel, PodModel, ClusterModel, TPU_V5E, default_cluster)
from repro.core.desim.trace import HloTrace, TraceOp  # noqa: F401
from repro.core.desim.simnodes import (  # noqa: F401
    ChipSim, ClusterSim, DcnSim, WireSim)
from repro.core.desim.timing import (  # noqa: F401
    AtomicTiming, DetailedTiming, TimingModel, get_timing_model)
from repro.core.desim.executor import (  # noqa: F401
    ExecResult, TraceExecutor, predict_step_time)
