"""SimObject components of the event-driven trace executor.

This is where the four core layers of the engine meet, the way they do
in gem5 itself (paper §1.3.1): ``SimObject``s with typed ``Param``s and
``StatGroup`` counters, wired through the ``Port`` API, scheduling their
completion events on the deterministic ``EventQueue``:

* :class:`ChipSim`  — one representative chip per pod; serializes
  compute regions on the chip's compute resource at roofline time.
* :class:`WireSim`  — the pod's ICI torus; collectives occupy concrete
  directed :class:`~repro.core.desim.network.LinkState` links
  (dimension-ordered routing, Garnet-style contention §2.13): two
  collectives whose regions share a link serialize, disjoint regions
  proceed in parallel.
* :class:`DcnSim`   — the shared inter-pod fabric; cross-pod collectives
  rendezvous here and complete through ``QuantumSync`` at a quantum
  boundary (dist-gem5 §2.17).
* :class:`ClusterSim` — the root of the per-run SimObject tree; its
  ``stats`` group is the gem5-style stats tree ``record_stats=True``
  dumps.

Topology is port-connected: each chip's ``coll`` requestor port plugs
into its wire's ``chip_in`` responder; each wire's ``dcn_out`` requestor
plugs into one ``DcnSim`` pod-side responder.  The port hop is gem5's
*atomic* protocol (synchronous arbitration); timing is realized by the
events the responder schedules (the *timing* protocol layered on top).

All resource bookkeeping is in integer ticks (1 tick = 1 ns), never
float seconds: determinism comes from the tick engine, not float
rounding order.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from repro.core import trace as dbg
from repro.core.desim.collectives import CollectiveAlgorithm
from repro.core.desim.machine import ClusterModel
from repro.core.desim.network import LinkState, TorusNetwork
from repro.core.events import EventQueue, QuantumSync
from repro.core.ports import PortError, PortSet
from repro.core.simobject import Param, SimObject

TICKS_PER_S = 1_000_000_000  # 1 tick = 1 ns (gem5 uses 1 ps)


def to_ticks(seconds: float) -> int:
    return int(round(seconds * TICKS_PER_S))


# completion callback: (start_tick, end_tick, payload) -> None
DoneFn = Callable[[int, int, dict], None]


class ChipSim(SimObject):
    """One representative chip of a pod (SPMD: every chip in the pod
    executes the same trace, so one chip + shared wires is exact for
    timing while keeping DES cost O(ops x pods))."""

    pod_id = Param(int, 0, "which pod this chip represents")
    slowdown = Param(float, 1.0, "straggler multiplier",
                     check=lambda v: v > 0)

    def __init__(self, name: str, model, queue: EventQueue, **params):
        super().__init__(name, **params)
        self._model = model          # machine.ChipModel (shared, frozen)
        self._eq = queue
        self._free = 0               # compute resource free tick
        self.ports = PortSet(self)
        self.coll_port = self.ports.requestor("coll", "collective")
        s = self.stats
        self.st_ops = s.scalar("ops_executed", "compute regions run")
        self.st_busy = s.scalar("busy_seconds", "compute busy time", "s")
        self.st_wait = s.distribution("queue_wait_seconds",
                                      "wait for the compute resource", "s")

    def startup(self) -> None:
        if self.ports.unconnected():
            raise PortError(f"{self.path}: unconnected ports "
                            f"{self.ports.unconnected()}")

    # ------------------------------------------------------------------
    def acquire(self, ready: int, flops: float,
                nbytes: float) -> Tuple[int, int]:
        """Arbitrate the compute resource: serialize at roofline time
        on the chip's integer free tick, record stats, and return
        ``(start, end)``.  Shared by both timing models — a chip is one
        instruction stream even at atomic fidelity."""
        dur = to_ticks(self._model.compute_time_s(flops, nbytes)
                       * self.slowdown)
        start = max(ready, self._free)
        end = start + dur
        self._free = end
        self.st_ops.inc()
        self.st_busy.inc(dur / TICKS_PER_S)
        self.st_wait.sample((start - ready) / TICKS_PER_S)
        if dbg._ACTIVE:
            dbg.dprintf("Chip", self,
                        "compute flops=%.3e start=%d dur=%d wait=%d",
                        flops, start, dur, start - ready, tick=end)
        return start, end

    def exec_compute(self, ready: int, flops: float, nbytes: float,
                     payload: dict) -> None:
        """Arbitrate the compute resource and schedule the completion
        (``payload['done']`` — same handoff as the wire/fabric path)."""
        done: DoneFn = payload["done"]
        start, end = self.acquire(ready, flops, nbytes)
        self._eq.schedule(lambda: done(start, end, payload), end,
                          name=payload.get("name", "compute"))

    def issue_collective(self, payload: dict) -> None:
        """Hand a collective to the wire through the port."""
        self.coll_port.send(payload)

    @property
    def free_tick(self) -> int:
        return self._free


class WireSim(SimObject):
    """The pod's ICI torus wire, with per-link occupancy.

    A collective's ring occupies the four directed links of every chip
    in its ``region`` (default: the whole pod) for the duration the
    collective algorithm predicts; ``collective-permute`` additionally
    walks a dimension-ordered route between the region's corners.  Link
    arbitration is ``max(busy_until)`` over the footprint — exactly the
    Garnet serialization rule at message granularity.
    """

    pod_id = Param(int, 0, "which pod this wire belongs to")
    contention = Param(bool, True, "serialize on shared links")

    def __init__(self, name: str, machine: ClusterModel,
                 algorithm: CollectiveAlgorithm, queue: EventQueue,
                 **params):
        super().__init__(name, **params)
        self._machine = machine
        self._alg = algorithm
        self._eq = queue
        self._busy_hwm = 0   # atomic-mode wire-occupancy high-water tick
        pod = machine.pod
        self._net = TorusNetwork(pod.nx, pod.ny, pod.ici.bw,
                                 pod.ici.latency_s)
        # region -> link list; LinkState objects are created once per
        # link, so caching keeps arbitration O(footprint hits) instead
        # of O(nx*ny) dict lookups per collective (the DSE hot path)
        self._footprints: Dict[Optional[Tuple[int, int, int, int]],
                               List[LinkState]] = {}
        self.ports = PortSet(self)
        self.chip_port = self.ports.responder("chip_in", "collective",
                                              handler=self._on_request)
        self.dcn_port = self.ports.requestor("dcn_out", "dcn")
        s = self.stats
        self.st_colls = s.scalar("collectives", "intra-pod collectives")
        self.st_bytes = s.scalar("bytes_on_wire", "payload bytes", "B")
        self.st_busy = s.scalar("busy_seconds", "wire occupancy", "s")
        self.st_wait = s.distribution("link_wait_seconds",
                                      "wait for contended links", "s")
        s.formula("links_used", lambda: float(len(self._net.links)),
                  "distinct directed links touched")

    def startup(self) -> None:
        if self.ports.unconnected():
            raise PortError(f"{self.path}: unconnected ports "
                            f"{self.ports.unconnected()}")

    # ------------------------------------------------------------------
    def _footprint(self, region: Optional[Tuple[int, int, int, int]]
                   ) -> List[LinkState]:
        """Directed links a ring collective over ``region`` occupies."""
        if region is not None:
            region = tuple(region)  # JSON-style lists must hash too
        cached = self._footprints.get(region)
        if cached is not None:
            return cached
        net = self._net
        x0, y0, w, h = region or (0, 0, net.nx, net.ny)
        links: List[LinkState] = []
        for dx in range(w):
            for dy in range(h):
                x, y = x0 + dx, y0 + dy
                for d in ("+x", "-x", "+y", "-y"):
                    links.append(net._link(x, y, d))
        self._footprints[region] = links
        return links

    def _on_request(self, payload: dict) -> dict:
        if payload.get("dcn"):
            # cross-pod: forward to the fabric through the dcn port
            return self.dcn_port.send(payload)

        ready = payload["ready"]
        kind, nbytes = payload["kind"], payload["nbytes"]
        region = payload.get("region")
        dur = to_ticks(self._alg.time_s(kind, nbytes,
                                        payload["participants"],
                                        self._machine))
        links = self._footprint(region)
        if kind == "collective-permute" and region:
            # point-to-point: dimension-ordered route between corners
            # (copy first — the footprint list is cached per region)
            x0, y0, w, h = region
            links = list(links)
            for hop in self._net.route((x0, y0),
                                       (x0 + w - 1, y0 + h - 1)):
                links.append(self._net._link(*hop))
        if self.contention:
            start = max([ready] + [int(l.busy_until) for l in links])
        else:
            start = ready
        end = start + dur
        share = nbytes / max(len(links), 1)
        for l in links:
            # never rewind occupancy: with contention off, transfers may
            # complete out of order and busy_until is a high-water mark
            l.busy_until = max(l.busy_until, end)
            l.bytes_carried += share
            l.transfers += 1
        payload.update(start=start, end=end, dur=dur)
        self.st_colls.inc()
        self.st_bytes.inc(nbytes)
        self.st_busy.inc(dur / TICKS_PER_S)
        self.st_wait.sample((start - ready) / TICKS_PER_S)
        if dbg._ACTIVE:
            if start > ready:
                dbg.dprintf("Wire.Contention", self,
                            "%s waited %d ticks on contended links",
                            payload.get("name", kind), start - ready,
                            tick=start)
            dbg.dprintf("Wire", self,
                        "%s kind=%s nbytes=%g links=%d start=%d dur=%d",
                        payload.get("name", kind), kind, nbytes,
                        len(links), start, dur, tick=end)
        done = payload["done"]
        self._eq.schedule(lambda: done(start, end, payload), end,
                          name=payload.get("name", kind))
        return payload

    def record_atomic(self, nbytes: float, dur: int, end: int) -> None:
        """Account a contention-free (AtomicTiming) collective: same
        counters as the detailed path, zero link wait, no link state."""
        self.st_colls.inc()
        self.st_bytes.inc(nbytes)
        self.st_busy.inc(dur / TICKS_PER_S)
        self.st_wait.sample(0.0)
        self._busy_hwm = max(self._busy_hwm, int(end))
        if dbg._ACTIVE:
            dbg.dprintf("Wire", self, "atomic collective nbytes=%g dur=%d",
                        nbytes, dur, tick=end)

    def busy_tick(self) -> int:
        if not self._net.links:
            return self._busy_hwm
        return max(self._busy_hwm,
                   int(max(l.busy_until for l in self._net.links.values())))


class DcnSim(SimObject):
    """Shared inter-pod fabric driven by ``QuantumSync``.

    A cross-pod collective is ONE fabric transaction: each pod's replica
    arrives through its wire's ``dcn_out`` port; when the last pod has
    arrived the transaction claims every pod uplink (serializing with
    any other in-flight cross-pod collective) and its completion is
    delivered to every pod's event queue via ``QuantumSync.send`` — i.e.
    at the first quantum boundary the dist-gem5 error model allows, at
    least one quantum after the last arrival.
    """

    num_pods = Param(int, 1, "pods on the fabric", check=lambda v: v >= 1)
    contention = Param(bool, True, "serialize on the pod uplinks")

    def __init__(self, name: str, machine: ClusterModel,
                 algorithm: CollectiveAlgorithm,
                 queues: List[EventQueue], sync: Optional[QuantumSync],
                 capture: Optional[Callable[[dict], None]] = None,
                 **params):
        super().__init__(name, **params)
        self._machine = machine
        self._alg = algorithm
        self._queues = queues
        self._sync = sync
        # parallel-shard mode: arrivals are forwarded to the capture
        # callback (and on to the coordinator process, which owns the
        # one true fabric) instead of rendezvousing locally
        self._capture = capture
        self.uplinks = [LinkState() for _ in range(len(queues))]
        self._rendezvous: Dict[int, dict] = {}
        self.ports = PortSet(self)
        self.pod_ports = [self.ports.responder(f"pod{p}", "dcn",
                                               handler=self._on_arrive)
                          for p in range(len(queues))]
        s = self.stats
        self.st_colls = s.scalar("collectives", "cross-pod collectives")
        self.st_bytes = s.scalar("bytes_on_fabric", "payload bytes", "B")
        self.st_busy = s.scalar("busy_seconds", "fabric occupancy", "s")
        self.st_skew = s.distribution("arrival_skew_seconds",
                                      "first-to-last pod arrival skew", "s")

    # ------------------------------------------------------------------
    def _on_arrive(self, payload: dict) -> dict:
        if dbg._ACTIVE:
            dbg.dprintf("Dcn", self, "%s op=%d arrive pod=%d",
                        payload.get("name", payload.get("kind", "dcn")),
                        payload["op_idx"], payload.get("pod", -1),
                        tick=payload["ready"])
        if self._capture is not None:
            self._capture(payload)
            return payload
        key = payload["op_idx"]
        r = self._rendezvous.setdefault(
            key, {"arrived": 0, "first": payload["ready"], "last": 0,
                  "waiters": []})
        r["arrived"] += 1
        r["first"] = min(r["first"], payload["ready"])
        r["last"] = max(r["last"], payload["ready"])
        r["waiters"].append(payload)
        if r["arrived"] < self.num_pods:
            return payload
        del self._rendezvous[key]

        dur = to_ticks(self._alg.time_s(payload["kind"], payload["nbytes"],
                                        payload["participants"],
                                        self._machine))
        if self.contention:
            start = max([r["last"]]
                        + [int(l.busy_until) for l in self.uplinks])
        else:
            start = r["last"]
        end = start + dur
        for l in self.uplinks:
            l.busy_until = max(l.busy_until, end)
            l.bytes_carried += payload["nbytes"] / len(self.uplinks)
            l.transfers += 1
        self.st_colls.inc()
        self.st_bytes.inc(payload["nbytes"])
        self.st_busy.inc(dur / TICKS_PER_S)
        self.st_skew.sample((r["last"] - r["first"]) / TICKS_PER_S)
        if dbg._ACTIVE:
            dbg.dprintf("Dcn", self,
                        "%s op=%d fire start=%d dur=%d skew=%d waiters=%d",
                        payload.get("name", payload.get("kind", "dcn")),
                        key, start, dur, r["last"] - r["first"],
                        len(r["waiters"]), tick=end)

        for w in r["waiters"]:
            w.update(start=start, dur=dur)
            q = self._queues[w["pod"]]
            done = w["done"]
            if self._sync is not None:
                # delivered at a quantum boundary >= end (dist-gem5)
                self._sync.send(
                    r["last"], q,
                    (lambda w=w, q=q, done=done, start=start:
                     done(start, q.now, w)),
                    latency=end - r["last"])
            else:
                # no quantum model: deliver at the exact tick — unless
                # that queue already drained past it (the executor runs
                # unsynchronized queues to completion one at a time)
                at = max(end, q.now)
                q.schedule(lambda w=w, done=done, start=start, at=at:
                           done(start, at, w), at,
                           name=w.get("name", "dcn"))
        return payload

    def record_atomic(self, nbytes: float, dur: int, skew: int) -> None:
        """Account a contention-free (AtomicTiming) cross-pod
        collective: same counters as the detailed path, no uplink
        state, no quantum rounding."""
        self.st_colls.inc()
        self.st_bytes.inc(nbytes)
        self.st_busy.inc(dur / TICKS_PER_S)
        self.st_skew.sample(skew / TICKS_PER_S)
        if dbg._ACTIVE:
            dbg.dprintf("Dcn", self,
                        "atomic transaction nbytes=%g dur=%d skew=%d",
                        nbytes, dur, skew)

    def busy_tick(self) -> int:
        if not self.uplinks:
            return 0
        return int(max(l.busy_until for l in self.uplinks))


class ClusterSim(SimObject):
    """Root of the per-run simulation tree (``sim`` in stats dumps)."""

    num_pods = Param(int, 1, "pods simulated", check=lambda v: v >= 1)
    quantum_ns = Param(int, 100_000, "dist-gem5 sync quantum (ticks)")
