"""Parameterized TPU machine models (gem5's CPU/DRAM model analogue).

gem5 ships "parameterized models for a wide number of components"; the
user configures them from Python and the event engine gives timing.
Here the components are TPU chips, ICI-connected pods, and DCN-connected
clusters.  Every number is a ``Param`` so design-space exploration over
hardware (the canonical gem5 use case) works: double HBM bandwidth,
re-run the trace, read the new step time — no recompilation (elastic
traces, §2.8).

Roofline terms (EXPERIMENTS.md §Roofline) are derived from these same
parameters, so desim and roofline are always consistent.

Hardware constants for the target (TPU v5e, per chip):
  peak bf16 compute 197 TFLOP/s ; HBM BW 819 GB/s ; ICI ~50 GB/s/link.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.core.simobject import Param, SimObject


class ChipModel(SimObject):
    """One accelerator chip (the 'CPU core model')."""

    peak_flops = Param(float, 197e12, "peak bf16 FLOP/s (MXU)")
    hbm_bw = Param(float, 819e9, "HBM bandwidth B/s")
    hbm_bytes = Param(float, 16e9, "HBM capacity bytes")
    vmem_bytes = Param(float, 128e6, "VMEM capacity bytes")
    # derates: achievable fraction of peak (gem5 models expose similar
    # efficiency knobs, e.g. DRAM bus utilization)
    mxu_efficiency = Param(float, 0.85, "achievable MXU fraction for big GEMMs")
    hbm_efficiency = Param(float, 0.8, "achievable HBM fraction")
    # clock-skew multiplier used for straggler injection (1.0 = nominal)
    slowdown = Param(float, 1.0, "straggler multiplier", check=lambda v: v > 0)

    def compute_time_s(self, flops: float, bytes_accessed: float) -> float:
        """Roofline execution time of one fused region on this chip."""
        tc = flops / (self.peak_flops * self.mxu_efficiency)
        tm = bytes_accessed / (self.hbm_bw * self.hbm_efficiency)
        return max(tc, tm) * self.slowdown


class LinkModel(SimObject):
    """One ICI/DCN link."""

    bw = Param(float, 50e9, "bandwidth B/s per direction")
    latency_s = Param(float, 1e-6, "per-hop latency seconds")

    def transfer_time_s(self, nbytes: float) -> float:
        return self.latency_s + nbytes / self.bw


class PodModel(SimObject):
    """A 2-D torus of chips (one TPU v5e pod = 16x16)."""

    nx = Param(int, 16, "torus x dimension")
    ny = Param(int, 16, "torus y dimension")

    def __init__(self, name: str = "pod", chip: Optional[ChipModel] = None,
                 ici: Optional[LinkModel] = None, **kw):
        super().__init__(name, **kw)
        self.chip = chip or ChipModel("chip")
        self.ici = ici or LinkModel("ici")

    @property
    def num_chips(self) -> int:
        return self.nx * self.ny

    def axis_links(self) -> int:
        """Usable torus links per chip (4 for a 2-D torus: +-x, +-y)."""
        return 4

    def bisection_bw(self) -> float:
        """Pod bisection bandwidth (B/s) of the 2-D torus."""
        # cutting a 2-D torus in half crosses 2*min(nx,ny) links,
        # times 2 for the wraparound
        return 2 * 2 * min(self.nx, self.ny) * self.ici.bw


class DcnModel(LinkModel):
    """Inter-pod data-center network (dist-gem5's TCP analogue)."""

    bw = Param(float, 12.5e9, "per-host DCN bandwidth B/s (100 Gb/s)")
    latency_s = Param(float, 10e-6, "cross-pod latency seconds")


class ClusterModel(SimObject):
    """Pods x PodModel joined by DCN."""

    num_pods = Param(int, 1, "number of pods", check=lambda v: v >= 1)
    # dist-gem5 quantum for multi-pod DES synchronization (ns ticks)
    quantum_ns = Param(int, 100_000, "sync quantum in ns")
    # cost context for sharded simulation: a dist-gem5 shard machine
    # carries only its own pods (num_pods = shard size) but collective
    # cost models must price the *global* topology; 0 = "I am the whole
    # machine" (the default for every non-shard machine)
    global_num_pods = Param(int, 0, "global pod count when this machine "
                            "is a shard of a larger one (0 = not a shard)",
                            check=lambda v: v >= 0)

    def __init__(self, name: str = "cluster", pod: Optional[PodModel] = None,
                 dcn: Optional[DcnModel] = None, **kw):
        super().__init__(name, **kw)
        self.pod = pod or PodModel("pod")
        self.dcn = dcn or DcnModel("dcn")

    @property
    def num_chips(self) -> int:
        return self.num_pods * self.pod.num_chips

    @property
    def total_pods(self) -> int:
        """Pod count of the machine this model *represents*: the global
        count for a shard (``global_num_pods`` set by ParallelEngine),
        ``num_pods`` otherwise.  Collective cost models must use this so
        a shard prices DCN phases identically to the full machine."""
        return self.global_num_pods or self.num_pods

    # -- roofline terms (per step, whole machine) -----------------------
    def roofline_terms(self, total_flops: float, total_bytes: float,
                       collective_bytes: float) -> dict:
        """The three §Roofline terms, in seconds.

        Definitions follow the assignment exactly:
          compute    = HLO_FLOPs / (chips * peak)
          memory     = HLO_bytes / (chips * HBM_bw)
          collective = collective_bytes / (chips * link_bw)

        where the per-chip totals are whole-program sums divided evenly
        over chips (the dry-run cost model is per-device already; callers
        pass per-device totals with chips=1, or global totals).
        """
        chips = self.num_chips
        compute = total_flops / (chips * self.pod.chip.peak_flops)
        memory = total_bytes / (chips * self.pod.chip.hbm_bw)
        coll = collective_bytes / (chips * self.pod.ici.bw)
        dominant = max(("compute", compute), ("memory", memory),
                       ("collective", coll), key=lambda kv: kv[1])[0]
        return {"compute_s": compute, "memory_s": memory,
                "collective_s": coll, "dominant": dominant,
                "bound_s": max(compute, memory, coll)}


# Catalog entry for the target hardware (like gem5's DDR3_1600_8x8 etc.)
TPU_V5E = dict(peak_flops=197e12, hbm_bw=819e9, hbm_bytes=16e9,
               vmem_bytes=128e6)


def default_cluster(mesh=None) -> ClusterModel:
    """Build the production machine matching a jax mesh (or 1 pod)."""
    num_pods = 1
    if mesh is not None and "pod" in mesh.shape:
        num_pods = mesh.shape["pod"]
    c = ClusterModel("cluster", num_pods=num_pods)
    c.instantiate()
    return c


@dataclass
class MachineSnapshot:
    """Plain-dict view used by benchmarks and JSON dumps."""

    chips: int
    peak_flops: float
    hbm_bw: float
    ici_bw: float
    dcn_bw: float

    @classmethod
    def of(cls, m: ClusterModel) -> "MachineSnapshot":
        return cls(chips=m.num_chips, peak_flops=m.pod.chip.peak_flops,
                   hbm_bw=m.pod.chip.hbm_bw, ici_bw=m.pod.ici.bw,
                   dcn_bw=m.dcn.bw)
