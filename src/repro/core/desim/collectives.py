"""Pluggable collective-algorithm models (the Ruby/SLICC analogue).

gem5's Ruby lets researchers swap *cache-coherence protocols* without
touching the rest of the system (§2.12); the protocol determines how
bytes move between caches.  On a TPU pod the analogous protocol is the
*collective algorithm*: how all-reduce / all-gather / reduce-scatter /
all-to-all bytes move over the ICI torus and the DCN.  g5x makes the
algorithm a plug-in: each is a small class with a closed-form cost
model plus an event-level phase generator, registered by name and
selectable per simulation — exactly how SLICC protocols are selected
per build/config.

Cost-model conventions (n participants, payload S bytes = the *global*
logical tensor size, link bandwidth B per direction, per-hop latency L):

* ring all-reduce        : 2(n-1)/n * S / B        + 2(n-1) L
* ring all-gather        :  (n-1)/n * S / B        +  (n-1) L
* ring reduce-scatter    :  (n-1)/n * S / B        +  (n-1) L
* bidirectional ring     : ring / 2 (both directions used)
* 2-D torus (v5e)        : reduce-scatter along x then y, all-gather
                           back; each phase uses both axis directions.
* hierarchical (pods)    : intra-pod reduce-scatter, inter-pod
                           all-reduce over DCN on 1/n_pod shard,
                           intra-pod all-gather (dist-gem5 layering).
* all-to-all             : each chip sends S/n to n-1 peers; torus
                           bisection-limited.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.core.desim.machine import ClusterModel


@dataclass
class Phase:
    """One timed phase of a collective (for the event executor)."""

    name: str
    time_s: float
    bytes_on_wire: float


class CollectiveAlgorithm:
    """Base plug-in.  ``kind`` names the HLO op it models."""

    name = "abstract"

    def time_s(self, kind: str, nbytes: float, participants: int,
               machine: ClusterModel) -> float:
        return sum(p.time_s for p in self.phases(kind, nbytes, participants,
                                                 machine))

    def phases(self, kind: str, nbytes: float, participants: int,
               machine: ClusterModel) -> List[Phase]:
        raise NotImplementedError


def _ring(kind: str, S: float, n: int, bw: float, lat: float,
          bidir: bool = False) -> List[Phase]:
    if n <= 1 or S <= 0:
        return [Phase(kind, 0.0, 0.0)]
    eff_bw = bw * (2 if bidir else 1)
    if kind == "all-reduce":
        t = 2 * (n - 1) / n * S / eff_bw + 2 * (n - 1) * lat
        wire = 2 * (n - 1) / n * S
    elif kind in ("all-gather", "reduce-scatter"):
        t = (n - 1) / n * S / eff_bw + (n - 1) * lat
        wire = (n - 1) / n * S
    elif kind == "all-to-all":
        # ring a2a: each step shifts S/n; n-1 steps; bisection-limited
        t = (n - 1) / n * S / eff_bw + (n - 1) * lat
        wire = (n - 1) / n * S
    elif kind == "collective-permute":
        t = S / eff_bw + lat
        wire = S
    else:
        raise ValueError(f"unknown collective kind {kind!r}")
    return [Phase(f"{kind}/ring", t, wire)]


class RingAlgorithm(CollectiveAlgorithm):
    name = "ring"

    def phases(self, kind, nbytes, participants, machine):
        ici = machine.pod.ici
        return _ring(kind, nbytes, participants, ici.bw, ici.latency_s)


class BidirRingAlgorithm(CollectiveAlgorithm):
    name = "bidir-ring"

    def phases(self, kind, nbytes, participants, machine):
        ici = machine.pod.ici
        return _ring(kind, nbytes, participants, ici.bw, ici.latency_s,
                     bidir=True)


class Torus2DAlgorithm(CollectiveAlgorithm):
    """v5e-native: phase per torus axis, both directions per axis.

    For an all-reduce over n chips arranged ~sqrt(n) x ~sqrt(n):
    reduce-scatter along x (payload S), then along y (payload S/nx),
    then all-gather y, all-gather x.  Each axis ring is bidirectional.
    """

    name = "torus2d"

    def phases(self, kind, nbytes, participants, machine):
        n = participants
        if n <= 1 or nbytes <= 0:
            return [Phase(kind, 0.0, 0.0)]
        pod = machine.pod
        nx = min(pod.nx, n)
        ny = max(1, n // nx)
        ici = pod.ici
        out: List[Phase] = []
        if kind == "all-reduce":
            out += _ring("reduce-scatter", nbytes, nx, ici.bw,
                         ici.latency_s, bidir=True)
            out += _ring("all-reduce", nbytes / nx, ny, ici.bw,
                         ici.latency_s, bidir=True)
            out += _ring("all-gather", nbytes, nx, ici.bw,
                         ici.latency_s, bidir=True)
        elif kind in ("all-gather", "reduce-scatter"):
            out += _ring(kind, nbytes, nx, ici.bw, ici.latency_s, bidir=True)
            if ny > 1:
                out += _ring(kind, nbytes, ny, ici.bw, ici.latency_s,
                             bidir=True)
        elif kind == "all-to-all":
            # bisection-limited: S/2 bytes must cross the bisection
            bis = pod.bisection_bw() * (n / pod.num_chips)
            t = (nbytes / 2) / max(bis, 1.0) + math.sqrt(n) * ici.latency_s
            out = [Phase("all-to-all/torus", t, nbytes / 2)]
        elif kind == "collective-permute":
            out = _ring(kind, nbytes, n, ici.bw, ici.latency_s, bidir=True)
        else:
            raise ValueError(kind)
        return out


class HierarchicalAlgorithm(CollectiveAlgorithm):
    """Cross-pod: intra-pod RS (ICI) -> inter-pod AR (DCN) -> intra-pod AG.

    The dist-gem5 layering: fast local interconnect inside a node
    (pod), slow TCP (DCN) between nodes, synchronized at quanta.
    """

    name = "hierarchical"

    def phases(self, kind, nbytes, participants, machine):
        # total_pods, not num_pods: on a dist-gem5 shard machine the DCN
        # ring spans the *global* pod count (ParallelEngine sets
        # machine.global_num_pods), so shard and serial cost identically
        pods = getattr(machine, "total_pods", None) or machine.num_pods
        per_pod = max(1, participants // max(pods, 1))
        ici = machine.pod.ici
        dcn = machine.dcn
        if pods <= 1:
            return Torus2DAlgorithm().phases(kind, nbytes, participants,
                                             machine)
        out: List[Phase] = []
        if kind == "all-reduce":
            out += _ring("reduce-scatter", nbytes, per_pod, ici.bw,
                         ici.latency_s, bidir=True)
            # DCN AR on the 1/per_pod shard; hosts move bytes in parallel,
            # so the shard is further split over the hosts of a pod.
            shard = nbytes / per_pod
            out += _ring("all-reduce", shard, pods, dcn.bw, dcn.latency_s)
            out += _ring("all-gather", nbytes, per_pod, ici.bw,
                         ici.latency_s, bidir=True)
        else:
            out += Torus2DAlgorithm().phases(kind, nbytes, per_pod, machine)
            shard = nbytes / max(per_pod, 1)
            out += _ring(kind, shard, pods, dcn.bw, dcn.latency_s)
        return out


ALGORITHMS: Dict[str, CollectiveAlgorithm] = {
    a.name: a for a in (RingAlgorithm(), BidirRingAlgorithm(),
                        Torus2DAlgorithm(), HierarchicalAlgorithm())
}


def get_algorithm(name: str) -> CollectiveAlgorithm:
    try:
        return ALGORITHMS[name]
    except KeyError:
        raise ValueError(
            f"unknown collective algorithm {name!r}; one of {list(ALGORITHMS)}")


def best_algorithm(kind: str, nbytes: float, participants: int,
                   machine: ClusterModel) -> Tuple[str, float]:
    """Auto-select (what XLA's collective scheduler would pick)."""
    best = None
    for name, alg in ALGORITHMS.items():
        t = alg.time_s(kind, nbytes, participants, machine)
        if best is None or t < best[1]:
            best = (name, t)
    return best
