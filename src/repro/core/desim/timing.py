"""Pluggable timing models: the gem5 CPU-model fidelity ladder (§1.3.1).

gem5's hallmark is that one system description runs under
interchangeable CPU models spanning a fidelity/speed spectrum — atomic
for fast-forward, detailed (timing/O3) for the region of interest —
with mid-run switching (``switch_cpus``) making sampled simulation
practical.  This module is the desim analogue: a :class:`TimingModel`
decides *how an issued op turns into completion ticks*, and the rest of
the stack (dependency bookkeeping, hooks, drain/snapshot/restore, the
``repro.sim`` front-end) is model-agnostic.

Two models:

* :class:`DetailedTiming` — today's full-contention semantics, bit-for-
  bit: compute serializes on the chip, intra-pod collectives occupy the
  concrete torus ``LinkState`` links of their region (shared links
  serialize), cross-pod collectives rendezvous on the DCN fabric and
  complete through ``QuantumSync`` at a quantum boundary.  Every
  completion is an engine event on a pod ``EventQueue``.

* :class:`AtomicTiming` — contention-free analytical op costing
  (gem5's atomic mode): compute still serializes on the chip resource
  (a chip is one instruction stream even without contention), but
  collectives start at their ready tick with the closed-form algorithm
  cost — no link state is touched, no quantum model applies, and
  completions are resolved on the model's own batch heap instead of
  engine events.  A full static-trace run fires ~zero engine events;
  wall time drops by the whole link-arbitration + event-dispatch cost.

Exactness: on a *contention-free* trace (chain dependencies — no two
collectives in flight on shared links, no quantum rounding, i.e. single
pod or ``quantum_ns=0``), atomic and detailed produce identical op
ticks and identical stats, which is what makes mid-run switching exact
there and a controlled approximation elsewhere (see
``docs/fidelity.md``).

Switching: a drained run snapshots to a plain dict
(``TraceExecutor.snapshot``) and may be **restored under a different
model** — the gem5 ``switch_cpus`` move, surfaced as
``repro.sim.Simulator.switch_timing``.  Both models therefore speak the
same snapshot vocabulary: the deferred issue frontier and partial DCN
rendezvous re-enter through :meth:`TimingModel.restore_issue` /
:meth:`TimingModel.restore_arrival`.
"""

from __future__ import annotations

import heapq
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import trace as dbg


class TimingModel:
    """How issued ops turn into completion ticks (one instance per
    executor run — models are stateful between ``reset`` calls)."""

    name = "abstract"
    #: True when link-level contention and the quantum error model are
    #: simulated (the ``Detailed`` end of the fidelity ladder)
    detailed = True
    #: True when cross-pod (dcn) traffic under this model is exchanged
    #: only at quantum boundaries — the property that lets the
    #: multiprocess engine (repro.core.desim.parallel) shard pods across
    #: workers bit-exactly.  Models that deliver dcn completions at
    #: exact ticks (atomic) need the global tick-ordered merge and fall
    #: back to the serial path when the trace has dcn ops.
    parallel_dcn_ok = False

    # -- lifecycle -------------------------------------------------------
    def reset(self, ex) -> None:
        """Clear per-run state (called from begin()/restore() setup)."""

    def issue(self, ex, p: int, idx: int, ready: int) -> None:
        """Cost op ``idx`` on pod ``p``, ready at tick ``ready``, and
        arrange for ``ex._on_done(start, end, payload)`` to run at its
        completion tick."""
        raise NotImplementedError

    def advance(self, ex, max_tick: Optional[int],
                stop_check: Optional[Callable[[], bool]]) -> None:
        """Fire pending completions up to ``max_tick`` (or until
        ``stop_check()`` pauses the run)."""
        raise NotImplementedError

    def quiescent(self, ex) -> bool:
        """True when the model holds no pending completions/issues
        (required for ``drained()``)."""
        return True

    # -- checkpointing ----------------------------------------------------
    def rendezvous_state(self, ex) -> List[Dict[str, Any]]:
        """Partial cross-pod rendezvous, as ``{"op_idx", "arrivals":
        [[pod, ready], ...]}`` rows (the snapshot format both models
        share, so a checkpoint restores under either)."""
        return []

    def restore_arrival(self, ex, p: int, idx: int, ready: int) -> None:
        """Re-arrive one pod of a partially-complete DCN rendezvous."""
        raise NotImplementedError

    def restore_issue(self, ex, p: int, idx: int, ready: int) -> None:
        """Re-schedule one deferred-frontier issue at its exact ready
        tick (arbitration must interleave with post-restore completions
        exactly as in an uninterrupted run)."""
        raise NotImplementedError


class DetailedTiming(TimingModel):
    """Full-contention timing through SimObject ports and engine events
    (bit-identical to the pre-refactor executor)."""

    name = "detailed"
    detailed = True
    parallel_dcn_ok = True

    def issue(self, ex, p, idx, ready):
        op = ex._trace.ops[idx]
        payload = ex._payload(p, idx, ready)
        if op.kind == "compute":
            # service time is end - start (wait precedes start)
            ex._chips[p].exec_compute(ready, op.flops, op.bytes, payload)
        else:
            ex._chips[p].issue_collective(payload)

    def advance(self, ex, max_tick, stop_check):
        if ex._sync is not None:
            ex._sync.run_until_drained(max_tick=max_tick,
                                       stop_check=stop_check)
        else:
            ex._advance_nosync(max_tick, stop_check)

    def rendezvous_state(self, ex):
        out = []
        for key in sorted(ex._dcn._rendezvous):
            r = ex._dcn._rendezvous[key]
            out.append({
                "op_idx": key,
                "arrivals": [[w["pod"], w["ready"]] for w in r["waiters"]],
            })
        return out

    def restore_arrival(self, ex, p, idx, ready):
        ex._chips[p].issue_collective(ex._payload(p, idx, ready))

    def restore_issue(self, ex, p, idx, ready):
        ex._queues[p].schedule(
            lambda: ex._issue(p, idx, ready), ready,
            name=f"issue:{ex._trace.ops[idx].name or idx}")


class AtomicTiming(TimingModel):
    """Contention-free analytical costing with batch-resolved
    completions (gem5's atomic fidelity).

    Ops are granted their resources at issue time — compute serializes
    on the chip's integer free tick exactly like detailed; collectives
    start at ``ready`` with the closed-form algorithm cost — and the
    completion is pushed onto a model-private ``(tick, seq)`` heap.
    ``advance`` drains that heap in tick order, so hooks, dependent
    issues, and dynamic-workload injections observe the same causal
    order as detailed, without one engine event per op: pod queues are
    only fast-forwarded (``run_until``), never scheduled on.

    Cross-pod (dcn) collectives still rendezvous (all pods must issue
    the op) but complete at ``last_arrival + cost`` exactly — no uplink
    serialization, no quantum rounding.
    """

    name = "atomic"
    detailed = False
    parallel_dcn_ok = False   # dcn completes at exact ticks, not quanta

    def reset(self, ex):
        self._heap: List[Tuple[int, int, str, tuple]] = []
        self._seq = 0
        self._rendezvous: Dict[int, Dict[str, Any]] = {}

    # ------------------------------------------------------------------
    def _push(self, tick: int, kind: str, data: tuple) -> None:
        heapq.heappush(self._heap, (int(tick), self._seq, kind, data))
        self._seq += 1

    def issue(self, ex, p, idx, ready):
        op = ex._trace.ops[idx]
        payload = ex._payload(p, idx, ready)
        if op.kind == "compute":
            start, end = ex._chips[p].acquire(ready, op.flops, op.bytes)
            self._push(end, "done", (start, end, payload))
        elif payload.get("dcn"):
            self._arrive(ex, payload)
        else:
            from repro.core.desim.simnodes import to_ticks
            dur = to_ticks(ex.alg.time_s(op.kind, op.coll_bytes,
                                         payload["participants"],
                                         ex.machine))
            start = int(ready)
            end = start + dur
            payload.update(start=start, end=end, dur=dur)
            ex._wires[p].record_atomic(op.coll_bytes, dur, end)
            self._push(end, "done", (start, end, payload))

    def _arrive(self, ex, payload):
        from repro.core.desim.simnodes import to_ticks
        key = payload["op_idx"]
        if dbg._ACTIVE:
            # atomic dcn arrivals bypass DcnSim._on_arrive, so trace here
            dbg.dprintf("Dcn", "atomic", "%s op=%d arrive pod=%d",
                        payload.get("name", payload.get("kind", "dcn")),
                        key, payload.get("pod", -1), tick=payload["ready"])
        r = self._rendezvous.setdefault(
            key, {"first": payload["ready"], "last": 0, "waiters": []})
        r["first"] = min(r["first"], payload["ready"])
        r["last"] = max(r["last"], payload["ready"])
        r["waiters"].append(payload)
        if len(r["waiters"]) < ex.machine.num_pods:
            return
        del self._rendezvous[key]
        dur = to_ticks(ex.dcn_alg.time_s(payload["kind"], payload["nbytes"],
                                         payload["participants"],
                                         ex.machine))
        start = r["last"]
        end = start + dur
        ex._dcn.record_atomic(payload["nbytes"], dur, r["last"] - r["first"])
        for w in r["waiters"]:
            w.update(start=start, end=end, dur=dur)
            self._push(end, "done", (start, end, w))

    # ------------------------------------------------------------------
    def advance(self, ex, max_tick, stop_check):
        heap = self._heap
        while heap:
            if stop_check is not None and stop_check():
                return
            if max_tick is not None and heap[0][0] > max_tick:
                return
            tick, _, kind, data = heapq.heappop(heap)
            if kind == "done":
                start, end, payload = data
                q = ex._queues[payload["pod"]]
                if end > q.now:
                    q.run_until(end)     # clock only: the queue is empty
                ex._on_done(start, end, payload)
            else:                        # deferred-frontier issue
                p, idx, ready = data
                q = ex._queues[p]
                if ready > q.now:
                    q.run_until(ready)
                ex._issue(p, idx, ready)

    def quiescent(self, ex):
        return not self._heap

    # -- checkpointing ----------------------------------------------------
    def rendezvous_state(self, ex):
        out = []
        for key in sorted(self._rendezvous):
            r = self._rendezvous[key]
            out.append({
                "op_idx": key,
                "arrivals": [[w["pod"], w["ready"]] for w in r["waiters"]],
            })
        return out

    def restore_arrival(self, ex, p, idx, ready):
        self._arrive(ex, ex._payload(p, idx, ready))

    def restore_issue(self, ex, p, idx, ready):
        self._push(ready, "issue", (p, idx, ready))


TIMING_MODELS = {
    DetailedTiming.name: DetailedTiming,
    AtomicTiming.name: AtomicTiming,
}


def get_timing_model(spec) -> TimingModel:
    """Resolve a model name / class / instance to a fresh-enough
    instance (instances are stateful: one per executor)."""
    if isinstance(spec, TimingModel):
        return spec
    if isinstance(spec, type) and issubclass(spec, TimingModel):
        return spec()
    try:
        return TIMING_MODELS[spec]()
    except (KeyError, TypeError):
        raise ValueError(f"unknown timing model {spec!r}; "
                         f"one of {list(TIMING_MODELS)}")
