"""Int8 gradient compression with error feedback (1-bit-Adam-style
residual correction).

On a real pod this halves/quarters the bytes of the cross-pod (DCN)
gradient all-reduce — the dominant collective of hierarchical data
parallelism.  Numerically: grads are block-quantized to int8 with a
per-block f32 scale; the quantization error is carried in an error
buffer and added to the next step's gradients, so the *accumulated*
update is unbiased.  ``repro.kernels.quantize`` provides the Pallas
TPU kernel for the quantize hot-loop; this module is its jnp oracle.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def int8_block_quantize(x: jnp.ndarray, block: int = 256
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """x (any shape) -> (q int8 (nblocks, block), scales (nblocks,), pad)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale, pad


def int8_block_dequantize(q: jnp.ndarray, scale: jnp.ndarray, pad: int,
                          shape, dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def compress_gradients(grads: Any, error: Any, block: int = 256
                       ) -> Tuple[Any, Any]:
    """Quantize (grads + error) leafwise; return (deq grads, new error)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s, pad = int8_block_quantize(corrected, block)
        deq = int8_block_dequantize(q, s, pad, g.shape)
        return deq.astype(g.dtype), corrected - deq

    # explicit flatten/unflatten (is_leaf=tuple would swallow
    # tuple-structured pytrees; see adamw_update)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return deq, new_err


def init_error_buffer(params: Any) -> Any:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
