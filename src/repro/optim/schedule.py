"""LR schedules.  ``wsd_schedule`` is the MiniCPM warmup-stable-decay
schedule [arXiv:2404.06395] — the paper-specific feature of the
minicpm-2b assigned architecture."""

from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(step, peak_lr: float, warmup: int, stable: int,
                 decay: int, min_ratio: float = 0.1):
    """Warmup (linear) -> Stable (constant) -> Decay (exponential to
    min_ratio * peak over `decay` steps)."""
    s = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
    decay_start = warmup + stable
    frac = jnp.clip((s - decay_start) / jnp.maximum(decay, 1), 0.0, 1.0)
    dec = peak_lr * (min_ratio ** frac)
    return jnp.where(s < decay_start, warm, dec)


def cosine_schedule(step, peak_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(s < warmup, warm, peak_lr * cos)
