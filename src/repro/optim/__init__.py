from repro.optim.adamw import (  # noqa: F401
    adamw_init, adamw_update, clip_by_global_norm, global_norm)
from repro.optim.schedule import wsd_schedule, cosine_schedule  # noqa: F401
from repro.optim.compress import (  # noqa: F401
    int8_block_quantize, int8_block_dequantize, compress_gradients)
