"""AdamW with decoupled weight decay and global-norm clipping.

Optimizer state is a pytree congruent with the parameters, so it
inherits the parameter sharding (FSDP x TP): per-chip optimizer memory
is N * 8 bytes / 256 on the production mesh.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params: Any, moment_dtype=jnp.float32) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(  # noqa: E731
        lambda p: jnp.zeros(p.shape, moment_dtype), params)
    return {"m": zeros(), "v": zeros(),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: Any, max_norm: float
                        ) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def adamw_update(grads: Any, state: Dict[str, Any], params: Any,
                 lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1) -> Tuple[Any, Dict[str, Any]]:
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        mdt = m.dtype      # moments may be bf16 (memory-constrained cfgs)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * pf
        p_new = pf - lr * step
        return p_new.astype(p.dtype), m_new.astype(mdt), v_new.astype(mdt)

    # flatten/unflatten explicitly: tree.map with is_leaf=tuple would
    # swallow tuple-STRUCTURED pytrees (the hybrid arch's per-position
    # layers tuple) and corrupt the state.
    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(params)
    outs = [upd(g, m, v, p)
            for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return new_params, {"m": new_m, "v": new_v, "count": count}
