"""Pure fault-tolerance policy for pod-scale training (the TrainSim
tentpole).

This module factors the *recovery brain* out of ``repro.train.trainer``
+ ``repro.checkpoint.manager`` into one pure, step-indexed state
machine, exactly the way ``repro.serve.policy`` factored the slot
scheduler out of ``BatchServer``:

* **when to checkpoint** — the cadence rule (``checkpoint_due``) plus
  proactive saves on preemption notice;
* **when to declare a pod dead** — a failed pod goes *silent*; the
  policy declares it dead after ``dead_after_misses`` consecutive
  missed heartbeats (until then the collective hangs and steps stall);
* **which mesh to restore onto** — ``repro.train.ft.plan_elastic_mesh``
  over the surviving chip count (elastic reshard down on failure, back
  up when a repaired pod rejoins).

Every decision is logged as an :class:`FTDecision`, so "the real
``Trainer`` fault-tolerance stack and the DES ``TrainSim`` recover
identically" is a pure list-equality assertion
(tests/test_train_ft_policy.py) — no timing, no jax, no event engine
in this module.

Driver contract (both engines follow it verbatim)::

    for d in policy.start():            # logs the step-0 checkpoint
        <save the initial state>
    while not policy.done():
        plan = policy.execute_step(schedule.events_at(policy.attempt))
        if plan.pre_save is not None:  <save now (preemption notice)>
        if plan.kind == "step":        <run one training step>
            if plan.post_save is not None:  <save>
        elif plan.kind == "stall":     <a silent pod hangs the step>
        elif plan.kind == "recover":   <restore checkpoint plan.restore_to
                                        onto plan.mesh>

Time is counted in *attempts* (global step executions, including
re-runs after a rollback) — the one clock both a wall-clock trainer
and a tick-clock DES share, which is what makes the decision logs
comparable bit-for-bit.

Failure model (:class:`FailureSchedule`, fully determined by ``seed``):

* ``pod_failed``  — MTBF-driven hard failures.  The pod goes silent;
  after declaration the policy reshards onto the survivors and rolls
  back to the last checkpoint.  ``repair`` attempts later the pod (or
  with ``repair=0``, an immediately-available replacement) rejoins and
  the policy reshards back up.
* ``straggler``   — a transient slowdown of one pod for ``duration``
  attempts (the whole SPMD step runs at the straggler's pace).
* ``preemption``  — an eviction *with notice*: the policy checkpoints
  proactively, so the pod leaves without losing work.

``young_interval`` / ``daly_interval`` give the classic optimum
checkpoint-interval approximations the ``benchmarks/ft_sweep``
goodput frontier is validated against.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig
from repro.train.ft import MeshPlan, plan_elastic_mesh


# ---------------------------------------------------------------------------
# cadence + optimum-interval formulas
# ---------------------------------------------------------------------------

def checkpoint_due(step: int, interval: int, start: int = 0) -> bool:
    """The checkpoint cadence rule: a checkpoint is due every
    ``interval`` completed steps (counted from ``start``).  Factored
    here so ``Trainer.run``, ``Trainer.run_ft`` and ``TrainSim`` all
    share one rule."""
    return interval > 0 and step > start and (step - start) % interval == 0


def young_interval(ckpt_cost: float, mtbf: float) -> float:
    """Young's first-order optimum checkpoint interval
    ``sqrt(2 * delta * M)`` (any consistent time unit)."""
    return math.sqrt(2.0 * ckpt_cost * mtbf)


def daly_interval(ckpt_cost: float, mtbf: float) -> float:
    """Daly's higher-order refinement of Young's formula,
    ``sqrt(2 * delta * M) - delta`` (valid for ``delta < M/2``)."""
    return max(math.sqrt(2.0 * ckpt_cost * mtbf) - ckpt_cost, ckpt_cost)


# ---------------------------------------------------------------------------
# the failure schedule
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FailureEvent:
    """One injected fault, fired when the driver reaches ``attempt``."""

    attempt: int
    kind: str            # "pod_failed" | "straggler" | "preemption"
    pod: int
    slowdown: float = 1.0   # straggler: step-time multiplier
    duration: int = 1       # straggler: attempts the slowdown lasts
    repair: int = 0         # attempts until the pod (or a replacement)
    #                         rejoins; 0 = replacement available at once


@dataclass
class FailureSchedule:
    """A seeded, immutable list of fault events indexed by attempt."""

    events: Tuple[FailureEvent, ...]
    seed: int = 0
    horizon: int = 0
    pods: int = 1

    def __post_init__(self):
        self.events = tuple(sorted(self.events,
                                   key=lambda e: (e.attempt, e.pod, e.kind)))
        by_attempt: Dict[int, List[FailureEvent]] = {}
        for ev in self.events:
            by_attempt.setdefault(ev.attempt, []).append(ev)
        self._by_attempt = {a: tuple(evs) for a, evs in by_attempt.items()}

    def events_at(self, attempt: int) -> Tuple[FailureEvent, ...]:
        return self._by_attempt.get(attempt, ())

    @classmethod
    def generate(cls, *, seed: int, horizon: int, pods: int,
                 mtbf: float = 0.0,
                 straggler_mtbs: float = 0.0,
                 straggler_slowdown: Tuple[float, float] = (2.0, 4.0),
                 straggler_duration: Tuple[int, int] = (2, 8),
                 preemption_mtbs: float = 0.0,
                 repair: Tuple[int, int] = (0, 0)) -> "FailureSchedule":
        """Draw a schedule over ``horizon`` attempts on ``pods`` pods.
        ``mtbf`` / ``straggler_mtbs`` / ``preemption_mtbs`` are mean
        attempts between events of each family (0 disables the family);
        ``repair`` is the inclusive range of pod repair times.  All
        randomness comes from ``seed``."""
        rng = random.Random(seed)
        out: List[FailureEvent] = []

        def poisson_times(mean: float) -> List[int]:
            ts, t = [], 0.0
            if mean <= 0:
                return ts
            while True:
                t += rng.expovariate(1.0 / mean)
                if t >= horizon:
                    return ts
                ts.append(int(t))

        for a in poisson_times(mtbf):
            out.append(FailureEvent(a, "pod_failed", rng.randrange(pods),
                                    repair=rng.randint(*repair)))
        for a in poisson_times(straggler_mtbs):
            out.append(FailureEvent(
                a, "straggler", rng.randrange(pods),
                slowdown=rng.uniform(*straggler_slowdown),
                duration=rng.randint(*straggler_duration)))
        for a in poisson_times(preemption_mtbs):
            out.append(FailureEvent(a, "preemption", rng.randrange(pods),
                                    repair=max(1, rng.randint(*repair))))
        return cls(tuple(out), seed=seed, horizon=horizon, pods=pods)


# ---------------------------------------------------------------------------
# decisions and per-attempt plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FTDecision:
    """One recovery decision, in decision order (the comparable log)."""

    kind: str          # "checkpoint" | "straggler" | "pod_dead" |
    #                    "pod_joined" | "preempt" | "reshard" | "restore"
    step: int          # training-step counter when the decision was taken
    attempt: int
    pod: int = -1
    mesh: Tuple[int, ...] = ()
    chips: int = 0
    note: str = ""

    def to_row(self) -> List[Any]:
        return [self.kind, self.step, self.attempt, self.pod,
                list(self.mesh), self.chips, self.note]

    @classmethod
    def from_row(cls, r: Sequence[Any]) -> "FTDecision":
        return cls(r[0], int(r[1]), int(r[2]), int(r[3]),
                   tuple(int(x) for x in r[4]), int(r[5]), r[6])


@dataclass(frozen=True)
class StepPlan:
    """What the driver must do for one attempt (in field order)."""

    attempt: int
    kind: str                       # "step" | "stall" | "recover"
    step: int                       # the training step attempted
    pre_save: Optional[int] = None  # save current state as this step now
    post_save: Optional[int] = None  # after the step completes
    restore_to: Optional[int] = None  # recover: checkpoint step to load
    lost_steps: int = 0             # recover: completed steps rolled back
    slowdown: float = 1.0           # straggler multiplier for this step
    capacity: float = 1.0           # mesh chips / full chips
    mesh: Tuple[int, ...] = ()
    decisions: Tuple[FTDecision, ...] = ()


# ---------------------------------------------------------------------------
# the policy
# ---------------------------------------------------------------------------

class FTPolicy:
    """Deterministic recovery policy over a fixed pod fleet.

    Pure: consumes attempt-indexed fault events, produces
    :class:`StepPlan`s and an :class:`FTDecision` log.  The driver owns
    all side effects (running steps, writing/restoring checkpoints,
    advancing simulated time)."""

    def __init__(self, cfg: ArchConfig, *, num_steps: int,
                 ckpt_interval: int, pods: int, chips_per_pod: int,
                 start_step: int = 0, dead_after_misses: int = 2,
                 prefer_model: int = 16, max_attempts: int = 0):
        if num_steps < 1 or pods < 1 or chips_per_pod < 1:
            raise ValueError("num_steps, pods, chips_per_pod must be >= 1")
        if dead_after_misses < 1:
            raise ValueError("dead_after_misses must be >= 1")
        self.cfg = cfg
        self.num_steps = num_steps
        self.ckpt_interval = ckpt_interval
        self.pods = pods
        self.chips_per_pod = chips_per_pod
        self.start_step = start_step
        self.dead_after_misses = dead_after_misses
        self.prefer_model = prefer_model
        self.max_attempts = max_attempts or 50 * num_steps + 1000
        # mutable state
        self.attempt = 0
        self.step = start_step          # next training step to execute
        self.last_ckpt = start_step
        self.decisions: List[FTDecision] = []
        self._silent: Dict[int, Tuple[int, int]] = {}  # pod -> (at, repair)
        self._dead: List[int] = []
        self._returns: Dict[int, List[int]] = {}       # attempt -> pods
        self._stragglers: Dict[int, Tuple[float, int]] = {}  # pod ->
        #                                               (slowdown, until)
        self._started = False
        self.mesh: MeshPlan = self._plan_mesh()

    # -- internals -------------------------------------------------------
    @property
    def _end(self) -> int:
        return self.start_step + self.num_steps

    def _alive_pods(self) -> int:
        return self.pods - len(self._dead) - len(self._silent)

    def _plan_mesh(self) -> MeshPlan:
        return plan_elastic_mesh(self.cfg,
                                 self._alive_pods() * self.chips_per_pod,
                                 prefer_model=self.prefer_model)

    def _log(self, out: List[FTDecision], kind: str, *, pod: int = -1,
             mesh: Tuple[int, ...] = (), chips: int = 0,
             note: str = "") -> None:
        d = FTDecision(kind, self.step, self.attempt, pod, mesh, chips,
                       note)
        self.decisions.append(d)
        out.append(d)

    def _reshard(self, out: List[FTDecision]) -> None:
        plan = self._plan_mesh()
        if plan.shape != self.mesh.shape or plan.chips != self.mesh.chips:
            self.mesh = plan
            self._log(out, "reshard", mesh=plan.shape, chips=plan.chips,
                      note=plan.note)

    def capacity(self) -> float:
        return self.mesh.chips / float(self.pods * self.chips_per_pod)

    # -- lifecycle -------------------------------------------------------
    def start(self) -> Tuple[FTDecision, ...]:
        """Log the step-``start_step`` checkpoint (the initial state is
        always restorable — the driver must actually save it)."""
        if self._started:
            return ()
        self._started = True
        out: List[FTDecision] = []
        self._log(out, "checkpoint", note="initial state")
        return tuple(out)

    def done(self) -> bool:
        return self.step >= self._end

    def execute_step(self, events: Sequence[FailureEvent] = ()
                     ) -> StepPlan:
        """Advance one attempt: absorb this attempt's fault events,
        decide, and return the plan the driver must execute."""
        if not self._started:
            raise RuntimeError("call start() before execute_step()")
        if self.done():
            raise RuntimeError("policy is done")
        if self.attempt >= self.max_attempts:
            raise RuntimeError(
                f"no progress after {self.attempt} attempts (failure "
                "rate too high for the checkpoint cadence?)")
        a = self.attempt
        out: List[FTDecision] = []
        pre_save: Optional[int] = None
        mesh_dirty = False

        # 1. repaired pods rejoin at the attempt boundary
        for at in sorted(k for k in self._returns if k <= a):
            for pod in self._returns.pop(at):
                if pod in self._dead:
                    self._dead.remove(pod)
                    self._log(out, "pod_joined", pod=pod)
                    mesh_dirty = True

        # 2. this attempt's fault events
        for ev in events:
            if ev.kind == "straggler":
                if ev.pod in self._dead or ev.pod in self._silent:
                    continue
                self._stragglers[ev.pod] = (ev.slowdown,
                                            a + max(1, ev.duration))
                self._log(out, "straggler", pod=ev.pod,
                          note=f"{ev.slowdown:.2f}x for {ev.duration}")
            elif ev.kind == "preemption":
                if (ev.pod in self._dead or ev.pod in self._silent
                        or self._alive_pods() <= 1):
                    continue          # never evict the last alive pod
                self._log(out, "preempt", pod=ev.pod,
                          note=f"notice, back in {ev.repair}")
                # proactive save: the pod leaves without losing work
                pre_save = self.step
                self.last_ckpt = self.step
                self._log(out, "checkpoint", note="preemption notice")
                self._dead.append(ev.pod)
                self._stragglers.pop(ev.pod, None)   # dies with the pod
                self._log(out, "pod_dead", pod=ev.pod, note="preempted")
                self._returns.setdefault(a + max(1, ev.repair),
                                         []).append(ev.pod)
                mesh_dirty = True
            elif ev.kind == "pod_failed":
                if ev.pod in self._dead or ev.pod in self._silent:
                    continue
                self._silent[ev.pod] = (a, ev.repair)
            else:
                raise ValueError(f"unknown failure kind {ev.kind!r}")

        # 3. silent pods hang the collective: stall until declared dead
        if self._silent:
            overdue = sorted(
                pod for pod, (at, _) in self._silent.items()
                if a - at + 1 >= self.dead_after_misses)
            if not overdue:
                if mesh_dirty:
                    self._reshard(out)
                plan = StepPlan(a, "stall", self.step,
                                pre_save=pre_save,
                                capacity=self.capacity(),
                                mesh=self.mesh.shape,
                                decisions=tuple(out))
                self.attempt += 1
                return plan
            for pod in overdue:
                _, repair = self._silent.pop(pod)
                # the slowdown was a property of the dead hardware; the
                # replacement (or the repaired pod) starts clean
                self._stragglers.pop(pod, None)
                self._log(out, "pod_dead", pod=pod,
                          note=f"missed {self.dead_after_misses} "
                               "heartbeats")
                if repair > 0 and self._alive_pods() > 1:
                    self._dead.append(pod)
                    self._returns.setdefault(a + repair, []).append(pod)
                else:
                    # a replacement pod is available immediately; it
                    # joins the restored mesh (state is still lost)
                    self._log(out, "pod_joined", pod=pod,
                              note="replacement")
            self._reshard(out)
            lost = self.step - self.last_ckpt
            self._log(out, "restore", note=f"step {self.last_ckpt}, "
                                           f"lost {lost} steps")
            self.step = self.last_ckpt
            plan = StepPlan(a, "recover", self.step, pre_save=pre_save,
                            restore_to=self.last_ckpt, lost_steps=lost,
                            capacity=self.capacity(),
                            mesh=self.mesh.shape, decisions=tuple(out))
            self.attempt += 1
            return plan

        if mesh_dirty:
            self._reshard(out)

        # 4. a normal step at the current capacity/slowdown
        for pod in sorted(p for p, (_, until) in self._stragglers.items()
                          if until <= a):
            del self._stragglers[pod]
        slowdown = max([1.0] + [s for p, (s, _) in self._stragglers.items()
                                if p not in self._dead])
        step = self.step
        self.step += 1
        post_save: Optional[int] = None
        if (checkpoint_due(self.step, self.ckpt_interval, self.start_step)
                or self.step == self._end):
            post_save = self.step
            self.last_ckpt = self.step
            self._log(out, "checkpoint")
        plan = StepPlan(a, "step", step, pre_save=pre_save,
                        post_save=post_save, slowdown=slowdown,
                        capacity=self.capacity(), mesh=self.mesh.shape,
                        decisions=tuple(out))
        self.attempt += 1
        return plan

    # -- checkpointing ---------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "attempt": self.attempt,
            "step": self.step,
            "last_ckpt": self.last_ckpt,
            "started": self._started,
            "dead": sorted(self._dead),
            "silent": sorted([p, at, rep] for p, (at, rep)
                             in self._silent.items()),
            "returns": sorted([at, sorted(pods)] for at, pods
                              in self._returns.items()),
            "stragglers": sorted([p, s, u] for p, (s, u)
                                 in self._stragglers.items()),
            "mesh": {"shape": list(self.mesh.shape),
                     "axes": list(self.mesh.axes),
                     "chips": self.mesh.chips, "note": self.mesh.note},
            "decisions": [d.to_row() for d in self.decisions],
        }

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self.attempt = int(d["attempt"])
        self.step = int(d["step"])
        self.last_ckpt = int(d["last_ckpt"])
        self._started = bool(d["started"])
        self._dead = [int(p) for p in d["dead"]]
        self._silent = {int(p): (int(at), int(rep))
                        for p, at, rep in d["silent"]}
        self._returns = {int(at): [int(p) for p in pods]
                         for at, pods in d["returns"]}
        self._stragglers = {int(p): (float(s), int(u))
                            for p, s, u in d["stragglers"]}
        m = d["mesh"]
        self.mesh = MeshPlan(tuple(int(x) for x in m["shape"]),
                             tuple(m["axes"]), int(m["chips"]), m["note"])
        self.decisions = [FTDecision.from_row(r) for r in d["decisions"]]
