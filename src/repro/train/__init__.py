"""Training: the real trainer loop and its pure fault-tolerance policy.

The ft/ft_policy modules are deliberately jax-free — the DES
(``repro.sim.workloads``) drives the identical ``FTPolicy`` the real
``Trainer`` uses, and the simulator stack must stay importable (and
fast to import) without jax.  The step/trainer modules *do* import
jax, so they load lazily (PEP 562) on first attribute access instead
of at package import — same pattern as ``repro.serve``.
"""

from repro.train.ft import (  # noqa: F401 (pure)
    Heartbeat, MeshPlan, StragglerWatchdog, plan_elastic_mesh)
from repro.train.ft_policy import (  # noqa: F401 (pure)
    FailureEvent, FailureSchedule, FTDecision, FTPolicy, StepPlan,
    checkpoint_due, daly_interval, young_interval)

_LAZY = {
    "TrainOptions": "repro.train.step",
    "build_train_step": "repro.train.step",
    "init_train_state": "repro.train.step",
    "train_state_specs": "repro.train.step",
    "Trainer": "repro.train.trainer",
}

__all__ = [
    "Heartbeat", "MeshPlan", "StragglerWatchdog", "plan_elastic_mesh",
    "FailureEvent", "FailureSchedule", "FTDecision", "FTPolicy",
    "StepPlan", "checkpoint_due", "daly_interval", "young_interval",
    *sorted(_LAZY),
]


def __getattr__(name):
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(target), name)


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
