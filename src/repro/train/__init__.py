from repro.train.step import (  # noqa: F401
    TrainOptions, build_train_step, init_train_state, train_state_specs)
from repro.train.trainer import Trainer  # noqa: F401
