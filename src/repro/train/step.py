"""Train-step builder: loss + grads + AdamW under pjit sharding.

The step is structured for compute/communication overlap: with
``accum_steps > 1`` gradients are accumulated over microbatches inside
a ``lax.scan``, which lets XLA overlap the reduce-scatter of microbatch
i's gradients with microbatch i+1's compute (the distributed-
optimization trick the DES models as ``overlap=True`` collectives).
Optional int8 gradient compression with error feedback halves the
cross-pod gradient bytes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.api import Model
from repro.models.common import IDENTITY_SHARDER, Sharder
from repro.models.layers import cross_entropy
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_gradients, cosine_schedule, wsd_schedule)
from repro.optim.compress import init_error_buffer


@dataclass(frozen=True)
class TrainOptions:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"          # cosine | wsd
    wsd_stable: int = 8000
    wsd_decay: int = 1000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    aux_weight: float = 0.01          # MoE load-balance loss weight
    accum_steps: int = 1
    grad_compress: bool = False       # int8 + error feedback
    chunk: int = 2048                 # attention kv-chunk
    moment_dtype: str = "float32"     # adam m/v dtype (bf16 at 141B scale)


def lr_at(opts: TrainOptions, step):
    if opts.schedule == "wsd":
        return wsd_schedule(step, opts.peak_lr, opts.warmup,
                            opts.wsd_stable, opts.wsd_decay)
    return cosine_schedule(step, opts.peak_lr, opts.warmup, opts.total_steps)


def default_options_for(cfg: ArchConfig) -> TrainOptions:
    # minicpm trains with the WSD schedule (its paper-specific feature)
    if cfg.name == "minicpm-2b":
        return TrainOptions(schedule="wsd")
    return TrainOptions()


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

def init_train_state(model: Model, key, opts: Optional[TrainOptions] = None
                     ) -> Dict[str, Any]:
    opts = opts or default_options_for(model.cfg)
    params = model.init(key)
    mdt = jnp.dtype(opts.moment_dtype)
    state = {"params": params, "opt": adamw_init(params, mdt),
             "step": jnp.zeros((), jnp.int32)}
    if opts.grad_compress:
        state["err"] = init_error_buffer(params)
    return state


def train_state_specs(model: Model, opts: Optional[TrainOptions] = None
                      ) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct tree, logical-axes tree) of the train state."""
    opts = opts or default_options_for(model.cfg)
    p_shapes, p_axes = model.param_specs()
    sds = jax.ShapeDtypeStruct
    state_shapes = {
        "params": p_shapes,
        "opt": {"m": p_shapes, "v": p_shapes,
                "count": sds((), jnp.int32)},
        "step": sds((), jnp.int32),
    }
    mdt = jnp.dtype(opts.moment_dtype)
    as_m = lambda t: jax.tree.map(  # noqa: E731
        lambda s: sds(s.shape, mdt), t)
    state_shapes["opt"]["m"] = as_m(p_shapes)
    state_shapes["opt"]["v"] = as_m(p_shapes)
    state_axes = {
        "params": p_axes,
        "opt": {"m": p_axes, "v": p_axes, "count": ()},
        "step": (),
    }
    if opts.grad_compress:
        state_shapes["err"] = jax.tree.map(
            lambda s: sds(s.shape, jnp.float32), p_shapes)
        state_axes["err"] = p_axes
    return state_shapes, state_axes


# ---------------------------------------------------------------------------
# step
# ---------------------------------------------------------------------------

def build_train_step(model: Model, opts: Optional[TrainOptions] = None,
                     sharder: Sharder = IDENTITY_SHARDER,
                     param_axes: Any = None) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)`` (pure).

    ``param_axes``: logical-axes tree matching the params.  When given,
    gradients are constrained to the PARAMETER sharding at the point of
    production.  Without this, XLA has no cotangent sharding to
    propagate and materializes replicated gradients — measured at jamba
    train_4k scale as a 14 GB/device gradient buffer and an all-reduce
    (instead of reduce-scatter) gradient sync.
    """
    cfg = model.cfg
    opts = opts or default_options_for(cfg)

    def _is_axes_leaf(x):
        return isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x)

    def shard_like_params(grads):
        if param_axes is None:
            return grads
        flat_g, treedef = jax.tree.flatten(grads)
        flat_a = jax.tree.flatten(param_axes, is_leaf=_is_axes_leaf)[0]
        out = [sharder.ac(g, tuple(a)) for g, a in zip(flat_g, flat_a)]
        return jax.tree.unflatten(treedef, out)

    def loss_fn(params, batch):
        logits, aux = model.train_logits(params, batch, sharder=sharder,
                                         chunk=opts.chunk)
        loss = cross_entropy(logits, batch["labels"], cfg,
                             mask=batch.get("mask"))
        return loss + opts.aux_weight * aux, (loss, aux)

    def grads_of(params, batch):
        (tot, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return shard_like_params(grads), loss, aux

    def train_step(state, batch):
        params = state["params"]
        if opts.accum_steps > 1:
            def micro(carry, mb):
                g_acc, l_acc, a_acc = carry
                g, l, a = grads_of(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l, a_acc + a), ()

            mb0 = jax.tree.map(
                lambda x: x.reshape((opts.accum_steps,
                                     x.shape[0] // opts.accum_steps)
                                    + x.shape[1:]) if x.ndim else
                jnp.broadcast_to(x, (opts.accum_steps,)), batch)
            zeros = shard_like_params(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss, aux), _ = jax.lax.scan(
                micro, (zeros, 0.0, 0.0), mb0)
            inv = 1.0 / opts.accum_steps
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss, aux = loss * inv, aux * inv
        else:
            grads, loss, aux = grads_of(params, batch)

        new_state = dict(state)
        if opts.grad_compress:
            grads, new_err = compress_gradients(grads, state["err"])
            new_state["err"] = new_err
        grads, gnorm = clip_by_global_norm(grads, opts.clip_norm)
        lr = lr_at(opts, state["step"])
        new_params, new_opt = adamw_update(
            grads, state["opt"], params, lr,
            weight_decay=opts.weight_decay)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        new_state["step"] = state["step"] + 1
        metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm,
                   "lr": lr}
        return new_state, metrics

    return train_step
