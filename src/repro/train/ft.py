"""Fault-tolerance machinery for 1000+-node operation.

* ``StragglerWatchdog`` — per-step wall-time monitor flagging outliers
  (the DES injects the same effect via per-pod ``slowdown``); at pod
  scale the mitigation is re-sharding around the slow host.
* ``Heartbeat`` — liveness file; a cluster controller (or test) detects
  a dead trainer by staleness.
* ``ElasticPlanner`` — pure function choosing a new (data, model) mesh
  factorization from a surviving chip count, respecting the model's
  divisibility constraints; with the resharding restore in
  ``repro.checkpoint`` this implements elastic scaling: fail -> plan
  new mesh -> restore last checkpoint onto it -> continue.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.configs.base import ArchConfig


class StragglerWatchdog:
    def __init__(self, threshold: float = 2.0, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times: List[float] = []
        self.flagged: List[Tuple[int, float]] = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler.

        Flagged samples are kept OUT of the rolling median window
        (``self.times``): a burst of stragglers must not inflate the
        median and desensitize later detection — with the old
        behaviour, enough flagged steps raised the median until equally
        slow steps stopped being flagged at all
        (tests/test_checkpoint_ft.py regression).
        """
        hist = self.times[-self.window:]
        if len(hist) >= 4:
            med = sorted(hist)[len(hist) // 2]
            if seconds > self.threshold * med:
                self.flagged.append((step, seconds))
                return True
        self.times.append(seconds)
        return False

    def reset_window(self) -> None:
        """Forget the learned baseline after an *intended* regime
        change (elastic reshard to fewer chips, hardware swap): every
        step is legitimately slower now, and without a reset the frozen
        old median would flag all of them forever.  The next 4 samples
        re-learn the baseline unflagged (``record``'s warm-up)."""
        self.times.clear()

    def median(self) -> float:
        if not self.times:
            return 0.0
        return sorted(self.times)[len(self.times) // 2]


class Heartbeat:
    """Liveness file.  Staleness is measured on the **monotonic** clock:
    wall-clock (``time.time``) deltas go negative under NTP steps /
    admin clock changes, which made a freshly-beating trainer look
    either immortal (negative age) or dead (forward step) — exactly the
    clock discipline problem 1000-node fleets hit in practice.
    ``CLOCK_MONOTONIC`` is per-boot and system-wide, so ages are
    comparable across processes on the same host (the controller and
    the trainer); wall time is still recorded, but as informational
    metadata only."""

    def __init__(self, path: str):
        self.path = path

    def beat(self, step: int) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "mono": time.monotonic(),
                       "wall_time": time.time()}, f)
        os.replace(tmp, self.path)

    def age(self) -> Optional[float]:
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None
        if "mono" in data:
            delta = time.monotonic() - data["mono"]
            if delta >= 0:
                return delta
            # a negative monotonic delta is impossible within one boot:
            # the file predates a reboot (CLOCK_MONOTONIC restarted at
            # 0), so the beat is at best wall-clock old — fall through
        # legacy files, or pre-reboot files: wall clock, clamped so a
        # backwards clock step cannot produce a negative age
        legacy = data.get("time", data.get("wall_time"))
        if legacy is None:
            return None
        return max(0.0, time.time() - legacy)

    def alive(self, max_age: float = 60.0) -> bool:
        age = self.age()
        return age is not None and age < max_age


@dataclass
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    chips: int
    note: str = ""


def plan_elastic_mesh(cfg: ArchConfig, surviving_chips: int,
                      prefer_model: int = 16) -> MeshPlan:
    """Choose (data, model) for the surviving chip count.

    Keeps the model axis as close to ``prefer_model`` as possible
    (weights must keep fitting) while requiring d_model % data == 0 and
    d_ff % model == 0.  Returns the largest usable power-of-two mesh
    (excess chips idle until the next full re-shard window).
    """
    best: Optional[MeshPlan] = None
    chips = surviving_chips
    # largest power-of-two <= chips
    usable = 1
    while usable * 2 <= chips:
        usable *= 2
    for model in sorted({prefer_model, 8, 4, 2, 1}, reverse=True):
        if model > usable or cfg.d_ff % model:
            continue
        data = usable // model
        if data == 0 or cfg.d_model % data:
            continue
        plan = MeshPlan((data, model), ("data", "model"), data * model,
                        note=f"{chips - data * model} chips idle")
        if best is None or plan.chips > best.chips:
            best = plan
    if best is None:
        best = MeshPlan((1, 1), ("data", "model"), 1, "degenerate fallback")
    return best
