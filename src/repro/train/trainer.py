"""Trainer: the driver loop as a SimObject (gem5-style composition).

The trainer is configured like every other g5x component — Params +
children (checkpoint manager, watchdog, heartbeat) — and exports a
stats group (loss, step-time distribution, straggler count, checkpoint
count) into the system tree.  Fault injection for tests: pass
``fail_at={step: exception}`` and the trainer demonstrates
checkpoint-restore recovery.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax

from repro.checkpoint import CheckpointManager
from repro.core.simobject import Param, SimObject
from repro.data.pipeline import SyntheticPipeline
from repro.train.ft import Heartbeat, StragglerWatchdog
from repro.train.ft_policy import (FailureSchedule, FTPolicy,
                                   checkpoint_due)


class SimulatedFailure(RuntimeError):
    pass


class Trainer(SimObject):
    ckpt_interval = Param(int, 50, "steps between checkpoints")
    log_interval = Param(int, 10, "steps between metric logs")
    max_retries = Param(int, 3, "restore attempts after failures")

    def __init__(self, name: str = "trainer", *, model, train_step: Callable,
                 pipeline: SyntheticPipeline, state: Any,
                 ckpt_dir: Optional[str] = None,
                 heartbeat_path: Optional[str] = None, **kw):
        super().__init__(name, **kw)
        self.model = model
        self.train_step = train_step
        self.pipeline = pipeline
        self.state = state
        self.ckpt = (CheckpointManager(ckpt_dir) if ckpt_dir else None)
        self.watchdog = StragglerWatchdog()
        self.heartbeat = Heartbeat(heartbeat_path) if heartbeat_path else None
        self._jitted = jax.jit(train_step, donate_argnums=(0,))
        # stats
        self.s_loss = self.stats.scalar("loss", "last loss")
        self.s_steps = self.stats.scalar("steps", "steps completed")
        self.s_failures = self.stats.scalar("failures", "failures recovered")
        self.s_stragglers = self.stats.scalar("stragglers", "slow steps")
        self.s_stalls = self.stats.scalar("stalls",
                                          "attempts hung on a silent pod")
        self.s_step_time = self.stats.distribution("step_time", unit="s")
        self.history: list = []

    # ------------------------------------------------------------------
    def _run_one_step(self, step: int) -> None:
        """One real training step with all its bookkeeping (stats,
        watchdog, history, heartbeat) — the single copy both ``run``
        and ``run_ft`` execute."""
        batch = {k: jax.numpy.asarray(v)
                 for k, v in self.pipeline.batch(step).items()}
        t0 = time.perf_counter()
        self.state, metrics = self._jitted(self.state, batch)
        loss = float(jax.device_get(metrics["loss"]))
        dt = time.perf_counter() - t0
        if self.watchdog.record(step, dt):
            self.s_stragglers.inc()
        self.s_step_time.sample(dt)
        self.s_loss.set(loss)
        self.s_steps.inc()
        self.history.append({"step": step, "loss": loss, "time_s": dt})
        if self.heartbeat:
            self.heartbeat.beat(step)

    def run(self, num_steps: int,
            fail_at: Optional[Dict[int, Exception]] = None) -> Dict:
        """Run ``num_steps``; simulated failures trigger restore+retry."""
        fail_at = dict(fail_at or {})
        retries = 0
        step = int(jax.device_get(self.state["step"]))
        end = step + num_steps
        while step < end:
            try:
                if step in fail_at:
                    exc = fail_at.pop(step)
                    raise exc
                self._run_one_step(step)
                step += 1
                if self.ckpt and checkpoint_due(step, self.ckpt_interval):
                    self.ckpt.save(self.state, step)
            except SimulatedFailure:
                self.s_failures.inc()
                retries += 1
                if retries > self.max_retries:
                    raise
                if self.ckpt and self.ckpt.latest_step() is not None:
                    self.state = self.ckpt.restore(self.state)
                    step = int(jax.device_get(self.state["step"]))
                # else: continue from in-memory state (lost step)
        if self.ckpt:
            self.ckpt.save(self.state, step)
            self.ckpt.wait()
        return {"final_step": step, "history": self.history,
                "stragglers": self.watchdog.flagged}

    # ------------------------------------------------------------------
    def run_ft(self, schedule: FailureSchedule, policy: FTPolicy) -> Dict:
        """Run under a seeded :class:`FailureSchedule` with every
        recovery decision delegated to the pure :class:`FTPolicy` — the
        identical policy object the DES ``repro.sim.workloads.TrainSim``
        drives, so the two produce the same decision log on the same
        schedule (tests/test_train_ft_policy.py).

        The trainer owns the side effects: it really runs the jitted
        steps, really writes checkpoints through
        :class:`CheckpointManager`, and on a declared pod death really
        restores the policy's chosen checkpoint (onto the policy's
        elastic mesh at pod scale; on this host the restore itself).
        """
        if self.ckpt is None:
            raise ValueError("run_ft requires a CheckpointManager "
                             "(construct the Trainer with ckpt_dir=)")
        start = int(jax.device_get(self.state["step"]))
        if start != policy.start_step:
            raise ValueError(
                f"state is at step {start}, policy starts at "
                f"{policy.start_step}")
        policy.start()
        self.ckpt.save(self.state, policy.start_step)  # always restorable
        while not policy.done():
            plan = policy.execute_step(
                schedule.events_at(policy.attempt))
            if any(d.kind == "reshard" for d in plan.decisions):
                # step times legitimately change with the mesh: the
                # watchdog must re-learn its baseline, not flag every
                # post-reshard step against the old capacity's median
                self.watchdog.reset_window()
            if plan.pre_save is not None:
                # preemption notice: save before losing the pod
                self.ckpt.save(self.state, plan.pre_save)
            if plan.kind == "step":
                self._run_one_step(plan.step)
                if plan.post_save is not None:
                    self.ckpt.save(self.state, plan.post_save)
            elif plan.kind == "stall":
                self.s_stalls.inc()     # collective hung on a silent pod
            else:                       # "recover"
                self.s_failures.inc()
                self.ckpt.wait()        # surface async-save errors first
                self.state = self.ckpt.restore(self.state,
                                               step=plan.restore_to)
        self.ckpt.wait()
        final = int(jax.device_get(self.state["step"]))
        return {"final_step": final, "attempts": policy.attempt,
                "decisions": list(policy.decisions),
                "history": self.history}
