"""Deterministic synthetic data pipeline.

Produces batches matching ``Model.input_specs`` exactly.  Determinism
contract (needed for fault-tolerant restart): batch(step) is a pure
function of (seed, step) — after a checkpoint restore at step k, the
pipeline regenerates the identical stream from k without any state.

The token stream is a order-2 Markov chain over the vocab (not iid
uniform) so that the cross-entropy actually *decreases* during the
example training runs — a learnable signal on CPU-scale models.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


class SyntheticPipeline:
    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, seed: int = 0,
                 learnable: bool = True):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.learnable = learnable
        # fixed random structure for the Markov stream
        rng = np.random.default_rng(seed)
        v = cfg.vocab_size
        self._period = rng.integers(2, 8)
        self._offsets = rng.integers(0, v, size=16)

    # -- token generation ------------------------------------------------
    def _tokens(self, rng, b: int, s: int) -> np.ndarray:
        v = self.cfg.vocab_size
        if not self.learnable:
            return rng.integers(0, v, size=(b, s), dtype=np.int64)
        # deterministic structure + noise: next = prev + offset[t%16] mod v
        start = rng.integers(0, v, size=(b, 1))
        steps = self._offsets[np.arange(s) % 16][None, :]
        toks = (start + np.cumsum(np.broadcast_to(steps, (b, s)), axis=1)) % v
        noise = rng.random((b, s)) < 0.05
        toks = np.where(noise, rng.integers(0, v, size=(b, s)), toks)
        return toks.astype(np.int64)

    # -- public ------------------------------------------------------------
    def batch(self, step: int, kind: Optional[str] = None) -> Dict[str, Any]:
        cfg, shape = self.cfg, self.shape
        kind = kind or shape.kind
        rng = np.random.default_rng((self.seed, step))
        B, S = shape.global_batch, shape.seq_len
        n_vis = cfg.n_vis if cfg.family == "vlm" else 0
        s_text = S - n_vis

        out: Dict[str, Any] = {}
        toks = self._tokens(rng, B, s_text + 1)     # +1 for next-token labels
        if kind == "train":
            out["tokens"] = toks[:, :-1].astype(np.int32)
            text_labels = toks[:, 1:]
            labels = np.zeros((B, S), np.int32)
            labels[:, n_vis:] = text_labels
            mask = np.zeros((B, S), np.float32)
            mask[:, n_vis:] = 1.0
            out["labels"] = labels
            out["mask"] = mask
        elif kind == "prefill":
            out["tokens"] = toks[:, :-1].astype(np.int32)
        else:  # decode
            out["tokens"] = toks[:, :1].astype(np.int32)
            out["cur_len"] = np.asarray(min(S - 1, s_text), np.int32)

        if cfg.family == "vlm" and kind != "decode":
            out["vision_embeds"] = rng.standard_normal(
                (B, cfg.n_vis, cfg.d_model)).astype(np.float32) * 0.1
        if cfg.family == "audio" and kind != "decode":
            out["enc_embeds"] = rng.standard_normal(
                (B, cfg.enc_seq, cfg.d_model)).astype(np.float32) * 0.1
        return out
