"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package is <name>/{kernel.py, ops.py, ref.py}:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper in model layout
  ref.py    — pure-jnp oracle (tests assert_allclose against it)

Kernels:
  flash_attention — online-softmax attention; deletes the (b,h,s,chunk)
                    f32 score traffic that dominates the dry-run memory
                    roofline for attention archs.
  rwkv6_wkv       — chunked WKV6 linear recurrence (data-dependent decay).
  moe_mlp         — fused per-expert SwiGLU over MoE capacity blocks;
                    d_ff intermediates never reach HBM.
  quantize        — int8 block quantization (gradient compression).

Validated in interpret=True mode on CPU (the container rule: TPU is the
TARGET, not the runtime); the dry-run XLA path never routes through
Pallas so the 512-device lower/compile stays kernel-free.
"""
