"""Jit'd wrapper for the int8 block-quantize kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import quantize_fwd


@functools.partial(jax.jit, static_argnames=("block", "block_rows",
                                             "interpret"))
def quantize(x, *, block: int = 256, block_rows: int = 64,
             interpret: bool = True):
    """x: any-shape f32 -> (q (nb, block) int8, scales (nb,), pad)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    nb = blocks.shape[0]
    br = block_rows
    while nb % br:
        br //= 2
    q, s = quantize_fwd(blocks, block_rows=max(br, 1), interpret=interpret)
    return q, s, pad
