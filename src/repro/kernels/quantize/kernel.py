"""Int8 block-quantize kernel (pl.pallas_call + BlockSpec).

One grid step loads a (Bn, block) tile of gradient blocks into VMEM,
computes per-row absmax -> scale, and writes the rounded int8 tile plus
the f32 scales.  Pure VPU work; the point of the kernel is bandwidth:
gradients are read exactly once and written at 1/4 the bytes (+scales),
which is the compression step of the cross-pod gradient all-reduce.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                     # (Bn, block)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize_fwd(x, *, block_rows: int = 64, interpret: bool = False):
    """x: (nb, block) f32 -> (q (nb, block) int8, scales (nb,) f32)."""
    nb, block = x.shape
    block_rows = min(block_rows, nb)
    assert nb % block_rows == 0
    grid = (nb // block_rows,)
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, block), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(x)
