"""Oracle for the int8 block-quantize kernel (the gradient-compression
hot loop): identical math to ``repro.optim.compress``."""

from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x):
    """x: (nb, block) f32 -> (q int8, scales f32 (nb,))."""
    scale = jnp.max(jnp.abs(x), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_ref(q, scale):
    return q.astype(jnp.float32) * scale[:, None]
