"""Chunked WKV6 kernel (pl.pallas_call + BlockSpec).

Grid: (batch*heads, seq_chunks) with the chunk axis marked
"arbitrary"-ordered sequential — the (n, n) state matrix lives in a
VMEM scratch accumulator carried across chunk steps (grid iteration on
TPU is sequential over the last axis, the standard Pallas carry
pattern).

Per chunk of length L (default 64) with head size n (= 64 for RWKV6):
  load r/k/v/logw tiles (L, n) -> VMEM,
  cum = cumsum(logw) along L,
  pairwise decay D[l, m] = exp(cum[l-1] - cum[m]) masked to m < l
  (every exponent <= 0: numerically safe by construction),
  intra = (r*exp(cum_prev)) @ state  +  ((r (x) k (x) D) @ v  + diag-u,
  state = exp(cum_L) * state + (k * exp(cum_L - cum))^T @ v.

Working set: 4 tiles (L, n) + state (n, n) f32 + the (L, L) score tile
~ 64KB << VMEM.  The MXU sees (L, n) x (n, n) and (L, L) x (L, n)
matmuls; the decay einsum is VPU work of the same element count as one
matmul.

The HBM win vs the jnp path: r/k/v/w are read once and y written once
per chunk — the (L, L, n) pairwise-decay tensor never leaves VMEM
(it dominates the jnp path's memory traffic at rwkv6-7b scale).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_ref, *,
                 chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    f32 = jnp.float32
    r = r_ref[0].astype(f32)           # (L, n)
    k = k_ref[0].astype(f32)
    v = v_ref[0].astype(f32)
    lw = lw_ref[0].astype(f32)         # log decay, <= 0
    u = u_ref[0].astype(f32)           # (1, n) bonus for this head

    cum = jnp.cumsum(lw, axis=0)       # (L, n)
    cum_prev = cum - lw
    S = s_ref[...]                     # (n, n)

    # inter-chunk: r_t * a_{t-1} applied to the carried state
    r_hat = r * jnp.exp(cum_prev)
    inter = jax.lax.dot_general(r_hat, S, (((1,), (0,)), ((), ())))

    # intra-chunk pairwise: D[l,m,n] = exp(cum_prev[l]-cum[m]) for m<l
    dmat = cum_prev[:, None, :] - cum[None, :, :]          # (L, L, n)
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1) \
        < jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    dmat = jnp.where(causal[:, :, None], dmat, -jnp.inf)
    scores = jnp.einsum("ln,mn,lmn->lm", r, k, jnp.exp(dmat))
    intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())))
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)       # (L, 1)
    y = inter + intra + diag * v

    # state update (all multipliers <= 1)
    a_L = jnp.exp(cum[-1])                                  # (n,)
    k_tail = k * jnp.exp(cum[-1:, :] - cum)                 # (L, n)
    s_ref[...] = a_L[:, None] * S + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())))
    y_ref[0] = y.astype(y_ref.dtype)


def wkv6_fwd(r, k, v, lw, u, *, chunk: int = 64, interpret: bool = False):
    """r/k/v/lw: (bh, s, n); u: (bh, n).  Returns y (bh, s, n).

    lw = log(decay) (<= 0).  bh = batch*heads; u is per-head, callers
    broadcast it to (bh, n).
    """
    bh, s, n = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    grid = (bh, s // chunk)
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, n), r.dtype),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
