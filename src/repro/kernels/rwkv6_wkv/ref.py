"""Pure-jnp oracle for the WKV6 kernel: the exact sequential recurrence.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t S_{t-1} + (r_t . u . k_t) v_t

r/k/v/w inputs are per-head (b, s, h, n) with w = decay in (0, 1);
u (h, n) is the bonus.  This is O(s) sequential — slow but
unambiguously correct, which is what an oracle is for.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, state0=None):
    """Returns (y (b, s, h, n), final state (b, h, n, n))."""
    b, s, h, n = r.shape
    f32 = jnp.float32
    rr, kk, vv, ww = (x.astype(f32) for x in (r, k, v, w))
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), f32)

    def step(S, xs):
        rt, kt, vt, wt = xs          # (b, h, n)
        y = jnp.einsum("bhn,bhnm->bhm", rt, S) + \
            jnp.einsum("bhn,hn,bhn->bh", rt, u.astype(f32), kt)[..., None] \
            * vt
        S = wt[..., None] * S + jnp.einsum("bhn,bhm->bhnm", kt, vt)
        return S, y

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (rr, kk, vv, ww))
    S, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), S
