"""Jit'd wrapper: model layout (b, s, h, n) -> WKV6 Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.kernel import wkv6_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = 64, interpret: bool = True):
    """r/k/v/w: (b, s, h, n) with w = decay in (0,1); u: (h, n)."""
    b, s, h, n = r.shape
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, n)

    u_bh = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, n)
    y = wkv6_fwd(to_bh(r), to_bh(k), to_bh(v), to_bh(lw), u_bh,
                 chunk=chunk, interpret=interpret)
    return y.reshape(b, h, s, n).transpose(0, 2, 1, 3)
