"""Oracle for the fused expert-MLP kernel: per-expert SwiGLU FFN over
capacity blocks (the expert compute of ``repro.models.moe``)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def expert_mlp_ref(x, wi, wg, wo):
    """x: (G, E, C, D); wi/wg: (E, D, F); wo: (E, F, D)."""
    h = jnp.einsum("gecd,edf->gecf", x, wi)
    u = jnp.einsum("gecd,edf->gecf", x, wg)
    h = jax.nn.silu(h) * u
    return jnp.einsum("gecf,efd->gecd", h, wo)
