"""Jit'd wrapper: (G, E, C, D) capacity blocks -> fused expert MLP."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moe_mlp.kernel import expert_mlp_fwd


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "interpret"))
def expert_mlp(x, wi, wg, wo, *, block_c: int = 128, block_f: int = 256,
               interpret: bool = True):
    """x: (G, E, C, D); wi/wg: (E, D, F); wo: (E, F, D) -> (G, E, C, D)."""
    g, e, c, d = x.shape
    out = expert_mlp_fwd(x.reshape(g * e, c, d), wi, wg, wo,
                         block_c=block_c, block_f=block_f,
                         interpret=interpret)
    return out.reshape(g, e, c, d)
