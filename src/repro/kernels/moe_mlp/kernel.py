"""Fused expert-MLP kernel (pl.pallas_call + BlockSpec): SwiGLU FFN per
expert over MoE capacity blocks.

Fusion rationale (from the dry-run roofline): the d_ff intermediate of
the expert FFN is top_k*capacity_factor times LARGER than the token
activations; on the XLA path it makes three HBM round-trips (write h,
write u, read both for the down-projection).  This kernel keeps the
(Bc, Bf) h/u tiles in VMEM and accumulates the down-projection across
the f-grid axis into a VMEM scratch, so d_ff traffic never reaches HBM.

Grid: (G*E, C/Bc, F/Bf) — for each (expert-block, token-tile) the last
axis walks d_ff tiles sequentially accumulating ``silu(x@wi)*(x@wg) @
wo`` into the (Bc, D) accumulator; written once at the final f step.

Weight tiles are indexed by the expert id e = (g*E+e)%E via the
BlockSpec index_map — each grid step touches one (D, Bf) wi/wg tile
and one (Bf, D) wo tile.  VMEM working set at defaults (Bc=128,
Bf=256, D=2048): x 1 MB + wi/wg/wo tiles 3*2 MB + acc 1 MB ~ 8 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _expert_mlp_kernel(x_ref, wi_ref, wg_ref, wo_ref, o_ref, acc_ref):
    fi = pl.program_id(2)
    nf = pl.num_programs(2)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)          # (Bc, D)
    wi = wi_ref[0].astype(jnp.float32)        # (D, Bf)
    wg = wg_ref[0].astype(jnp.float32)
    wo = wo_ref[0].astype(jnp.float32)        # (Bf, D)
    h = jax.lax.dot_general(x, wi, (((1,), (0,)), ((), ())))
    u = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())))
    h = (h * jax.nn.sigmoid(h)) * u           # silu(h) * u, in VMEM
    acc_ref[...] += jax.lax.dot_general(h, wo, (((1,), (0,)), ((), ())))

    @pl.when(fi == nf - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def expert_mlp_fwd(x, wi, wg, wo, *, block_c: int = 128, block_f: int = 256,
                   interpret: bool = False):
    """x: (GE, C, D) capacity blocks (GE = groups*experts, expert id =
    index % E); wi/wg: (E, D, F); wo: (E, F, D).  Returns (GE, C, D)."""
    ge, c, d = x.shape
    e, _, f = wi.shape
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    assert c % block_c == 0 and f % block_f == 0
    grid = (ge, c // block_c, f // block_f)
    return pl.pallas_call(
        _expert_mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda g, ci, fi: (g, ci, 0)),
            pl.BlockSpec((1, d, block_f), lambda g, ci, fi: (g % e, 0, fi)),
            pl.BlockSpec((1, d, block_f), lambda g, ci, fi: (g % e, 0, fi)),
            pl.BlockSpec((1, block_f, d), lambda g, ci, fi: (g % e, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d), lambda g, ci, fi: (g, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((ge, c, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, d), jnp.float32)],
        interpret=interpret,
    )(x, wi, wg, wo)
