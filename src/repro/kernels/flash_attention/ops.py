"""Jit'd public wrapper for the flash-attention kernel.

``flash_attention(q, k, v)`` takes model-layout (b, s, h, d) tensors,
flattens (b, h) into the grid's leading axis, and dispatches to the
Pallas kernel.  On this CPU container the kernel runs in interpret
mode (assignment rule: TPU is the TARGET, interpret mode validates
correctness); on TPU set ``interpret=False``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q/k/v: (b, s, h, d) -> (b, s, h, d)."""
    b, s, h, d = q.shape
    skv = k.shape[1]

    def to_bh(x, sl):
        return x.transpose(0, 2, 1, 3).reshape(b * h, sl, d)

    o = flash_attention_fwd(
        to_bh(q, s), to_bh(k, skv), to_bh(v, skv),
        causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)
