"""Pure-jnp oracle for the flash-attention kernel.

Causal (optionally sliding-window) multi-head attention with f32
softmax accumulation — numerically the ground truth the Pallas kernel
must match (and the same math as
``repro.models.layers.naive_causal_attention``, kept standalone so the
kernel package is self-contained).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q/k/v: (b, s, h, d) -> (b, s, h, d)."""
    b, s, h, d = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(d)
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        mask = ki <= qi
        if window:
            mask &= ki > qi - window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v)
