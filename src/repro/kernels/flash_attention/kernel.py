"""Flash-attention forward kernel (pl.pallas_call + BlockSpec).

TPU-native tiling: the grid walks (batch*heads, q_blocks, kv_blocks);
each step loads a (Bq, d) query tile and a (Bk, d) KV tile into VMEM,
runs the online-softmax update against f32 accumulators held in VMEM
scratch, and writes the normalized (Bq, d) output tile on the last KV
step.  The score tensor NEVER touches HBM — on the baseline XLA path
the dry-run measured the (b, h, s, chunk) f32 score traffic as the
dominant memory-roofline contributor at train_4k/prefill_32k shapes,
which is exactly the traffic this kernel deletes.

Block sizes default to (128, 128): the MXU is 128x128, so q/k tiles
are MXU-aligned; the working set per grid step is
  q (128, d) + k/v (128, d) * 2 + acc (128, d) f32 + scores (128, 128) f32
which for d=128 is ~260 KB << 16 MB VMEM, leaving headroom for
double-buffered pipelining.

The causal variant masks by absolute positions; sliding windows mask
``q_pos - kv_pos >= window``.  Out-of-range KV blocks are skipped via
``pl.when`` (no MXU work issued), which restores the ~2x triangular
FLOP saving that the baseline jnp path leaves on the table.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      scale: float, block_q: int, block_k: int,
                      causal: bool, window: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # skip fully-masked KV blocks (causal: block entirely in the future;
    # windowed: block entirely before the window)
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
        if window:
            run &= (k_start + block_k - 1) >= (q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (Bq, d)
        k = k_ref[0].astype(jnp.float32)                # (Bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (Bq, Bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            mask = kpos <= qpos
            if window:
                mask &= kpos > qpos - window
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q/k/v: (bh, s, d) — batch*heads flattened.  Returns (bh, s, d)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    grid = (bh, sq // block_q, skv // block_k)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_fwd_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, kv_len=skv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),     # acc
            pltpu.VMEM((block_q,), jnp.float32),       # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),       # l (running sum)
        ],
        interpret=interpret,
    )(q, k, v)
