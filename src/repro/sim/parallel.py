"""User-facing helpers for multiprocess (dist-gem5-style) simulation.

The engine itself lives in :mod:`repro.core.desim.parallel`; the normal
entry points are the ``workers=N`` knobs on :class:`repro.sim.Simulator`
and :meth:`repro.sim.boards.Board.executor`.  This module adds the
one-shot convenience wrapper and the stats-combination helper sweep
drivers use when they shard *independent* runs across processes
themselves.

Exactness contract (test-enforced in ``tests/test_parallel_engine.py``
and documented in ``docs/parallel.md``): a parallel run's final tick,
full stats tree, checkpoints and decision logs are bit-identical to the
serial engine's, and a checkpoint taken under any worker count restores
under any other.
"""

from __future__ import annotations

from typing import Iterable, Optional

from repro.core.desim.executor import ExecResult
from repro.core.desim.parallel import ParallelEngine
from repro.core.desim.trace import HloTrace
from repro.core.stats import StatGroup
from repro.sim.boards import Board

__all__ = ["ParallelEngine", "run_parallel", "merge_stat_trees",
           "parallel_supported"]


def run_parallel(board: Board, trace: HloTrace, workers: int = 2,
                 mp_context: Optional[str] = None, **kw) -> ExecResult:
    """One-shot parallel trace replay on a board: shard the board's
    pods across ``workers`` processes, run to completion, return the
    :class:`ExecResult` (bit-identical to ``board.executor().
    execute(trace)``)."""
    ex = board.executor(workers=workers, mp_context=mp_context, **kw)
    try:
        return ex.execute(trace)
    finally:
        close = getattr(ex, "close", None)
        if close is not None:
            close()


def parallel_supported(board: Board, trace: HloTrace,
                       timing: Optional[str] = None) -> bool:
    """True when a run of ``trace`` on ``board`` would actually shard
    across workers (rather than taking the exact-by-construction serial
    fallback — see the rules in ``repro.core.desim.parallel``)."""
    eng = ParallelEngine(board.machine, workers=2,
                         algorithm=board.algorithm,
                         straggler_slowdowns=board.straggler_slowdowns,
                         timing=timing or board.timing)
    return eng._parallel_plan(trace, None) is not None


def merge_stat_trees(trees: Iterable[StatGroup]) -> StatGroup:
    """Fold several runs' stats trees into one combined tree via
    :meth:`StatGroup.merge` — the sweep-sharding helper: when a driver
    farms *independent* simulations out to processes, this merges their
    gem5-style stats databases as if one run had accumulated all
    samples.  Merges into (and returns) the **first** tree; pass a
    throwaway ordering if the originals must stay pristine."""
    it = iter(trees)
    try:
        acc = next(it)
    except StopIteration:
        raise ValueError("merge_stat_trees() needs at least one tree")
    for t in it:
        acc.merge(t)
    return acc
