"""gem5-stdlib-style ``Simulator`` front-end with typed exit events.

The gem5 standard library (PAPERS.md: "Toward Reproducible and
Standardized Computer Architecture Simulation with gem5") made gem5
usable at scale with one object: ``Simulator(board, workload)`` owns
``m5.instantiate()``, drives the event loop, and turns simulation-exit
causes into typed ``ExitEvent``s the user scripts against in plain
Python — checkpoint here, switch CPU models there, stop at max-tick.
Before it, every config hand-rolled the instantiate/run/exit plumbing;
exactly the state of our desim drivers after PR 1.

g5x reproduction::

    sim = Simulator(v5e_multipod(2), trace)
    sim.schedule_max_tick(5_000_000)
    sim.schedule_checkpoint(20_000_000)
    for ev in sim.run():                      # generator of ExitEvents
        if ev.kind is ExitEventType.MAX_TICK:
            print("warmed up at", ev.tick)    # ... then keep iterating
        elif ev.kind is ExitEventType.CHECKPOINT:
            path = ev.payload["path"]         # restore later / elsewhere
    res = sim.result()                        # ExecResult of the run

Exit-event semantics:

* ``MAX_TICK``     — a ``schedule_max_tick`` point was reached; the sim
                     is paused (no event at tick <= point pending).
* ``CHECKPOINT``   — the run was gem5-drained, serialized (see
                     ``repro.sim.serialize``), and resumed *through the
                     restore path* (resume == restore, so every
                     checkpoint is exercised end-to-end).
* ``WORK_BEGIN`` / ``WORK_END`` — a trace op named ``work_begin*`` /
                     ``work_end*`` completed on pod 0 (gem5 work items,
                     §2.7: delimit the region of interest in the
                     workload itself).  Under QuantumSync these are
                     delivered at the next quantum boundary — the only
                     points where global state is observable in
                     dist-gem5.
* ``SAMPLE_BEGIN`` — a sampled-simulation window starts (emitted by
                     ``repro.sim.sampling``, not by ``Simulator``).
* ``SLO_VIOLATION`` — a dynamic serving workload finished a request
                     over its TTFT/latency SLO (``repro.sim.workloads.
                     ServeSim`` with ``exit_on_slo=True``).
* ``POD_FAILED``   — a dynamic training workload declared a pod dead
                     (``repro.sim.workloads.TrainSim`` with
                     ``exit_on_fault=True``).
* ``RESHARD``      — the training workload's FT policy replanned the
                     elastic mesh (after a death or a rejoin).
* ``SCALE_UP`` / ``SCALE_DOWN`` — the fleet workload's autoscaler
                     brought a replica up (warming starts; it serves
                     after its cold start) or retired an idle one
                     (``repro.sim.fleet.FleetSim``).
* ``DONE``         — the workload completed; ``result()`` is available.

Dynamic workloads (``repro.sim.workloads.DynamicWorkload``) generate
ops *while the simulation runs* — ``Simulator`` co-simulates them:
advance the engine to the workload's next external event, ``poll`` the
workload, repeat.  See ``docs/serving.md``.
"""

from __future__ import annotations

import enum
import os
import time
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.core import trace as dbg
from repro.core.desim.executor import ExecResult, TraceExecutor
from repro.core.desim.machine import ClusterModel
from repro.core.desim.simnodes import TICKS_PER_S
from repro.core.desim.trace import HloTrace
from repro.sim import instrument as inst
from repro.sim.boards import Board
from repro.sim.workloads import DynamicWorkload


class ExitEventType(enum.Enum):
    MAX_TICK = "max_tick"
    CHECKPOINT = "checkpoint"
    WORK_BEGIN = "work_begin"
    WORK_END = "work_end"
    SAMPLE_BEGIN = "sample_begin"
    SLO_VIOLATION = "slo_violation"
    POD_FAILED = "pod_failed"
    RESHARD = "reshard"
    SCALE_UP = "scale_up"
    SCALE_DOWN = "scale_down"
    STAT_DUMP = "stat_dump"
    DONE = "done"


@dataclass(frozen=True)
class ExitEvent:
    """One typed simulation exit (gem5 ``ExitEvent`` analogue)."""

    kind: ExitEventType
    tick: int
    cause: str = ""
    payload: Dict[str, Any] = field(default_factory=dict)

    @property
    def time_s(self) -> float:
        return self.tick / TICKS_PER_S

    def __str__(self) -> str:
        return (f"ExitEvent({self.kind.value} @ {self.tick} "
                f"[{self.time_s:.6f}s] {self.cause})")


WORK_BEGIN_PREFIX = "work_begin"
WORK_END_PREFIX = "work_end"


# ---------------------------------------------------------------------------
# workloads
# ---------------------------------------------------------------------------

def repeat_trace(step: HloTrace, num_steps: int,
                 name: Optional[str] = None) -> HloTrace:
    """Chain ``num_steps`` copies of a one-step trace: each step's root
    ops depend on the previous step's sink ops (steady-state training:
    step N+1 cannot start before step N's last collective lands)."""
    if num_steps < 1:
        raise ValueError("num_steps must be >= 1")
    n = len(step.ops)
    has_dependent = [False] * n
    for op in step.ops:
        for d in op.deps:
            has_dependent[d] = True
    sinks = tuple(i for i in range(n) if not has_dependent[i])
    out = HloTrace(name or f"{step.name}x{num_steps}",
                   meta=dict(step.meta, steps=num_steps))
    for rep in range(num_steps):
        off = rep * n
        for idx, op in enumerate(step.ops):
            deps = tuple(d + off for d in op.deps)
            if not deps and rep > 0:
                deps = tuple(s + off - n for s in sinks)
            out.ops.append(replace(
                op, deps=deps,
                name=f"step{rep}/{op.name}" if op.name else ""))
    return out


@dataclass
class SteadyStateWorkload:
    """``num_steps`` repetitions of one step trace (a training run)."""

    step: HloTrace
    num_steps: int

    def trace(self) -> HloTrace:
        return repeat_trace(self.step, self.num_steps)


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

class Simulator:
    """Owns instantiate/startup and the exit-event loop of one run.

    ``board``    : a :class:`repro.sim.boards.Board` (or a bare
                   ``ClusterModel``, wrapped with default knobs).
    ``workload`` : an :class:`HloTrace`, or anything with ``.trace()``
                   (e.g. ``SteadyStateWorkload``).
    ``checkpoint_dir`` : when set, CHECKPOINT exits also write
                   ``ckpt_tick<T>.json`` there (see serialize.py).
    ``timing``   : fidelity model to start under ("detailed" |
                   "atomic"; default: the board's).  Switch mid-run
                   with :meth:`switch_timing` — the gem5 ``switch_cpus``
                   move.
    ``workers``  : shard the board's pods across N worker processes
                   (dist-gem5 multiprocess simulation, §2.17 — see
                   ``repro.core.desim.parallel``).  Results and
                   checkpoints are bit-identical to ``workers=1``.
                   Dynamic workloads co-simulate in-process (their
                   injected ops couple the run to host code every
                   event), so ``workers`` is coerced to 1 for them.
    """

    def __init__(self, board, workload, *,
                 checkpoint_dir: Optional[str] = None,
                 record_stats: bool = True, record_timeline: bool = False,
                 contention: Optional[bool] = None,
                 timing: Optional[str] = None,
                 workers: int = 1, mp_context: Optional[str] = None,
                 outdir: Optional[str] = None, trace_events: bool = False,
                 verbose: bool = False):
        if isinstance(board, ClusterModel):
            board = Board(machine=board)
        self.board = board.instantiate()     # Simulator owns instantiate()
        if isinstance(workload, DynamicWorkload):
            # dynamic workloads inject their ops into a live run; the
            # run begins from an empty trace that grows as events fire
            self._dyn: Optional[DynamicWorkload] = workload
            self._trace = HloTrace(f"dynamic:{getattr(workload, 'name', '')}")
        else:
            self._dyn = None
            self._trace = (workload if isinstance(workload, HloTrace)
                           else workload.trace())
        if self._dyn is not None:
            workers = 1        # co-simulation is inherently in-process
        # m5out-style instrumentation (repro.sim.instrument): the
        # recorder rides _ex_cfg so every executor this Simulator builds
        # (initial, checkpoint restores, parallel spawns) records into
        # the same merged timeline
        self.outdir = inst.OutDir(outdir) if outdir else None
        self._recorder = (inst.TraceEventRecorder() if trace_events
                          else None)
        self.verbose = bool(verbose)
        self._host_t0: Optional[float] = None
        self._host_seconds = 0.0
        self._final_tick: Optional[int] = None
        self._stat_dump_period: Optional[int] = None
        self._stat_dump_reset = False
        self._ex_cfg = dict(record_stats=record_stats,
                            record_timeline=record_timeline,
                            contention=contention, timing=timing,
                            workers=int(workers or 1),
                            mp_context=mp_context,
                            instrument=self._recorder)
        self._ex = board.executor(**self._ex_cfg)
        # pin the resolved model: checkpoints/switches restore under it
        self._ex_cfg["timing"] = self._ex.timing.name
        self._ex_cfg.pop("contention")
        self._has_markers = any(
            (op.name or "").rpartition("/")[2].startswith(
                (WORK_BEGIN_PREFIX, WORK_END_PREFIX))
            for op in self._trace.ops)
        self._marker_exits: deque = deque()
        self._scheduled: List[Tuple[int, int, ExitEventType]] = []
        self._sched_seq = 0
        self._started = False
        self._result: Optional[ExecResult] = None
        self.checkpoint_dir = checkpoint_dir
        self.last_checkpoint: Optional[Dict[str, Any]] = None
        self.checkpoint_paths: List[str] = []
        if self.outdir is not None:
            # gem5 writes config.json/config.ini at instantiate time:
            # the run's full configuration as a versioned artifact
            self.outdir.write_config(self._config_doc())

    # -- construction from a checkpoint ---------------------------------
    @classmethod
    def from_checkpoint(cls, source, board: Optional[Board] = None, *,
                        workload=None, timing: Optional[str] = None,
                        checkpoint_dir: Optional[str] = None,
                        workers: int = 1,
                        mp_context: Optional[str] = None,
                        outdir: Optional[str] = None,
                        trace_events: bool = False,
                        verbose: bool = False) -> "Simulator":
        """Resume a serialized simulation, optionally onto a
        re-parameterized ``board`` (the checkpoint-once, sweep-hardware
        workflow).  ``source`` is a path or a checkpoint dict.

        ``timing`` restores under a *different* fidelity model than the
        checkpoint was taken under (gem5 ``switch_cpus`` through the
        checkpoint file: atomic fast-forward elsewhere, restore here
        under "detailed" for the region of interest).

        A checkpoint of a *dynamic* workload stores the workload's
        state but not its construction (request streams are code, not
        data): pass an equivalently-built ``workload`` (same requests /
        seed / knobs) and its state is restored into it.
        """
        from repro.sim import serialize as ser
        ckpt = (ser.load_checkpoint(source) if isinstance(source, str)
                else source)
        cfg = ckpt["executor"]
        explicit_board = board is not None
        if board is None:
            board = Board(machine=ser.machine_from_dict(ckpt["machine"]),
                          algorithm=cfg["algorithm"],
                          straggler_slowdowns=cfg["straggler_slowdowns"])
        if ser.WORKLOAD_KEY in ckpt \
                and not isinstance(workload, DynamicWorkload):
            raise ser.CheckpointError(
                "checkpoint carries dynamic-workload state; pass the "
                "rebuilt DynamicWorkload object (same request stream) "
                "via workload=")
        want_kind = ckpt.get(ser.WORKLOAD_KIND_KEY)
        if want_kind is not None and isinstance(workload, DynamicWorkload) \
                and type(workload).__name__ != want_kind:
            raise ser.CheckpointError(
                f"checkpoint carries {want_kind} state but a "
                f"{type(workload).__name__} was passed via workload=")
        if workload is not None and ser.WORKLOAD_KEY not in ckpt:
            # a static checkpoint resumes its own serialized trace; a
            # passed workload would be silently ignored — refuse instead
            raise ser.CheckpointError(
                "a workload was passed but the checkpoint has no "
                "workload state (it was taken of a static trace run, "
                "which restores its own trace)")
        sim = cls(board, workload if workload is not None
                  else ser.trace_from_checkpoint(ckpt),
                  checkpoint_dir=checkpoint_dir,
                  record_stats=cfg["record_stats"],
                  record_timeline=cfg["record_timeline"],
                  timing=(timing if timing is not None
                          else cfg.get("timing")),
                  contention=(None if timing is not None
                              or cfg.get("timing") is not None
                              else cfg.get("contention")),
                  workers=workers, mp_context=mp_context,
                  outdir=outdir, trace_events=trace_events,
                  verbose=verbose)
        overrides = dict(sim._ex_cfg)
        if explicit_board:
            # an explicitly-passed board wins wholesale: it bundles the
            # run knobs (algorithm, stragglers), not just the machine —
            # a board-based DSE re-sweep must actually apply them
            overrides.update(
                algorithm=board.algorithm,
                straggler_slowdowns=board.straggler_slowdowns)
        sim._ex = ser.restore_executor(ckpt, machine=board.machine,
                                       **overrides)
        sim._trace = sim._ex._trace
        sim._install_hook()
        if sim._dyn is not None:
            sim._dyn.bind(sim._ex)
            sim._dyn.load_state_dict(ckpt[ser.WORKLOAD_KEY])
        sim._started = True
        return sim

    # -- exit scheduling --------------------------------------------------
    def _schedule(self, tick: int, kind: ExitEventType) -> None:
        self._scheduled.append((int(tick), self._sched_seq, kind))
        self._sched_seq += 1
        self._scheduled.sort()

    def schedule_max_tick(self, tick: int) -> None:
        """Pause and yield ``MAX_TICK`` once no event at tick <= ``tick``
        remains (gem5 ``simulate(ticks)``)."""
        self._schedule(tick, ExitEventType.MAX_TICK)

    def schedule_checkpoint(self, tick: int) -> None:
        """Drain + serialize at the first pause point >= ``tick`` and
        yield ``CHECKPOINT`` (gem5 checkpoint exit event)."""
        self._schedule(tick, ExitEventType.CHECKPOINT)

    def schedule_stat_dump(self, period: int, reset: bool = False) -> None:
        """Dump statistics every ``period`` ticks (gem5
        ``m5.stats.periodicStatDump``): the run pauses at each cadence
        point exactly like a ``schedule_max_tick`` (so the dump cannot
        perturb event order), renders a ``stats.txt`` section to the
        outdir (or just yields ``STAT_DUMP`` when there is none), and
        reschedules.  ``reset=True`` also zeroes the stats after each
        dump (per-interval sections, gem5's dump-and-reset)."""
        period = int(period)
        if period <= 0:
            raise ValueError("stat-dump period must be positive")
        self._stat_dump_period = period
        self._stat_dump_reset = bool(reset)
        self._schedule(self._ex.now + period, ExitEventType.STAT_DUMP)

    # -- internals --------------------------------------------------------
    def _install_hook(self) -> None:
        self._ex.op_hook = self._on_op if self._has_markers else None

    def _on_op(self, op, idx, start, end) -> None:
        base = (op.name or "").rpartition("/")[2]
        if base.startswith(WORK_BEGIN_PREFIX):
            kind = ExitEventType.WORK_BEGIN
        elif base.startswith(WORK_END_PREFIX):
            kind = ExitEventType.WORK_END
        else:
            return
        self._marker_exits.append(
            ExitEvent(kind, tick=end, cause=op.name,
                      payload={"op_idx": idx, "start": start}))

    def _stop_check(self) -> bool:
        # pause the engine as soon as there is something to yield: a
        # work-item marker, or a workload-raised exit (SLO violation,
        # pod death, reshard) — exits must surface at the tick they
        # happen, not after the run completes
        return bool(self._marker_exits) or (
            self._dyn is not None and bool(self._dyn.pending_exits))

    def _do_checkpoint(self, requested_tick: int,
                       save: bool = True) -> ExitEvent:
        self._ex.drain()
        from repro.sim import serialize as ser
        ckpt = ser.checkpoint_executor(self._ex)
        if self._dyn is not None:
            ckpt[ser.WORKLOAD_KEY] = self._dyn.state_dict()
            ckpt[ser.WORKLOAD_KIND_KEY] = type(self._dyn).__name__
        self.last_checkpoint = ckpt
        path = None
        if save and self.checkpoint_dir:
            path = os.path.join(self.checkpoint_dir,
                                f"ckpt_tick{ckpt['tick']}.json")
            ser.save_checkpoint(ckpt, path)
            self.checkpoint_paths.append(path)
        # resume == restore: rebuild the executor from the checkpoint we
        # just took, so serialization is exercised on every checkpoint
        self._ex = ser.restore_executor(ckpt, machine=self.board.machine,
                                        **self._ex_cfg)
        self._trace = self._ex._trace
        self._install_hook()
        if self._dyn is not None:
            # the workload resumes through its own serialization too
            self._dyn.bind(self._ex)
            self._dyn.load_state_dict(ckpt[ser.WORKLOAD_KEY])
        return ExitEvent(ExitEventType.CHECKPOINT, tick=requested_tick,
                         cause="checkpoint",
                         payload={"checkpoint": ckpt, "path": path,
                                  "drained_tick": ckpt["tick"]})

    def _ensure_started(self) -> None:
        if self._host_t0 is None:
            self._host_t0 = time.perf_counter()
        if not self._started:
            self._ex.begin(self._trace)
            self._install_hook()
            if self._dyn is not None:
                self._dyn.bind(self._ex)
                self._dyn.start()
            self._started = True

    def _all_done(self) -> bool:
        return self._ex.done() and (self._dyn is None or self._dyn.done())

    # -- the exit-event loop ----------------------------------------------
    def run(self, verbose: Optional[bool] = None) -> Iterator[ExitEvent]:
        """Generator of :class:`ExitEvent`s; drive multi-phase
        simulations by iterating (and scheduling further exits between
        yields).

        Dynamic workloads run as a co-simulation: the engine advances
        to the workload's next external event (e.g. a request arrival),
        then ``poll`` lets the workload inject ops before the engine
        continues.  Workload-raised exits (SLO violations) yield like
        any other exit event.

        ``verbose`` (default: the constructor's ``verbose`` knob) prints
        the gem5 exit banner — ``Exiting @ tick N because <reason>`` —
        for every yielded event, plus the host-performance line
        (simSeconds/hostSeconds/simRate) at DONE.  Nothing is printed
        otherwise: all narration goes through the DPRINTF layer
        (``repro.core.trace``), so stdout stays silent unless a debug
        flag or the verbosity knob is explicitly enabled.
        """
        v = self.verbose if verbose is None else bool(verbose)
        for ev in self._run_events():
            dbg.dprintf("Sim", "simulator", "exiting because %s",
                        ev.cause, tick=ev.tick)
            if ev.kind is ExitEventType.DONE:
                self._finalize(ev)
            if v:
                print(f"Exiting @ tick {ev.tick} because {ev.cause}")
                if ev.kind is ExitEventType.DONE:
                    print(inst.format_host_banner(self.host_record()))
            yield ev

    def _finalize(self, done_ev: ExitEvent) -> None:
        """Close out the run's artifacts at DONE: host clock, final
        stats section, telemetry record, Perfetto trace."""
        if self._host_t0 is not None:
            self._host_seconds = time.perf_counter() - self._host_t0
        self._final_tick = done_ev.tick
        if self.outdir is not None:
            self.dump_stats(reason="final")
            self.outdir.write_json(inst.OutDir.TELEMETRY,
                                   self.host_record())
            if self._recorder is not None:
                self.write_trace()

    def _run_events(self) -> Iterator[ExitEvent]:
        self._ensure_started()
        stop = (self._stop_check
                if self._has_markers or self._dyn is not None else None)
        while True:
            if self._marker_exits:
                yield self._marker_exits.popleft()
                continue
            if self._dyn is not None and self._dyn.pending_exits:
                e = self._dyn.pending_exits.popleft()
                # workloads tag their exits with a "kind" (POD_FAILED,
                # RESHARD, ...); untagged entries are SLO violations
                # (the original ServeSim contract)
                kind = ExitEventType(
                    e.get("kind", ExitEventType.SLO_VIOLATION.value))
                yield ExitEvent(kind, tick=int(e["tick"]),
                                cause=e["cause"],
                                payload=dict(e.get("payload", {})))
                continue
            if self._all_done():
                if self._result is None:
                    self._result = self._ex.result()
                # makespan tick, not queue tick: a restored run's queues
                # restart at 0 but the simulated time does not
                yield ExitEvent(
                    ExitEventType.DONE,
                    tick=self._result.final_tick,
                    cause="workload complete")
                return
            sched_tick = self._scheduled[0][0] if self._scheduled else None
            dyn_tick = (self._dyn.next_event_tick()
                        if self._dyn is not None else None)
            if dyn_tick is not None and (sched_tick is None
                                         or dyn_tick <= sched_tick):
                # advance to the workload's next external event, then
                # let it react (submit arrivals, wake idle replicas)
                self._ex.advance(max_tick=dyn_tick, stop_check=stop)
                if self._stop_check():
                    continue     # deliver first; poll on the next pass
                self._dyn.poll(dyn_tick)
                continue
            if sched_tick is not None:
                tick, _, kind = self._scheduled[0]
                self._ex.advance(max_tick=tick, stop_check=stop)
                if self._stop_check():
                    continue                 # scheduled exit stays queued
                if self._all_done():
                    # workload ended before the exit point: drop it
                    self._scheduled.pop(0)
                    continue
                self._scheduled.pop(0)
                if kind is ExitEventType.CHECKPOINT:
                    yield self._do_checkpoint(tick)
                elif kind is ExitEventType.STAT_DUMP:
                    self.dump_stats(reason=f"periodic @ tick {tick}")
                    if self._stat_dump_reset:
                        self.reset_stats()
                    if self._stat_dump_period:
                        self._schedule(tick + self._stat_dump_period,
                                       ExitEventType.STAT_DUMP)
                    yield ExitEvent(kind, tick=tick, cause="stat dump")
                else:
                    yield ExitEvent(kind, tick=tick, cause="max tick")
            else:
                finished = self._ex.advance(stop_check=stop)
                if self._stop_check():
                    continue
                if self._dyn is not None:
                    if (not self._dyn.done()
                            and self._dyn.next_event_tick() is None
                            and not self._dyn.pending_exits):
                        raise RuntimeError(
                            "dynamic workload stalled: engine idle, no "
                            "pending arrivals, workload not done")
                    continue
                if not finished:
                    self._ex.result()        # raises the deadlock error
        # not reached

    def run_to_completion(self,
                          verbose: Optional[bool] = None) -> ExecResult:
        """Drain every exit event and return the final ExecResult."""
        for _ in self.run(verbose=verbose):
            pass
        return self.result()

    # -- mid-run fidelity switching ---------------------------------------
    def switch_timing(self, timing) -> str:
        """Switch the run to another fidelity model *now* — the gem5
        ``switch_cpus`` move (§1.3.1): drain the in-flight work,
        serialize, and restore the very same state under ``timing``
        ("atomic" | "detailed").  Call between ``run()`` yields (at any
        exit event) or before the first; subsequent checkpoints resume
        under the new model.  Returns the resolved model name.

        The canonical sampled-simulation loop::

            sim = Simulator(board, trace, timing="atomic")
            sim.schedule_max_tick(region_of_interest_start)
            for ev in sim.run():
                if ev.kind is ExitEventType.MAX_TICK:
                    sim.switch_timing("detailed")   # warmed up: go O3
        """
        from repro.core.desim.timing import get_timing_model
        name = get_timing_model(timing).name       # validate early
        self._ensure_started()
        if name == self._ex.timing.name:
            return name                            # already there
        self._ex_cfg["timing"] = name
        self._do_checkpoint(self._ex.now, save=False)
        return name

    # -- results / checkpoint API ----------------------------------------
    def save_checkpoint(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Checkpoint *now* (between ``run()`` yields, or before the
        first — a tick-0 checkpoint of a never-run simulation is
        valid): drain, serialize (to ``path`` if given), resume through
        restore.  Returns the checkpoint dict."""
        self._ensure_started()
        ev = self._do_checkpoint(self._ex.now)
        if path is not None:
            from repro.sim import serialize as ser
            ser.save_checkpoint(ev.payload["checkpoint"], path)
            self.checkpoint_paths.append(path)
        return ev.payload["checkpoint"]

    def result(self) -> ExecResult:
        if self._result is None:
            raise RuntimeError("simulation has not completed; iterate "
                               "run() until DONE (or run_to_completion())")
        return self._result

    # -- observability (repro.sim.instrument) -----------------------------
    def _stat_groups(self) -> List[Any]:
        groups = []
        if self._ex.sim_root is not None:
            groups.append(self._ex.sim_root.stats)
        dyn_stats = getattr(self._dyn, "stats", None)
        if dyn_stats is not None:
            groups.append(dyn_stats)
        return groups

    def dump_stats(self, reason: str = "manual") -> str:
        """Render one gem5-format stats section (engine tree + dynamic-
        workload tree) — appended to ``<outdir>/stats.txt`` when the
        Simulator owns an outdir, returned either way.  Callable at any
        exit event, like gem5's ``m5.stats.dump()``."""
        self._ensure_started()
        now = self._ex.now
        extra = {"simTicks": now, "simSeconds": now / TICKS_PER_S}
        groups = self._stat_groups()
        if self.outdir is not None:
            return self.outdir.dump_stats(groups, extra=extra,
                                          reason=reason)
        return inst.render_stats_txt(groups, extra=extra, reason=reason)

    def reset_stats(self) -> None:
        """Zero every stat (gem5 ``m5.stats.reset()``): subsequent dumps
        cover only the interval since this call.  Reads of simulation
        *timing* state are untouched — resetting cannot perturb."""
        for g in self._stat_groups():
            g.reset()

    def host_record(self) -> Dict[str, Any]:
        """The machine-readable exit record (final tick, simSeconds,
        hostSeconds, simRate, events fired) — gem5's end-of-run banner
        as data.  Available once the run is DONE."""
        res = self.result()
        tick = (self._final_tick if self._final_tick is not None
                else res.final_tick)
        return inst.host_record(tick, self._host_seconds, res.events)

    def write_trace(self, path: Optional[str] = None) -> str:
        """Write the Perfetto/Chrome trace-event timeline (requires
        ``trace_events=True``).  Defaults to ``<outdir>/trace.json``;
        open at https://ui.perfetto.dev.  Under ``workers>1`` the
        worker lanes merge at result/snapshot collection, so call this
        after the run (run() does it automatically with an outdir)."""
        if self._recorder is None:
            raise RuntimeError("Simulator(trace_events=True) required "
                               "for write_trace()")
        if path is None:
            if self.outdir is None:
                raise ValueError("no path given and no outdir set")
            path = self.outdir.file(inst.OutDir.TRACE)
        return self._recorder.write(path)

    @property
    def trace_recorder(self):
        """The live TraceEventRecorder (None without trace_events)."""
        return self._recorder

    def _config_doc(self) -> Dict[str, Any]:
        """The run's full configuration as a JSON-able artifact
        (gem5 ``config.json``: defensible runs dump what they ran)."""
        ex_cfg = {k: v for k, v in self._ex_cfg.items()
                  if k != "instrument"}
        if self._dyn is not None:
            wl: Dict[str, Any] = {"kind": type(self._dyn).__name__}
            ser = getattr(self._dyn, "serialize", None)
            if callable(ser):
                wl["config"] = ser()
        else:
            wl = {"kind": "trace", "name": self._trace.name,
                  "ops": len(self._trace.ops),
                  "meta": dict(getattr(self._trace, "meta", {}) or {})}
        return {
            "format": "g5x-config",
            "version": 1,
            "board": {"name": self.board.name,
                      "algorithm": self.board.algorithm,
                      "straggler_slowdowns":
                          self.board.straggler_slowdowns,
                      "timing": self.board.timing},
            "machine": self.board.machine.serialize(),
            "executor": ex_cfg,
            "workload": wl,
            "debug_flags": dbg.enabled_flags(),
            "trace_events": self._recorder is not None,
        }

    @property
    def tick(self) -> int:
        return self._ex.now

    @property
    def sim_root(self):
        """Root of the run's SimObject tree (stats live on it)."""
        return self._ex.sim_root

    @property
    def workload(self):
        """The dynamic workload driving this run (None for traces)."""
        return self._dyn

    @property
    def machine(self) -> ClusterModel:
        return self.board.machine

    @property
    def timing(self) -> str:
        """Name of the fidelity model currently driving the run."""
        return self._ex.timing.name
