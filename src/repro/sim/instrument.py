"""m5out, Perfetto timelines, and host telemetry (paper §2.21, §3).

gem5 drops every run's artifacts into an output directory (``m5out/``
by default): ``stats.txt`` with one *section* per dump (bracketed by
``Begin/End Simulation Statistics``), ``config.json`` describing the
instantiated SimObject graph, and a closing banner reporting how fast
the host simulated (simSeconds, hostSeconds, simRate).  This module is
that layer for the desim stack, plus a Chrome/Perfetto trace-event
exporter gem5 never had but its users keep rebuilding (see PAPERS.md
on call-stack profiling — *seeing where simulated time goes is itself
a research instrument*):

* :class:`OutDir` — the m5out analogue the Simulator can own.
* :func:`render_stats_txt` — gem5-format stats sections from the
  existing :class:`~repro.core.stats.StatGroup` tree (``path.stat
  value  # desc (unit)``; dict/vector values expand as ``::key`` rows).
* :class:`TraceEventRecorder` — collects op issue/complete, DCN
  rendezvous, and quantum barriers as compact rows during the run and
  renders them to trace-event JSON (`ui.perfetto.dev` /
  ``chrome://tracing``) with per-pod compute/ICI lanes, a coordinator
  lane for DCN transactions + barriers, and — under the
  ParallelEngine — one process group per worker, merged into a single
  coherent file.
* :func:`host_record` / :func:`format_host_banner` — the machine-
  readable exit record and the human banner line.

House rule: everything here only *reads* simulation state (recorder
hooks append to Python lists; stats rendering walks the tree).  A run
with tracing fully enabled is bit-identical to a silent one —
``tests/test_observability.py`` enforces it, serial and workers=4.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.desim.simnodes import TICKS_PER_S
from repro.core.stats import StatGroup

# ---------------------------------------------------------------------------
# gem5-format stats.txt rendering
# ---------------------------------------------------------------------------

_BEGIN = "---------- Begin Simulation Statistics ----------"
_END = "---------- End Simulation Statistics    ----------"


def _fmt_value(v: Any) -> str:
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, float):
        if v == int(v) and abs(v) < 1e15:
            return str(int(v))
        return f"{v:.6f}"
    return str(v)


def _stat_lines(key: str, value: Any, desc: str, unit: str) -> List[str]:
    comment = ""
    if desc or unit:
        comment = f" # {desc}" if desc else " #"
        if unit:
            comment += f" ({unit})"
    if isinstance(value, dict):
        return [f"{f'{key}::{k}':<56} {_fmt_value(v):>14}{comment}"
                for k, v in value.items()]
    if isinstance(value, (list, tuple)):
        return [f"{f'{key}::{i}':<56} {_fmt_value(v):>14}{comment}"
                for i, v in enumerate(value)]
    return [f"{key:<56} {_fmt_value(value):>14}{comment}"]


def render_stats_txt(groups: Iterable[StatGroup],
                     extra: Optional[Dict[str, Any]] = None,
                     reason: str = "") -> str:
    """One gem5 ``stats.txt`` section: every stat in the given trees as
    ``path.stat  value  # desc (unit)``, in tree order, between the
    Begin/End markers.  ``extra`` rows (host telemetry, final tick)
    come first, like gem5's simSeconds/hostSeconds block."""
    lines = [_BEGIN + (f" // {reason}" if reason else "")]
    for k, v in (extra or {}).items():
        lines.extend(_stat_lines(k, v, "", ""))

    def walk(g: StatGroup, prefix: str) -> None:
        path = f"{prefix}{g.name}"
        for name, stat in g.stats().items():
            lines.extend(_stat_lines(f"{path}.{name}", stat.value(),
                                     stat.desc, stat.unit))
        for child in g._children:
            walk(child, f"{path}.")

    for g in groups:
        walk(g, "")
    lines.append(_END)
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# the m5out directory
# ---------------------------------------------------------------------------

class OutDir:
    """gem5's ``m5out/``: a per-run artifact directory owning
    ``stats.txt`` (appended a section per dump), ``config.json`` (the
    instantiated configuration), ``telemetry.json`` (the host-perf
    record), and ``trace.json`` (the Perfetto timeline).  Created
    eagerly; ``stats.txt`` is truncated so every run starts clean,
    exactly like gem5 re-running into the same m5out."""

    STATS = "stats.txt"
    CONFIG = "config.json"
    TELEMETRY = "telemetry.json"
    TRACE = "trace.json"

    def __init__(self, path: str, truncate: bool = True):
        self.path = str(path)
        os.makedirs(self.path, exist_ok=True)
        self.dumps = 0
        if truncate:
            open(self.file(self.STATS), "w").close()

    def file(self, name: str) -> str:
        return os.path.join(self.path, name)

    def dump_stats(self, groups: Iterable[StatGroup],
                   extra: Optional[Dict[str, Any]] = None,
                   reason: str = "") -> str:
        """Append one stats section; returns the rendered text."""
        text = render_stats_txt(groups, extra=extra, reason=reason)
        with open(self.file(self.STATS), "a") as f:
            f.write(text + "\n\n")
        self.dumps += 1
        return text

    def write_json(self, name: str, doc: Any) -> str:
        p = self.file(name)
        with open(p, "w") as f:
            json.dump(doc, f, indent=1, default=str)
            f.write("\n")
        return p

    def write_config(self, doc: Dict[str, Any]) -> str:
        return self.write_json(self.CONFIG, doc)


# ---------------------------------------------------------------------------
# host telemetry (the gem5 exit banner, in record + banner form)
# ---------------------------------------------------------------------------

def host_record(final_tick: int, host_seconds: float,
                events: int) -> Dict[str, Any]:
    """The machine-readable exit record: what gem5 prints at the end of
    every run (simSeconds, hostSeconds, simRate) plus the engine's
    event throughput.  Wired into ``benchmarks.run --json`` rows."""
    sim_seconds = final_tick / TICKS_PER_S
    host = max(float(host_seconds), 0.0)
    return {
        "final_tick": int(final_tick),
        "sim_seconds": sim_seconds,
        "host_seconds": host,
        "sim_rate": (sim_seconds / host) if host > 0 else 0.0,
        "events": int(events),
        "events_per_host_sec": (events / host) if host > 0 else 0.0,
    }


def format_host_banner(rec: Dict[str, Any]) -> str:
    return (f"simSeconds {rec['sim_seconds']:.6f}  "
            f"hostSeconds {rec['host_seconds']:.3f}  "
            f"simRate {rec['sim_rate']:.2f}x  "
            f"events {rec['events']}  "
            f"({rec['events_per_host_sec']:.0f}/s)")


# ---------------------------------------------------------------------------
# Perfetto / Chrome trace-event exporter
# ---------------------------------------------------------------------------

# op row layout (kept as flat lists: the executor hook runs per op x pod)
_R_POD, _R_IDX, _R_NAME, _R_KIND, _R_READY, _R_START, _R_END, _R_DCN, \
    _R_DUR = range(9)

#: pid layout of the exported trace
PID_ENGINE = 1          # serial TraceExecutor rows
PID_COORD = 2           # DCN transactions + quantum barriers
PID_WORKER0 = 10        # ParallelEngine worker w -> pid 10 + w


class TraceEventRecorder:
    """Collects timeline rows during a run; renders Chrome trace-event
    JSON afterwards.  The hot hook is :meth:`op_event` (called from
    ``TraceExecutor._on_done`` — one append per completed op per pod);
    the coordinator-side hooks (:meth:`dcn_event`, :meth:`barrier_event`,
    :meth:`add_worker`) fire per rendezvous / quantum / collect.

    The same recorder object serves serial and parallel runs, and
    survives checkpoint/restore cycles (the Simulator threads it into
    every executor it builds), so a run that switches timing models or
    worker counts mid-flight still lands in one merged file.
    """

    def __init__(self):
        self.rows: List[list] = []            # serial / facade op rows
        self.barriers: List[int] = []         # quantum barrier ticks
        self.dcn_tx: List[list] = []          # [idx, name, start, dur,
        #                                        deliver, [(pod, ready)..]]
        self.worker_rows: Dict[int, List[list]] = {}   # widx -> op rows

    # -- hot hooks (must only read + append) ---------------------------
    def op_event(self, pod: int, payload: Dict[str, Any], start: int,
                 end: int) -> None:
        """One completed op on one pod.  ``payload`` is the executor's
        in-flight record (name/kind/ready/dcn/dur...)."""
        self.rows.append([
            pod, payload.get("op_idx", -1), payload.get("name", "op"),
            payload.get("kind", "compute"), payload.get("ready", start),
            start, end, bool(payload.get("dcn")), payload.get("dur"),
        ])

    def barrier_event(self, tick: int) -> None:
        self.barriers.append(int(tick))

    def dcn_event(self, idx: int, name: str, start: int, dur: int,
                  deliver: int,
                  arrivals: Sequence[Tuple[int, int]]) -> None:
        """A cross-pod rendezvous completing (coordinator side):
        transaction occupies ``[start, start+dur)``, results delivered
        at ``deliver``; ``arrivals`` are (pod, ready-tick) pairs."""
        self.dcn_tx.append([int(idx), name, int(start), int(dur),
                            int(deliver), list(arrivals)])

    def add_worker(self, widx: int, labels: Sequence[int],
                   members: Sequence[Sequence[int]],
                   rows: Sequence[list]) -> None:
        """Merge one worker's op rows (ParallelEngine collect).  Worker
        rows are keyed by representative pod label; SPMD clone folding
        means one row stands for every member of its replica group —
        expand so the merged trace shows all pods, matching serial."""
        out = self.worker_rows.setdefault(widx, [])
        expand = {int(labels[i]): [int(g) for g in members[i]]
                  for i in range(len(labels))}
        for r in rows:
            for g in expand.get(int(r[_R_POD]), [int(r[_R_POD])]):
                rr = list(r)
                rr[_R_POD] = g
                out.append(rr)

    # -- rendering ------------------------------------------------------
    @staticmethod
    def _us(tick: int) -> float:
        return tick / 1_000.0          # 1 tick = 1 ns; trace ts is in us

    def _emit_rows(self, events: List[dict], rows: List[list],
                   pid: int) -> None:
        pods = sorted({r[_R_POD] for r in rows})
        for g in pods:
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": 2 * g,
                           "args": {"name": f"pod{g}/compute"}})
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": 2 * g + 1,
                           "args": {"name": f"pod{g}/ici+dcn"}})
        for r in rows:
            tid = 2 * r[_R_POD] + (0 if r[_R_KIND] == "compute" else 1)
            events.append({
                "name": r[_R_NAME], "cat": r[_R_KIND], "ph": "X",
                "ts": self._us(r[_R_START]),
                "dur": max(self._us(r[_R_END]) - self._us(r[_R_START]), 0.0),
                "pid": pid, "tid": tid,
                "args": {"op": r[_R_IDX], "ready_tick": r[_R_READY],
                         "start_tick": r[_R_START], "end_tick": r[_R_END]},
            })
            if r[_R_DCN]:
                # rendezvous flow arrow: this pod's arrival -> transaction
                events.append({"ph": "s", "id": int(r[_R_IDX]),
                               "name": r[_R_NAME], "cat": "dcn",
                               "pid": pid, "tid": tid,
                               "ts": self._us(r[_R_READY])})
                events.append({"ph": "f", "bp": "e", "id": int(r[_R_IDX]),
                               "name": r[_R_NAME], "cat": "dcn",
                               "pid": PID_COORD, "tid": 0,
                               "ts": self._us(r[_R_START])})

    def _derived_dcn_tx(self) -> List[list]:
        """Serial runs have no coordinator: reconstruct one transaction
        per DCN op from its (identical-across-pods) start/dur rows."""
        seen: Dict[int, list] = {}
        for rows in [self.rows, *self.worker_rows.values()]:
            for r in rows:
                if r[_R_DCN] and r[_R_IDX] not in seen:
                    dur = r[_R_DUR]
                    if dur is None:
                        dur = r[_R_END] - r[_R_START]
                    seen[r[_R_IDX]] = [r[_R_IDX], r[_R_NAME], r[_R_START],
                                       int(dur), r[_R_END], []]
        return [seen[k] for k in sorted(seen)]

    def to_chrome(self) -> Dict[str, Any]:
        """Render everything recorded so far as a trace-event document
        (``{"traceEvents": [...]}``) loadable by ui.perfetto.dev."""
        events: List[dict] = []

        def pname(pid: int, name: str) -> None:
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "args": {"name": name}})

        if self.rows:
            pname(PID_ENGINE, "engine")
            self._emit_rows(events, self.rows, PID_ENGINE)
        for widx in sorted(self.worker_rows):
            pid = PID_WORKER0 + widx
            pods = sorted({r[_R_POD] for r in self.worker_rows[widx]})
            pname(pid, f"worker{widx} (pods {pods[0]}..{pods[-1]})"
                  if pods else f"worker{widx}")
            self._emit_rows(events, self.worker_rows[widx], pid)

        pname(PID_COORD, "coordinator (dcn + quantum)")
        events.append({"ph": "M", "name": "thread_name", "pid": PID_COORD,
                       "tid": 0, "args": {"name": "dcn transactions"}})
        events.append({"ph": "M", "name": "thread_name", "pid": PID_COORD,
                       "tid": 1, "args": {"name": "quantum barriers"}})
        tx = self.dcn_tx if self.dcn_tx else self._derived_dcn_tx()
        for idx, name, start, dur, deliver, arrivals in tx:
            events.append({
                "name": name, "cat": "dcn", "ph": "X",
                "ts": self._us(start), "dur": max(self._us(dur), 0.0),
                "pid": PID_COORD, "tid": 0,
                "args": {"op": idx, "deliver_tick": deliver,
                         "arrivals": [list(a) for a in arrivals]},
            })
        for t in self.barriers:
            events.append({"name": "quantum barrier", "cat": "quantum",
                           "ph": "i", "s": "p", "pid": PID_COORD, "tid": 1,
                           "ts": self._us(t)})

        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "format": "repro.sim trace-event export",
                "ticks_per_second": TICKS_PER_S,
                "workers": sorted(self.worker_rows),
            },
        }

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, default=str)
            f.write("\n")
        return path


def validate_trace_events(doc: Dict[str, Any]) -> List[str]:
    """Schema check for an exported trace (ci.sh trace tier): returns a
    list of problems, empty when the document is valid trace-event
    JSON.  Checks the envelope, per-event required keys by phase, and
    that every event's pid/tid/ts are numeric."""
    problems: List[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    need = {"X": ("name", "ts", "dur", "pid", "tid"),
            "i": ("name", "ts", "pid", "tid"),
            "s": ("id", "ts", "pid", "tid"),
            "f": ("id", "ts", "pid", "tid"),
            "M": ("name", "pid")}
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict) or "ph" not in ev:
            problems.append(f"event {i}: not an object with ph")
            continue
        ph = ev["ph"]
        if ph not in need:
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        for k in need[ph]:
            if k not in ev:
                problems.append(f"event {i} (ph={ph}): missing {k!r}")
        for k in ("ts", "dur", "pid", "tid"):
            if k in ev and not isinstance(ev[k], (int, float)):
                problems.append(f"event {i}: {k} not numeric")
    return problems
