"""repro.sim — the gem5-stdlib-style simulation front-end.

One import gives the full workflow the gem5 paper's pillars describe
(§1.3 checkpoint/restore, fast-forward, sampled detail; PAPERS.md
"Toward Reproducible and Standardized Computer Architecture
Simulation"):

* :class:`Simulator` + :class:`ExitEvent` — instantiate/run/exit loop.
* :mod:`repro.sim.boards` — prebuilt machines (``v5e_pod()``, ...).
* :mod:`repro.sim.serialize` — drain-then-serialize checkpoints.
* :mod:`repro.sim.sampling` — SimPoint/SMARTS sampled simulation.
* :mod:`repro.sim.instrument` — m5out-style output dirs, gem5-format
  stats dumps, Perfetto trace export, host telemetry (with the debug
  flag/DPRINTF layer in :mod:`repro.core.trace`).
"""

from repro.core.trace import (disable as disable_debug_flags,
                              enable as enable_debug_flags,
                              flag_context, flags as debug_flags)
from repro.sim.boards import (BOARDS, Board, get_board, v5e_degraded,
                              v5e_fleet, v5e_fleet_big, v5e_multipod,
                              v5e_pod, v5e_serving, v5e_straggler,
                              v5e_unreliable)
from repro.sim.ckptlib import (CheckpointLibrary, RegionTime,
                               board_digest, reconstruct, restore_fanout,
                               take_region_checkpoints, trace_digest)
from repro.sim.fingerprint import (FEATURE_NAMES, Fingerprint,
                                   bursty_trace, chain_steps,
                                   cluster_fingerprint, fingerprint_trace,
                                   record_op_stream, simpoint_plan)
from repro.sim.fleet import (FleetRequest, FleetSim, diurnal_requests,
                             flash_crowd_requests)
from repro.sim.instrument import (OutDir, TraceEventRecorder,
                                  format_host_banner, host_record,
                                  render_stats_txt, validate_trace_events)
from repro.sim.parallel import (ParallelEngine, merge_stat_trees,
                                parallel_supported, run_parallel)
from repro.sim.sampling import (SampledResult, SampledSimulation,
                                SamplePlan, SimPointPlan,
                                atomic_step_time_s, sampled_run)
from repro.sim.serialize import (CHECKPOINT_VERSION, WORKLOAD_KEY,
                                 WORKLOAD_KIND_KEY, CheckpointError,
                                 checkpoint_executor, load_checkpoint,
                                 machine_from_dict, restore_executor,
                                 save_checkpoint)
from repro.sim.simulator import (ExitEvent, ExitEventType, Simulator,
                                 SteadyStateWorkload, repeat_trace)
from repro.sim.workloads import (DynamicWorkload, ServeRequest, ServeSim,
                                 ServingCost, TrainSim, TrainStepCost,
                                 poisson_requests, trace_requests,
                                 uniform_requests)

__all__ = [
    "Board", "BOARDS", "get_board", "v5e_pod", "v5e_multipod",
    "v5e_straggler", "v5e_degraded", "v5e_serving", "v5e_fleet",
    "v5e_fleet_big", "v5e_unreliable",
    "Simulator", "ExitEvent", "ExitEventType", "SteadyStateWorkload",
    "repeat_trace",
    "DynamicWorkload", "ServeSim", "ServeRequest", "ServingCost",
    "TrainSim", "TrainStepCost",
    "poisson_requests", "trace_requests", "uniform_requests",
    "FleetSim", "FleetRequest", "diurnal_requests",
    "flash_crowd_requests",
    "SamplePlan", "SimPointPlan", "SampledResult", "SampledSimulation",
    "sampled_run", "atomic_step_time_s",
    "FEATURE_NAMES", "Fingerprint", "fingerprint_trace",
    "cluster_fingerprint", "simpoint_plan", "record_op_stream",
    "chain_steps", "bursty_trace",
    "CheckpointLibrary", "RegionTime", "board_digest", "trace_digest",
    "take_region_checkpoints", "restore_fanout", "reconstruct",
    "CHECKPOINT_VERSION", "WORKLOAD_KEY", "WORKLOAD_KIND_KEY",
    "CheckpointError",
    "checkpoint_executor", "save_checkpoint", "load_checkpoint",
    "restore_executor", "machine_from_dict",
    "ParallelEngine", "run_parallel", "parallel_supported",
    "merge_stat_trees",
    "OutDir", "TraceEventRecorder", "render_stats_txt", "host_record",
    "format_host_banner", "validate_trace_events",
    "enable_debug_flags", "disable_debug_flags", "debug_flags",
    "flag_context",
]
