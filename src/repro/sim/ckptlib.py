"""Versioned checkpoint library for SimPoint regions (gem5 §2.7).

gem5's sampled workflow is checkpoint-*centric*: SimPoint picks the
representative regions once, one checkpoint is taken per region in a
single cheap pass, and every detailed experiment thereafter restores
those checkpoints — onto whatever CPU/cache configuration is under
study.  "Toward Reproducible and Standardized Computer Architecture
Simulation with gem5" (PAPERS.md) adds the reproducibility requirement:
the checkpoint artifacts must be versioned and indexed (what board,
what trace, what tick, what weight) or results built on them are not
portable.

:class:`CheckpointLibrary` is that artifact: a directory of
``repro.sim.checkpoint`` JSON files plus one ``index.json``::

    {
      "format": "repro.sim.ckptlib", "version": 1,
      "board": "<board name>",
      "board_digest": "<sha1 of the serialized machine>",
      "trace_digest": "<sha1 of the trace JSON>",
      "timing": "<capture fidelity>",
      "window": <steps per window>, "num_steps": <total steps>,
      "step_ops": <ops per step>,
      "entries": [
        {"id": "region-0007", "file": "region-0007.ckpt.json",
         "window": 7, "step": 14, "steps": 2, "tick": 123456789,
         "weight": 0.22},
        ...
      ]
    }

* :func:`take_region_checkpoints` — ONE atomic fast-forward pass over
  the chained trace, drain + checkpoint at each representative window
  boundary (gem5: one functional pass, N checkpoints).
* :func:`restore_fanout` — restore every region in parallel worker
  processes (the parallel-engine spawn conventions: plain-data init
  payloads, module-level entry point, ``default_mp_context()``), each
  timing only its window at detailed fidelity — optionally onto a
  **re-parameterized board** or a **different timing model** than the
  capture pass: the checkpoint-once / sweep-everything DSE move.
* :func:`reconstruct` — the SimPoint weighted total from the fanout's
  per-region step times.

Digests mismatching at restore raise loudly (a checkpoint restored
onto a silently different board is the least debuggable failure mode a
sampled methodology has); re-parameterization is explicit via
``board=``.
"""

from __future__ import annotations

import hashlib
import json
import multiprocessing as mp
import os
import traceback
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.desim.parallel import default_mp_context
from repro.core.desim.simnodes import TICKS_PER_S
from repro.core.desim.trace import HloTrace
from repro.sim import serialize as ser
from repro.sim.boards import Board

__all__ = [
    "INDEX_FORMAT", "INDEX_VERSION", "CheckpointLibrary", "RegionTime",
    "board_digest", "trace_digest", "take_region_checkpoints",
    "restore_fanout", "reconstruct",
]

INDEX_FORMAT = "repro.sim.ckptlib"
INDEX_VERSION = 1
INDEX_NAME = "index.json"


def board_digest(board: Board) -> str:
    """sha1 of the board's serialized machine (config.ini identity)."""
    board.instantiate()
    blob = json.dumps(board.machine.serialize(), sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()


def trace_digest(trace: HloTrace) -> str:
    """sha1 of the trace JSON (dataclass field order is fixed, so the
    digest is stable across interpreters)."""
    return hashlib.sha1(trace.to_json().encode()).hexdigest()


class CheckpointLibrary:
    """A directory of versioned region checkpoints + ``index.json``."""

    def __init__(self, root: str):
        self.root = root
        self.meta: Dict[str, Any] = {}
        self.entries: List[Dict[str, Any]] = []
        path = os.path.join(root, INDEX_NAME)
        if os.path.exists(path):
            with open(path) as f:
                doc = json.load(f)
            if doc.get("format") != INDEX_FORMAT:
                raise ser.CheckpointError(
                    f"not a {INDEX_FORMAT} index "
                    f"(format={doc.get('format')!r})")
            if doc.get("version") != INDEX_VERSION:
                raise ser.CheckpointError(
                    f"index version {doc.get('version')!r} != "
                    f"{INDEX_VERSION} (no migration registered)")
            self.entries = list(doc.get("entries", []))
            self.meta = {k: v for k, v in doc.items()
                         if k not in ("format", "version", "entries")}

    # -- write ---------------------------------------------------------
    def add(self, ckpt: Dict[str, Any], entry: Dict[str, Any]) -> Dict:
        """Save one checkpoint file and register its index entry
        (``entry`` needs at least ``id``; ``file``/``tick`` are
        filled in)."""
        eid = entry["id"]
        fname = entry.setdefault("file", f"{eid}.ckpt.json")
        ser.save_checkpoint(ckpt, os.path.join(self.root, fname))
        entry.setdefault("tick", int(ckpt["state"]["tick"]))
        self.entries = [e for e in self.entries if e["id"] != eid]
        self.entries.append(entry)
        return entry

    def save_index(self) -> str:
        os.makedirs(self.root, exist_ok=True)
        path = os.path.join(self.root, INDEX_NAME)
        doc = {"format": INDEX_FORMAT, "version": INDEX_VERSION,
               **self.meta, "entries": self.entries}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
        os.replace(tmp, path)
        return path

    # -- read ----------------------------------------------------------
    def get(self, eid: str) -> Dict[str, Any]:
        for e in self.entries:
            if e["id"] == eid:
                return e
        raise KeyError(f"no checkpoint {eid!r} in {self.root} "
                       f"(have {[e['id'] for e in self.entries]})")

    def load(self, eid: str) -> Dict[str, Any]:
        """The full checkpoint document of one entry."""
        return ser.load_checkpoint(
            os.path.join(self.root, self.get(eid)["file"]))

    def check_board(self, board: Board) -> None:
        """Refuse a silent board mismatch (re-parameterization must be
        an explicit ``board=`` at restore time, not an accident)."""
        want = self.meta.get("board_digest")
        if want and board_digest(board) != want:
            raise ser.CheckpointError(
                f"board digest mismatch: library {self.root} was "
                f"captured on {self.meta.get('board')!r} "
                f"({want[:12]}…); pass this board explicitly via "
                "restore_fanout(..., board=) to re-parameterize")


# ---------------------------------------------------------------------------
# capture: one atomic pass, N region checkpoints
# ---------------------------------------------------------------------------

def take_region_checkpoints(board: Board, trace: HloTrace, plan,
                            root: str, timing: str = "atomic",
                            name: Optional[str] = None
                            ) -> CheckpointLibrary:
    """Capture one checkpoint per representative window of ``plan`` (a
    :class:`~repro.sim.sampling.SimPointPlan`) in a single ``timing``-
    fidelity fast-forward pass over the chained ``trace``.

    At each window boundary the run is drained and serialized (the ops
    already in flight — the boundary step's compute, whose cost is
    model-identical — complete into the checkpoint), then the pass
    resumes from the in-memory state.  Region step times measured after
    restore are therefore computed from per-op end ticks, not wall
    spans (see :func:`restore_fanout`).
    """
    board = board.instantiate()
    num_steps = int(trace.meta.get("steps", 0))
    if num_steps < 1:
        raise ValueError("trace must be chained with meta['steps'] "
                         "(repeat_trace / chain_steps)")
    n_ops = len(trace.ops) // num_steps
    lib = CheckpointLibrary(root)
    lib.meta = {
        "board": name or board.name,
        "board_digest": board_digest(board),
        "trace_digest": trace_digest(trace),
        "timing": timing,
        "window": plan.window,
        "num_steps": num_steps,
        "step_ops": n_ops,
    }

    progress = {"ops": 0}

    def hook(op, idx, start, end):
        progress["ops"] += 1

    ex = board.executor(record_stats=True, timing=timing)
    ex.op_hook = hook
    ex.begin(trace)
    for widx, weight in zip(plan.representatives, plan.weights):
        lo_step = widx * plan.window
        steps = min(plan.window, num_steps - lo_step)
        target = lo_step * n_ops
        ex.advance(stop_check=lambda: progress["ops"] >= target)
        ex.drain()
        ckpt = ser.checkpoint_executor(ex)
        lib.add(ckpt, {
            "id": f"region-{widx:04d}",
            "window": widx,
            "step": lo_step,
            "steps": steps,
            "weight": weight,
        })
        # a drained executor cannot resume in place — rebuild from the
        # snapshot we just took and continue the pass
        fresh = board.executor(record_stats=True, timing=timing,
                               straggler_slowdowns=list(ex.slow))
        ex = fresh.restore(trace, ckpt["state"])
        ex.op_hook = hook
    lib.save_index()
    return lib


# ---------------------------------------------------------------------------
# restore: parallel fanout
# ---------------------------------------------------------------------------

@dataclass
class RegionTime:
    """One region's detailed measurement out of the fanout."""

    id: str
    window: int
    steps: int
    weight: float
    step_s: float        # measured per-step time of the region
    start_tick: int      # max op-end tick before the window (t0)
    end_tick: int        # max op-end tick inside the window  (t1)


def _measure_region(ckpt: Dict[str, Any], entry: Dict[str, Any],
                    step_ops: int, machine_dict: Optional[Dict],
                    timing: Optional[str]) -> RegionTime:
    """Restore one region checkpoint and run ONLY its window.

    Step time comes from per-op end ticks — ``t0`` = latest end among
    ops before the window (from the checkpoint), ``t1`` = latest end
    among the window's ops — so the boundary compute op that drained
    into the checkpoint is charged to the window it belongs to (its
    cost is identical under either timing model).
    """
    machine = (ser.machine_from_dict(machine_dict)
               if machine_dict is not None else None)
    ex = ser.restore_executor(ckpt, machine=machine, timing=timing,
                              record_stats=False)
    lo = entry["step"] * step_ops
    hi = (entry["step"] + entry["steps"]) * step_ops

    def window_done() -> bool:
        ends = ex._op_end[0]
        return all(ends[i] >= 0 for i in range(lo, hi))

    ex.advance(stop_check=window_done)
    if not window_done():
        raise RuntimeError(
            f"{entry['id']}: window ops [{lo}, {hi}) did not complete "
            "(truncated trace or corrupt checkpoint?)")
    pods = range(ex.machine.num_pods)
    t0 = max((ex._op_end[p][i] for p in pods for i in range(lo)
              if ex._op_end[p][i] >= 0), default=0)
    t1 = max(ex._op_end[p][i] for p in pods for i in range(lo, hi))
    return RegionTime(
        id=entry["id"], window=int(entry["window"]),
        steps=int(entry["steps"]), weight=float(entry["weight"]),
        step_s=(t1 - t0) / TICKS_PER_S / max(int(entry["steps"]), 1),
        start_tick=int(t0), end_tick=int(t1))


def _fanout_worker(conn) -> None:
    """Worker entry point (module-level: spawn-safe, like the parallel
    engine's ``_worker_main``; init payloads are plain data)."""
    try:
        init = conn.recv()
        out = []
        for eid in init["ids"]:
            lib = CheckpointLibrary(init["root"])
            rt = _measure_region(lib.load(eid), lib.get(eid),
                                 init["step_ops"], init["machine"],
                                 init["timing"])
            out.append(rt.__dict__)
        conn.send({"regions": out})
    except BaseException:
        try:
            conn.send({"error": traceback.format_exc()})
        except Exception:
            pass
    finally:
        try:
            conn.close()
        except Exception:
            pass


def restore_fanout(lib: CheckpointLibrary, *,
                   board: Optional[Board] = None,
                   timing: Optional[str] = "detailed",
                   workers: int = 1,
                   mp_context: Optional[str] = None
                   ) -> List[RegionTime]:
    """Restore every region of the library and time its window —
    in parallel across ``workers`` processes (regions are independent,
    so this is embarrassingly parallel, unlike the quantum-synced
    ParallelEngine).

    ``timing``: fidelity to re-time the windows under (default
    detailed — the SimPoint measurement pass; ``None`` keeps each
    checkpoint's own model).  ``board``: restore onto a
    re-parameterized board instead of the captured machine (pod count
    must match — the gem5 checkpoint-once/sweep-everything move).
    Returns :class:`RegionTime` rows sorted by window index.
    """
    workers = ser.validate_workers(workers)
    entries = sorted(lib.entries, key=lambda e: int(e["window"]))
    if not entries:
        return []
    machine_dict = None
    if board is not None:
        board.instantiate()
        machine_dict = board.machine.serialize()
    step_ops = int(lib.meta["step_ops"])

    if workers <= 1 or len(entries) == 1:
        return [_measure_region(lib.load(e["id"]), e, step_ops,
                                machine_dict, timing)
                for e in entries]

    ctx = mp.get_context(mp_context or default_mp_context())
    shards: List[List[str]] = [[] for _ in range(min(workers,
                                                    len(entries)))]
    for i, e in enumerate(entries):
        shards[i % len(shards)].append(e["id"])
    conns, procs = [], []
    for ids in shards:
        parent, child = ctx.Pipe()
        proc = ctx.Process(target=_fanout_worker, args=(child,),
                           daemon=True)
        proc.start()
        child.close()
        parent.send({"root": lib.root, "ids": ids,
                     "step_ops": step_ops, "machine": machine_dict,
                     "timing": timing})
        conns.append(parent)
        procs.append(proc)
    rows: List[RegionTime] = []
    errors: List[str] = []
    for parent in conns:
        try:
            reply = parent.recv()
        except EOFError:
            errors.append("fanout worker died without a reply")
            continue
        if "error" in reply:
            errors.append(reply["error"])
        else:
            rows.extend(RegionTime(**r) for r in reply["regions"])
        parent.close()
    for proc in procs:
        proc.join()
    if errors:
        raise RuntimeError("restore_fanout worker failed:\n"
                           + "\n".join(errors))
    return sorted(rows, key=lambda r: r.window)


def reconstruct(regions: Sequence[RegionTime],
                num_steps: Optional[int] = None,
                lib: Optional[CheckpointLibrary] = None) -> float:
    """SimPoint weighted total: ``num_steps * Σ w_i * step_time_i``."""
    if num_steps is None:
        if lib is None:
            raise ValueError("pass num_steps or the library")
        num_steps = int(lib.meta["num_steps"])
    return num_steps * sum(r.weight * r.step_s for r in regions)
