"""FleetSim: autoscaled datacenter-scale serving on the event engine.

The layer above :class:`repro.sim.workloads.ServeSim` the ROADMAP's
"millions of users" story needs: a *fleet* of continuous-batching
replicas (one per pod of a ``v5e_fleet`` board) behind a request
router and an autoscaler, both driven by the pure
:class:`repro.serve.fleet_policy.FleetPolicy` — the *identical* policy
object the real :class:`repro.serve.fleet.FleetController` wraps, so
DES and real-controller decision logs match exactly (test-enforced,
tests/test_fleet_sim.py).

The model, in one paragraph: seeded traffic (diurnal curves, flash
crowds, heavy-tailed lognormal prompt/decode lengths, multi-tenant
priority classes) arrives as tick-stamped :class:`FleetRequest`s; the
policy routes each to a replica (round-robin / least-loaded /
power-of-two-choices / prefix-cache-affinity), where it runs through
the same ``SlotScheduler`` continuous-batching loop and
:class:`~repro.sim.workloads.ServingCost` roofline ops as ServeSim; at
every control boundary the policy compares load and SLO pressure
against its watermarks and scales the fleet — a scaled-up replica
spends ``cold_start_ticks`` *warming* (it queues work but does not
execute: the cold start is a first-class simulated cost that shows up
in TTFT), and only idle replicas are retired, so no drain protocol
exists.  Scale actions surface as ``SCALE_UP`` / ``SCALE_DOWN`` exit
events from ``Simulator.run()``.

Liveness: ``next_event_tick`` is the earlier of the next arrival and
``policy.next_wake()`` (the next control boundary or warming-replica
promotion), so the co-simulation always has a wake point while
requests remain; every queued request is eventually served because
routing only targets live/warming replicas and every promotion gets a
wake at its exact ready tick.

The run records a ``feed`` — the ordered, tick-stamped policy event
stream (routes, finishes with SLO verdicts, observation ticks).
Replaying it through a fresh ``FleetController`` (its ``replay``) is
the decision-log identity test: the controller re-makes every routing
and scaling decision from events alone and must match bit-for-bit.

Like ServeSim, only per-pod compute ops are injected, so FleetSim is
tick-exact under ``timing="atomic"`` — the fleet sweeps
(``benchmarks/fleet_sweep.py``) default to atomic with a detailed
spot-check.  ``span_s`` in ``summary()`` is measured from the first
submitted request to the last finish (not from tick 0), and empty
percentile sketches report NaN, never a fake 0.0.
"""

from __future__ import annotations

import hashlib
import heapq
import json
import math
import random
from collections import deque
from dataclasses import dataclass
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple)

from repro.core.desim.simnodes import TICKS_PER_S, to_ticks
from repro.core.desim.trace import TraceOp
from repro.core.simobject import Param, SimObject
from repro.serve.fleet_policy import LIVE, FleetPolicy
from repro.serve.policy import SlotScheduler
from repro.sim.workloads import DynamicWorkload, ServingCost


# ---------------------------------------------------------------------------
# requests and traffic models
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FleetRequest:
    """One fleet request.  ``rid`` equals its index in the request
    list; ``tenant`` picks the priority class and SLO multiplier;
    ``prefix_group`` (>= 0) marks requests sharing a cacheable prompt
    prefix (what the affinity router keys on)."""

    rid: int
    prompt_len: int
    decode_len: int
    arrival_tick: int = 0
    tenant: str = "interactive"
    prefix_group: int = -1


def _lognormal(rng: random.Random, median: float, sigma: float,
               lo: int, hi: int) -> int:
    """Heavy-tailed length draw: lognormal with the given median,
    clamped to ``[lo, hi]`` (production length distributions are
    famously lognormal-ish with a hard context cap)."""
    return max(lo, min(hi, int(rng.lognormvariate(math.log(median), sigma))))


def _pick_tenant(rng: random.Random,
                 tenants: Sequence[Tuple[str, float]]) -> str:
    u = rng.random() * sum(w for _, w in tenants)
    for name, w in tenants:
        u -= w
        if u <= 0:
            return name
    return tenants[-1][0]


def _thinned_requests(num_requests: int, *, seed: int, peak_rps: float,
                      rate_fn: Callable[[float], float],
                      prompt_lognorm: Tuple[float, float, int, int],
                      decode_lognorm: Tuple[float, float, int, int],
                      tenants: Sequence[Tuple[str, float]],
                      prefix_groups: int) -> List[FleetRequest]:
    """Non-homogeneous Poisson arrivals by thinning a homogeneous
    ``peak_rps`` process (Lewis–Shedler): exact, and fully determined
    by ``seed``."""
    if peak_rps <= 0:
        raise ValueError("peak rate must be positive")
    rng = random.Random(seed)
    out: List[FleetRequest] = []
    t = 0.0
    while len(out) < num_requests:
        t += rng.expovariate(peak_rps)
        accept = rng.random() < rate_fn(t) / peak_rps
        # draw attributes unconditionally so the stream at one seed is
        # a prefix-stable function of the arrival index
        p = _lognormal(rng, *prompt_lognorm)
        d = _lognormal(rng, *decode_lognorm)
        tenant = _pick_tenant(rng, tenants)
        group = rng.randrange(prefix_groups) if prefix_groups > 0 else -1
        if accept:
            out.append(FleetRequest(
                rid=len(out), prompt_len=p, decode_len=d,
                arrival_tick=to_ticks(t), tenant=tenant,
                prefix_group=group))
    return out


DEFAULT_TENANTS: Tuple[Tuple[str, float], ...] = (("interactive", 0.8),
                                                  ("batch", 0.2))
DEFAULT_PROMPT = (128.0, 1.0, 8, 768)     # (median, sigma, lo, hi)
DEFAULT_DECODE = (32.0, 0.8, 4, 192)


def diurnal_requests(num_requests: int, *, seed: int, base_rps: float,
                     peak_rps: float, period_s: float,
                     prompt_lognorm: Tuple[float, float, int, int]
                     = DEFAULT_PROMPT,
                     decode_lognorm: Tuple[float, float, int, int]
                     = DEFAULT_DECODE,
                     tenants: Sequence[Tuple[str, float]] = DEFAULT_TENANTS,
                     prefix_groups: int = 0) -> List[FleetRequest]:
    """A diurnal rate curve: sinusoid from ``base_rps`` (trough, at
    t=0) to ``peak_rps`` over ``period_s``-second days."""
    if peak_rps < base_rps:
        raise ValueError("peak_rps must be >= base_rps")

    def rate(t: float) -> float:
        phase = 0.5 * (1.0 - math.cos(2.0 * math.pi * t / period_s))
        return base_rps + (peak_rps - base_rps) * phase

    return _thinned_requests(num_requests, seed=seed, peak_rps=peak_rps,
                             rate_fn=rate, prompt_lognorm=prompt_lognorm,
                             decode_lognorm=decode_lognorm,
                             tenants=tenants, prefix_groups=prefix_groups)


def flash_crowd_requests(num_requests: int, *, seed: int, base_rps: float,
                         crowd_rps: float, crowd_start_s: float,
                         crowd_len_s: float,
                         prompt_lognorm: Tuple[float, float, int, int]
                         = DEFAULT_PROMPT,
                         decode_lognorm: Tuple[float, float, int, int]
                         = DEFAULT_DECODE,
                         tenants: Sequence[Tuple[str, float]]
                         = DEFAULT_TENANTS,
                         prefix_groups: int = 0) -> List[FleetRequest]:
    """A flash crowd: steady ``base_rps`` with a burst to ``crowd_rps``
    during ``[crowd_start_s, crowd_start_s + crowd_len_s)``."""
    if crowd_rps < base_rps:
        raise ValueError("crowd_rps must be >= base_rps")

    def rate(t: float) -> float:
        in_crowd = crowd_start_s <= t < crowd_start_s + crowd_len_s
        return crowd_rps if in_crowd else base_rps

    return _thinned_requests(num_requests, seed=seed, peak_rps=crowd_rps,
                             rate_fn=rate, prompt_lognorm=prompt_lognorm,
                             decode_lognorm=decode_lognorm,
                             tenants=tenants, prefix_groups=prefix_groups)


# ---------------------------------------------------------------------------
# FleetSim
# ---------------------------------------------------------------------------

class _FleetReplica:
    """One replica (one pod): scheduler + in-flight tracking, exactly
    ServeSim's per-pod shape.  Whether the replica may *execute* is the
    policy's call (``state == live``), not stored here."""

    def __init__(self, pod: int, sched: SlotScheduler):
        self.pod = pod
        self.sched = sched
        self.busy = False


def _nan_if_empty(stat, value: float) -> float:
    return value if stat.count else float("nan")


class FleetSim(SimObject, DynamicWorkload):
    """Autoscaled fleet serving as a :class:`DynamicWorkload`.

    Replica ``r`` runs on pod ``r`` of the bound machine (a
    ``v5e_fleet`` board sized to ``policy.max_replicas``); decode batch
    size per replica is ``policy.slots_per_replica``.  See the module
    docstring for the model and ``docs/serving.md`` for the exactness
    bar.
    """

    seq_capacity = Param(int, 2048, "KV capacity (tokens) per slot",
                         check=lambda v: v >= 2)
    slo_ttft_s = Param(float, 0.0, "TTFT SLO in seconds (0 = none)")
    slo_latency_s = Param(float, 0.0, "request-latency SLO (0 = none)")
    exit_on_slo = Param(bool, False,
                        "surface each SLO violation as an exit event")
    exit_on_scale = Param(bool, True,
                          "surface autoscaler actions as exit events")

    def __init__(self, name: str = "fleet", *, cost: ServingCost,
                 requests: List[FleetRequest], policy: FleetPolicy,
                 tenant_slo: Optional[Dict[str, float]] = None,
                 tenant_priority: Sequence[str] = ("interactive", "batch"),
                 **params):
        super().__init__(name, **params)
        if not requests:
            raise ValueError("FleetSim needs at least one request")
        for i, r in enumerate(requests):
            if r.rid != i:
                raise ValueError(f"request {i} has rid {r.rid}; rids must "
                                 "equal list indices")
            if r.prompt_len >= self.seq_capacity:
                raise ValueError(
                    f"request {i}: prompt_len {r.prompt_len} does not fit "
                    f"seq_capacity {self.seq_capacity}")
            if r.decode_len < 1 or r.prompt_len < 1:
                raise ValueError(
                    f"request {i}: prompt_len/decode_len must be >= 1")
        self.cost = cost
        self.policy = policy
        self._requests = list(requests)
        self._tenant_slo = dict(tenant_slo or {})
        self._rank = {t: i for i, t in enumerate(tenant_priority)}
        self._ex = None
        self._reps: Optional[List[_FleetReplica]] = None
        self._heap: List[Tuple[int, int, int]] = []  # (tick, rank, rid)
        self._done_count = 0
        self._started = False
        self._pcursor = 0          # policy decisions already drained
        self._peak_serving = 0
        self.feed: List[List[Any]] = []   # the replayable event stream
        self.pending_exits: Deque[Dict[str, Any]] = deque()
        self._rt: Dict[int, Dict[str, Any]] = {}
        s = self.stats
        self.s_admitted = s.scalar("admitted", "requests admitted to slots")
        self.s_requests = s.scalar("requests_done", "requests completed")
        self.s_tokens = s.scalar("tokens_out", "decode tokens generated")
        self.s_decode_steps = s.scalar("decode_steps", "batched decode steps")
        self.s_prefills = s.scalar("prefills", "prefill ops run")
        self.s_slo_viol = s.scalar("slo_violations", "requests over SLO")
        self.s_scale_ups = s.scalar("scale_ups", "replicas scaled up")
        self.s_scale_downs = s.scalar("scale_downs", "replicas scaled down")
        self.p_ttft = s.percentiles("ttft", "time to first token", "s")
        self.p_tpot = s.percentiles("tpot", "time per output token", "s")
        self.p_latency = s.percentiles("latency", "request latency", "s")
        self.p_queue_wait = s.percentiles("queue_wait",
                                          "arrival-to-admission wait", "s")
        self.d_batch = s.distribution("decode_batch",
                                      "active slots per decode step")
        self.p_ttft_tenant = {
            t: s.percentiles(f"ttft_{t}", f"TTFT of tenant {t}", "s")
            for t in sorted({r.tenant for r in requests})}

    # -- DynamicWorkload: lifecycle --------------------------------------
    def bind(self, executor) -> None:
        self._ex = executor
        executor.injection_hook = self._on_op_done
        if self._reps is None:
            pods = executor.machine.num_pods
            if pods < self.policy.max_replicas:
                raise ValueError(
                    f"policy allows up to {self.policy.max_replicas} "
                    f"replicas but the machine has {pods} pods — use a "
                    "v5e_fleet board sized to max_replicas")
            self._reps = [
                _FleetReplica(p, SlotScheduler(
                    self.policy.slots_per_replica, self.seq_capacity))
                for p in range(self.policy.max_replicas)]

    def start(self) -> None:
        if self._started:
            return
        self._started = True
        self.policy.start()
        self._drain_policy()
        # same-tick arrivals are routed in priority order (then rid) —
        # the multi-tenant classes' only scheduling privilege
        self._heap = [(r.arrival_tick,
                       self._rank.get(r.tenant, len(self._rank)), r.rid)
                      for r in self._requests]
        heapq.heapify(self._heap)

    def next_event_tick(self) -> Optional[int]:
        arrival = self._heap[0][0] if self._heap else None
        wake = self.policy.next_wake()
        return wake if arrival is None else min(arrival, wake)

    def poll(self, tick: int) -> None:
        t = int(tick)
        self.feed.append(["tick", t])
        self.policy.observe(t)
        self._drain_policy()
        self._catch_up(t)
        self._reconcile(t)

    def done(self) -> bool:
        return self._done_count == len(self._requests)

    # -- the fleet engine -------------------------------------------------
    def _catch_up(self, t: int) -> None:
        """Route + submit every arrival with tick <= ``t`` in
        (tick, priority, rid) order, then wake idle live replicas that
        received work, at the exact arrival tick (ServeSim's contract,
        with the policy replacing rid-round-robin dispatch)."""
        while self._heap and self._heap[0][0] <= t:
            tick = self._heap[0][0]
            touched: List[_FleetReplica] = []
            while self._heap and self._heap[0][0] == tick:
                _, _, rid = heapq.heappop(self._heap)
                req = self._requests[rid]
                self.feed.append(["route", tick, rid])
                ridx = self.policy.route(tick, rid, tenant=req.tenant,
                                         prefix=req.prefix_group)
                self._drain_policy()
                rep = self._reps[ridx]
                rep.sched.submit(rid, req.prompt_len, req.decode_len)
                self._rt[rid] = {"submit": tick, "first": -1, "finish": -1,
                                 "ok": True}
                if rep not in touched:
                    touched.append(rep)
            for rep in touched:
                if not rep.busy and self.policy.state(rep.pod) == LIVE:
                    self._iteration(rep, tick)

    def _reconcile(self, t: int) -> None:
        """Wake any idle live replica holding queued work — how a
        freshly-promoted (warming -> live) replica starts serving its
        cold-start queue at exactly its ready tick."""
        for rep in self._reps:
            if (not rep.busy and not rep.sched.idle()
                    and self.policy.state(rep.pod) == LIVE):
                self._iteration(rep, t)

    def _iteration(self, rep: _FleetReplica, now: int) -> None:
        sched = rep.sched
        prefill_deps = []
        for slot, rid in sched.fill():
            req = self._requests[rid]
            self.s_admitted.inc()
            self.s_prefills.inc()
            self.p_queue_wait.sample(
                (now - self._rt[rid]["submit"]) / TICKS_PER_S)
            fl, by = self.cost.prefill_cost(req.prompt_len)
            prefill_deps.append(self._ex.inject_op(
                TraceOp("compute", flops=fl, bytes=by,
                        name=f"fleet/p{rep.pod}/prefill/r{rid}"),
                ready=now, pod=rep.pod))
        active = sched.active_slots()
        if not active:
            rep.busy = False
            return
        ctx = sum(sched.context_len(s) for s in active)
        fl, by = self.cost.decode_cost(len(active), ctx)
        self.d_batch.sample(len(active))
        self._ex.inject_op(
            TraceOp("compute", flops=fl, bytes=by, deps=tuple(prefill_deps),
                    name=f"fleet/p{rep.pod}/decode/s{sched.steps}"),
            ready=now, pod=rep.pod)
        rep.busy = True

    def _on_op_done(self, op: TraceOp, idx: int, pod: int, start: int,
                    end: int) -> None:
        parts = (op.name or "").split("/")
        if len(parts) < 3 or parts[0] != "fleet":
            return
        rep = self._reps[pod]
        if parts[2] == "prefill":
            rid = int(parts[3][1:])
            rt = self._rt[rid]
            rt["first"] = end
            ttft = (end - rt["submit"]) / TICKS_PER_S
            self.p_ttft.sample(ttft)
            self.p_ttft_tenant[self._requests[rid].tenant].sample(ttft)
            return
        sched = rep.sched
        sched.note_step()
        self.s_decode_steps.inc()
        for slot in sched.active_slots():
            rid = sched.active[slot]
            self.s_tokens.inc()
            fin = sched.complete_token(slot)
            if fin is not None:
                self._finish_request(rid, end, rep)
        self._catch_up(end)
        if self.policy.state(rep.pod) == LIVE:
            self._iteration(rep, end)
        else:
            rep.busy = False     # retired while idle: stays parked
        self._reconcile(end)

    def _finish_request(self, rid: int, end: int,
                        rep: _FleetReplica) -> None:
        rt = self._rt[rid]
        rt["finish"] = end
        req = self._requests[rid]
        latency = (end - rt["submit"]) / TICKS_PER_S
        tokens = rep.sched.requests[rid].tokens_out
        ttft = (rt["first"] - rt["submit"]) / TICKS_PER_S
        tpot = ((end - rt["first"]) / TICKS_PER_S) / max(tokens - 1, 1)
        self.p_latency.sample(latency)
        self.p_tpot.sample(tpot)
        self.s_requests.inc()
        self._done_count += 1
        factor = self._tenant_slo.get(req.tenant, 1.0)
        violated = ((self.slo_ttft_s > 0
                     and ttft > self.slo_ttft_s * factor)
                    or (self.slo_latency_s > 0
                        and latency > self.slo_latency_s * factor))
        if violated:
            rt["ok"] = False
            self.s_slo_viol.inc()
            if self.exit_on_slo:
                self.pending_exits.append({
                    "tick": end, "cause": f"slo violation: request {rid}",
                    "payload": {"rid": rid, "tenant": req.tenant,
                                "ttft_s": ttft, "latency_s": latency}})
        self.feed.append(["finish", end, rid, rep.pod, int(not violated)])
        self.policy.finish(end, rid, ok=not violated)
        self._drain_policy()

    def _drain_policy(self) -> None:
        """Mirror fresh policy decisions into stats + exit events."""
        new = self.policy.decisions[self._pcursor:]
        self._pcursor = len(self.policy.decisions)
        if new:
            self._peak_serving = max(
                self._peak_serving, len(self.policy.serving_replicas()))
        for d in new:
            if d.kind == "scale_up":
                self.s_scale_ups.inc()
                if self.exit_on_scale:
                    self.pending_exits.append({
                        "tick": d.tick, "kind": "scale_up",
                        "cause": f"scale up: replica {d.replica} warming "
                                 f"({d.note})",
                        "payload": {"replica": d.replica, "note": d.note,
                                    "ready_tick": d.tick
                                    + self.policy.cold_start_ticks}})
            elif d.kind == "scale_down":
                self.s_scale_downs.inc()
                if self.exit_on_scale:
                    self.pending_exits.append({
                        "tick": d.tick, "kind": "scale_down",
                        "cause": f"scale down: replica {d.replica} retired "
                                 f"({d.note})",
                        "payload": {"replica": d.replica, "note": d.note}})

    # -- results -----------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        """Fleet-level result row.  ``span_s`` runs from the first
        *submitted* request to the last finish; percentile keys are NaN
        when no sample landed (a zero-finish run must not look
        perfect)."""
        finished = [rt for rt in self._rt.values() if rt["finish"] >= 0]
        if finished:
            first = min(rt["submit"] for rt in finished)
            span_s = (max(rt["finish"] for rt in finished)
                      - first) / TICKS_PER_S
        else:
            span_s = 0.0
        ok = sum(1 for rt in finished if rt["ok"])
        out = {
            "requests": float(len(finished)),
            "span_s": span_s,
            "throughput_rps": len(finished) / span_s if span_s else 0.0,
            "goodput_rps": ok / span_s if span_s else 0.0,
            "slo_violations": self.s_slo_viol.value(),
            "tokens_out": self.s_tokens.value(),
            "p50_ttft_s": _nan_if_empty(self.p_ttft,
                                        self.p_ttft.quantile(0.50)),
            "p99_ttft_s": _nan_if_empty(self.p_ttft,
                                        self.p_ttft.quantile(0.99)),
            "p50_latency_s": _nan_if_empty(self.p_latency,
                                           self.p_latency.quantile(0.50)),
            "p99_latency_s": _nan_if_empty(self.p_latency,
                                           self.p_latency.quantile(0.99)),
            "mean_tpot_s": _nan_if_empty(self.p_tpot, self.p_tpot.mean),
            "mean_batch": _nan_if_empty(self.d_batch, self.d_batch.mean),
            "scale_ups": self.s_scale_ups.value(),
            "scale_downs": self.s_scale_downs.value(),
            "replicas_peak": float(self._peak_serving),
            "replicas_final": float(len(self.policy.live_replicas())),
            "cold_start_s": self.policy.cold_start_ticks / TICKS_PER_S,
        }
        for tenant, p in self.p_ttft_tenant.items():
            out[f"p99_ttft_{tenant}_s"] = _nan_if_empty(
                p, p.quantile(0.99))
        return out

    def slo_ok_frac(self, after_s: float = 0.0) -> float:
        """Fraction of finished requests *submitted after* ``after_s``
        that met their SLO — the recovery metric (did the fleet return
        to compliance once the autoscaler reacted?).  NaN when nothing
        in the window finished."""
        after = to_ticks(after_s)
        rts = [rt for rt in self._rt.values()
               if rt["finish"] >= 0 and rt["submit"] >= after]
        if not rts:
            return float("nan")
        return sum(1 for rt in rts if rt["ok"]) / len(rts)

    # -- checkpointing -----------------------------------------------------
    def _requests_digest(self) -> str:
        rows = [[r.rid, r.prompt_len, r.decode_len, r.arrival_tick,
                 r.tenant, r.prefix_group] for r in self._requests]
        return hashlib.sha1(json.dumps(rows).encode()).hexdigest()[:16]

    def state_dict(self) -> Dict[str, Any]:
        return {
            "num_requests": len(self._requests),
            "requests_digest": self._requests_digest(),
            "started": self._started,
            "done_count": self._done_count,
            "pcursor": self._pcursor,
            "peak_serving": self._peak_serving,
            "heap": sorted([t, k, r] for t, k, r in self._heap),
            "runtime": {str(rid): dict(rt) for rid, rt in self._rt.items()},
            "reps": [{"pod": rep.pod, "busy": rep.busy,
                      "sched": rep.sched.state_dict()}
                     for rep in (self._reps or [])],
            "policy": self.policy.state_dict(),
            "feed": [list(row) for row in self.feed],
            "pending_exits": [dict(e) for e in self.pending_exits],
            "stats": self.stats.state_dict(),
        }

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        mine = self._requests_digest()
        if int(d["num_requests"]) != len(self._requests) \
                or d.get("requests_digest", mine) != mine:
            raise ValueError(
                "checkpoint was taken under a different request stream "
                f"({d['num_requests']} requests, digest "
                f"{d.get('requests_digest')}) than this FleetSim's "
                f"({len(self._requests)}, digest {mine}) — rebuild with "
                "the same seed/params")
        if self._reps is None:
            raise RuntimeError("bind() the FleetSim before loading state")
        # validate the policy configuration first: its mismatch message
        # names the offending knob, which a per-replica scheduler shape
        # error downstream would obscure
        self.policy.load_state_dict(d["policy"])
        self._started = bool(d["started"])
        self._done_count = int(d["done_count"])
        self._pcursor = int(d["pcursor"])
        self._peak_serving = int(d["peak_serving"])
        self._heap = [(int(t), int(k), int(r)) for t, k, r in d["heap"]]
        heapq.heapify(self._heap)
        self._rt = {int(rid): dict(rt) for rid, rt in d["runtime"].items()}
        for rep, rd in zip(self._reps, d["reps"]):
            rep.busy = bool(rd["busy"])
            rep.sched = SlotScheduler(self.policy.slots_per_replica,
                                      self.seq_capacity)
            rep.sched.load_state_dict(rd["sched"])
        self.feed = [list(row) for row in d["feed"]]
        self.pending_exits = deque(dict(e) for e in d["pending_exits"])
        self.stats.load_state_dict(d["stats"])
