"""Prebuilt boards (the gem5 stdlib ``X86DemoBoard`` analogue).

"Toward Reproducible and Standardized Computer Architecture Simulation
with gem5" (PAPERS.md) attributes much of the stdlib's usability to
*prebuilt boards*: known-good, named hardware configurations users pass
straight to ``Simulator`` instead of hand-wiring SimObjects.  The g5x
analogue is a catalog of instantiated :class:`ClusterModel`s bundled
with the software-side choices a run needs (collective algorithm,
straggler injection) — everything ``TraceExecutor`` takes beyond the
trace itself.

Boards accept per-component override dicts so DSE sweeps stay
one-liners::

    v5e_pod(chip={"hbm_bw": 2 * 819e9}, ici={"bw": 100e9})

Catalog:

* ``v5e_pod``       — one 16x16 TPU v5e pod (the default machine).
* ``v5e_multipod``  — N pods over DCN with dist-gem5 quantum sync.
* ``v5e_straggler`` — multipod with one (or more) slow pods, the
                      fault-injection variant (§straggler watchdog).
* ``v5e_degraded``  — a pod with derated HBM/ICI, the "sick hardware"
                      variant for capacity planning.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.desim.executor import TraceExecutor
from repro.core.desim.machine import ClusterModel


@dataclass
class Board:
    """A machine plus the run-level knobs ``Simulator`` needs.

    ``failure_schedule``: fault-injection boards (``v5e_unreliable``)
    bundle a seeded :class:`repro.train.ft_policy.FailureSchedule`;
    pass it to the workload (``TrainSim(schedule=board.
    failure_schedule, ...)``) so one board name pins the whole
    reproducible fault scenario.
    """

    machine: ClusterModel
    algorithm: str = "torus2d"
    straggler_slowdowns: Optional[List[float]] = None
    name: str = "board"
    failure_schedule: Optional[object] = None
    #: default fidelity model runs on this board start under
    #: ("detailed" | "atomic"; see repro.core.desim.timing)
    timing: str = "detailed"

    def instantiate(self) -> "Board":
        if not getattr(self.machine, "_frozen", False):
            self.machine.instantiate()
        return self

    def executor(self, **kw) -> TraceExecutor:
        """A TraceExecutor wired for this board (kw: record_stats,
        record_timeline, timing, ... pass through).  ``workers=N`` (N>1)
        returns the multiprocess :class:`~repro.core.desim.parallel.
        ParallelEngine` instead — a drop-in executor that shards the
        board's pods across N worker processes with dist-gem5
        quantum-barrier sync (bit-identical results; ``mp_context``
        picks the multiprocessing start method)."""
        self.instantiate()
        kw.setdefault("algorithm", self.algorithm)
        kw.setdefault("straggler_slowdowns", self.straggler_slowdowns)
        # the board's default timing applies unless the caller chose a
        # model — explicitly via timing=, or through the deprecated
        # contention flag (False maps to AtomicTiming in the executor;
        # an explicit True is a request for contention simulation and
        # must not be overridden by an atomic board default)
        if kw.get("timing") is None and kw.get("contention") is None:
            kw["timing"] = self.timing
        from repro.sim.serialize import validate_workers
        workers = validate_workers(kw.pop("workers", None))
        mp_context = kw.pop("mp_context", None)
        if workers > 1:
            from repro.core.desim.parallel import ParallelEngine
            return ParallelEngine(self.machine, workers=workers,
                                  mp_context=mp_context, **kw)
        return TraceExecutor(self.machine, **kw)


def _apply(obj, overrides: Optional[Dict]) -> None:
    for k, v in (overrides or {}).items():
        setattr(obj, k, v)


def _cluster(name: str, num_pods: int, quantum_ns: Optional[int],
             nx: int, ny: int, chip: Optional[Dict], ici: Optional[Dict],
             dcn: Optional[Dict]) -> ClusterModel:
    kw = {"num_pods": num_pods}
    if quantum_ns is not None:
        kw["quantum_ns"] = quantum_ns
    m = ClusterModel(name, **kw)
    m.pod.nx, m.pod.ny = nx, ny
    _apply(m.pod.chip, chip)
    _apply(m.pod.ici, ici)
    _apply(m.dcn, dcn)
    m.instantiate()
    return m


def v5e_pod(nx: int = 16, ny: int = 16, *, chip: Optional[Dict] = None,
            ici: Optional[Dict] = None, algorithm: str = "torus2d",
            timing: str = "detailed") -> Board:
    """One TPU v5e pod: a ``nx x ny`` ICI torus of v5e chips."""
    m = _cluster("cluster", 1, None, nx, ny, chip, ici, None)
    return Board(m, algorithm=algorithm, timing=timing,
                 name=f"v5e_pod_{nx}x{ny}")


def v5e_multipod(num_pods: int = 2, quantum_ns: int = 100_000,
                 nx: int = 16, ny: int = 16, *,
                 chip: Optional[Dict] = None, ici: Optional[Dict] = None,
                 dcn: Optional[Dict] = None,
                 algorithm: str = "torus2d",
                 timing: str = "detailed") -> Board:
    """``num_pods`` v5e pods joined by DCN, synchronized in dist-gem5
    quanta of ``quantum_ns`` (0 disables the quantum error model)."""
    m = _cluster("cluster", num_pods, quantum_ns, nx, ny, chip, ici, dcn)
    return Board(m, algorithm=algorithm, timing=timing,
                 name=f"v5e_multipod_{num_pods}")


def v5e_straggler(num_pods: int = 2, slowdown: float = 2.0,
                  slow_pods: Optional[List[int]] = None,
                  quantum_ns: int = 100_000, nx: int = 16, ny: int = 16,
                  timing: str = "detailed") -> Board:
    """Multipod with straggling pods (default: the last pod runs at
    ``1/slowdown`` speed) — the fault-injection board."""
    m = _cluster("cluster", num_pods, quantum_ns, nx, ny, None, None, None)
    slow = [1.0] * num_pods
    for p in (slow_pods if slow_pods is not None else [num_pods - 1]):
        slow[p] = slowdown
    return Board(m, straggler_slowdowns=slow, timing=timing,
                 name=f"v5e_straggler_{num_pods}x{slowdown}")


def v5e_degraded(hbm_frac: float = 0.5, ici_frac: float = 0.5,
                 nx: int = 16, ny: int = 16, *,
                 timing: str = "detailed") -> Board:
    """A single pod with derated HBM and ICI bandwidth — what a step
    costs on sick hardware (capacity-planning variant)."""
    m = _cluster("cluster", 1, None, nx, ny,
                 chip={"hbm_bw": 819e9 * hbm_frac},
                 ici={"bw": 50e9 * ici_frac}, dcn=None)
    return Board(m, timing=timing,
                 name=f"v5e_degraded_h{hbm_frac}_i{ici_frac}")


def v5e_serving(nx: int = 8, ny: int = 8, replicas: int = 1, *,
                chip: Optional[Dict] = None,
                timing: str = "detailed") -> Board:
    """Serving deployment: ``replicas`` independent pod *slices* of
    ``nx x ny`` chips each (inference replicas are sliced much smaller
    than training pods).  With a dynamic serving workload every pod is
    one continuous-batching replica; requests load-balance round-robin
    (``repro.sim.workloads.ServeSim``)."""
    # quantum 0: serving replicas never speak DCN, so no quantum model
    m = _cluster("cluster", replicas, 0, nx, ny, chip, None, None)
    return Board(m, timing=timing, name=f"v5e_serving_{replicas}x{nx}x{ny}")


def v5e_fleet(max_replicas: int = 8, nx: int = 8, ny: int = 8, *,
              chip: Optional[Dict] = None,
              timing: str = "detailed") -> Board:
    """Autoscaled serving fleet: ``max_replicas`` independent pod
    slices of ``nx x ny`` chips each — one per replica the
    ``repro.sim.fleet.FleetSim`` workload's policy may ever bring up
    (pods above the live fleet sit idle until a scale-up warms them).
    Quantum 0: replicas never speak DCN, so no quantum model."""
    m = _cluster("cluster", max_replicas, 0, nx, ny, chip, None, None)
    return Board(m, timing=timing,
                 name=f"v5e_fleet_{max_replicas}x{nx}x{ny}")


def v5e_fleet_big(num_pods: int = 64, quantum_ns: int = 100_000,
                  nx: int = 4, ny: int = 4, *,
                  chip: Optional[Dict] = None, ici: Optional[Dict] = None,
                  dcn: Optional[Dict] = None,
                  algorithm: str = "hierarchical",
                  timing: str = "detailed") -> Board:
    """Fleet-scale multipod (64-128 pods of small ``nx x ny`` slices)
    joined by DCN under dist-gem5 quantum sync — the board the
    ``ParallelEngine`` workers=8 scaling gate runs on (``tools/ci.sh
    parallel``).  Slices are kept small so the per-pod event cost stays
    cheap enough that coordinator overhead, not compute, is what the
    benchmark measures; the default collective algorithm is
    hierarchical, exercising the ``global_num_pods`` shard cost
    context."""
    m = _cluster("cluster", num_pods, quantum_ns, nx, ny, chip, ici, dcn)
    return Board(m, algorithm=algorithm, timing=timing,
                 name=f"v5e_fleet_big_{num_pods}")


def v5e_unreliable(num_pods: int = 4, *, seed: int = 0,
                   horizon: int = 2000, mtbf: float = 400.0,
                   straggler_mtbs: float = 0.0,
                   preemption_mtbs: float = 0.0,
                   repair: tuple = (40, 120), nx: int = 16, ny: int = 16,
                   chip: Optional[Dict] = None, ici: Optional[Dict] = None,
                   timing: str = "detailed") -> Board:
    """An unreliable multipod: ``num_pods`` v5e pods plus a seeded
    :class:`~repro.train.ft_policy.FailureSchedule` (MTBF-driven pod
    failures, optional transient stragglers and preemptions, all in
    step-attempt units over ``horizon`` attempts) — the fault-injected
    training board for ``TrainSim``.  Quantum 0: the training workload
    injects its op chain on one pod, so no quantum error model."""
    from repro.train.ft_policy import FailureSchedule
    m = _cluster("cluster", num_pods, 0, nx, ny, chip, ici, None)
    sched = FailureSchedule.generate(
        seed=seed, horizon=horizon, pods=num_pods, mtbf=mtbf,
        straggler_mtbs=straggler_mtbs, preemption_mtbs=preemption_mtbs,
        repair=repair)
    return Board(m, failure_schedule=sched, timing=timing,
                 name=f"v5e_unreliable_{num_pods}_s{seed}")


BOARDS: Dict[str, Callable[..., Board]] = {
    "v5e_pod": v5e_pod,
    "v5e_multipod": v5e_multipod,
    "v5e_straggler": v5e_straggler,
    "v5e_degraded": v5e_degraded,
    "v5e_serving": v5e_serving,
    "v5e_fleet": v5e_fleet,
    "v5e_fleet_big": v5e_fleet_big,
    "v5e_unreliable": v5e_unreliable,
}


def get_board(name: str, **kw) -> Board:
    try:
        return BOARDS[name](**kw)
    except KeyError:
        raise ValueError(f"unknown board {name!r}; one of {list(BOARDS)}")
